#!/usr/bin/env python
"""Emit the committed hardware artifacts from the typed fixed-point IR.

Lowers the deployed integer programs (the same targets
``scripts/analyze.py`` gates, full config) through ``repro.ir`` and writes,
per executable target, the synthesizable artifact set under
``artifacts/ir/<target>/``:

    program.c     -- one-file C reference of the whole datapath (int32
                     two's-complement, shift/add/compare only; compiles
                     with any C99 compiler, ``main`` reads/writes raw
                     little-endian register images)
    program.v     -- synthesizable Verilog netlist: one time-multiplexed
                     FSM over interval-width registers, shift/add/compare
                     datapath, ROMs loaded from rom/*.mem ($readmemh)
    rom/<n>.mem   -- one $readmemh init file per constant ROM (taps,
                     mu/sigma, shift tables, classifier weights)
    alloc.json    -- the register allocation report: interval-proven
                     widths vs the int32 carrier, ROM bits, datapath
                     unit sites (the stand-in for the paper's slice count)
    ir.json       -- the machine-readable program: op census (pinned ==
                     the jaxpr-walk census), instruction/ROM totals, and
                     the full typed register table with proven worst-case
                     intervals and minimal two's-complement widths

Every executable target's netlist is verified here, at emit time, to
replay the IR interpreter bit-for-bit on seeded interval-drawn inputs —
through iverilog when installed, through the in-repo cycle simulator
(``repro.ir.vsim``) otherwise.

Pallas-grid targets have no sequential SSA execution, so they get only
``ir.json`` + ``alloc.json`` (census + register table + widths) — their
bit-exactness is covered by the kernel parity tests, their counts by the
census pin here.

Everything written is DETERMINISTIC (no timestamps, sorted keys, fixed
target order): tier-1 regenerates the tree and fails on ``git diff``,
exactly like ANALYSIS.json — a PR that changes the deployed datapath must
commit the new hardware artifacts, and drift without a source change is an
error.

    PYTHONPATH=src python scripts/emit_ir.py              # full config
    PYTHONPATH=src python scripts/emit_ir.py --smoke --out-dir /tmp/ir
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(REPO, "src"))

# the two sequential deployment programs get the full artifact set; the
# grid (Pallas) twins are census/typing surfaces only
EXECUTABLE_TARGETS = ("oneshot_q", "session_step_q")
CENSUS_TARGETS = ("oneshot_q_pallas", "stream_pallas")


def emit_target(t, out_dir: str) -> dict:
    from repro.analysis.legality import census_jaxpr
    from repro.ir import build_program, census_program
    from repro.ir.alloc import allocate
    from repro.ir.cgen import emit_c, emit_rom_mem
    from repro.ir.verilog import emit_verilog

    prog = build_program(t.jaxpr, name=t.name, in_intervals=t.in_intervals)
    c_ir = dict(census_program(prog))
    c_jx = dict(census_jaxpr(t.jaxpr))
    if c_ir != c_jx:
        raise AssertionError(
            f"{t.name}: IR census {c_ir} != jaxpr census {c_jx}")

    tdir = os.path.join(out_dir, t.name)
    if os.path.isdir(tdir):
        shutil.rmtree(tdir)
    os.makedirs(tdir)

    alloc = allocate(prog)
    with open(os.path.join(tdir, "alloc.json"), "w") as f:
        json.dump(alloc.report, f, indent=2, sort_keys=True)
        f.write("\n")

    if prog.executable:
        with open(os.path.join(tdir, "program.c"), "w") as f:
            f.write(emit_c(prog))
        with open(os.path.join(tdir, "program.v"), "w") as f:
            f.write(emit_verilog(prog, alloc))
        romdir = os.path.join(tdir, "rom")
        os.makedirs(romdir)
        for fname, text in sorted(emit_rom_mem(prog).items()):
            with open(os.path.join(romdir, fname), "w") as f:
                f.write(text)
        verify_netlist(t, prog, alloc, tdir)

    doc = {
        "name": t.name,
        "executable": prog.executable,
        "census": {k: int(v) for k, v in sorted(c_ir.items())},
        "num_instrs": prog.num_instrs(),
        "num_inputs": len(prog.inputs),
        "num_outputs": len(prog.outputs),
        "num_registers": len(prog.regs),
        "num_roms": len(prog.roms),
        "rom_bytes": prog.rom_bytes(),
        "roms": [{"name": r.name, "shape": list(r.shape)}
                 for r in prog.roms],
        "registers": prog.register_table(),
    }
    with open(os.path.join(tdir, "ir.json"), "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return {"name": t.name, "executable": prog.executable,
            "census": doc["census"], "num_instrs": doc["num_instrs"],
            "rom_bytes": doc["rom_bytes"]}


def verify_netlist(t, prog, alloc, tdir: str) -> None:
    """The netlist parity gate: the freshly written ``program.v`` must
    replay the IR interpreter bit-for-bit on seeded random inputs drawn
    from each input register's proven interval. Simulated with iverilog
    when installed, with the in-repo cycle simulator otherwise; any
    mismatch is localized to the first diverging instruction."""
    import numpy as np
    from repro.ir import interp as ir_interp
    from repro.ir import vsim
    from repro.ir.debug import first_divergence
    from repro.ir.verilog import emit_testbench

    rng = np.random.default_rng(0x1CF11)
    inputs = []
    for iv, reg_i in zip(t.in_intervals, prog.inputs):
        r = prog.regs[reg_i]
        arr = rng.integers(int(iv.lo), int(iv.hi) + 1,
                           size=r.shape if r.shape else (),
                           dtype=np.int64).astype(np.int32)
        inputs.append(arr != 0 if r.dtype == "i1" else arr)

    with open(os.path.join(tdir, "program.v")) as f:
        text = f.read()
    want = ir_interp.run(prog, inputs)
    if vsim.have_iverilog():
        got = vsim.run_iverilog(text, emit_testbench(prog, alloc),
                                inputs, rom_dir=tdir)
        how = "iverilog"
    else:
        got = vsim.run_netlist(text, inputs,
                               vsim.rom_loader_from_dir(tdir))
        how = "vsim"
    for i, (g, w) in enumerate(zip(got, want)):
        if not np.array_equal(np.asarray(g), np.asarray(w)):
            detail = ""
            if how == "vsim":
                d = first_divergence(prog, text, inputs,
                                     vsim.rom_loader_from_dir(tdir))
                detail = f" ({d})"
            raise AssertionError(
                f"{t.name}: netlist output {i} diverges from the IR "
                f"interpreter under {how}{detail}")
    print(f"{t.name}: netlist == interpreter ({how}, "
          f"{len(want)} outputs)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (NOT the committed artifacts; "
                         "use --out-dir)")
    ap.add_argument("--out-dir", default=None,
                    help="output tree (default: artifacts/ir at the repo "
                         "root; required with --smoke)")
    args = ap.parse_args(argv)

    out_dir = args.out_dir
    if out_dir is None:
        if args.smoke:
            ap.error("--smoke regenerates different numbers; give an "
                     "explicit --out-dir so the committed artifacts/ir "
                     "tree is never clobbered with smoke output")
        out_dir = os.path.join(REPO, "artifacts", "ir")
    os.makedirs(out_dir, exist_ok=True)

    from repro.analysis.targets import build_targets

    targets, _meta = build_targets(smoke=args.smoke)
    by_name = {t.name: t for t in targets}
    summary = []
    for name in EXECUTABLE_TARGETS + CENSUS_TARGETS:
        s = emit_target(by_name[name], out_dir)
        summary.append(s)
        kind = "C+ROM+json" if s["executable"] else "census json"
        print(f"{name}: {kind}  instrs={s['num_instrs']} "
              f"rom_bytes={s['rom_bytes']} census={s['census']}")
    print(f"wrote {out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
