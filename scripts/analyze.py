#!/usr/bin/env python
"""Static verification gate: run the analysis passes over the deployed
integer programs and emit the machine-readable report.

Runs op-legality, worst-case interval analysis and the determinism lint
(``src/repro/analysis/``) over the standard targets — the compiled
``esc10_mp`` fixed one-shot program, the per-chunk ``session_step_q``
step, both int Pallas kernels, and the float reference path (lint only) —
and writes ``ANALYSIS.json`` (deterministic: no timestamps, sorted keys;
the committed artifact diffs meaningfully across PRs).

Exit status is the gate: nonzero when any gating target has an illegal
primitive, a possible integer overflow, or a float op on the fixed path.

    PYTHONPATH=src python scripts/analyze.py            # full config gate
    PYTHONPATH=src python scripts/analyze.py --smoke    # reduced config
    PYTHONPATH=src python scripts/analyze.py --out /tmp/r.json
"""

from __future__ import annotations

import argparse
import os
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(REPO, "src"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (3 octaves, 0.4 s) — same passes")
    ap.add_argument("--out", default=None,
                    help="report path (default: ANALYSIS.json at the repo "
                         "root for the full config, stdout-only for smoke)")
    ap.add_argument("--top-registers", type=int, default=20,
                    help="tightest registers to include per target")
    args = ap.parse_args(argv)

    from repro.analysis import report as rp
    from repro.analysis.targets import build_targets

    targets, meta = build_targets(smoke=args.smoke)
    report = rp.build_report(targets, meta,
                             top_registers=args.top_registers)

    out = args.out
    if out is None and not args.smoke:
        out = os.path.join(REPO, "ANALYSIS.json")
    if out:
        rp.write_report(out, report)
        print(f"wrote {out}")
    print(rp.summarize(report))
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
