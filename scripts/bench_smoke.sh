#!/usr/bin/env bash
# Benchmark bit-rot gate: run the cheap --smoke variants of the serving and
# e2e pipeline benchmarks and fail on any exception. Called from tier1.sh so
# a PR that breaks a benchmark entry point is caught at tier-1 time.
# --stream-impl both also smokes the stateful Pallas streaming kernel path
# (interpret mode on CPU) so fir_mp_stream bit-rot is caught here too.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m benchmarks.serve_streams --smoke --stream-impl both
# fixed numerics through BOTH stream impls: the server-parity and
# streaming-parity rows are exact-equality gates (int Pallas == int XLA ==
# one-shot), so int-kernel bit-rot fails the smoke, not just the tests
python -m benchmarks.serve_streams --smoke --stream-impl both --numerics fixed
# async-pipeline parity gate: replay churning fleet traffic through the
# sharded router twice — G sync feed() callers vs the same G callers
# coalesced through submit()/drain() — and HARD-assert the decisions are
# bit-for-bit identical, for BOTH numerics modes, evict/reopen included
python -m benchmarks.load_gen --smoke
python -m benchmarks.pipeline_e2e --smoke
# the streaming-kernel shape sweep entry point (tiny grid; exercises the
# autotune-table plumbing for the float AND int stream kernels)
python -m benchmarks.kernel_sweep --smoke
# the multiplierless gate: census the int32 hardware-twin jaxprs — the
# one-shot program, the per-chunk integer streaming step (what an FPGA
# executes per sensor packet), AND the Pallas-lowered int streaming kernel
# — and FAIL if any multiply/divide leaked in
python -m benchmarks.hardware_cost --smoke
# Verilog emit + simulate smoke (reduced config, tmp out-dir): exercises
# the full netlist pipeline — emitter, register allocator, cycle
# simulator, and the netlist==interpreter parity assertion inside
# emit_ir.py — without touching the committed artifacts/ir tree
ir_smoke_dir=$(mktemp -d)
trap 'rm -rf "$ir_smoke_dir"' EXIT
python scripts/emit_ir.py --smoke --out-dir "$ir_smoke_dir"
echo "bench_smoke OK"
