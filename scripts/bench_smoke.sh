#!/usr/bin/env bash
# Benchmark bit-rot gate: run the cheap --smoke variants of the serving and
# e2e pipeline benchmarks and fail on any exception. Called from tier1.sh so
# a PR that breaks a benchmark entry point is caught at tier-1 time.
# --stream-impl both also smokes the stateful Pallas streaming kernel path
# (interpret mode on CPU) so fir_mp_stream bit-rot is caught here too.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m benchmarks.serve_streams --smoke --stream-impl both
python -m benchmarks.pipeline_e2e --smoke
# the multiplierless gate: census the int32 hardware-twin jaxprs — the
# one-shot program AND the per-chunk integer streaming step (what an FPGA
# executes per sensor packet) — and FAIL if any multiply/divide leaked in
python -m benchmarks.hardware_cost --smoke
echo "bench_smoke OK"
