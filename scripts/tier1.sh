#!/usr/bin/env bash
# Tier-1 verify: the gate every PR must keep green (see ROADMAP.md).
# Runs the test suite (which includes the streaming-parity harness in
# tests/test_streaming_parity.py — the bit-for-bit XLA-vs-Pallas gate —
# and the fixed-point hardware-twin gates: tests/test_fixed.py carrier
# parity + the EXACT-match integer golden fixtures in tests/test_golden.py;
# the `pallas` marker selects just the kernel-path subset), then the
# benchmark smoke pass (bench_smoke.sh, which also censuses the int32
# jaxpr and fails on any multiply) so benchmark bit-rot is caught here
# rather than at release time.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# docs gate: broken intra-repo links in README/ROADMAP/docs fail tier-1
python scripts/check_docs.py
python -m pytest -x -q "$@"
scripts/bench_smoke.sh
