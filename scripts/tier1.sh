#!/usr/bin/env bash
# Tier-1 verify: the gate every PR must keep green (see ROADMAP.md).
#
# Order: docs link check -> lint -> test suite -> static analysis gate ->
# benchmark smoke. The test suite includes the streaming-parity harness in
# tests/test_streaming_parity.py — the bit-for-bit XLA-vs-Pallas gate —
# and the fixed-point hardware-twin gates: tests/test_fixed.py carrier
# parity + the EXACT-match integer golden fixtures in tests/test_golden.py
# (the `pallas` marker selects just the kernel-path subset). The analysis
# gate (scripts/analyze.py, full config) statically PROVES the deployed
# integer programs multiplierless and int32-overflow-free (docs/
# analysis.md). bench_smoke.sh also censuses the int32 jaxpr and fails on
# any multiply, so benchmark bit-rot is caught here, not at release time.
#
# The suite runs as a few pytest processes, not one: this container's
# jaxlib 0.4.37 XLA CPU compiler segfaults after ~90 heavy compilations
# in a single process (see CHANGES.md PR 6 note — a pristine-seed
# worktree crashes identically, so it is environmental, not a
# regression). Each group keeps -x fail-fast semantics; extra args are
# passed to every group.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# docs gate: broken intra-repo links in README/ROADMAP/docs fail tier-1
python scripts/check_docs.py

# lint gate: conventional linter alongside the domain-specific passes
# (config in pyproject.toml; this container has no ruff — skip loudly)
if command -v ruff >/dev/null 2>&1; then
  ruff check .
else
  echo "tier1: WARNING: ruff not installed; skipping lint gate" >&2
fi

# test groups: compile-heavy files spread out so no single process crosses
# the XLA CPU segfault threshold
group1=(tests/test_fixed.py tests/test_golden.py tests/test_quant.py)
group2=(tests/test_streaming_parity.py tests/test_kernels.py
        tests/test_analysis.py)
group3=(tests/test_pipeline.py tests/test_ssm.py tests/test_ir.py)
group4=(tests/test_serving.py tests/test_slot_surgery.py
        tests/test_server_contract.py tests/test_async_serving.py)
group5=(tests/test_archs.py tests/test_checkpoint.py
        tests/test_distributed.py tests/test_filterbank.py
        tests/test_hlo_cost.py tests/test_kernel_machine.py
        tests/test_mp.py tests/test_system.py)
group6=(tests/test_verilog.py tests/test_ir_artifacts.py)

# coverage guard: every tests/test_*.py must appear in exactly one group,
# so a new test file can't silently drop out of tier-1
all_grouped=$(printf '%s\n' "${group1[@]}" "${group2[@]}" "${group3[@]}" \
                     "${group4[@]}" "${group5[@]}" "${group6[@]}" | sort)
all_files=$(ls tests/test_*.py | sort)
if [ "$all_grouped" != "$all_files" ]; then
  echo "tier1: test group lists are out of sync with tests/test_*.py:" >&2
  diff <(echo "$all_grouped") <(echo "$all_files") >&2 || true
  exit 1
fi

python -m pytest -x -q "${group1[@]}" "$@"
python -m pytest -x -q "${group2[@]}" "$@"
python -m pytest -x -q "${group3[@]}" "$@"
python -m pytest -x -q "${group4[@]}" "$@"
python -m pytest -x -q "${group5[@]}" "$@"
python -m pytest -x -q "${group6[@]}" "$@"

# static verification gate: op-legality + worst-case interval proof +
# determinism lint over the deployed integer programs (full config;
# refreshes the committed ANALYSIS.json artifact)
python scripts/analyze.py

# artifact-drift gate: analyze.py rewrites ANALYSIS.json in place, so a
# stale committed report would otherwise pass silently — the diff IS the
# review signal, make it a failure, not a dirty working tree to notice
if git -C . rev-parse --is-inside-work-tree >/dev/null 2>&1 \
    && ! git diff --exit-code -- ANALYSIS.json; then
  echo "tier1: ANALYSIS.json drifted from the committed copy —" \
       "commit the refreshed artifact (diff above)" >&2
  exit 1
fi

# hardware-artifact drift gate: regenerate the IR-derived C/Verilog/ROM/
# register artifacts (full config, deterministic) and fail if they moved —
# emit_ir.py also re-proves, per executable target, that the freshly
# emitted netlist replays the IR interpreter bit-for-bit (iverilog when
# installed, the in-repo cycle simulator otherwise) before writing — a PR
# that changes the deployed datapath must commit the new artifacts/ir
# tree, and artifact drift without a source change is a bug in the
# emitters, not noise
python scripts/emit_ir.py
if git -C . rev-parse --is-inside-work-tree >/dev/null 2>&1 \
    && ! git diff --exit-code -- artifacts/ir; then
  echo "tier1: artifacts/ir drifted from the committed tree —" \
       "commit the regenerated hardware artifacts (diff above)" >&2
  exit 1
fi

scripts/bench_smoke.sh
