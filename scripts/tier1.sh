#!/usr/bin/env bash
# Tier-1 verify: the gate every PR must keep green (see ROADMAP.md).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
