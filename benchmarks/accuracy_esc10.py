"""Table III reproduction: ESC-10(-like) classification accuracy.

Columns mirror the paper: Normal SVM baseline (full-precision template
kernel machine on MAC filter-bank features), MP In-Filter Compute in
floating point, and MP In-Filter Compute at 8-bit fixed point. The dataset
is the synthetic ESC-10 stand-in (offline environment — see
data/acoustic.py); the paper's own numbers are quoted in EXPERIMENTS.md.

One-vs-all per-class accuracy, as in the paper's table.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core.filterbank import FilterBank, FilterBankConfig
from repro.core import trainer
from repro.data.acoustic import ESC10_CLASSES, make_esc10_like

# CPU-budget configuration: 8 kHz / 0.5 s clips, 5 octaves x 5 filters.
FS = 8000.0
SECONDS = 0.5
OCTAVES = 5


def features(fb, x, mu=None, sd=None):
    s = jax.jit(fb.accumulate)(jnp.asarray(x))
    if mu is None:
        mu, sd = s.mean(0), s.std(0, ddof=1) + 1e-6
    return (s - mu) / sd, mu, sd


def one_vs_all_acc(p, y, cls):
    pred = (np.asarray(p)[:, cls] > 0).astype(int)
    truth = (np.asarray(y) == cls).astype(int)
    return float((pred == truth).mean())


def main():
    ds = make_esc10_like(per_class_train=16, per_class_test=8,
                         fs=FS, seconds=SECONDS, seed=0)
    t0 = time.time()
    results = {}
    for tag, mode, qbits in [("mac_svm_fp", "mac", None),
                             ("mp_infilter_fp", "mp", None),
                             ("mp_infilter_q8", "mp", 8)]:
        fb = FilterBank(FilterBankConfig(
            fs=FS, num_octaves=OCTAVES, filters_per_octave=5,
            mode=mode, gamma_f=4.0, quant_bits=qbits))
        K_tr, mu, sd = features(fb, ds.x_train)
        K_te, _, _ = features(fb, ds.x_test, mu, sd)
        cfg = trainer.TrainConfig(num_steps=500, lr=0.5, batch_size=96,
                                  gamma_anneal_start=4.0,
                                  gamma_anneal_steps=200, quant_bits=qbits)
        params, _ = trainer.train(K_tr, jnp.asarray(ds.y_train), 10, cfg)
        from repro.core import kernel_machine as km
        from repro.core.trainer import _maybe_quant
        p_te = km.forward(_maybe_quant(params, qbits), K_te, 1.0)
        per_class = [one_vs_all_acc(p_te, ds.y_test, c) for c in range(10)]
        acc = trainer.evaluate(params, K_te, jnp.asarray(ds.y_test), qbits)
        results[tag] = (per_class, acc)
        for c, name in enumerate(ESC10_CLASSES):
            row(f"esc10.{tag}.{name}", None, f"ova_acc={per_class[c]:.3f}")
        row(f"esc10.{tag}.multiclass", None, f"acc={acc:.3f}")
    us = (time.time() - t0) * 1e6
    row("esc10.total_runtime", us,
        "paper_avg=0.88 (ESC-10, Table II/III)")
    return results


if __name__ == "__main__":
    main()
