"""Fig. 8 reproduction: accuracy vs bit width (crying-baby one-vs-all).

The paper's claim: train/test accuracy is stable down to 8 bits and falls
sharply below. We sweep {16, 12, 10, 8, 6, 4} bits of weight quantization
(QAT) on the MP in-filter pipeline — and, since the fixed-point refactor,
also report a TRUE-INTEGER column per bit width: the same trained pipeline
lowered to the int32 hardware twin (``repro.core.fixed``) with b-bit
signals/weights and a (b+2)-bit internal path, evaluated end to end in
add/sub/shift/compare arithmetic. The QAT number is the proxy; the int
number is what the hardware would actually score.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core import fixed
from repro.core.filterbank import FilterBank, FilterBankConfig
from repro.core.pipeline import InFilterPipeline
from repro.core import trainer
from repro.data.acoustic import make_esc10_like

FS = 8000.0
BITS = [16, 12, 10, 8, 6, 4]


def main():
    ds = make_esc10_like(per_class_train=16, per_class_test=8,
                         fs=FS, seconds=0.5, seed=3)
    fb = FilterBank(FilterBankConfig(fs=FS, num_octaves=5,
                                     filters_per_octave=5, mode="mp",
                                     gamma_f=4.0))
    feat = jax.jit(fb.accumulate)
    s_tr = feat(jnp.asarray(ds.x_train))
    mu, sd = s_tr.mean(0), s_tr.std(0, ddof=1) + 1e-6
    K_tr = (s_tr - mu) / sd
    K_te = (feat(jnp.asarray(ds.x_test)) - mu) / sd
    y_tr, y_te = jnp.asarray(ds.y_train), jnp.asarray(ds.y_test)

    baby = 3  # crying_baby class index (paper uses this class)
    accs = {}
    accs_int = {}
    amax = float(np.max(np.abs(ds.x_train)))
    for bits in BITS:
        cfg = trainer.TrainConfig(num_steps=400, lr=0.5, quant_bits=bits,
                                  seed=0)
        params, _ = trainer.train(K_tr, y_tr, 10, cfg)
        from repro.core import kernel_machine as km
        from repro.core.trainer import _maybe_quant
        p_tr = np.asarray(km.forward(_maybe_quant(params, bits), K_tr, 1.0))
        p_te = np.asarray(km.forward(_maybe_quant(params, bits), K_te, 1.0))
        acc_tr = float(((p_tr[:, baby] > 0) ==
                        (np.asarray(ds.y_train) == baby)).mean())
        acc_te = float(((p_te[:, baby] > 0) ==
                        (np.asarray(ds.y_test) == baby)).mean())
        accs[bits] = (acc_tr, acc_te)
        # the true-integer column: lower the trained pipeline to the int32
        # hardware twin at this bit width and score it bit-true
        pipe = InFilterPipeline.from_filterbank(fb, params, mu, sd)
        prog = fixed.compile_pipeline(
            pipe, amax=amax, signal_bits=bits, internal_bits=bits + 2,
            calibration_audio=np.asarray(ds.x_train))
        pq_tr, _ = fixed.predict(prog, jnp.asarray(ds.x_train))
        pq_te, _ = fixed.predict(prog, jnp.asarray(ds.x_test))
        int_tr = float(((np.asarray(pq_tr)[:, baby] > 0) ==
                        (np.asarray(ds.y_train) == baby)).mean())
        int_te = float(((np.asarray(pq_te)[:, baby] > 0) ==
                        (np.asarray(ds.y_test) == baby)).mean())
        accs_int[bits] = (int_tr, int_te)
        row(f"bitwidth.{bits}b", None,
            f"train={acc_tr:.3f} test={acc_te:.3f} "
            f"int_train={int_tr:.3f} int_test={int_te:.3f}")
    # the Fig. 8 claim, checked numerically: >= 8b stable, < 8b degrades
    stable = min(accs[b][1] for b in (16, 12, 10, 8))
    low = accs[4][1]
    row("bitwidth.claim", None,
        f"stable_min(>=8b)={stable:.3f} at4b={low:.3f} "
        f"degrades={'yes' if low <= stable else 'no'}")
    stable_int = min(accs_int[b][1] for b in (16, 12, 10, 8))
    row("bitwidth.claim_int", None,
        f"int stable_min(>=8b)={stable_int:.3f} at4b={accs_int[4][1]:.3f} "
        "(true int32 execution, not the QAT proxy)")
    return accs


if __name__ == "__main__":
    main()
