"""Load generator: replay a fleet of logical sensor streams through the
serving tier and measure streams/sec + per-feed latency percentiles.

This is the acoupi traffic shape (PAPERS.md): many long-lived edge
recorders phoning home with jittery, variable-length packets and churning
lifetimes. The generator builds a DETERMINISTIC schedule (seeded rng,
O(active-set) memory — ``--streams 1000000`` streams a million logical
ids without materializing them) and replays the SAME schedule through two
paths over an identically-configured ``StreamRouter``:

  sync   G independent callers per round, each paying a full synchronous
         ``feed()`` (dispatch + decision readback per caller);
  async  the same G callers ``submit()`` into the coalescing queue and
         one ``drain()`` resolves the round (shared waves, one readback).

Decisions must match bit-for-bit between the paths — under churn
(admission pressure auto-evicts LRU sessions to per-shard checkpoints;
evicted streams reopen losslessly when they next emit), under request
splitting, and under coalesced wave composition. ``--smoke`` runs a small
traffic sample through BOTH numerics modes with that equality as a hard
assert (wired into scripts/bench_smoke.sh -> tier1.sh); the full run
asserts it too unless ``--no-parity``.

    PYTHONPATH=src python -m benchmarks.load_gen [--window 256] [--smoke]
    PYTHONPATH=src python -m benchmarks.load_gen \
        --streams 1000000 --rounds 2000 --paths async --no-parity

Emits ``name,us_per_call,derived`` CSV rows like every other benchmark;
``benchmarks.run`` folds them into BENCH_pipeline.json.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import row
from repro.serving import StreamRouter

POOL = 1 << 15  # shared sample pool; packets slice it at random offsets


def _traffic(seed, n_streams, window, rounds, chunk_lo, chunk_hi,
             life_lo, life_hi, emit_prob, evict_prob):
    """Yield (admits, burst, retires, evicts) per round. Deterministic for
    a given seed, so both replay paths see identical traffic; memory is
    O(window) no matter how many logical streams the fleet cycles
    through. ``evicts`` picks still-alive streams to park mid-lifetime —
    they reopen (losslessly, from their shard's checkpoint) when they next
    emit, which is what makes churn a PARITY test and not just load."""
    rng = np.random.default_rng(seed)
    active: dict = {}          # sid -> packets remaining in its lifetime
    next_id = 0
    for _ in range(rounds):
        admits = []
        while len(active) < window and next_id < n_streams:
            sid = f"st-{next_id:07d}"
            active[sid] = int(rng.integers(life_lo, life_hi + 1))
            admits.append(sid)
            next_id += 1
        burst, retires = [], []
        for sid in list(active):
            if rng.random() < emit_prob:
                ln = int(rng.integers(chunk_lo, chunk_hi + 1))
                off = int(rng.integers(0, POOL - ln))
                burst.append((sid, off, ln))
                active[sid] -= 1
                if active[sid] <= 0:
                    retires.append(sid)
                    del active[sid]
        evicts = [sid for sid in active if rng.random() < evict_prob]
        yield admits, burst, retires, evicts


def _replay(router: StreamRouter, schedule, pool, groups: int, mode: str,
            keep_decisions: bool):
    """Drive one schedule through the router. Returns (decisions, latency
    seconds per packet, packets fed, reopens)."""
    decisions = {} if keep_decisions else None
    lat: list = []
    n_pkts = 0
    reopens = 0

    def record(results):
        if decisions is None:
            return
        for r in results:
            decisions[(r.session_id, r.samples_seen)] = (r.label,
                                                         r.confidence)

    for admits, burst, retires, evicts in schedule:
        for sid in admits:
            router.open(sid)
        # parked streams reopen (losslessly, from their shard's
        # checkpoint) BEFORE the round's submits — open() flushes the
        # coalescing queue, so admissions mid-round would change wave
        # composition between the two paths
        for sid, _, _ in burst:
            if not router.is_open(sid):
                router.open(sid)
                reopens += 1
        reqs = [(sid, pool[off:off + ln]) for sid, off, ln in burst]
        n_pkts += len(reqs)
        parts = [reqs[g::groups] for g in range(groups)]
        if mode == "sync":
            for part in parts:
                if not part:
                    continue
                t0 = time.perf_counter()
                res = router.feed(part)
                dt = time.perf_counter() - t0
                lat.extend([dt] * len(part))
                record(res)
        else:
            staged = []
            for part in parts:
                if not part:
                    continue
                staged.append((time.perf_counter(), part,
                               router.submit(part)))
            router.drain()
            t_end = time.perf_counter()
            for t0, part, ticket in staged:
                lat.extend([t_end - t0] * len(part))
                record(ticket.results)
        for sid in retires:
            if router.is_open(sid):
                router.close(sid)
        for sid in evicts:
            if router.is_open(sid):
                router.evict(sid)
    return decisions, lat, n_pkts, reopens


def _pcts(lat_s):
    us = np.asarray(lat_s) * 1e6
    return float(np.percentile(us, 50)), float(np.percentile(us, 99))


def _run_fleet(args, numerics: str, tag: str, hard_parity: bool):
    import tempfile

    from repro.configs.esc10_mp import make_pipeline

    pipe = make_pipeline(smoke=True, stream_impl=args.stream_impl,
                         numerics=numerics,
                         fixed_amax=4.0 if numerics == "fixed" else None)
    rng = np.random.default_rng(args.seed)
    pool = rng.standard_normal(POOL).astype(np.float32)

    def make_router():
        # full-window capacity per shard: crc32 imbalance must never make
        # a shard unable to hold its share of one round's burst (churn
        # comes from the schedule's explicit evict events, not from
        # admission pressure)
        return StreamRouter(pipe, num_shards=args.shards,
                            capacity=args.window,
                            checkpoint_dir=tempfile.mkdtemp(
                                prefix="load_gen_ck_"),
                            max_chunk=args.max_chunk)

    def schedule():
        return _traffic(args.seed, args.streams, args.window, args.rounds,
                        args.chunk_lo, args.chunk_hi,
                        args.life_lo, args.life_hi, args.emit_prob,
                        args.evict_prob)

    keep = not args.no_parity
    out = {}
    for mode in (("sync", "async") if args.paths == "both"
                 else (args.paths,)):
        router = make_router()
        # warmup: compile the WHOLE bucket ladder off the clock, for every
        # shard's server alike (they share one step, so one pass does it) —
        # otherwise whichever path runs first eats the compile time and the
        # speedup row measures cache luck, not pipelining
        L = 16
        while L <= args.max_chunk:
            router.open("warm")
            router.feed([("warm", pool[:L])])
            router.close("warm")
            L <<= 1
        t0 = time.perf_counter()
        dec, lat, n_pkts, reopens = _replay(
            router, schedule(), pool, args.groups, mode, keep)
        wall = time.perf_counter() - t0
        p50, p99 = _pcts(lat)
        out[mode] = (dec, wall, n_pkts, reopens)
        row(f"load_gen.{mode}{tag}.W{args.window}.G{args.groups}",
            wall / max(n_pkts, 1) * 1e6,
            f"{n_pkts / max(wall, 1e-9):.0f} streams/s "
            f"({n_pkts} packets, {reopens} evict-reopens)")
        row(f"load_gen.latency.{mode}{tag}.W{args.window}", None,
            f"p50={p50:.0f}us p99={p99:.0f}us")

    if args.paths == "both":
        (dec_s, wall_s, n, _), (dec_a, wall_a, _, _) = out["sync"], \
            out["async"]
        speedup = wall_s / max(wall_a, 1e-9)
        bitwise = None
        if keep:
            bitwise = dec_s == dec_a   # exact: labels, confidences, counts
        row(f"load_gen.async_speedup{tag}.W{args.window}.G{args.groups}",
            None, f"speedup_vs_sync={speedup:.2f}x bitwise={bitwise}")
        if keep and not bitwise:
            raise AssertionError(
                f"async/coalesced decisions != sync feed() decisions "
                f"({numerics} numerics, {args.stream_impl}) — the bitwise "
                "serving contract is violated")
        if hard_parity:
            assert bitwise
            # the parity claim must have covered churn: at least one
            # evicted stream must have come back through a checkpoint
            assert out["async"][3] > 0, \
                "smoke schedule exercised no evict->reopen churn"
        return speedup
    return None


def main(argv=()):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fleet, BOTH numerics modes, hard assert "
                         "async decisions == sync decisions (CI gate)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--streams", type=int, default=1024,
                    help="logical stream ids cycled through the window "
                         "(schedule is O(window) memory: 10^6 works)")
    ap.add_argument("--window", type=int, default=256,
                    help="max concurrently-active streams (= total slot "
                         "capacity across shards)")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--groups", type=int, default=8,
                    help="independent callers per round (sync pays one "
                         "feed() each; async coalesces them)")
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--max-chunk", type=int, default=256)
    ap.add_argument("--chunk-lo", type=int, default=20)
    ap.add_argument("--chunk-hi", type=int, default=200)
    ap.add_argument("--life-lo", type=int, default=2)
    ap.add_argument("--life-hi", type=int, default=6)
    ap.add_argument("--emit-prob", type=float, default=0.85)
    ap.add_argument("--evict-prob", type=float, default=0.1,
                    help="per-round chance an active stream is parked to "
                         "its shard's checkpoint (reopens on next emit)")
    ap.add_argument("--stream-impl", choices=["xla", "pallas"],
                    default="xla")
    ap.add_argument("--numerics", choices=["float", "fixed"],
                    default="float")
    ap.add_argument("--paths", choices=["both", "sync", "async"],
                    default="both")
    ap.add_argument("--no-parity", action="store_true",
                    help="skip decision recording/compare (million-stream "
                         "throughput runs)")
    args = ap.parse_args(argv)

    if args.smoke:
        # tiny fleet but real churn: window > capacity pressure comes from
        # crc32 shard imbalance, so evict/reopen paths ARE exercised
        args.streams, args.window, args.rounds = 40, 12, 8
        args.groups, args.shards, args.max_chunk = 3, 2, 128
        args.chunk_lo, args.chunk_hi = 10, 100
        args.evict_prob = 0.3   # make evict->reopen churn certain
        for nm in ("float", "fixed"):
            tag = "" if nm == "float" else ".fixed"
            _run_fleet(args, nm, f".smoke{tag}", hard_parity=True)
        print("load_gen --smoke: async == sync decisions (both numerics)",
              flush=True)
        return

    tag = "" if args.numerics == "float" else ".fixed"
    _run_fleet(args, args.numerics, tag, hard_parity=False)


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
