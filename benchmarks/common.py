"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` CSV rows so
``python -m benchmarks.run`` output is machine-readable.
"""

from __future__ import annotations

import time

import jax

__all__ = ["time_fn", "row"]


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (post-jit, blocked)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)
