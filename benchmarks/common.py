"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` CSV rows so
``python -m benchmarks.run`` output is machine-readable. Rows are also
collected in-process so the driver can emit a ``BENCH_pipeline.json``
trajectory artifact (one file per run, diffable across PRs).
"""

from __future__ import annotations

import time

import jax

__all__ = ["time_fn", "row", "drain_rows"]

_ROWS: list = []


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (post-jit, blocked)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float | None, derived: str = "") -> None:
    """Emit one CSV row. ``us=None`` marks a non-timing row (accuracy,
    parity, census): the CSV field is empty and the JSON trajectory gets
    ``us_per_call: null`` — never 0.0, so tooling can't mistake it for a
    free call."""
    us_txt = "" if us is None else f"{us:.1f}"
    print(f"{name},{us_txt},{derived}", flush=True)
    _ROWS.append({"name": name,
                  "us_per_call": None if us is None else round(float(us), 1),
                  "derived": derived})


def drain_rows() -> list:
    """Hand the rows emitted since the last drain to the caller (the
    ``benchmarks.run`` driver groups them per module for the trajectory
    artifact)."""
    rows = list(_ROWS)
    _ROWS.clear()
    return rows
