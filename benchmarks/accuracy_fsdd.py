"""Table IV reproduction: FSDD(-like) speaker identification (2 speakers),
Normal-SVM baseline vs MP kernel machine (float + 8-bit)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core.filterbank import FilterBank, FilterBankConfig
from repro.core import trainer
from repro.data.acoustic import make_fsdd_like

FS = 8000.0


def main():
    ds = make_fsdd_like(per_speaker_train=40, per_speaker_test=16,
                        fs=FS, seconds=0.5, seed=1)
    out = {}
    for tag, mode, qbits in [("mac_svm_fp", "mac", None),
                             ("mp_kernel_fp", "mp", None),
                             ("mp_kernel_q8", "mp", 8)]:
        fb = FilterBank(FilterBankConfig(fs=FS, num_octaves=5,
                                         filters_per_octave=5, mode=mode,
                                         gamma_f=4.0, quant_bits=qbits))
        feat = jax.jit(fb.accumulate)
        s_tr = feat(jnp.asarray(ds.x_train))
        mu, sd = s_tr.mean(0), s_tr.std(0, ddof=1) + 1e-6
        K_tr = (s_tr - mu) / sd
        K_te = (feat(jnp.asarray(ds.x_test)) - mu) / sd
        cfg = trainer.TrainConfig(num_steps=300, lr=0.5, quant_bits=qbits)
        params, _ = trainer.train(K_tr, jnp.asarray(ds.y_train), 2, cfg)
        tr = trainer.evaluate(params, K_tr, jnp.asarray(ds.y_train), qbits)
        te = trainer.evaluate(params, K_te, jnp.asarray(ds.y_test), qbits)
        out[tag] = (tr, te)
        row(f"fsdd.{tag}", None, f"train={tr:.3f} test={te:.3f}")
    row("fsdd.reference", None,
        "paper: Theo 92/93, Nicolas 99/98 (MP float, Table IV)")
    return out


if __name__ == "__main__":
    main()
