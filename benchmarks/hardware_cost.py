"""Table I/II analogue: hardware operation census.

The paper's headline is resource count: the MP design uses 0 DSPs and <1K
slices because it is multiplierless. We can't synthesize Verilog here, but
we can count the primitive operations each inference performs by walking
the traced jaxpr and convert multiplier counts to LUT-equivalents with the
paper's own figures (8x8 signed Baugh-Wooley multiplier = 72 LUTs;
adds/compares = ~8 LUTs at 8 bit).

Since the fixed-point refactor the census has a REAL target: the integer
hardware twin (``repro.core.fixed``, numerics="fixed") executes the whole
audio -> decision path in int32. Its jaxpr is walked here with a HARD
assertion that no multiply and no divide survives — the multiplierless
claim as an executable regression gate, not prose. The float MP/MAC paths
are kept for comparison (the float MP census still counts the pow2
bisection halvings as shifts, exactly as the FPGA implements them).

Both the one-shot program AND the per-chunk integer streaming step
(``fixed.session_step_q`` — what a deployed FPGA executes per sensor
packet) are censused and asserted multiplierless, and so is the int
Pallas streaming kernel (``kernels.fir_mp_stream_q``): the census
recurses into ``pallas_call`` kernel jaxprs scaled by the grid product,
so the gate covers the VMEM-resident datapath as lowered.

Since the IR refactor the integer censuses are computed by lowering each
program to the typed fixed-point IR (``repro.ir``) and counting with the
IR census pass — the same lowering the interpreter, the XLA re-emitter and
the C/ROM generator consume — with a runtime assertion that the counts are
EXACTLY the legacy jaxpr-walk numbers (``repro.analysis.legality``, which
still backs the float rows and the op-legality verifier). The committed
``hw.*`` rows are therefore pinned byte-identical across the rebase, and
the benchmark, ``scripts/analyze.py`` and the hardware artifacts under
``artifacts/ir/`` can never disagree about what a program contains. This module also surfaces the analysis summary (bitwidth
headroom per target, the session-accumulator safety envelope) as bench
rows so headroom is tracked across PRs alongside the op counts.

Run with ``--smoke`` (used by scripts/bench_smoke.sh) for a reduced config
that still exercises the assertions.
"""

from __future__ import annotations

import argparse
from collections import Counter

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.analysis import assert_multiplierless, census  # noqa: F401
from repro.analysis.intervals import Interval
from repro.analysis.legality import census_jaxpr
from repro.core.filterbank import FilterBank, FilterBankConfig
from repro.core import fixed
from repro.core import kernel_machine as km
from repro.core.pipeline import InFilterPipeline
from repro.ir import build_program, census_program

FS = 16000.0
N = 16000  # 1 s


def census_ir(fn, *args, tag: str, in_intervals=None):
    """Census an integer program THROUGH the typed IR: trace, lower with
    ``repro.ir.build`` (which rejects anything outside the multiplierless
    contract), and count with the IR census pass. Pinned at runtime
    against the legacy jaxpr walk — if the lowering ever re-associates or
    drops an op, the committed ``hw.*`` rows can't silently move; the
    bench fails instead. Returns ``(census, program)`` — the typed
    program also feeds the allocator cost rows; passing ``in_intervals``
    runs the interval pass so register widths are the proven minima."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    prog = build_program(jaxpr, name=tag, in_intervals=in_intervals)
    c_ir = census_program(prog)
    c_jx = census_jaxpr(jaxpr)
    if dict(c_ir) != dict(c_jx):
        raise AssertionError(
            f"{tag}: IR census {dict(c_ir)} != jaxpr census {dict(c_jx)} "
            "— the IR lowering moved the pinned hw.* numbers")
    return c_ir, prog


def lut_estimate(c: Counter) -> float:
    """8-bit LUT-equivalents using the paper's conversion factors."""
    return (c["multiply"] * 72          # 8x8 Baugh-Wooley (paper: 72 LUTs)
            + c["add"] * 8
            + c["compare"] * 8
            + c["shift"] * 0            # wiring on FPGA
            + c["transcendental_or_div"] * 200)


def _fixed_pipeline(cfg, seed: int = 0) -> InFilterPipeline:
    fb = FilterBank(cfg)
    P = cfg.num_filters
    params = km.init_params(jax.random.PRNGKey(seed), P, 10)
    mu = jnp.zeros((P,))
    sigma = jnp.ones((P,))
    return InFilterPipeline.from_filterbank(fb, params, mu, sigma)


def emit_rows(tag: str, c: Counter, n_samples: int) -> None:
    per = {k: v / n_samples for k, v in c.items()}  # per input sample
    row(f"hw.{tag}.mult_per_sample", None, f"{per.get('multiply', 0):.1f}")
    row(f"hw.{tag}.add_per_sample", None, f"{per.get('add', 0):.1f}")
    row(f"hw.{tag}.cmp_per_sample", None, f"{per.get('compare', 0):.1f}")
    row(f"hw.{tag}.shift_per_sample", None, f"{per.get('shift', 0):.1f}")
    row(f"hw.{tag}.lut_weighted_ops_per_sample", None,
        f"{lut_estimate(c) / n_samples:.0f} (ops-weighted; the FPGA time-"
        f"multiplexes 3 MP modules so unit count is far lower)")


def emit_alloc_rows(tag: str, prog) -> None:
    """Allocator-derived hardware totals — the repo's slice-count proxy
    (paper Table I: 0 DSP, <1K slices). Register/adder/ROM totals come
    from the same allocation the committed ``program.v`` declares; with
    typed inputs the register bits are the interval-proven minima, and
    the carrier-saving row says how much the interval pass buys over a
    uniform int32 register file."""
    from repro.ir.alloc import allocate

    rep = allocate(prog).report
    regs, dp, roms = rep["registers"], rep["datapath"], rep["roms"]
    row(f"hw.{tag}.alloc_registers", None, f"{regs['count']}")
    row(f"hw.{tag}.alloc_register_bits", None,
        f"{regs['bits_allocated']} "
        f"(int32 carrier: {regs['bits_carrier']}, saving "
        f"{100 * regs['carrier_saving']:.1f}%)")
    row(f"hw.{tag}.alloc_rom_bits", None,
        f"{roms['bits_stored']} ({roms['count']} ROMs, "
        f"width-trimmed minimum {roms['bits_minimal']})")
    row(f"hw.{tag}.alloc_adder_sites", None,
        f"{dp['adder_sites']} (+{dp['comparator_sites']} comparators, "
        f"{dp['dyn_shifter_sites']} barrel shifters; time-multiplexed "
        f"over {rep['time_multiplexed']['element_ops_per_inference']} "
        f"element-ops/inference)")


def emit_analysis_rows(smoke: bool) -> None:
    """Static-analysis summary rows: per-target bitwidth headroom and the
    session accumulator envelope (see docs/analysis.md). Tracked across
    PRs so a register-growth regression shows up in the bench diff."""
    from repro.analysis import report as rp
    from repro.analysis.targets import build_targets

    targets, meta = build_targets(smoke=smoke)
    for t in targets:
        s = rp.analyze_target(t, top_registers=0)
        leg = s["legality"]
        row(f"analysis.{t.name}.legal_ops_per_sample", None,
            f"{sum(leg['legal_ops'].values()) / t.n_samples:.1f} "
            f"(legality {'ok' if leg['ok'] else 'FAIL'})")
        if "intervals" in s:
            iv = s["intervals"]
            row(f"analysis.{t.name}.min_headroom_bits", None,
                f"{iv['min_headroom_bits']} over {iv['num_registers']} "
                f"registers (max required {iv['max_required_bits']} bits)")
            row(f"analysis.{t.name}.int32_safe", None,
                "PROVEN for any ADC input" if iv["ok"]
                else f"FAIL: {len(iv['violations'])} possible overflow(s)")
    row("analysis.session.max_safe_session_samples", None,
        f"{meta['max_safe_session_samples']} input samples before any "
        f"int32 accumulator can overflow (acc <= "
        f"{meta['acc_envelope'][1]} within the "
        f"{meta['envelope_samples']}-sample envelope)")


def main(argv=()):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (3 octaves, 0.1 s) — still runs "
                         "the multiplierless assertion on the integer path")
    args = ap.parse_args(argv)

    if args.smoke:
        n = 1600
        base = FilterBankConfig(fs=4000.0, num_octaves=3,
                                filters_per_octave=3, mode="mp",
                                gamma_f=4.0, solver="bisect")
    else:
        n = N
        base = FilterBankConfig(fs=FS, num_octaves=6, mode="mp",
                                gamma_f=4.0, solver="bisect")
    x = jnp.zeros((1, n), jnp.float32)
    P = base.num_filters

    # --- MP in-filter path (bisection filtering + MP classifier) ---
    # solver="bisect": the census models the FPGA, whose MP modules run the
    # add/compare/shift bisection — not the software-fast Newton path
    fb_mp = FilterBank(base)
    params = km.init_params(jax.random.PRNGKey(0), P, 10)

    def mp_infer(x):
        s = fb_mp.accumulate(x)
        return km.forward(params, s)

    # --- MAC baseline (conv filtering + linear classifier) ---
    fb_mac = FilterBank(base._replace(mode="mac"))
    w = jnp.zeros((P, 10))
    b = jnp.zeros((10,))

    def mac_infer(x):
        s = fb_mac.accumulate(x)
        return km.forward_baseline(w, b, s)

    for tag, fn in [("mp_infilter", mp_infer), ("mac_baseline", mac_infer)]:
        emit_rows(tag, census(fn, x), n)

    # --- the integer hardware twin: census the REAL int32 jaxpr ----------
    # (from quantized codes onward — the ADC rounding at the boundary is
    # analog-side; everything after it must be add/sub/shift/compare)
    for tag, mode in [("fixed_mp", "mp"), ("fixed_mac_shift_add", "mac")]:
        pipe = _fixed_pipeline(base._replace(mode=mode, numerics="fixed"))
        prog = pipe.fixed_program()
        xq = fixed.quantize_signal(prog, x)
        sig = Interval(int(prog.signal.qmin), int(prog.signal.qmax))
        c, prog_ir = census_ir(lambda q: fixed.infer_q(prog, q), xq,
                               tag=tag, in_intervals=[sig])
        assert_multiplierless(c, tag)
        emit_rows(tag, c, n)
        emit_alloc_rows(tag, prog_ir)
        row(f"hw.{tag}.multiplierless_assert", None,
            "PASS (0 multiplies, 0 divides in the integer IR, counts "
            "pinned == jaxpr census)")

    # --- the integer STREAMING step: what a deployed FPGA actually runs --
    # per sensor packet (delay-line splice, kept-only decimation, readout
    # every chunk). Censused per chunk and asserted multiplierless — the
    # per-chunk step, not the one-shot program, is the deployment datapath.
    chunk_len = 160  # one 10 ms packet at 16 kHz (smoke: same length)
    for tag, mode in [("fixed_mp_stream", "mp"),
                      ("fixed_mac_stream", "mac")]:
        pipe = _fixed_pipeline(base._replace(mode=mode, numerics="fixed"))
        prog = pipe.fixed_program()
        state = pipe.init_session(1)
        xq = fixed.quantize_signal(prog, jnp.zeros((1, chunk_len)))
        nv = jnp.full((1,), chunk_len, jnp.int32)
        c, prog_ir = census_ir(
            lambda st, q, v: fixed.session_step_q(prog, st, q, v),
            state, xq, nv, tag=tag)
        assert_multiplierless(c, tag)
        emit_rows(tag, c, chunk_len)
        emit_alloc_rows(tag, prog_ir)
        row(f"hw.{tag}.multiplierless_assert", None,
            f"PASS (0 mul/div in the per-chunk int32 streaming IR, "
            f"chunk={chunk_len}, counts pinned == jaxpr census)")

    # --- the int PALLAS streaming step: the census recurses into the
    # pallas_call kernel jaxpr (scaled by the grid product), so the hard
    # gate covers the VMEM-resident datapath too — what actually lowers,
    # not just the XLA twin it mirrors.
    tag = "fixed_mp_stream_pallas"
    pipe = _fixed_pipeline(base._replace(mode="mp", numerics="fixed",
                                         stream_impl="pallas"))
    prog = pipe.fixed_program()
    state = pipe.init_session(1)
    xq = fixed.quantize_signal(prog, jnp.zeros((1, chunk_len)))
    nv = jnp.full((1,), chunk_len, jnp.int32)
    c, prog_ir = census_ir(
        lambda st, q, v: pipe._cascade_pallas_fixed(prog, st, q, v),
        state, xq, nv, tag=tag)
    assert_multiplierless(c, tag)
    emit_rows(tag, c, chunk_len)
    emit_alloc_rows(tag, prog_ir)
    row(f"hw.{tag}.multiplierless_assert", None,
        f"PASS (0 mul/div in the Pallas-lowered per-chunk int32 IR, "
        f"chunk={chunk_len}, counts pinned == jaxpr census)")

    emit_analysis_rows(args.smoke)

    row("hw.reference", None,
        "paper Table I: 0 DSP, 1503 LUT, 2376 FF, 17mW@50MHz; "
        "[6] CAR-IHC uses 4 DSPs (~890 LUT-equiv). Key check: fixed_mp "
        "multiplies/sample == 0 ENFORCED on the int32 jaxpr (was a float "
        "proxy before the fixed-point refactor), MAC baseline > 0")


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
