"""Table I/II analogue: hardware operation census.

The paper's headline is resource count: the MP design uses 0 DSPs and <1K
slices because it is multiplierless. We can't synthesize Verilog here, but
we can count the primitive operations each inference performs by walking
the traced jaxpr of (a) the MP in-filter classifier and (b) the MAC
baseline, and convert multiplier counts to LUT-equivalents with the paper's
own figures (8x8 signed Baugh-Wooley multiplier = 72 LUTs; adds/compares
= ~8 LUTs at 8 bit).

Multiplications by power-of-two literals are classified as shifts (the MP
bisection's halving step), exactly as the FPGA implements them.
"""

from __future__ import annotations

import math
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core.filterbank import FilterBank, FilterBankConfig
from repro.core import kernel_machine as km
from repro.core import mp as mp_mod

FS = 16000.0
N = 16000  # 1 s


def _literal_pow2(eqn) -> bool:
    from jax._src.core import Literal
    for v in eqn.invars:
        if isinstance(v, Literal):
            try:
                val = float(np.ravel(v.val)[0])
            except Exception:
                return False
            if val != 0 and abs(math.log2(abs(val)) % 1.0) < 1e-9:
                return True
    return False


def _out_elems(eqn) -> int:
    tot = 0
    for v in eqn.outvars:
        if hasattr(v.aval, "shape"):
            n = 1
            for d in v.aval.shape:
                n *= d
            tot += n
    return tot


MUL_OPS = {"mul"}
ADD_OPS = {"add", "sub"}
CMP_OPS = {"max", "min", "gt", "lt", "ge", "le", "select_n", "eq"}


def census(fn, *args) -> Counter:
    jaxpr = jax.make_jaxpr(fn)(*args)
    counts: Counter = Counter()

    def walk(jx):
        for eqn in jx.eqns:
            name = eqn.primitive.name
            n = _out_elems(eqn)
            if name in ("pjit", "closed_call", "custom_vjp_call",
                        "custom_jvp_call", "remat", "checkpoint"):
                for sub in eqn.params.values():
                    if hasattr(sub, "jaxpr"):
                        walk(sub.jaxpr if hasattr(sub.jaxpr, "eqns")
                             else sub)
                continue
            if name in ("scan", "while"):
                length = eqn.params.get("length", 1) or 1
                inner = eqn.params.get("jaxpr")
                if inner is not None:
                    before = counts.copy()
                    walk(inner.jaxpr if hasattr(inner, "jaxpr") else inner)
                    for k in counts:
                        counts[k] = before.get(k, 0) + \
                            (counts[k] - before.get(k, 0)) * length
                continue
            if name == "conv_general_dilated":
                # MACs: out elems x kernel taps (per output channel)
                rhs = eqn.invars[1].aval.shape
                k_elems = 1
                for d in rhs:
                    k_elems *= d
                counts["multiply"] += n * max(k_elems // max(rhs[0], 1), 1)
                counts["add"] += n * max(k_elems // max(rhs[0], 1), 1)
            elif name == "dot_general":
                # MACs: out elems x contraction size
                lhs = eqn.invars[0].aval.shape
                ((lc, _), _) = eqn.params["dimension_numbers"]
                contract = 1
                for d in lc:
                    contract *= lhs[d]
                counts["multiply"] += n * contract
                counts["add"] += n * contract
            elif name in MUL_OPS:
                if _literal_pow2(eqn):
                    counts["shift"] += n
                else:
                    counts["multiply"] += n
            elif name in ADD_OPS:
                counts["add"] += n
            elif name in CMP_OPS:
                counts["compare"] += n
            elif name in ("exp", "log", "tanh", "logistic", "rsqrt", "sqrt",
                          "div", "integer_pow"):
                counts["transcendental_or_div"] += n

    walk(jaxpr.jaxpr)
    return counts


def lut_estimate(c: Counter) -> float:
    """8-bit LUT-equivalents using the paper's conversion factors."""
    return (c["multiply"] * 72          # 8x8 Baugh-Wooley (paper: 72 LUTs)
            + c["add"] * 8
            + c["compare"] * 8
            + c["shift"] * 0            # wiring on FPGA
            + c["transcendental_or_div"] * 200)


def main():
    x = jnp.zeros((1, N), jnp.float32)
    P = 30

    # --- MP in-filter path (bisection filtering + MP classifier) ---
    # solver="bisect": the census models the FPGA, whose MP modules run the
    # add/compare/shift bisection — not the software-fast Newton path
    fb_mp = FilterBank(FilterBankConfig(fs=FS, num_octaves=6, mode="mp",
                                        gamma_f=4.0, solver="bisect"))
    params = km.init_params(jax.random.PRNGKey(0), P, 10)

    def mp_infer(x):
        s = fb_mp.accumulate(x)
        return km.forward(params, s)

    # --- MAC baseline (conv filtering + linear classifier) ---
    fb_mac = FilterBank(FilterBankConfig(fs=FS, num_octaves=6, mode="mac"))
    w = jnp.zeros((P, 10))
    b = jnp.zeros((10,))

    def mac_infer(x):
        s = fb_mac.accumulate(x)
        return km.forward_baseline(w, b, s)

    for tag, fn in [("mp_infilter", mp_infer), ("mac_baseline", mac_infer)]:
        c = census(fn, x)
        per = {k: v / N for k, v in c.items()}  # per input sample
        row(f"hw.{tag}.mult_per_sample", 0.0, f"{per.get('multiply', 0):.1f}")
        row(f"hw.{tag}.add_per_sample", 0.0, f"{per.get('add', 0):.1f}")
        row(f"hw.{tag}.cmp_per_sample", 0.0, f"{per.get('compare', 0):.1f}")
        row(f"hw.{tag}.shift_per_sample", 0.0, f"{per.get('shift', 0):.1f}")
        row(f"hw.{tag}.lut_weighted_ops_per_sample", 0.0,
            f"{lut_estimate(c) / N:.0f} (ops-weighted; the FPGA time-"
            f"multiplexes 3 MP modules so unit count is far lower)")
    row("hw.reference", 0.0,
        "paper Table I: 0 DSP, 1503 LUT, 2376 FF, 17mW@50MHz; "
        "[6] CAR-IHC uses 4 DSPs (~890 LUT-equiv). Key check: MP path "
        "multiplies/sample == 0 (multiplierless), MAC baseline > 0")


if __name__ == "__main__":
    main()
