"""End-to-end in-filter pipeline benchmark (the tentpole numbers).

Compares, at the paper-scale workload (B=32 clips x 16000 samples, 6
octaves x 5 filters = 30 bands):

  seed_perfilter   the seed implementation: one vmap'd per-filter FIR per
                   octave with the 26-iteration bisection solver, Python
                   list + stack readout, feature / standardize / classifier
                   dispatched separately
  pipeline_oneshot unified InFilterPipeline.predict: stacked-tap octave
                   kernels (chunked, Newton water-filling) + classifier in
                   ONE jit computation
  pipeline_stream  the same audio pushed through the stateful streaming API
                   in 1600-sample chunks (fixed-memory continuous mode)
  pipeline_stream_pallas
                   the same chunked stream through the stateful
                   ``fir_mp_stream`` Pallas kernel (stream_impl="pallas";
                   interpret mode off-TPU — wiring/bit-rot gate there, the
                   VMEM-residency win is a TPU measurement) with a
                   bit-for-bit check against the XLA streaming path

Emits ``name,us_per_call,derived`` CSV rows like every other benchmark.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core import kernel_machine as km
from repro.core import mp as mp_mod
from repro.core.filterbank import FilterBank, FilterBankConfig
from repro.core.pipeline import InFilterPipeline

B, N = 32, 16000
CHUNK = 1600


def _seed_conv(x, h, gamma):
    """The seed's per-filter MP FIR: window gather + bisection solver."""
    M = h.shape[0]
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(M - 1, 0)])
    idx = jnp.arange(x.shape[-1])[:, None] + jnp.arange(M)[None, :]
    return mp_mod.mp_dot(xp[..., idx], h[::-1], gamma, exact=False)


def seed_accumulate_fn(fb: FilterBank):
    cfg = fb.config

    def accumulate(x):
        s = []
        x_o = x
        for o in range(cfg.num_octaves):
            taps = fb.bp_by_octave[o]
            y = jax.vmap(lambda h: _seed_conv(x_o, h, cfg.gamma_f))(taps)
            for p in range(taps.shape[0]):
                s.append(jnp.sum(jnp.maximum(y[p], 0.0), -1) * 2.0 ** o)
            if o < cfg.num_octaves - 1:
                lp = jnp.asarray(fb.lp_tap_list[o])
                x_o = _seed_conv(x_o, lp, cfg.gamma_f)[..., ::2]
        return jnp.stack(s, -1)

    return accumulate


def main(argv=()):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run for CI bit-rot checks")
    args = ap.parse_args(argv)
    global B, N, CHUNK
    if args.smoke:
        B, N, CHUNK = 4, 4000, 400
        cfg = FilterBankConfig(fs=4000.0, num_octaves=4,
                               filters_per_octave=3, mode="mp", gamma_f=4.0)
    else:
        cfg = FilterBankConfig(fs=16000.0, num_octaves=6,
                               filters_per_octave=5, mode="mp", gamma_f=4.0)
    fb = FilterBank(cfg)
    P = cfg.num_filters
    clf = km.init_params(jax.random.PRNGKey(0), P, 10)
    mu = jnp.ones((P,))
    sigma = jnp.full((P,), 2.0)
    pipe = InFilterPipeline.from_filterbank(fb, clf, mu, sigma)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, N))

    # -- seed flow: separate dispatches, per-filter bisection bank ----------
    feat_seed = jax.jit(seed_accumulate_fn(fb))
    fwd = jax.jit(lambda K: km.forward(clf, K))

    def seed_e2e(x):
        s = feat_seed(x)
        return fwd((s - mu) / sigma)

    us_seed = time_fn(seed_e2e, x, warmup=1, iters=3)
    row(f"pipeline_e2e.seed_perfilter.B{B}xN{N}xP{P}", us_seed,
        f"{B * N / us_seed:.1f} samples/us")

    # -- unified one-shot ----------------------------------------------------
    predict = jax.jit(pipe.predict)
    us_one = time_fn(predict, x, warmup=1, iters=3)
    row(f"pipeline_e2e.pipeline_oneshot.B{B}xN{N}xP{P}", us_one,
        f"speedup_vs_seed={us_seed / us_one:.2f}x")

    # -- streaming -----------------------------------------------------------
    step = jax.jit(InFilterPipeline.step)

    def stream_e2e(x):
        state = pipe.init_state(B)
        p = None
        for i in range(0, N, CHUNK):
            state, p = step(pipe, state, x[:, i:i + CHUNK])
        return p

    us_stream = time_fn(stream_e2e, x, warmup=1, iters=3)
    row(f"pipeline_e2e.pipeline_stream.chunk{CHUNK}", us_stream,
        f"per_chunk_us={us_stream / (N // CHUNK):.1f}")

    # -- streaming through the stateful Pallas kernel ------------------------
    pipe_k = InFilterPipeline(cfg._replace(stream_impl="pallas"),
                              pipe.bp_taps, pipe.lp_taps, pipe.mu,
                              pipe.sigma, pipe.clf)
    apply_k = jax.jit(InFilterPipeline.apply)

    def stream_pallas_e2e(x):
        state = pipe_k.init_session(B)
        p = None
        for i in range(0, N, CHUNK):
            p, state = apply_k(pipe_k, x[:, i:i + CHUNK], state)
        return p

    us_kstream = time_fn(stream_pallas_e2e, x, warmup=1, iters=3)
    row(f"pipeline_e2e.pipeline_stream_pallas.chunk{CHUNK}", us_kstream,
        f"vs_xla_stream={us_stream / us_kstream:.2f}x "
        "(interpret off-TPU)")

    # parity: all flows classify identically (f32 round-off; the two
    # streaming impls must agree bit-for-bit in interpret mode)
    p_seed = seed_e2e(x)
    p_one = predict(x)
    p_stream = stream_e2e(x)
    p_kstream = stream_pallas_e2e(x)
    err_one = float(jnp.max(jnp.abs(p_one - p_seed)))
    err_stream = float(jnp.max(jnp.abs(p_stream - p_one)))
    err_k = float(jnp.max(jnp.abs(p_kstream - p_stream)))
    row("pipeline_e2e.parity", None,
        f"oneshot_vs_seed={err_one:.2e} stream_vs_oneshot={err_stream:.2e} "
        f"pallas_vs_xla_stream={err_k:.2e} "
        f"bitwise={bool(err_k == 0.0)}")


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
