"""Kernel micro-benchmarks (CPU wall time; interpret-mode Pallas).

Timings here are NOT the TPU performance story (that is the §Roofline
analysis) — they are regression tracking for the reference implementations
and a check that the exact and bisection solvers have sane relative cost.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core import mp as mp_mod


def main():
    key = jax.random.PRNGKey(0)

    for rows_, m in [(1024, 64), (4096, 64), (1024, 512)]:
        L = jax.random.normal(key, (rows_, m))
        f_exact = jax.jit(lambda L: mp_mod.mp_exact(L, 2.0))
        f_bis = jax.jit(lambda L: mp_mod.mp_bisect(L, 2.0))
        f_newt = jax.jit(lambda L: mp_mod.mp_newton(L, 2.0))
        us_e = time_fn(f_exact, L)
        us_b = time_fn(f_bis, L)
        us_n = time_fn(f_newt, L)
        row(f"mp_exact.{rows_}x{m}", us_e,
            f"{rows_ * m / us_e:.0f} elem/us")
        row(f"mp_bisect.{rows_}x{m}", us_b,
            f"{rows_ * m / us_b:.0f} elem/us")
        row(f"mp_newton.{rows_}x{m}", us_n,
            f"{rows_ * m / us_n:.0f} elem/us vs_bisect={us_b/us_n:.1f}x")

    x = jax.random.normal(key, (64, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 128))
    f_mp = jax.jit(lambda x, w: mp_mod.mp_linear(x, w, 1.0, exact=False))
    f_mac = jax.jit(lambda x, w: x @ w)
    us_mp = time_fn(f_mp, x, w)
    us_mac = time_fn(f_mac, x, w)
    row("mp_linear.64x256x128", us_mp, f"vs_mac={us_mp/us_mac:.1f}x")
    row("mac_linear.64x256x128", us_mac, "")

    sig = jax.random.normal(key, (8, 4096))
    h = jax.random.normal(jax.random.PRNGKey(2), (16,)) * 0.3
    f_fir = jax.jit(lambda x: mp_mod.mp_conv1d(x, h, 4.0, exact=False))
    us_fir = time_fn(f_fir, sig)
    row("mp_fir.8x4096xM16", us_fir, f"{8*4096/us_fir:.0f} samples/us")


if __name__ == "__main__":
    main()
