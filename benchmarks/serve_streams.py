"""Multi-stream serving throughput: slot-batched StreamServer vs a naive
per-stream step() loop.

The ROADMAP north-star workload: thousands of concurrent sensor streams per
chip. The naive baseline drives S independent one-stream cohorts through the
jitted legacy ``step`` — S dispatches per round. The server packs the same S
streams into one slot-batched ``SessionState`` and advances ALL of them with
ONE donated-state compiled call per round (padding + per-slot valid counts),
which is where the >=5x at S=256 comes from.

Also reports quantized streaming parity: with the running amax seeded (a
calibrated/held stream), chunked session ``apply()`` must reproduce one-shot
``predict()`` — the deployment-faithful semantics the old chunk-local amax
could not deliver.

``--stream-impl`` selects the session-step hot path ("xla" | "pallas" |
"both"); "both" additionally reports pallas-vs-xla speedup and their
bit-for-bit decision parity. ``--numerics fixed`` serves the bit-true
int32 hardware twin instead of the float engine — there the "both" parity
row is a HARD bitwise gate (int Pallas == int XLA registers and
decisions), and the streaming-parity row compares streamed decisions
against one-shot ``apply`` at exact equality. Off-TPU the Pallas kernels
run in interpret mode, so CPU numbers measure wiring, not the
VMEM-residency win — the >=1.5x target is a TPU measurement (see
ROADMAP).

    PYTHONPATH=src python -m benchmarks.serve_streams [--slots 256] [--smoke]

Emits ``name,us_per_call,derived`` CSV rows like every other benchmark.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.configs.esc10_mp import make_pipeline
from repro.core.pipeline import InFilterPipeline
from repro.serving import StreamServer, make_batched_step

ROUNDS = 2  # chunks per stream per timed call


def _pow2_at_least(n: int) -> int:
    """Smallest power of two >= n: the server validates its chunk bounds
    as pow2 (the bucket-ladder contract), so an arbitrary packet length
    maps to the bucket it would pad into."""
    b = 1
    while b < n:
        b <<= 1
    return b


def main(argv=()):
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=256)
    ap.add_argument("--chunk", type=int, default=40,
                    help="sensor packet length in samples (default: 10 ms "
                         "at the smoke config's 4 kHz)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run for CI bit-rot checks")
    ap.add_argument("--stream-impl", choices=["xla", "pallas", "both"],
                    default="xla",
                    help="session-step hot path; 'both' also reports the "
                         "pallas-vs-xla speedup and decision parity")
    ap.add_argument("--numerics", choices=["float", "fixed"],
                    default="float",
                    help="serving engine; 'fixed' serves the bit-true "
                         "int32 hardware twin (parity rows become exact-"
                         "equality gates)")
    args = ap.parse_args(argv)
    S = 16 if args.smoke else args.slots
    CH = args.chunk
    iters = 2 if args.smoke else 3
    primary_impl = "xla" if args.stream_impl == "both" else args.stream_impl
    nm = args.numerics
    tag = "" if nm == "float" else ".fixed"

    def _pipe(impl):
        # fixed: full-scale at ~4 sigma of the N(0,1) test audio so the
        # static ADC grid is exercised, not just saturated
        return make_pipeline(smoke=True, stream_impl=impl, numerics=nm,
                             fixed_amax=4.0 if nm == "fixed" else None)

    pipe = _pipe(primary_impl)
    rng = np.random.default_rng(0)
    audio = rng.standard_normal((S, ROUNDS * CH)).astype(np.float32)

    # -- naive: per-stream serving, one jitted step + one host->device
    # upload + one decision readback PER STREAM per packet (exactly what a
    # stream-at-a-time server pays; the slot-batched server amortizes all
    # three across S streams) ----------------------------------------------
    if nm == "fixed":
        # the integer program lowers host-side: jit a closure over the
        # concrete pipeline (same shape as the server's donated step)
        _step = jax.jit(lambda s, c: InFilterPipeline.step(pipe, s, c))
        step = lambda p, s, c: _step(s, c)  # noqa: E731
    else:
        step = jax.jit(InFilterPipeline.step)

    def naive():
        states = [pipe.init_state(1) for _ in range(S)]
        labels = None
        for r in range(ROUNDS):
            labels = []
            for s in range(S):
                chunk = jnp.asarray(audio[s:s + 1, r * CH:(r + 1) * CH])
                states[s], p = step(pipe, states[s], chunk)
                labels.append(int(np.asarray(p).argmax()))
        return labels

    us_naive = time_fn(naive, warmup=1, iters=iters)
    row(f"serve_streams.naive_loop{tag}.S{S}xC{CH}", us_naive,
        f"{S * ROUNDS / us_naive * 1e6:.0f} chunks/s")

    # -- slot-batched server: ONE donated compiled call per round -----------
    server = StreamServer(pipe, capacity=S, max_chunk=_pow2_at_least(CH))
    ids = [f"s{i:04d}" for i in range(S)]
    for sid in ids:
        server.open(sid)

    def served():
        res = None
        for r in range(ROUNDS):
            res = server.feed([(sid, audio[i, r * CH:(r + 1) * CH])
                               for i, sid in enumerate(ids)])
        jax.block_until_ready(server.state.acc)
        return res

    us_srv = time_fn(served, warmup=1, iters=iters)
    row(f"serve_streams.stream_server{tag}.S{S}xC{CH}", us_srv,
        f"speedup_vs_naive={us_naive / us_srv:.2f}x")
    row(f"serve_streams.per_chunk_latency{tag}.S{S}", us_srv / ROUNDS,
        f"{S * ROUNDS / us_srv * 1e6:.0f} chunks/s")

    # -- async/coalescing front end: G independent callers per round.
    # sync pays G full feed() calls (dispatch + readback each); async
    # coalesces the same G submits into shared waves resolved by ONE
    # drain. Decisions must stay bit-for-bit identical — for BOTH
    # numerics modes this is a hard gate, not a footnote. --------------------
    import time as _time

    G = 4 if args.smoke else 8
    L_ROUNDS = 2 if args.smoke else 4
    groups = [list(range(g, S, G)) for g in range(G)]
    # one pipeline + ONE shared compiled step across the fresh servers
    # below — exactly how the router shares it across shards; without
    # this, fixed numerics (a per-server jit closure) would recompile in
    # every pass and the latency rows would measure compile time
    pipe_c = _pipe(primary_impl)
    step_c = make_batched_step(pipe_c)

    def _caller_pass(async_path: bool):
        srv = StreamServer(pipe_c, capacity=S,
                           max_chunk=_pow2_at_least(CH), step_fn=step_c)
        for sid in ids:
            srv.open(sid)
        lat, dec = [], {}
        t_all = _time.perf_counter()
        for r in range(L_ROUNDS):
            rr = r % ROUNDS
            if async_path:
                staged = []
                for g in groups:
                    part = [(ids[i], audio[i, rr * CH:(rr + 1) * CH])
                            for i in g]
                    staged.append((_time.perf_counter(),
                                   srv.submit(part)))
                srv.drain()
                t_end = _time.perf_counter()
                for t0, ticket in staged:
                    lat.append(t_end - t0)
                    for res in ticket.results:
                        dec[(res.session_id, res.samples_seen)] = \
                            (res.label, res.confidence)
            else:
                for g in groups:
                    part = [(ids[i], audio[i, rr * CH:(rr + 1) * CH])
                            for i in g]
                    t0 = _time.perf_counter()
                    out = srv.feed(part)
                    lat.append(_time.perf_counter() - t0)
                    for res in out:
                        dec[(res.session_id, res.samples_seen)] = \
                            (res.label, res.confidence)
        wall = _time.perf_counter() - t_all
        return wall, np.asarray(lat) * 1e6, dec

    _caller_pass(False)  # warmup (compile off the clock)
    wall_s, lat_s, dec_s = _caller_pass(False)
    wall_a, lat_a, dec_a = _caller_pass(True)
    fed = S * L_ROUNDS
    row(f"serve_streams.feed_sync_callers{tag}.S{S}.G{G}",
        wall_s / fed * 1e6, f"{fed / wall_s:.0f} streams/s")
    row(f"serve_streams.feed_async_coalesced{tag}.S{S}.G{G}",
        wall_a / fed * 1e6,
        f"{fed / wall_a:.0f} streams/s "
        f"speedup_vs_sync={wall_s / wall_a:.2f}x "
        f"bitwise={dec_s == dec_a}")
    row(f"serve_streams.feed_latency_sync{tag}.S{S}", None,
        f"p50={np.percentile(lat_s, 50):.0f}us "
        f"p99={np.percentile(lat_s, 99):.0f}us")
    row(f"serve_streams.feed_latency_async{tag}.S{S}", None,
        f"p50={np.percentile(lat_a, 50):.0f}us "
        f"p99={np.percentile(lat_a, 99):.0f}us")
    if dec_s != dec_a:
        raise AssertionError(
            "async/coalesced decisions != sync feed() decisions "
            f"({nm} numerics, {primary_impl}) — the bitwise serving "
            "contract is violated")

    # -- stateful Pallas streaming kernel vs the XLA session step -----------
    if args.stream_impl == "both":
        pipe_k = _pipe("pallas")
        server_k = StreamServer(pipe_k, capacity=S,
                                max_chunk=_pow2_at_least(CH))
        for sid in ids:
            server_k.open(sid)

        def served_pallas():
            res = None
            for r in range(ROUNDS):
                res = server_k.feed([(sid, audio[i, r * CH:(r + 1) * CH])
                                     for i, sid in enumerate(ids)])
            jax.block_until_ready(server_k.state.acc)
            return res

        us_k = time_fn(served_pallas, warmup=1, iters=iters)
        # decision parity on FRESH servers (history-free comparison);
        # registers are compared too — the server-parity gate covers the
        # full SessionState, not just the argmax
        fresh, regs = [], []
        for impl in ("xla", "pallas"):
            srv = StreamServer(_pipe(impl), capacity=S,
                               max_chunk=_pow2_at_least(CH))
            for sid in ids:
                srv.open(sid)
            res = None
            for r in range(ROUNDS):
                res = srv.feed([(sid, audio[i, r * CH:(r + 1) * CH])
                                for i, sid in enumerate(ids)])
            fresh.append(res)
            regs.append(np.asarray(srv.state.acc))
        bitwise = (all(a.label == b.label and a.confidence == b.confidence
                       for a, b in zip(*fresh))
                   and bool(np.array_equal(*regs)))
        if nm == "fixed" and not bitwise:
            # the int kernels carry an EXACT parity contract — a mismatch
            # is a correctness bug, not a benchmark footnote
            raise AssertionError(
                "fixed-numerics server parity violated: int Pallas != "
                "int XLA decisions/registers")
        row(f"serve_streams.stream_server_pallas{tag}.S{S}xC{CH}", us_k,
            f"speedup_vs_xla={us_srv / us_k:.2f}x bitwise={bitwise} "
            f"(interpret mode off-TPU; >=1.5x target is a TPU number)")

    if nm == "fixed":
        # -- fixed streaming parity: chunked == one-shot at EXACT equality
        # (static ADC grid; docs/numerics.md) -------------------------------
        pipe_q = _pipe(primary_impl)
        xq = jnp.asarray(rng.standard_normal((4, 8 * CH)).astype(np.float32))
        p_one = pipe_q.apply(xq)
        state = pipe_q.init_session(4)
        p_s = None
        for i in range(0, xq.shape[1], CH):
            p_s, state = pipe_q.apply(xq[:, i:i + CH], state)
        exact = bool(np.array_equal(np.asarray(p_s), np.asarray(p_one)))
        row(f"serve_streams.fixed_parity.{primary_impl}", None,
            f"stream_vs_oneshot bitwise={exact}")
        if not exact:
            raise AssertionError(
                "fixed-numerics streaming parity violated: chunked apply "
                "!= one-shot apply")
    else:
        # -- quantized streaming parity (running amax, seeded = held
        # stream) -----------------------------------------------------------
        pipe_q = make_pipeline(smoke=True, quant_bits=8,
                               stream_impl=primary_impl)
        xq = jnp.asarray(rng.standard_normal((4, 8 * CH)).astype(np.float32))
        p_one = pipe_q.predict(xq)
        amax0 = jnp.max(jnp.abs(xq), axis=-1)
        state = pipe_q.init_session(4, amax=amax0)
        p_s = None
        for i in range(0, xq.shape[1], CH):
            p_s, state = pipe_q.apply(xq[:, i:i + CH], state)
        err = float(jnp.max(jnp.abs(p_s - p_one)))
        row("serve_streams.quant_parity", None,
            f"stream_vs_oneshot={err:.2e} bitwise={bool(err == 0.0)}")


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
