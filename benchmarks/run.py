"""Benchmark driver — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [names...]

Emits ``name,us_per_call,derived`` CSV rows. Modules:
  accuracy_esc10       Table III  (ESC-10-like accuracy, 3 systems)
  accuracy_fsdd        Table IV   (speaker ID)
  bitwidth_sweep       Fig. 8     (accuracy vs bit width)
  filterbank_response  Fig. 4/6   (downsampling + MP distortion)
  hardware_cost        Table I/II (op census -> LUT equivalents)
  microbench           kernel reference timings
  pipeline_e2e         unified audio->decision pipeline: one-shot vs
                       streaming vs the seed per-filter path
  serve_streams        slot-batched StreamServer vs naive per-stream
                       step loop (+ quantized streaming parity)
"""

from __future__ import annotations

import sys
import time
import traceback

MODULES = [
    "microbench",
    "pipeline_e2e",
    "serve_streams",
    "filterbank_response",
    "hardware_cost",
    "accuracy_fsdd",
    "bitwidth_sweep",
    "accuracy_esc10",
]


def main() -> None:
    names = sys.argv[1:] or MODULES
    failures = []
    for name in names:
        print(f"# === benchmarks.{name} ===", flush=True)
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001
            failures.append(name)
            print(f"# {name} FAILED:\n{traceback.format_exc()}", flush=True)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
