"""Benchmark driver — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [names...]

Emits ``name,us_per_call,derived`` CSV rows on stdout AND writes a
``BENCH_pipeline.json`` trajectory artifact (override the path with
``BENCH_OUT=...``): every row grouped per module plus run metadata, so
benchmark results are a diffable file instead of scrollback. Modules:
  accuracy_esc10       Table III  (ESC-10-like accuracy, 3 systems)
  accuracy_fsdd        Table IV   (speaker ID)
  bitwidth_sweep       Fig. 8     (accuracy vs bit width, QAT + true-int)
  filterbank_response  Fig. 4/6   (downsampling + MP distortion)
  hardware_cost        Table I/II (op census -> LUT equivalents; asserts
                       the int32 hardware twin is multiplierless, incl.
                       the Pallas-lowered streaming kernel)
  kernel_sweep         streaming-kernel shape sweep (block_s x chunk x
                       capacity, float + int; feeds the committed
                       autotune table)
  microbench           kernel reference timings
  pipeline_e2e         unified audio->decision pipeline: one-shot vs
                       streaming vs the seed per-filter path
  serve_streams        slot-batched StreamServer vs naive per-stream
                       step loop (+ async/coalesced feed vs sync callers,
                       per-feed latency percentiles, quantized streaming
                       parity)
  load_gen             fleet load generator: churning logical streams
                       through the sharded router, async vs sync paths,
                       streams/s + p50/p99 + bitwise-parity gate
"""

from __future__ import annotations

import json
import os
import platform
import sys
import time
import traceback

from benchmarks.common import drain_rows

MODULES = [
    "microbench",
    "pipeline_e2e",
    "serve_streams",
    "load_gen",
    "kernel_sweep",
    "filterbank_response",
    "hardware_cost",
    "accuracy_fsdd",
    "bitwidth_sweep",
    "accuracy_esc10",
]

DEFAULT_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "..", "BENCH_pipeline.json")


def main() -> None:
    names = sys.argv[1:] or MODULES
    failures = []
    t_run = time.time()
    artifact = {
        "schema": "bench-trajectory-v1",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "platform": platform.platform(),
        "modules_requested": names,
        "modules": {},
        "failures": failures,
    }
    try:
        import jax
        artifact["jax"] = jax.__version__
        artifact["devices"] = [str(d) for d in jax.devices()]
    except Exception:  # noqa: BLE001
        pass
    for name in names:
        print(f"# === benchmarks.{name} ===", flush=True)
        t0 = time.time()
        drain_rows()  # rows printed outside a module don't leak into it
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
            elapsed = time.time() - t0
            print(f"# {name} done in {elapsed:.1f}s", flush=True)
            artifact["modules"][name] = {
                "seconds": round(elapsed, 1),
                "rows": drain_rows(),
            }
        except Exception:  # noqa: BLE001
            failures.append(name)
            artifact["modules"][name] = {
                "seconds": round(time.time() - t0, 1),
                "error": traceback.format_exc(limit=5),
                "rows": drain_rows(),
            }
            print(f"# {name} FAILED:\n{traceback.format_exc()}", flush=True)
    artifact["total_seconds"] = round(time.time() - t_run, 1)
    # partial runs must not clobber the committed full-trajectory artifact:
    # only the full module list writes BENCH_pipeline.json by default
    # (BENCH_OUT always wins)
    default = DEFAULT_OUT if names == MODULES \
        else DEFAULT_OUT.replace(".json", ".partial.json")
    out = os.environ.get("BENCH_OUT", default)
    with open(out, "w") as f:
        json.dump(artifact, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {os.path.normpath(out)} "
          f"({len(artifact['modules'])} modules)", flush=True)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
