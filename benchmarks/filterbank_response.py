"""Fig. 4 + Fig. 6 reproduction: filter-bank gain response to a chirp.

Fig. 4 claim: with octave downsampling, fixed 16-tap filters resolve every
band (without it, the low bands need ~200 taps). Fig. 6: the MP filter bank
shows the same band structure with some approximation distortion.

We quantify "resolves the band" as band selectivity: energy in the peak
filter / mean energy, per octave, for octave-matched tones.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.core.filterbank import (FilterBank, FilterBankConfig,
                                   design_bandpass)
from repro.data.acoustic import chirp

FS = 16000.0


def selectivity(fb, tone_hz, n=4096):
    t = np.arange(n) / FS
    x = jnp.asarray(np.sin(2 * np.pi * tone_hz * t,
                           dtype=np.float64).astype(np.float32))[None]
    s = np.asarray(fb.accumulate(x))[0]
    return float(s.max() / (s.mean() + 1e-9)), int(s.argmax())


def main():
    cfg = FilterBankConfig(fs=FS, num_octaves=6, filters_per_octave=5,
                           bp_taps=16, mode="mac")
    fb = FilterBank(cfg)
    tones = [6000, 3000, 1500, 750, 375, 190]  # one per octave
    for o, tone in enumerate(tones):
        sel, peak = selectivity(fb, tone)
        row(f"fig4.downsampled_16tap.octave{o+1}", None,
            f"tone={tone}Hz selectivity={sel:.1f} peak_filter={peak} "
            f"peak_octave={fb.octave_of[peak]+1}")

    # counterfactual: NO downsampling — a 16-tap bank at the full rate
    # cannot separate the low bands (this is why the paper downsamples)
    lo, hi = 125.0, 250.0
    h16 = design_bandpass(16, lo, hi, FS)       # full-rate 16 taps
    h200 = design_bandpass(200, lo, hi, FS)     # what full rate would need
    freqs = np.linspace(50, 1000, 96)
    def resp(h):
        n = np.arange(len(h))
        return np.array([abs(np.sum(h * np.exp(-2j * np.pi * f / FS * n)))
                         for f in freqs])
    r16, r200 = resp(h16), resp(h200)
    inband = (freqs >= lo) & (freqs <= hi)
    c16 = r16[inband].mean() / (r16[~inband].mean() + 1e-9)
    c200 = r200[inband].mean() / (r200[~inband].mean() + 1e-9)
    row("fig4.fullrate_16tap_lowband", None, f"contrast={c16:.2f}")
    row("fig4.fullrate_200tap_lowband", None,
        f"contrast={c200:.2f} (16-tap needs downsampling: "
        f"{'confirmed' if c200 > 3 * c16 else 'NOT confirmed'})")

    # Fig. 6: MP-domain response to a chirp tracks the MAC response
    x = jnp.asarray(chirp(8192, FS, 100, 7500))[None]
    mac = np.asarray(fb.accumulate(x))[0]
    fb_mp = FilterBank(cfg._replace(mode="mp", gamma_f=4.0))
    mp_ = np.asarray(fb_mp.accumulate(x))[0]
    corr = float(np.corrcoef(mac, mp_)[0, 1])
    row("fig6.mp_vs_mac_chirp_corr", None,
        f"corr={corr:.3f} (distortion present but structure preserved)")
    return corr


if __name__ == "__main__":
    main()
