"""Streaming-kernel shape sweep: slot_block x chunk length x capacity.

Times one jitted session step through the stateful Pallas streaming
kernels — float (``kernels.fir_mp_stream``) and integer
(``kernels.fir_mp_stream_q``) — across slot tiles (``block_s``), chunk
lengths, and session capacities (S in {64, 256} for the full run; the
ROADMAP's >=1.5x streams/sec target is stated at S=256). Rows land in the
``BENCH_pipeline.json`` trajectory like every other benchmark, so shape
regressions are visible across PRs, and ``--update-table`` persists each
(kernel, capacity) winner into the committed autotune table
(``src/repro/kernels/stream_shapes.json``) that ``ops.fir_mp_stream`` /
``ops.fir_mp_stream_q`` consult by default — re-tuning on real TPU
hardware is one command plus a one-line JSON diff.

Shape choice never changes VALUES (``block_s`` only tiles the
row-independent slot axis), so the sweep needs no parity checks — those
live in tests/test_streaming_parity.py. Off-TPU the kernels run in
interpret mode: CPU numbers track wiring overhead, not the VMEM-residency
win.

    PYTHONPATH=src python -m benchmarks.kernel_sweep [--smoke]
        [--update-table]

Emits ``name,us_per_call,derived`` CSV rows like every other benchmark.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.configs.esc10_mp import make_pipeline
from repro.core import fixed
from repro.kernels import fir_mp_stream, fir_mp_stream_q
from repro.kernels import stream_shapes


def _sweep_float(pipe, S, chunks, blocks, iters):
    """us per session step for each (chunk, block_s); returns
    {block_s: total_us} for the winner pick."""
    cfg = pipe.config
    totals: dict[int, float] = {}
    for ch in chunks:
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((S, ch)).astype(np.float32))
        n = jnp.full((S,), ch, jnp.int32)
        st = pipe.init_session(S)
        for bs in blocks:
            us = time_fn(
                lambda bs=bs, x=x, n=n, st=st: fir_mp_stream(
                    x, n, st.delays, st.consumed, st.acc, st.amax,
                    pipe.bp_taps, pipe.lp_taps, cfg.gamma_f,
                    solver=cfg.solver, block_s=bs),
                warmup=1, iters=iters)
            row(f"kernel_sweep.fir_mp_stream.S{S}xC{ch}.bs{bs}", us,
                f"{S / us * 1e6:.0f} chunks/s")
            totals[bs] = totals.get(bs, 0.0) + us
    return totals


def _sweep_int(pipe, S, chunks, blocks, iters):
    prog = pipe.fixed_program()
    totals: dict[int, float] = {}
    for ch in chunks:
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((S, ch)).astype(np.float32))
        xq = fixed.quantize_signal(prog, x)
        n = jnp.full((S,), ch, jnp.int32)
        st = pipe.init_session(S)
        for bs in blocks:
            # the program lowers host-side: jit a closure over it (the
            # same shape the server's donated fixed step uses)
            step = jax.jit(lambda q, nn, d, co, a, am, bs=bs:
                           fir_mp_stream_q(prog, q, nn, d, co, a, am,
                                           block_s=bs))
            us = time_fn(
                lambda: step(xq, n, st.delays, st.consumed, st.acc,
                             st.amax),
                warmup=1, iters=iters)
            row(f"kernel_sweep.fir_mp_stream_q.S{S}xC{ch}.bs{bs}", us,
                f"{S / us * 1e6:.0f} chunks/s")
            totals[bs] = totals.get(bs, 0.0) + us
    return totals


def main(argv=()):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI bit-rot checks")
    ap.add_argument("--update-table", action="store_true",
                    help="persist each (kernel, capacity) winner into the "
                         "committed autotune table "
                         "(src/repro/kernels/stream_shapes.json)")
    args = ap.parse_args(argv)
    if args.smoke:
        caps, blocks, chunks, iters = (8,), (4, 8), (40,), 2
    else:
        caps, blocks, chunks, iters = (64, 256), (4, 8, 16, 32), \
            (40, 160), 3

    pipe_f = make_pipeline(smoke=True, stream_impl="pallas")
    pipe_q = make_pipeline(smoke=True, stream_impl="pallas",
                           numerics="fixed", fixed_amax=4.0)
    winners: dict[str, dict[str, int]] = {"fir_mp_stream": {},
                                          "fir_mp_stream_q": {}}
    for S in caps:
        bl = [b for b in blocks if b <= S] or [min(blocks)]
        for kernel, sweep, pipe in [
                ("fir_mp_stream", _sweep_float, pipe_f),
                ("fir_mp_stream_q", _sweep_int, pipe_q)]:
            totals = sweep(pipe, S, chunks, bl, iters)
            best = min(totals, key=totals.get)
            winners[kernel][str(S)] = best
            row(f"kernel_sweep.best.{kernel}.S{S}", None,
                f"block_s={best} (min total us over chunk lengths "
                f"{list(chunks)})")

    if args.update_table:
        current = stream_shapes.table()
        merged = {k: dict(current.get(k, {})) for k in
                  set(current) | set(winners)}
        for k, ent in winners.items():
            merged[k].update(ent)
        with open(stream_shapes.TABLE_PATH, "w") as f:
            json.dump(merged, f, indent=1, sort_keys=True)
            f.write("\n")
        stream_shapes.table.cache_clear()
        row("kernel_sweep.table_updated", None,
            f"wrote {stream_shapes.TABLE_PATH}")


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
