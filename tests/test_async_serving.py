"""Async feed pipeline + routing tier contracts (PR 9).

What this file pins down:

* **submit/poll/drain semantics**: tickets resolve only at drain points,
  in request order, with ``feed()`` itself being submit+drain (one code
  path, parity by construction).
* **Coalescing bitwise parity**: many small ``submit()`` batches resolved
  by ONE ``drain()`` produce decisions and registers bit-for-bit equal to
  a single synchronous ``feed()`` of the concatenated requests — for BOTH
  numerics modes and BOTH stream impls. Wave composition differs between
  the paths (that is the whole point of coalescing); equality holds
  because the slot-batched step is row-parallel and zero-padding is
  inert.
* **Churn property**: random open/feed/evict/reopen lifecycles driven
  through the async path track a synchronous single-caller server
  register-exactly.
* **Watermark/deadline dispatch** and poisoned-state visibility through
  ``stats()``.
* **StreamRouter**: sharded serving is bitwise the single-server story,
  request order survives shard merging, backpressure errors name the
  shard, stats aggregate.

Randomization uses the hypothesis-or-fallback sampler in ``conftest.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st

from repro.core import kernel_machine as km
from repro.core.filterbank import FilterBank, FilterBankConfig
from repro.core.pipeline import InFilterPipeline
from repro.serving import StreamRouter, StreamServer, make_batched_step

pytestmark = pytest.mark.pallas

_BASE = dict(fs=8000.0, num_octaves=3, filters_per_octave=2, bp_taps=8,
             lp_taps=4, mode="mp", gamma_f=4.0)

_PIPES: dict = {}
_STEPS: dict = {}


def _pipe(numerics="float", stream_impl="xla"):
    key = (numerics, stream_impl)
    if key not in _PIPES:
        kw = dict(_BASE, stream_impl=stream_impl)
        if numerics == "fixed":
            kw.update(numerics="fixed", fixed_amax=3.0)
        cfg = FilterBankConfig(**kw)
        fb = FilterBank(cfg)
        P = cfg.num_filters
        clf = km.init_params(jax.random.PRNGKey(0), P, 4)
        mu = jax.random.normal(jax.random.PRNGKey(1), (P,)) * 0.1 + 1.0
        sigma = jnp.abs(jax.random.normal(jax.random.PRNGKey(2),
                                          (P,))) + 0.5
        _PIPES[key] = InFilterPipeline(cfg, fb.bp_by_octave, fb.lp_filters,
                                       mu, sigma, clf)
        # ONE compiled step per (numerics, impl) for the whole module —
        # fixed numerics jits a fresh closure per make_batched_step, so
        # sharing it is what keeps this file inside the compile budget
        _STEPS[key] = make_batched_step(_PIPES[key])
    return _PIPES[key]


def _server(numerics="float", stream_impl="xla", **kw):
    p = _pipe(numerics, stream_impl)
    kw.setdefault("max_chunk", 64)
    kw.setdefault("min_chunk", 16)
    kw.setdefault("capacity", 4)
    return StreamServer(p, step_fn=_STEPS[(numerics, stream_impl)], **kw)


_LENS = [5, 16, 33, 64, 100]    # buckets 16/32/64 (+ splits past 64)


def _results_key(results):
    return [(r.session_id, r.label, r.confidence, r.samples_seen)
            for r in results]


def _assert_state_bitwise(sa, sb, msg):
    for la, lb in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=msg)


# ---------------------------------------------------------------------------
# submit / poll / drain semantics
# ---------------------------------------------------------------------------


def test_submit_poll_drain_semantics():
    srv = _server()
    srv.open("a")
    srv.open("b")
    rng = np.random.default_rng(0)
    t1 = srv.submit([("a", rng.standard_normal(33).astype(np.float32))])
    t2 = srv.feed_async([("b", rng.standard_normal(16).astype(np.float32)),
                         ("a", rng.standard_normal(5).astype(np.float32))])
    assert not t1.done and not t2.done
    assert srv.poll(t1) is None                 # nothing dispatched yet
    assert srv.stats()["queued_requests"] == 3
    srv.drain()
    assert t1.done and t2.done
    assert [r.session_id for r in t2.results] == ["b", "a"]
    assert t2.results[1].samples_seen == 33 + 5  # a's submits in order
    assert srv.poll(t2) == t2.results           # poll after done: results
    assert srv.stats()["queued_requests"] == 0
    assert srv.stats()["unresolved_requests"] == 0
    # empty submit resolves immediately
    t0 = srv.submit([])
    assert t0.done and t0.results == []


def test_feed_is_submit_plus_drain_and_validates_atomically():
    srv = _server()
    srv.open("a")
    ok = np.zeros(16, np.float32)
    with pytest.raises(KeyError, match=r"session 'ghost' is not open"):
        srv.submit([("a", ok), ("ghost", ok)])
    with pytest.raises(ValueError, match="1-D"):
        srv.submit([("a", np.zeros((2, 16), np.float32))])
    with pytest.raises(ValueError, match="empty chunk"):
        srv.submit([("a", np.zeros(0, np.float32))])
    # failed validation enqueued NOTHING
    assert srv.stats()["queued_requests"] == 0
    res = srv.feed([("a", ok)])
    assert _results_key(res) == _results_key(srv.feed([("a", ok)])[:1]) \
        or res[0].samples_seen == 16


def test_watermark_dispatches_on_submit():
    srv = _server(coalesce_watermark=2)
    srv.open("a")
    srv.open("b")
    x = np.ones(16, np.float32)
    srv.submit([("a", x)])
    assert srv.stats()["queued_requests"] == 1      # below watermark
    assert srv.stats()["steps_run"] == 0
    t = srv.submit([("b", x)])
    assert srv.stats()["queued_requests"] == 0      # watermark hit
    assert srv.stats()["steps_run"] >= 1            # wave launched
    assert not t.done                               # readback deferred
    srv.drain()
    assert t.done


def test_deadline_dispatches_on_poll():
    srv = _server(coalesce_deadline=0.0)            # expires immediately
    srv.open("a")
    t = srv.submit([("a", np.ones(16, np.float32))])
    # deadline is cooperative: the next poll() dispatches, then resolves
    # once the device is done — bounded spin, no background thread
    for _ in range(1000):
        if srv.poll(t) is not None:
            break
    else:
        srv.drain()
    assert t.done
    assert t.results[0].samples_seen == 16


def test_lifecycle_calls_flush_the_queue(tmp_path):
    srv = _server(checkpoint_dir=str(tmp_path))
    srv.open("a")
    rng = np.random.default_rng(1)
    x = rng.standard_normal(40).astype(np.float32)
    t = srv.submit([("a", x)])
    srv.close("a", checkpoint=True)     # must absorb the queued feed
    assert t.done
    assert t.results[0].samples_seen == 40
    srv.open("a")                       # and the parked registers saw it
    assert srv.session("a").samples_seen == 40


# ---------------------------------------------------------------------------
# coalescing bitwise parity: async(submits)+drain == sync feed(concat)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("numerics,impl", [
    ("float", "xla"), ("float", "pallas"),
    ("fixed", "xla"), ("fixed", "pallas"),
])
@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10 ** 9))
def test_async_coalescing_bitwise_matches_sync_feed(numerics, impl, seed):
    rng = np.random.default_rng(seed)
    ids = ["a", "b", "c"]
    reqs = []
    for _ in range(int(rng.integers(3, 9))):
        sid = ids[int(rng.integers(len(ids)))]
        ln = int(rng.choice(_LENS))
        reqs.append((sid, rng.standard_normal(ln).astype(np.float32)))

    srv_sync = _server(numerics, impl)
    srv_async = _server(numerics, impl)
    for srv in (srv_sync, srv_async):
        for sid in ids:
            srv.open(sid)
    res_sync = srv_sync.feed(reqs)

    # random split into k submit batches, ONE drain — different wave
    # composition than the sync path, same bits demanded
    tickets, i = [], 0
    while i < len(reqs):
        k = int(rng.integers(1, len(reqs) - i + 1))
        tickets.append(srv_async.submit(reqs[i:i + k]))
        i += k
    srv_async.drain()
    res_async = [r for t in tickets for r in t.results]

    assert _results_key(res_sync) == _results_key(res_async), \
        f"seed={seed} {numerics}/{impl}"
    _assert_state_bitwise(srv_sync.state, srv_async.state,
                          f"seed={seed} {numerics}/{impl}: registers")


# ---------------------------------------------------------------------------
# churn property: async path vs sync single-caller, register-exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("numerics,impl", [
    ("float", "xla"), ("float", "pallas"),
    ("fixed", "xla"), ("fixed", "pallas"),
])
@settings(max_examples=2, deadline=None)
@given(st.integers(0, 10 ** 9))
def test_async_churn_register_exact_vs_sync(numerics, impl, tmp_path, seed):
    rng = np.random.default_rng(seed)
    ids = [f"s{i}" for i in range(4)]
    srv_sync = _server(numerics, impl, capacity=3,
                       checkpoint_dir=str(tmp_path / "sync"))
    srv_async = _server(numerics, impl, capacity=3,
                        checkpoint_dir=str(tmp_path / "async"))
    open_set: set = set()
    tickets, expected = [], []

    for _ in range(25):
        op = rng.choice(["open", "feed", "evict", "close"],
                        p=[0.3, 0.45, 0.15, 0.1])
        sid = ids[int(rng.integers(len(ids)))]
        if op == "open" and sid not in open_set and len(open_set) < 3:
            srv_sync.open(sid)
            srv_async.open(sid)
            open_set.add(sid)
        elif op == "feed" and open_set:
            pool = sorted(open_set)
            batch = [(pool[int(rng.integers(len(pool)))],
                      rng.standard_normal(
                          int(rng.choice(_LENS))).astype(np.float32))
                     for _ in range(int(rng.integers(1, 4)))]
            expected.append(srv_sync.feed(batch))       # sync: immediate
            tickets.append(srv_async.submit(batch))     # async: queued
            if rng.random() < 0.4:
                srv_async.drain()
        elif op == "evict" and sid in open_set:
            srv_sync.evict(sid)
            srv_async.evict(sid)    # flushes srv_async's queue first
            open_set.discard(sid)
        elif op == "close" and sid in open_set:
            srv_sync.close(sid)
            srv_async.close(sid)
            open_set.discard(sid)
    srv_async.drain()

    for exp, t in zip(expected, tickets):
        assert t.done
        assert _results_key(exp) == _results_key(t.results), f"seed={seed}"
    _assert_state_bitwise(srv_sync.state, srv_async.state,
                          f"seed={seed} {numerics}/{impl}: churn registers")


# ---------------------------------------------------------------------------
# stats: async depth + poisoned visibility
# ---------------------------------------------------------------------------


def test_stats_surface_async_depth_and_bucket_totals():
    srv = _server()
    srv.open("a")
    srv.feed([("a", np.zeros(16, np.float32))])
    srv.feed([("a", np.zeros(33, np.float32))])
    s = srv.stats()
    assert s["poisoned"] is None
    assert s["bucket_steps_total"] == sum(s["buckets"].values()) >= 2
    assert abs(sum(s["bucket_hit_rate"].values()) - 1.0) < 1e-6
    assert s["queued_requests"] == 0
    assert s["inflight_waves"] == 0


def test_stats_surface_poisoned_string():
    srv = _server()
    srv.open("a")

    def bad_step(p, state, chunk, valid):
        raise RuntimeError("boom")

    srv._step = bad_step
    with pytest.raises(RuntimeError):
        srv.feed([("a", np.zeros(16, np.float32))])
    s = srv.stats()     # stats() must NOT raise on a poisoned server
    assert isinstance(s["poisoned"], str) and "wave 1" in s["poisoned"]


# ---------------------------------------------------------------------------
# routing tier
# ---------------------------------------------------------------------------


def test_router_bitwise_matches_single_server(tmp_path):
    pipe = _pipe()
    rng = np.random.default_rng(7)
    ids = [f"mic-{i:02d}" for i in range(8)]
    reqs = [(sid, rng.standard_normal(
        int(rng.choice(_LENS))).astype(np.float32)) for sid in ids]
    router = StreamRouter(pipe, num_shards=3, capacity=8,
                          checkpoint_dir=str(tmp_path),
                          step_fn=_STEPS[("float", "xla")],
                          max_chunk=64, min_chunk=16)
    single = _server(capacity=8)
    for sid in ids:
        router.open(sid)
        single.open(sid)
    res_r = router.feed(reqs)
    res_s = single.feed(reqs)
    assert _results_key(res_r) == _results_key(res_s)
    # shard mapping is stable and total residency is the sum
    assert all(router.shard_of(sid) == router.shard_of(sid) for sid in ids)
    st_ = router.stats()
    assert st_["resident"] == 8
    assert st_["poisoned"] is None
    assert len(st_["shards"]) == 3


def test_router_async_request_order_across_shards(tmp_path):
    router = StreamRouter(_pipe(), num_shards=2, capacity=8,
                          checkpoint_dir=str(tmp_path),
                          step_fn=_STEPS[("float", "xla")],
                          max_chunk=64, min_chunk=16)
    rng = np.random.default_rng(3)
    ids = [f"m{i}" for i in range(6)]
    for sid in ids:
        router.open(sid)
    # interleave shards in the request list; results must come back in
    # the ORIGINAL order, not shard-major
    order = [ids[i] for i in rng.permutation(len(ids))]
    reqs = [(sid, rng.standard_normal(16).astype(np.float32))
            for sid in order]
    t = router.submit(reqs)
    assert router.poll(t) is None
    router.drain()
    assert [r.session_id for r in t.results] == order
    t_empty = router.submit([])
    assert t_empty.done and t_empty.results == []


def test_router_churn_reopen_finds_shard_checkpoint(tmp_path):
    router = StreamRouter(_pipe(), num_shards=3, capacity=4,
                          checkpoint_dir=str(tmp_path),
                          step_fn=_STEPS[("float", "xla")],
                          max_chunk=64, min_chunk=16)
    rng = np.random.default_rng(5)
    router.open("edge-7")
    x = rng.standard_normal(100).astype(np.float32)
    r1 = router.feed([("edge-7", x[:64])])[0]
    router.evict("edge-7")
    assert not router.is_open("edge-7")
    router.open("edge-7")               # restored from its shard's store
    assert router.session("edge-7").samples_seen == 64
    r2 = router.feed([("edge-7", x[64:])])[0]
    # reference: uninterrupted single server
    srv = _server(capacity=2)
    srv.open("edge-7")
    q1 = srv.feed([("edge-7", x[:64])])[0]
    q2 = srv.feed([("edge-7", x[64:])])[0]
    assert _results_key([r1, r2]) == _results_key([q1, q2])


def test_router_backpressure_names_shard(tmp_path):
    router = StreamRouter(_pipe(), num_shards=2, capacity=1,
                          step_fn=_STEPS[("float", "xla")],
                          max_chunk=64, min_chunk=16)
    # find two ids on the same shard; no checkpoint_dir -> second open
    # must raise naming that shard
    by_shard: dict = {}
    for i in range(32):
        by_shard.setdefault(router.shard_of(f"x{i}"), []).append(f"x{i}")
    k, pair = next((k, v) for k, v in by_shard.items() if len(v) >= 2)
    router.open(pair[0])
    with pytest.raises(RuntimeError, match=rf"shard {k}: .*capacity"):
        router.open(pair[1])


def test_router_rejects_bad_config():
    with pytest.raises(ValueError, match="num_shards"):
        StreamRouter(_pipe(), num_shards=0)
