"""Property-based chunking-parity harness for the streaming session step.

The deployment contract this suite pins down (ISSUE 3 / ROADMAP "Pallas
streaming kernel"):

* **Streaming == one-shot**: feeding a signal through ``apply(x, state)`` in
  ANY chunk partition — including length-0 and length-1 chunks, per-slot
  valid counts, and interleaved slot lifecycles — yields the same decisions
  as one-shot ``apply(x)`` to f32 round-off (identical FIR windows and MP
  solves; only cross-chunk accumulator addition order differs).
* **Pallas == XLA, bit-for-bit**: the stateful ``fir_mp_stream`` kernel
  (``stream_impl="pallas"``) and the XLA session step agree EXACTLY in
  interpret mode — same solver math on the same window values, same blocked
  HWR reduction order — for every register in the ``SessionState``, not just
  the decisions.
* **Single chunk == one-shot, bit-for-bit**: with the whole signal in one
  call, both streaming impls reproduce the one-shot accumulate exactly
  (shared ``hwr_accumulate`` blocking).
* **Fixed-point streaming == one-shot, bit-for-bit, ANY chunking** (PR 5):
  with ``numerics="fixed"`` the int32 session step must land on EXACTLY
  the one-shot integer program's codes — registers and decisions gate with
  ``==`` from the first chunk (static ADC grid, associative integer adds;
  docs/numerics.md).
* **Int Pallas == int XLA == one-shot, bit-for-bit** (PR 6): with
  ``numerics="fixed"`` + ``stream_impl="pallas"`` the VMEM-resident
  integer kernel (``fir_mp_stream_q``) must track the int XLA session step
  register-for-register under random chunkings and slot lifecycles, and
  land on the one-shot program exactly — the same ``==`` gate, through the
  jitted step and the StreamServer.

Randomization comes through the hypothesis-or-fallback sampler in
``conftest.py``: each example draws one seed; numpy generates audio, chunk
partitions, and slot schedules from it, so the harness runs identically
with or without hypothesis installed.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st

from repro.core import kernel_machine as km
from repro.core.filterbank import FilterBank, FilterBankConfig
from repro.core.pipeline import InFilterPipeline, set_active

pytestmark = pytest.mark.pallas

# small bank, short taps: T1 = 7 keeps delay lines tight so length-1 chunks
# and phase flips get real coverage without hiding behind long histories
_BASE = dict(fs=8000.0, num_octaves=3, filters_per_octave=2, bp_taps=8,
             lp_taps=4, mode="mp", gamma_f=4.0)


def _make_pipelines(**cfg_over):
    """One trained-shape pipeline per stream_impl, sharing taps/weights."""
    kw = dict(_BASE)
    kw.update(cfg_over)
    cfg = FilterBankConfig(**kw)
    fb = FilterBank(cfg)
    P = cfg.num_filters
    clf = km.init_params(jax.random.PRNGKey(0), P, 4)
    mu = jax.random.normal(jax.random.PRNGKey(1), (P,)) * 0.1 + 1.0
    sigma = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (P,))) + 0.5
    pipes = {}
    for impl in ("xla", "pallas"):
        pipes[impl] = InFilterPipeline(
            cfg._replace(stream_impl=impl), fb.bp_by_octave, fb.lp_filters,
            mu, sigma, clf)
    return pipes["xla"], pipes["pallas"]


_PIPES = {}

# one jitted apply for the whole suite: the pipeline rides along as a pytree
# (config is static aux data), so each (impl, config, chunk-shape) variant
# compiles once and is reused across property examples — the same retrace
# bounding the serving layer gets from pow2 chunk buckets
_APP = jax.jit(InFilterPipeline.apply)


def _pipes(**cfg_over):
    key = tuple(sorted(cfg_over.items()))
    if key not in _PIPES:
        _PIPES[key] = _make_pipelines(**cfg_over)
    return _PIPES[key]


# Chunk lengths are drawn from a fixed menu: every distinct (S, L) retraces
# the jitted kernel wrapper (exactly like serving's pow2 buckets bound
# retraces in production), so an unbounded draw would spend the whole suite
# compiling. The menu still covers the edge cases that matter: empty calls,
# single samples, odd lengths (decimator phase flips), and multi-block
# lengths (129 > two 64-blocks; 513 spills into a second 512-block upstream).
_LEN_MENU = [0, 1, 3, 8, 13, 32, 77, 129]


def _partition(rng, max_chunks=6):
    """Random chunk-length sequence from the menu; returns (lens, total).
    Always includes at least one 0- and one 1-length chunk."""
    k = int(rng.integers(1, max_chunks + 1))
    lens = [int(rng.choice(_LEN_MENU)) for _ in range(k)] + [0, 1]
    rng.shuffle(lens)
    if sum(lens) == 0:
        lens.append(int(rng.choice(_LEN_MENU[4:])))
    return lens, sum(lens)


def _assert_states_bitwise(sa, sb, msg):
    for name, a, b in zip(sa._fields, sa, sb):
        for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(
                np.asarray(la), np.asarray(lb),
                err_msg=f"{msg}: SessionState.{name} diverged")


# ---------------------------------------------------------------------------
# the core property: random chunkings
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10 ** 9))
def test_random_chunking_stream_matches_one_shot_and_pallas_matches_xla(seed):
    rng = np.random.default_rng(seed)
    px, pk = _pipes()
    S = 2
    lens, n = _partition(rng)
    x = jnp.asarray(rng.standard_normal((S, n)).astype(np.float32))
    p_one = _APP(px, x)

    sx, sk = px.init_session(S), pk.init_session(S)
    p_x = p_k = None
    off = 0
    for ln in lens:
        ch = x[:, off:off + ln]
        off += ln
        p_x, sx = _APP(px, ch, sx)
        p_k, sk = _APP(pk, ch, sk)
        np.testing.assert_array_equal(
            np.asarray(p_x), np.asarray(p_k),
            err_msg=f"seed={seed}: xla/pallas decisions diverged at {off}")
    _assert_states_bitwise(sx, sk, f"seed={seed}")
    np.testing.assert_allclose(np.asarray(p_x), np.asarray(p_one),
                               atol=1e-4,
                               err_msg=f"seed={seed}: stream vs one-shot")
    assert int(sx.count[0]) == n


@pytest.mark.parametrize("solver", ["newton", "bisect"])
@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10 ** 9))
def test_solver_choices_agree_bitwise(solver, seed):
    """Both fixed-iteration solvers route through both impls identically."""
    rng = np.random.default_rng(seed)
    px, pk = _pipes(solver=solver)
    lens, n = _partition(rng, max_chunks=3)
    x = jnp.asarray(rng.standard_normal((2, n)).astype(np.float32))
    sx, sk = px.init_session(2), pk.init_session(2)
    off = 0
    for ln in lens:
        ch = x[:, off:off + ln]
        off += ln
        p_x, sx = _APP(px, ch, sx)
        p_k, sk = _APP(pk, ch, sk)
        np.testing.assert_array_equal(np.asarray(p_x), np.asarray(p_k))
    _assert_states_bitwise(sx, sk, f"seed={seed} solver={solver}")


# ---------------------------------------------------------------------------
# slot lifecycles: open / feed / close in random orders
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10 ** 9))
def test_random_slot_lifecycles_parity(seed):
    """S slots on random open/feed/close schedules with per-slot valid
    counts: every slot's final decision matches its dedicated one-shot run,
    and the two impls track each other bit-for-bit throughout."""
    rng = np.random.default_rng(seed)
    px, pk = _pipes()
    S = 3
    total = [int(rng.integers(40, 200)) for _ in range(S)]
    audio = [rng.standard_normal(t).astype(np.float32) for t in total]
    fed = [0] * S
    opened = [False] * S
    closed = [False] * S

    sx, sk = px.init_session(S), pk.init_session(S)
    sx = set_active(sx, jnp.arange(S), False)
    sk = set_active(sk, jnp.arange(S), False)
    last_p = [None] * S

    for _ in range(25):
        slot = int(rng.integers(S))
        if not opened[slot]:
            opened[slot] = True
            sx = set_active(sx, jnp.asarray([slot]), True)
            sk = set_active(sk, jnp.asarray([slot]), True)
            continue
        if closed[slot]:
            continue
        take = min(int(rng.choice(_LEN_MENU)), total[slot] - fed[slot])
        # pad bucket: smallest menu length covering `take` (valid counts are
        # traced values; only the chunk SHAPE keys a retrace)
        L = min((l for l in _LEN_MENU if l >= max(take, 1)),
                default=_LEN_MENU[-1])
        chunk = np.zeros((S, L), np.float32)
        # non-fed rows carry garbage that the valid mask must neutralize
        chunk[:] = rng.standard_normal((S, L)) * 50.0
        chunk[slot, :take] = audio[slot][fed[slot]:fed[slot] + take]
        valid = np.zeros((S,), np.int32)
        valid[slot] = take
        fed[slot] += take
        p_x, sx = _APP(px, jnp.asarray(chunk), sx, valid=jnp.asarray(valid))
        p_k, sk = _APP(pk, jnp.asarray(chunk), sk, valid=jnp.asarray(valid))
        np.testing.assert_array_equal(np.asarray(p_x), np.asarray(p_k),
                                      err_msg=f"seed={seed}")
        last_p[slot] = np.asarray(p_x[slot])
        if fed[slot] == total[slot]:
            closed[slot] = True
            sx = set_active(sx, jnp.asarray([slot]), False)
            sk = set_active(sk, jnp.asarray([slot]), False)

    _assert_states_bitwise(sx, sk, f"seed={seed}")
    for s in range(S):
        if not closed[s]:
            continue
        ref = np.asarray(_APP(px, jnp.asarray(audio[s])[None]))[0]
        np.testing.assert_allclose(last_p[s], ref, atol=1e-4,
                                   err_msg=f"seed={seed} slot={s}")


# ---------------------------------------------------------------------------
# bit-for-bit single-chunk and quantized deployment
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 100, 513, 1200])
def test_single_chunk_is_bitwise_one_shot_both_impls(n):
    """Whole signal in ONE session call == one-shot predict, bit-for-bit,
    through either impl (the shared blocked HWR reduction order)."""
    px, pk = _pipes()
    x = jax.random.normal(jax.random.PRNGKey(n), (2, n))
    p_one = np.asarray(_APP(px, x))
    for pipe in (px, pk):
        p, state = _APP(pipe, x, pipe.init_session(2))
        np.testing.assert_array_equal(np.asarray(p), p_one)
        assert int(state.count[0]) == n


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10 ** 9))
def test_quantized_streaming_parity_pallas(seed):
    """Quantized deployment: running-amax semantics are identical across
    impls (bitwise), and with a seeded calibration amax the stream matches
    one-shot to f32 round-off."""
    rng = np.random.default_rng(seed)
    px, pk = _pipes(quant_bits=8)
    lens, n = _partition(rng, max_chunks=4)
    x = rng.standard_normal((2, n)).astype(np.float32)
    x[:, 0] = 3.5                    # a known global peak
    x = jnp.asarray(x)
    p_one = _APP(px, x)
    amax0 = jnp.max(jnp.abs(x), axis=-1)
    sx = px.init_session(2, amax=amax0)
    sk = pk.init_session(2, amax=amax0)
    off = 0
    p_x = p_k = None
    for ln in lens:
        ch = x[:, off:off + ln]
        off += ln
        p_x, sx = _APP(px, ch, sx)
        p_k, sk = _APP(pk, ch, sk)
        np.testing.assert_array_equal(np.asarray(p_x), np.asarray(p_k))
    _assert_states_bitwise(sx, sk, f"seed={seed}")
    np.testing.assert_allclose(np.asarray(p_k), np.asarray(p_one), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(sk.amax), np.asarray(amax0))


# ---------------------------------------------------------------------------
# edges: inert slots, zero-length calls, jit, mac guard
# ---------------------------------------------------------------------------


def test_masked_slots_inert_under_jit_pallas():
    """Garbage rows behind active=False / valid=0 leave every register
    bit-identical through the Pallas kernel, under jit."""
    _, pk = _pipes()
    app = jax.jit(InFilterPipeline.apply)
    state = pk.init_session(4)
    state = set_active(state, jnp.asarray([1, 3]), False)
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 300)) * 100.0
    valid = jnp.asarray([300, 123, 0, 7], jnp.int32)   # 1, 3 inert anyway
    p, state2 = app(pk, x, state, valid=valid)
    # 1, 3: inactive; 2: active but zero valid — all must be bit-identical
    idle = np.asarray([1, 2, 3])
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
        np.testing.assert_array_equal(np.asarray(a)[idle],
                                      np.asarray(b)[idle])


def test_zero_length_chunk_is_pure_readout():
    """A (S, 0) chunk moves no registers and reads out the current
    decision — identically for both impls."""
    px, pk = _pipes()
    x = jax.random.normal(jax.random.PRNGKey(11), (2, 150))
    for pipe in (px, pk):
        state = pipe.init_session(2)
        p1, state = _APP(pipe, x, state)
        p0, state2 = _APP(pipe, jnp.zeros((2, 0)), state)
        np.testing.assert_array_equal(np.asarray(p0), np.asarray(p1))
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_jitted_session_step_matches_eager_pallas():
    _, pk = _pipes()
    x = jax.random.normal(jax.random.PRNGKey(13), (2, 257))
    app = jax.jit(InFilterPipeline.apply)
    p_e, s_e = pk.apply(x, pk.init_session(2))
    p_j, s_j = app(pk, x, pk.init_session(2))
    np.testing.assert_array_equal(np.asarray(p_e), np.asarray(p_j))
    _assert_states_bitwise(s_e, s_j, "jit vs eager")


def test_mac_mode_rejects_pallas_stream_impl():
    px, _ = _pipes()
    cfg = px.config._replace(mode="mac", stream_impl="pallas")
    fb = FilterBank(cfg)
    pipe = InFilterPipeline(cfg, fb.bp_by_octave, fb.lp_filters,
                            px.mu, px.sigma, px.clf)
    with pytest.raises(ValueError, match="pallas"):
        pipe.apply(jnp.zeros((2, 64)), pipe.init_session(2))


# ---------------------------------------------------------------------------
# fixed-point (int32) session streaming: EXACT equality, not allclose —
# the ADC grid is static and integer addition is associative, so any chunk
# partition must reproduce the one-shot integer program bit-for-bit from
# the FIRST chunk (no peak-seen caveat, unlike quant_bits float streaming)
# ---------------------------------------------------------------------------


_FIXED_PIPES = {}


def _fixed_pipe(**cfg_over):
    """A numerics='fixed' pipeline + its closure-jitted session step (the
    program lowers host-side, so the pipeline must NOT ride along as a
    traced pytree the way _APP passes it)."""
    key = tuple(sorted(cfg_over.items()))
    if key not in _FIXED_PIPES:
        kw = dict(_BASE, numerics="fixed", fixed_amax=3.0)
        kw.update(cfg_over)
        cfg = FilterBankConfig(**kw)
        fb = FilterBank(cfg)
        P = cfg.num_filters
        clf = km.init_params(jax.random.PRNGKey(0), P, 4)
        mu = jax.random.normal(jax.random.PRNGKey(1), (P,)) * 0.1 + 1.0
        sigma = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (P,))) + 0.5
        pipe = InFilterPipeline(cfg, fb.bp_by_octave, fb.lp_filters,
                                mu, sigma, clf)
        app = jax.jit(lambda st, ch, v: pipe.apply(ch, st, valid=v))
        _FIXED_PIPES[key] = (pipe, app)
    return _FIXED_PIPES[key]


@pytest.mark.parametrize("mode", ["mp", "mac"])
@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10 ** 9))
def test_fixed_random_chunking_is_bitwise_one_shot(mode, seed):
    """Random chunk partitions through the int32 session step reproduce the
    one-shot integer program EXACTLY: decisions, features, and the 32-bit
    accumulator registers all gate with ==, from the first chunk."""
    from repro.core import fixed

    rng = np.random.default_rng(seed)
    pipe, app = _fixed_pipe(mode=mode)
    prog = pipe.fixed_program()
    S = 2
    lens, n = _partition(rng)
    x = jnp.asarray(rng.standard_normal((S, n)).astype(np.float32))
    p_q, phi_q, s_q = fixed.infer_q(prog, fixed.quantize_signal(prog, x))
    p_one = prog.out_spec.dequantize(p_q)

    state = pipe.init_session(S)
    assert state.acc.dtype == jnp.int32
    assert all(d.dtype == jnp.int32 for d in state.delays)
    p_s = None
    off = 0
    for ln in lens:
        ch = x[:, off:off + ln]
        off += ln
        p_s, state = app(state, ch, jnp.full((S,), ln, jnp.int32))
    np.testing.assert_array_equal(np.asarray(state.acc), np.asarray(s_q),
                                  err_msg=f"seed={seed}: acc registers")
    np.testing.assert_array_equal(np.asarray(p_s), np.asarray(p_one),
                                  err_msg=f"seed={seed}: decisions")
    assert int(state.count[0]) == n


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10 ** 9))
def test_fixed_slot_lifecycles_bitwise(seed):
    """Random open/feed/close lifecycles with per-slot valid counts: every
    completed slot's decision equals its dedicated one-shot integer run
    EXACTLY, and garbage in non-fed rows never perturbs a register."""
    rng = np.random.default_rng(seed)
    pipe, app = _fixed_pipe()
    S = 3
    total = [int(rng.integers(40, 200)) for _ in range(S)]
    audio = [rng.standard_normal(t).astype(np.float32) for t in total]
    fed = [0] * S
    state = pipe.init_session(S)
    last_p = [None] * S
    for _ in range(20):
        slot = int(rng.integers(S))
        take = min(int(rng.choice(_LEN_MENU)), total[slot] - fed[slot])
        L = min((l for l in _LEN_MENU if l >= max(take, 1)),
                default=_LEN_MENU[-1])
        chunk = (rng.standard_normal((S, L)) * 50.0).astype(np.float32)
        chunk[slot, :take] = audio[slot][fed[slot]:fed[slot] + take]
        valid = np.zeros((S,), np.int32)
        valid[slot] = take
        fed[slot] += take
        p, state = app(state, jnp.asarray(chunk), jnp.asarray(valid))
        last_p[slot] = np.asarray(p[slot])
    for s in range(S):
        if fed[s] != total[s]:
            continue
        ref = np.asarray(pipe.apply(jnp.asarray(audio[s])[None]))[0]
        np.testing.assert_array_equal(last_p[s], ref,
                                      err_msg=f"seed={seed} slot={s}")


def test_fixed_zero_length_chunk_is_pure_readout():
    pipe, app = _fixed_pipe()
    x = jax.random.normal(jax.random.PRNGKey(11), (2, 150))
    state = pipe.init_session(2)
    p1, state = app(state, x, jnp.full((2,), 150, jnp.int32))
    p0, state2 = app(state, jnp.zeros((2, 0)), jnp.zeros((2,), jnp.int32))
    np.testing.assert_array_equal(np.asarray(p0), np.asarray(p1))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10 ** 9))
def test_fixed_pallas_random_chunking_bitwise_matches_xla_and_one_shot(seed):
    """The int Pallas streaming kernel under random chunk partitions: every
    SessionState register tracks the int XLA step EXACTLY chunk-by-chunk,
    and the final registers/decisions equal the one-shot integer program —
    all gates are ==, in jit, in interpret mode on CPU."""
    from repro.core import fixed

    rng = np.random.default_rng(seed)
    px, appx = _fixed_pipe()
    pk, appk = _fixed_pipe(stream_impl="pallas")
    prog = px.fixed_program()
    S = 2
    lens, n = _partition(rng)
    x = jnp.asarray(rng.standard_normal((S, n)).astype(np.float32))
    p_q, _, s_q = fixed.infer_q(prog, fixed.quantize_signal(prog, x))
    p_one = prog.out_spec.dequantize(p_q)

    sx, sk = px.init_session(S), pk.init_session(S)
    p_x = p_k = None
    off = 0
    for ln in lens:
        ch = x[:, off:off + ln]
        off += ln
        v = jnp.full((S,), ln, jnp.int32)
        p_x, sx = appx(sx, ch, v)
        p_k, sk = appk(sk, ch, v)
        np.testing.assert_array_equal(
            np.asarray(p_x), np.asarray(p_k),
            err_msg=f"seed={seed}: int xla/pallas decisions diverged "
                    f"at {off}")
    _assert_states_bitwise(sx, sk, f"seed={seed} (fixed)")
    np.testing.assert_array_equal(np.asarray(sk.acc), np.asarray(s_q),
                                  err_msg=f"seed={seed}: acc vs one-shot")
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_one),
                                  err_msg=f"seed={seed}: decision vs "
                                          "one-shot")


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10 ** 9))
def test_fixed_pallas_slot_lifecycles_bitwise(seed):
    """Slot surgery through the int Pallas kernel: random open/feed/close
    schedules with garbage in non-fed rows — registers track the int XLA
    step exactly and completed slots equal their one-shot run."""
    rng = np.random.default_rng(seed)
    px, appx = _fixed_pipe()
    pk, appk = _fixed_pipe(stream_impl="pallas")
    S = 3
    total = [int(rng.integers(40, 200)) for _ in range(S)]
    audio = [rng.standard_normal(t).astype(np.float32) for t in total]
    fed = [0] * S
    sx, sk = px.init_session(S), pk.init_session(S)
    last_p = [None] * S
    for _ in range(15):
        slot = int(rng.integers(S))
        take = min(int(rng.choice(_LEN_MENU)), total[slot] - fed[slot])
        L = min((l for l in _LEN_MENU if l >= max(take, 1)),
                default=_LEN_MENU[-1])
        chunk = (rng.standard_normal((S, L)) * 50.0).astype(np.float32)
        chunk[slot, :take] = audio[slot][fed[slot]:fed[slot] + take]
        valid = np.zeros((S,), np.int32)
        valid[slot] = take
        fed[slot] += take
        p_x, sx = appx(sx, jnp.asarray(chunk), jnp.asarray(valid))
        p_k, sk = appk(sk, jnp.asarray(chunk), jnp.asarray(valid))
        np.testing.assert_array_equal(np.asarray(p_x), np.asarray(p_k),
                                      err_msg=f"seed={seed}")
        last_p[slot] = np.asarray(p_k[slot])
    _assert_states_bitwise(sx, sk, f"seed={seed} (fixed lifecycles)")
    for s in range(S):
        if fed[s] != total[s]:
            continue
        ref = np.asarray(pk.apply(jnp.asarray(audio[s])[None]))[0]
        np.testing.assert_array_equal(last_p[s], ref,
                                      err_msg=f"seed={seed} slot={s}")


def test_fixed_stream_server_end_to_end(tmp_path):
    """StreamServer serves numerics='fixed': open/feed/split/evict/reopen,
    with the final decision per stream equal (exactly — same codes, same
    dequantization) to one-shot inference on the concatenated audio, and
    the int32 registers round-tripping the named-checkpoint store."""
    from repro.serving import StreamServer

    pipe, _ = _fixed_pipe()
    rng = np.random.default_rng(9)
    xa = rng.standard_normal(700).astype(np.float32)
    xb = rng.standard_normal(420).astype(np.float32)
    srv = StreamServer(pipe, capacity=2, max_chunk=256,
                       checkpoint_dir=str(tmp_path))
    assert srv.stats()["numerics"] == "fixed"
    assert srv.state.acc.dtype == jnp.int32
    srv.open("a")
    srv.open("b")
    out = []
    out += srv.feed([("a", xa[:300]), ("b", xb[:33])])
    out += srv.feed([("b", xb[33:420]), ("a", xa[300:301])])
    srv.evict("a")                      # parks int32 registers on disk
    srv.open("a")                       # restores them dtype-checked
    out += srv.feed([("a", xa[301:700])])
    final = {r.session_id: (r.label, r.confidence) for r in out}
    for sid, x in (("a", xa), ("b", xb)):
        p = np.asarray(pipe.apply(jnp.asarray(x)[None]))[0]
        assert final[sid] == (int(p.argmax()), float(p.max())), sid


def test_fixed_server_pallas_end_to_end_bitwise(tmp_path):
    """StreamServer serves numerics='fixed' + stream_impl='pallas'
    end-to-end (open/feed/split/evict/reopen): every result — label,
    confidence, samples_seen — and the final int32 registers equal the
    fixed XLA server's exactly."""
    from repro.serving import StreamServer

    rng = np.random.default_rng(9)
    xa = rng.standard_normal(700).astype(np.float32)
    xb = rng.standard_normal(420).astype(np.float32)
    results, accs = [], []
    for impl in ("xla", "pallas"):
        pipe, _ = _fixed_pipe() if impl == "xla" \
            else _fixed_pipe(stream_impl=impl)
        srv = StreamServer(pipe, capacity=2, max_chunk=256,
                           checkpoint_dir=str(tmp_path / impl))
        assert srv.stats()["numerics"] == "fixed"
        srv.open("a")
        srv.open("b")
        out = []
        out += srv.feed([("a", xa[:300]), ("b", xb[:33])])
        out += srv.feed([("b", xb[33:420]), ("a", xa[300:301])])
        srv.evict("a")                  # parks int32 registers on disk
        srv.open("a")                   # restores them dtype-checked
        out += srv.feed([("a", xa[301:700])])
        results.append([(r.session_id, r.label, r.confidence,
                         r.samples_seen) for r in out])
        accs.append(np.asarray(srv.state.acc))
    assert results[0] == results[1]
    np.testing.assert_array_equal(accs[0], accs[1])


def test_fixed_server_async_submit_drain_bitwise(tmp_path):
    """The async feed pipeline (PR 9) through the fixed-numerics server,
    BOTH stream impls: submits coalesced across evict/reopen churn and
    resolved by one drain() must equal the synchronous feed() path
    bit-for-bit — results AND the int32 registers."""
    from repro.serving import StreamServer, make_batched_step

    rng = np.random.default_rng(11)
    xa = rng.standard_normal(500).astype(np.float32)
    xb = rng.standard_normal(300).astype(np.float32)
    for impl in ("xla", "pallas"):
        pipe, _ = _fixed_pipe() if impl == "xla" \
            else _fixed_pipe(stream_impl=impl)
        step = make_batched_step(pipe)
        outs, accs = [], []
        for use_async in (False, True):
            srv = StreamServer(pipe, capacity=2, max_chunk=256,
                               checkpoint_dir=str(
                                   tmp_path / f"{impl}-{use_async}"),
                               step_fn=step)
            srv.open("a")
            srv.open("b")
            out = []
            if use_async:
                t1 = srv.submit([("a", xa[:300]), ("b", xb[:33])])
                t2 = srv.submit([("b", xb[33:200])])
                srv.drain()
                srv.evict("a")          # parks registers incl. queued work
                srv.open("a")
                t3 = srv.submit([("a", xa[300:500]),
                                 ("b", xb[200:300])])
                srv.drain()
                for t in (t1, t2, t3):
                    assert t.done
                    out += t.results
            else:
                out += srv.feed([("a", xa[:300]), ("b", xb[:33])])
                out += srv.feed([("b", xb[33:200])])
                srv.evict("a")
                srv.open("a")
                out += srv.feed([("a", xa[300:500]), ("b", xb[200:300])])
            outs.append([(r.session_id, r.label, r.confidence,
                          r.samples_seen) for r in out])
            accs.append(np.asarray(srv.state.acc))
        assert outs[0] == outs[1], impl
        np.testing.assert_array_equal(accs[0], accs[1],
                                      err_msg=f"{impl}: async registers")


def test_stream_server_pallas_bitwise_matches_xla_server(tmp_path):
    """End-to-end through StreamServer: open/feed/split/evict/reopen with
    the kernel hot path tracks the XLA server bit-for-bit."""
    from repro.serving import StreamServer

    px, pk = _pipes()
    rng = np.random.default_rng(5)
    xa = rng.standard_normal(700).astype(np.float32)
    xb = rng.standard_normal(420).astype(np.float32)
    results = []
    for pipe in (px, pk):
        srv = StreamServer(pipe, capacity=2, max_chunk=256,
                           checkpoint_dir=str(tmp_path / pipe.config.stream_impl))
        srv.open("a")
        srv.open("b")
        out = []
        out += srv.feed([("a", xa[:300]), ("b", xb[:33])])
        out += srv.feed([("b", xb[33:420]), ("a", xa[300:301])])
        srv.evict("a")
        srv.open("a")                    # restore from checkpoint
        out += srv.feed([("a", xa[301:700])])
        results.append([(r.session_id, r.label, r.confidence,
                         r.samples_seen) for r in out])
    assert results[0] == results[1]
