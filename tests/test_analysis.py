"""The static-analysis framework (repro.analysis): op-legality /
census-compat edge cases, the worst-case interval pass (including a
deliberately-seeded overflow it must reject by name), the determinism
lint, and the standard targets' int32-safety proof on a reduced config."""

import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    Interval,
    analyze_intervals,
    census,
    check_legality,
    lint_determinism,
    literal_pow2_multiplicand,
)
from repro.analysis.legality import assert_legal


# ---------------------------------------------------------------------------
# pow2-literal classification (the fixed _literal_pow2 semantics)
# ---------------------------------------------------------------------------


def test_pow2_literal_mul_counts_as_shift():
    c = census(lambda x: x * 4.0, jnp.zeros((8,), jnp.float32))
    assert c["shift"] == 8 and c["multiply"] == 0


def test_non_pow2_literal_mul_is_a_multiply():
    c = census(lambda x: x * 3.0, jnp.zeros((8,), jnp.float32))
    assert c["multiply"] == 8 and c["shift"] == 0


def _literal(val):
    from jax._src import core
    arr = np.asarray(val)
    return core.Literal(arr, core.get_aval(arr))


def test_mixed_pow2_array_literal_is_not_a_shift():
    """The pre-refactor classifier looked at the FIRST element only: a
    [4.0, 3.0] multiplier would have been miscounted as a pure shift."""
    eqn = types.SimpleNamespace(
        primitive=types.SimpleNamespace(name="mul"),
        invars=[_literal([4.0, 3.0]), types.SimpleNamespace()])
    assert not literal_pow2_multiplicand(eqn)
    eqn.invars[0] = _literal([4.0, 2.0])  # all-pow2 vector IS a shift bank
    assert literal_pow2_multiplicand(eqn)


def test_two_literal_operands_are_not_a_shift():
    """'Exactly one literal operand' — with both operands literal there is
    no runtime multiplicand for a shifter to act on."""
    eqn = types.SimpleNamespace(
        primitive=types.SimpleNamespace(name="mul"),
        invars=[_literal(4.0), _literal(8.0)])
    assert not literal_pow2_multiplicand(eqn)


def test_zero_literal_is_not_a_shift():
    eqn = types.SimpleNamespace(
        primitive=types.SimpleNamespace(name="mul"),
        invars=[_literal(0.0), types.SimpleNamespace()])
    assert not literal_pow2_multiplicand(eqn)


def test_legality_names_the_offending_mul():
    jx = jax.make_jaxpr(lambda x: x * x)(jnp.zeros((4,), jnp.int32))
    r = check_legality(jx)
    assert not r.ok
    assert r.violations[0].primitive == "mul"
    with pytest.raises(AssertionError, match="mul"):
        assert_legal(jx, "test")


# ---------------------------------------------------------------------------
# grid-product scaling inside pallas_call
# ---------------------------------------------------------------------------


def test_census_scales_by_pallas_grid_product():
    from repro.kernels.fir_mp import fir_mp_bank_q_pallas

    def bank(b):
        # batch is a static shape: close over it so the census traces a
        # (b, N) program with grid (b // block_b, F)
        def run():
            x = jnp.zeros((b, 64), jnp.int32)
            h = jnp.ones((2, 8), jnp.int32)
            return fir_mp_bank_q_pallas(x, h, gamma_q=4, iters=5, qmin=-512,
                                        qmax=511, block_b=8, interpret=True)
        return run

    c8 = census(bank(8))    # grid (1, F)
    c16 = census(bank(16))  # grid (2, F): per-block kernel ops run twice
    assert c8["add"] > 0
    assert c16["add"] == 2 * c8["add"]
    assert c16["compare"] == 2 * c8["compare"]


# ---------------------------------------------------------------------------
# interval pass: arithmetic, seeded overflow, zero-length chunks
# ---------------------------------------------------------------------------


def test_interval_arithmetic_is_tight():
    def f(x):
        return (x << 2) + x - jnp.max(x)
    jx = jax.make_jaxpr(f)(jnp.zeros((4,), jnp.int32))
    r = analyze_intervals(jx, [Interval(-128, 127)])
    assert r.ok
    # x<<2 in [-512, 508]; +x -> [-640, 635]; -max(x) -> [-767, 763]
    assert r.out_intervals[0] == Interval(-767, 763)
    assert r.min_headroom_bits == 21  # 32 - 11 bits required


def test_interval_pass_rejects_seeded_overflow_by_name():
    """(q << 24) + (q << 24) with q in [-128, 127] peaks at 2^32 — one bit
    past int32. The violation must name the offending add."""
    def f(q):
        return (q << 24) + (q << 24)
    jx = jax.make_jaxpr(f)(jnp.zeros((4,), jnp.int32))
    r = analyze_intervals(jx, [Interval(-128, 127)])
    assert not r.ok
    v = r.violations[0]
    assert v.primitive == "add"
    assert v.required_bits == 33
    assert "add@" in v.name  # named eqn with source location


def test_interval_pass_rejects_overflowing_program_variant():
    """Program-level seeding: widen one octave's accumulator shift in a
    compiled program until the interval pass must reject the register."""
    import dataclasses

    from repro.analysis.targets import _fixed_pipeline, _signal_iv

    pipe = _fixed_pipeline(True)
    prog = pipe.fixed_program()
    from repro.core import fixed
    st0 = prog.bank.octaves[0]
    bank = dataclasses.replace(
        prog.bank,
        octaves=(dataclasses.replace(st0, acc_shift=st0.acc_shift + 24),)
        + prog.bank.octaves[1:])
    bad_prog = dataclasses.replace(prog, bank=bank)
    n = 1600
    jx = jax.make_jaxpr(
        lambda q: fixed.infer_q(bad_prog, q))(jnp.zeros((1, n), jnp.int32))
    r = analyze_intervals(jx, [_signal_iv(prog)])
    assert not r.ok
    assert any(v.primitive in ("shift_left", "add") for v in r.violations)


def test_zero_length_chunk_jaxpr_analyzes_clean():
    """L == 0 session step is the pure-readout path; the analysis must
    traverse it (no FIR eqns, no crash, no violations)."""
    from repro.analysis import report as rp
    from repro.analysis.targets import (_fixed_pipeline, _session_inputs,
                                        session_envelope)
    from repro.core import fixed

    pipe = _fixed_pipeline(True)
    prog = pipe.fixed_program()
    state = pipe.init_session(1)
    chunk = jnp.zeros((1, 0), jnp.int32)
    nv = jnp.zeros((1,), jnp.int32)
    jx = jax.make_jaxpr(
        lambda st, q, v: fixed.session_step_q(prog, st, q, v))(
            state, chunk, nv)
    env = session_envelope(prog, 1600)
    ivs = _session_inputs(prog, state, 0, env["acc_interval"])
    r = analyze_intervals(jx, ivs)
    assert r.ok, r.violations
    c = census(lambda st, q, v: fixed.session_step_q(prog, st, q, v),
               state, chunk, nv)
    assert c["multiply"] == 0
    t = types.SimpleNamespace(name="zero_chunk", jaxpr=jx, numerics="fixed",
                              n_samples=1, in_intervals=ivs,
                              assumptions={}, gate=True)
    assert rp.target_ok(rp.analyze_target(t))


def test_zero_length_scan_keeps_initial_carry():
    """length=0 must NOT analyze one body iteration: the true carry out is
    the initial carry (a step(init) result like [1000, 1005] would exclude
    every real output — unsound, not just loose)."""
    def f(c):
        out, _ = jax.lax.scan(lambda c, _: (c + 1000, c), c, None, length=0)
        return out
    jx = jax.make_jaxpr(f)(jnp.zeros((), jnp.int32))
    r = analyze_intervals(jx, [Interval(0, 5)])
    assert r.ok
    assert r.out_intervals[0] == Interval(0, 5)
    # census: the body executes zero times, so it contributes zero ops
    assert census(f, jnp.zeros((), jnp.int32))["add"] == 0


def test_pallas_fixpoint_nonconvergence_widens_to_top():
    """A grid past grid_unroll_limit whose ref state never stabilizes in
    fixpoint_iters must widen to TOP and FAIL — exiting with the partial
    state would certify e.g. [1, 64] for a 8192-step accumulator and claim
    'PROVEN int32-safe' for an overflowing program."""
    from jax.experimental import pallas as pl

    def k(o_ref):
        @pl.when(pl.program_id(0) == 0)
        def _():
            o_ref[...] = jnp.zeros_like(o_ref)
        o_ref[...] += 1

    jx = jax.make_jaxpr(
        lambda: pl.pallas_call(
            k, out_shape=jax.ShapeDtypeStruct((8,), jnp.int32),
            grid=(8192,), interpret=True)())()
    r = analyze_intervals(jx, [])
    assert not r.ok
    assert r.out_intervals[0].hi == float("inf")


def test_pallas_fixpoint_convergent_large_grid_stays_tight():
    """The widening fallback must only fire on non-convergence: a
    per-block copy kernel over the same huge grid stabilizes immediately
    and keeps the input bound."""
    from jax.experimental import pallas as pl

    def k(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    jx = jax.make_jaxpr(
        lambda x: pl.pallas_call(
            k, out_shape=jax.ShapeDtypeStruct((8192, 8), jnp.int32),
            grid=(8192,),
            in_specs=[pl.BlockSpec((1, 8), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((1, 8), lambda i: (i, 0)),
            interpret=True)(x))(jnp.zeros((8192, 8), jnp.int32))
    r = analyze_intervals(jx, [Interval(-128, 127)])
    assert r.ok
    assert r.out_intervals[0] == Interval(-128, 127)


class _Var:
    """Hashable jaxpr-var stand-in (SimpleNamespace defines __eq__ and so
    can't key the interpreter's env dict)."""

    def __init__(self, aval=None):
        self.aval = aval


def _swap_eqn(outvars):
    return types.SimpleNamespace(
        primitive=types.SimpleNamespace(name="swap"),
        invars=[_Var(), _Var()], outvars=outvars, params={"tree": None})


def test_swap_of_unwritten_ref_flags_read_before_write():
    """swap whose old value is USED must report the same read-before-write
    violation as get and return the dtype range, not the newly written
    value (optimistic)."""
    from jax._src import core
    from repro.analysis.intervals import RefCell, _Analyzer, _dtype_range

    a = _Analyzer()
    cell = RefCell((8,), np.int32, None)
    eqn = _swap_eqn([_Var(core.ShapedArray((8,), np.int32))])
    env = {eqn.invars[0]: cell, eqn.invars[1]: Interval(5, 5)}
    out = a._eval_swap(eqn, env, "t")
    assert out == _dtype_range(np.int32)
    assert len(a.violations) == 1
    assert "(read-before-write)" in a.violations[0].name


def test_first_store_to_unwritten_ref_is_clean():
    """Plain stores lower to swap with a DropVar result: the first write
    to an output/scratch ref reads nothing and must not be flagged."""
    from jax._src import core
    from repro.analysis.intervals import RefCell, _Analyzer

    a = _Analyzer()
    cell = RefCell((8,), np.int32, None)
    eqn = _swap_eqn([core.DropVar(core.ShapedArray((8,), np.int32))])
    env = {eqn.invars[0]: cell, eqn.invars[1]: Interval(5, 5)}
    assert a._eval_swap(eqn, env, "t") == Interval(5, 5)
    assert not a.violations
    assert cell.hull() == Interval(5, 5)


def test_unsigned_registers_use_unsigned_carrier_bits():
    """uint32 holding [0, 2^32-1] needs 32 unsigned bits (headroom 0), not
    the 33 two's-complement bits that would distort the report with
    negative headroom for a value that fits."""
    from repro.analysis.intervals import INF, carrier_bits, signed_bits

    full = Interval(2**31, 2**32 - 1)
    assert signed_bits(full) == 33
    assert carrier_bits(full, unsigned=True) == 32
    assert carrier_bits(Interval(-1, 3), unsigned=True) == INF

    jx = jax.make_jaxpr(lambda x: x + jnp.uint32(0))(
        jnp.zeros((4,), jnp.uint32))
    r = analyze_intervals(jx, [Interval(0, 2**32 - 1)])
    assert r.ok
    assert r.max_required_bits == 32
    assert r.min_headroom_bits == 0


# ---------------------------------------------------------------------------
# determinism lint
# ---------------------------------------------------------------------------


def test_float_reduce_sum_is_flagged_as_free_tree():
    jx = jax.make_jaxpr(lambda x: jnp.sum(x))(jnp.zeros((16,), jnp.float32))
    r = lint_determinism(jx, numerics="float")
    assert any(f.kind == "free_tree_reduction" and f.primitive == "reduce_sum"
               for f in r.findings)
    assert r.ok  # informational on the float path


def test_integer_reduce_sum_is_exact_and_clean():
    jx = jax.make_jaxpr(lambda x: jnp.sum(x))(jnp.zeros((16,), jnp.int32))
    r = lint_determinism(jx, numerics="fixed")
    assert r.ok and not r.findings


def test_fixed_tree_sum_is_clean():
    from repro.core import mp
    jx = jax.make_jaxpr(mp.tree_sum)(jnp.zeros((2, 16), jnp.float32))
    r = lint_determinism(jx, numerics="float")
    assert not r.findings


def test_float_op_in_fixed_program_gates():
    jx = jax.make_jaxpr(
        lambda x: (x.astype(jnp.float32) * 0.5).astype(jnp.int32))(
            jnp.zeros((4,), jnp.int32))
    r = lint_determinism(jx, numerics="fixed")
    assert not r.ok
    assert any(f.kind == "float_in_fixed" for f in r.findings)


# ---------------------------------------------------------------------------
# the deployed programs, proven on the reduced config (the full config is
# the scripts/analyze.py tier-1 gate)
# ---------------------------------------------------------------------------


def test_smoke_targets_prove_int32_safe():
    from repro.analysis import report as rp
    from repro.analysis.targets import build_targets

    targets, meta = build_targets(smoke=True)
    names = {t.name for t in targets}
    assert {"oneshot_q", "oneshot_q_pallas", "session_step_q",
            "stream_pallas"} <= names
    report = rp.build_report(targets, meta, top_registers=5)
    assert report["ok"], report
    for name in ("oneshot_q", "session_step_q", "stream_pallas"):
        s = report["targets"][name]
        assert s["legality"]["ok"]
        assert s["intervals"]["ok"]
        assert s["intervals"]["min_headroom_bits"] >= 0
        assert s["determinism"]["ok"]
        # every register was actually bounded (no TOP escapes)
        assert s["intervals"]["max_required_bits"] is not None
    assert meta["max_safe_session_samples"] > meta["envelope_samples"]


def test_census_smoke_numbers_pinned():
    """The refactor onto the shared traversal must not move the committed
    benchmark numbers: pin the smoke-config fixed one-shot census exactly
    (verified identical to the pre-refactor walk when the refactor landed).
    Also exercises the compat re-export surface in benchmarks."""
    from benchmarks.hardware_cost import assert_multiplierless
    from repro.analysis.targets import _fixed_pipeline
    from repro.core import fixed

    pipe = _fixed_pipeline(True)
    prog = pipe.fixed_program()
    c = census(lambda q: fixed.infer_q(prog, q),
               jnp.zeros((1, 1600), jnp.int32))
    assert_multiplierless(c, "pin")
    assert c["add"] == 21_277_335
    assert c["compare"] == 10_726_792
    assert c["shift"] == 311_366
