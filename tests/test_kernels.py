"""Pallas kernel validation: shape/dtype sweeps, interpret mode (CPU)
against the pure-jnp oracles in repro.kernels.ref."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (fir_mp, fir_mp_accumulate, mp_linear, mp_waterfill)
from repro.kernels import ref

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 3e-2}


@pytest.mark.parametrize("rows,m", [(1, 8), (7, 100), (64, 128), (33, 257),
                                    (256, 31), (300, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mp_waterfill_sweep(rows, m, dtype):
    key = jax.random.PRNGKey(rows * 1000 + m)
    L = (jax.random.normal(key, (rows, m)) * 3).astype(dtype)
    gamma = 2.0
    z = mp_waterfill(L, gamma)
    zr = ref.mp_waterfill_ref(L.astype(jnp.float32), gamma)
    np.testing.assert_allclose(np.asarray(z, np.float32), np.asarray(zr),
                               atol=ATOL[dtype], rtol=ATOL[dtype])


def test_mp_waterfill_batched_shape():
    L = jax.random.normal(jax.random.PRNGKey(0), (3, 5, 40))
    z = mp_waterfill(L, 1.0)
    assert z.shape == (3, 5)
    zr = ref.mp_waterfill_ref(L, 1.0)
    np.testing.assert_allclose(np.asarray(z), np.asarray(zr), atol=2e-5)


@pytest.mark.parametrize("B,d,O", [(1, 16, 8), (5, 64, 37), (8, 128, 128),
                                   (13, 1024, 10), (3, 256, 200)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_mp_linear_sweep(B, d, O, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(B * 100 + O))
    x = (jax.random.normal(k1, (B, d)) * 0.5).astype(dtype)
    w = (jax.random.normal(k2, (d, O)) * 0.5).astype(dtype)
    y = mp_linear(x, w, 1.5)
    yr = ref.mp_linear_ref(x, w, 1.5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)


def test_mp_linear_gradients_match_exact_path():
    from repro.core.mp import mp_linear as exact_linear
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 32)) * 0.3
    w = jax.random.normal(jax.random.PRNGKey(2), (32, 12)) * 0.3
    g1 = jax.grad(lambda x, w: mp_linear(x, w, 1.0).sum(), (0, 1))(x, w)
    g2 = jax.grad(lambda x, w: exact_linear(x, w, 1.0).sum(), (0, 1))(x, w)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_mp_linear_leading_batch_dims():
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 3, 16))
    w = jax.random.normal(jax.random.PRNGKey(4), (16, 5))
    y = mp_linear(x, w, 1.0)
    assert y.shape == (2, 3, 5)
    yr = ref.mp_linear_ref(x.reshape(6, 16), w, 1.0).reshape(2, 3, 5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)


@pytest.mark.parametrize("B,N,M", [(1, 64, 4), (4, 300, 16), (8, 128, 6),
                                   (2, 500, 15)])
def test_fir_mp_sweep(B, N, M):
    k1, k2 = jax.random.split(jax.random.PRNGKey(B + N + M))
    x = jax.random.normal(k1, (B, N))
    h = jax.random.normal(k2, (M,)) * 0.3
    y = fir_mp(x, h, 2.0)
    yr = ref.fir_mp_ref(x, h, 2.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)


@pytest.mark.parametrize("B,N,M", [(4, 300, 16), (8, 100, 6)])
def test_fir_mp_accumulate_fused(B, N, M):
    """The fused FIR+HWR+accumulate readout (the paper's s_p) matches the
    compositional reference, including the padded-tail masking."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(k1, (B, N))
    h = jax.random.normal(k2, (M,)) * 0.3
    s = fir_mp_accumulate(x, h, 2.0)
    sr = ref.fir_mp_accumulate_ref(x, h, 2.0)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=1e-5, atol=1e-3)


def test_fir_kernel_matches_filterbank_path():
    """kernels.fir_mp == core.filterbank MP filtering (use_pallas flag)."""
    from repro.core.filterbank import FilterBank, FilterBankConfig
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 256))
    cfg_a = FilterBankConfig(fs=4000, num_octaves=2, mode="mp",
                             use_pallas=False)
    cfg_b = cfg_a._replace(use_pallas=True)
    sa = FilterBank(cfg_a).accumulate(x)
    sb = FilterBank(cfg_b).accumulate(x)
    np.testing.assert_allclose(np.asarray(sa), np.asarray(sb),
                               rtol=1e-3, atol=1e-2)
