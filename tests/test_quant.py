"""Direct coverage for core/quant.py: round-trip properties, clamp
saturation, the STE gradient, degenerate spec handling, and the
power-of-two spec builder the fixed-point twin is built on."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import (FixedPointSpec, QuantSpec, dequantize,
                              fake_quant, pow2_spec_for, quantize, spec_for)


class TestRoundTrip:
    def test_quantize_dequantize_idempotent(self):
        x = jnp.asarray(np.random.default_rng(0).standard_normal(256),
                        jnp.float32) * 3.0
        spec = spec_for(x, 8)
        q = quantize(x, spec)
        q2 = quantize(dequantize(q, spec), spec)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))

    def test_quantized_values_are_integers_in_range(self):
        x = jnp.linspace(-5.0, 5.0, 101)
        spec = spec_for(x, 6)
        q = np.asarray(quantize(x, spec))
        np.testing.assert_array_equal(q, np.round(q))
        assert q.min() >= spec.qmin and q.max() <= spec.qmax

    def test_clamp_saturates_at_qmin_qmax(self):
        spec = QuantSpec(bits=8, scale=0.1)
        q = np.asarray(quantize(jnp.asarray([1e6, -1e6]), spec))
        assert q[0] == spec.qmax == 127
        assert q[1] == spec.qmin == -128

    def test_fixed_point_spec_round_trip(self):
        x = jnp.asarray(np.random.default_rng(1).standard_normal(128),
                        jnp.float32)
        spec = pow2_spec_for(x, 8)
        q = spec.quantize(x)
        q2 = spec.quantize(spec.dequantize(q))
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
        # pow2 dequantization is EXACT: q * 2^exp has no rounding
        deq = np.asarray(spec.dequantize(q))
        np.testing.assert_array_equal(
            deq, np.asarray(q, np.float64) * spec.scale)


class TestSTE:
    def test_fake_quant_gradient_passes_through_in_range(self):
        x = jnp.asarray([-0.7, -0.2, 0.1, 0.65])
        g = jax.grad(lambda v: jnp.sum(fake_quant(v, 8, amax=1.0)))(x)
        np.testing.assert_allclose(np.asarray(g), 1.0, atol=1e-6)

    def test_fake_quant_gradient_zero_when_clipped(self):
        x = jnp.asarray([3.0, -4.0])  # far beyond amax=1.0 -> clipped
        g = jax.grad(lambda v: jnp.sum(fake_quant(v, 8, amax=1.0)))(x)
        np.testing.assert_allclose(np.asarray(g), 0.0, atol=1e-6)

    def test_fake_quant_forward_is_quantized(self):
        x = jnp.asarray(np.random.default_rng(2).standard_normal(64),
                        jnp.float32)
        y = np.asarray(fake_quant(x, 4))
        assert len(np.unique(y)) <= 16  # 4 bits -> at most 16 levels


class TestSpecForEdges:
    def test_all_zero_tensor(self):
        spec = spec_for(jnp.zeros((8,)), 8)
        assert spec.scale == pytest.approx(1.0 / 127)
        assert np.asarray(quantize(jnp.zeros((8,)), spec)).max() == 0

    def test_empty_tensor(self):
        spec = spec_for(jnp.zeros((0,)), 8)
        assert spec.scale == pytest.approx(1.0 / 127)

    def test_single_value_hits_qmax(self):
        spec = spec_for(jnp.asarray([2.5]), 8)
        assert np.asarray(quantize(jnp.asarray([2.5]), spec))[0] == 127

    def test_nonfinite_raises(self):
        with pytest.raises(ValueError, match="non-finite"):
            spec_for(jnp.asarray([1.0, jnp.inf]), 8)
        with pytest.raises(ValueError, match="non-finite"):
            spec_for(jnp.asarray([jnp.nan]), 8)

    def test_bad_bits_raises(self):
        with pytest.raises(ValueError, match="bits"):
            spec_for(jnp.ones((4,)), 1)
        with pytest.raises(ValueError, match="bits"):
            pow2_spec_for(jnp.ones((4,)), 0)


class TestPow2Spec:
    def test_scale_is_power_of_two_and_covers(self):
        rng = np.random.default_rng(3)
        for _ in range(20):
            amax = float(10.0 ** rng.uniform(-4, 4))
            spec = pow2_spec_for(None, 8, amax=amax)
            assert spec.scale == math.ldexp(1.0, spec.exp)
            assert spec.qmax * spec.scale >= amax           # covers
            assert spec.qmax * (spec.scale / 2) < amax      # minimal

    def test_from_tensor(self):
        x = jnp.asarray([0.1, -0.9, 0.4])
        spec = pow2_spec_for(x, 8)
        assert spec.amax >= 0.9
        frac, _ = math.frexp(spec.scale)
        assert frac == 0.5  # a pure power of two

    def test_degenerate_tensors(self):
        assert pow2_spec_for(jnp.zeros((4,)), 8) == \
            pow2_spec_for(None, 8, amax=1.0)
        assert pow2_spec_for(jnp.zeros((0,)), 8) == \
            pow2_spec_for(None, 8, amax=1.0)

    def test_exact_pow2_amax(self):
        # amax already on the grid: qmax * 2^exp must still cover it
        spec = pow2_spec_for(None, 8, amax=2.0)
        assert spec.qmax * spec.scale >= 2.0

    def test_bad_amax_raises(self):
        with pytest.raises(ValueError, match="amax"):
            pow2_spec_for(None, 8, amax=0.0)
        with pytest.raises(ValueError, match="amax"):
            pow2_spec_for(None, 8, amax=float("inf"))


def test_fixed_point_spec_fields():
    spec = FixedPointSpec(bits=10, exp=-7)
    assert spec.qmin == -512 and spec.qmax == 511
    assert spec.scale == 2.0 ** -7
    assert spec.amax == 511 * 2.0 ** -7
