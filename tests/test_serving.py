"""Session-oriented streaming API: unified apply(), slot-batched
SessionState (masked-slot inertness, per-slot ages, quantized running-amax
parity), StreamServer lifecycle (open/feed/evict/reopen), chunk bucketing,
and slot-axis sharding specs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernel_machine as km
from repro.core.filterbank import FilterBank, FilterBankConfig
from repro.core.pipeline import (InFilterPipeline, SessionState,
                                 StreamingState, set_active)
from repro.serving import StreamServer, bucket_length


def _pipeline(num_octaves=3, filters_per_octave=3, num_classes=5,
              fs=8000.0, **cfg_over) -> InFilterPipeline:
    kw = dict(mode="mp", gamma_f=4.0)
    kw.update(cfg_over)
    cfg = FilterBankConfig(fs=fs, num_octaves=num_octaves,
                           filters_per_octave=filters_per_octave, **kw)
    fb = FilterBank(cfg)
    P = cfg.num_filters
    clf = km.init_params(jax.random.PRNGKey(0), P, num_classes)
    mu = jax.random.normal(jax.random.PRNGKey(1), (P,)) * 0.1 + 1.0
    sigma = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (P,))) + 0.5
    return InFilterPipeline.from_filterbank(fb, clf, mu, sigma)


@pytest.fixture(scope="module")
def pipe():
    return _pipeline()


# ---------------------------------------------------------------------------
# unified apply()
# ---------------------------------------------------------------------------


def test_apply_stateless_matches_predict(pipe):
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 512))
    np.testing.assert_array_equal(np.asarray(pipe.apply(x)),
                                  np.asarray(pipe.predict(x)))
    p, phi = pipe.apply(x, return_features=True)
    np.testing.assert_array_equal(np.asarray(phi),
                                  np.asarray(pipe.features(x)))


def test_apply_stateful_chunks_match_one_shot(pipe):
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 1500))
    p_one = pipe.predict(x)
    state = pipe.init_session(2)
    p = None
    for i in range(0, 1500, 77):                 # odd chunks + short tail
        p, state = pipe.apply(x[:, i:i + 77], state)
    np.testing.assert_allclose(np.asarray(p), np.asarray(p_one), atol=1e-4)
    assert int(state.count[0]) == 1500
    assert bool(state.active[0])


def test_apply_rejects_legacy_streaming_state(pipe):
    legacy = pipe.init_state(2)
    assert isinstance(legacy, StreamingState)
    with pytest.raises(TypeError, match="SessionState"):
        pipe.apply(jnp.zeros((2, 64)), legacy)


def test_apply_rejects_capacity_mismatch(pipe):
    state = pipe.init_session(4)
    with pytest.raises(ValueError, match="capacity"):
        pipe.apply(jnp.zeros((2, 64)), state)


def test_stream_dtype_mismatch_raises(pipe):
    chunks_ok = [np.zeros((1, 64), np.float32), np.zeros((1, 64), np.float32)]
    pipe.stream(chunks_ok)  # uniform dtype fine
    mixed = [np.zeros((1, 64), np.float32), np.zeros((1, 64), np.float16)]
    with pytest.raises(ValueError, match="dtype"):
        pipe.stream(mixed)
    with pytest.raises(ValueError, match="dtype"):
        pipe.stream([np.zeros((1, 64), np.float16)], dtype=jnp.float32)


# ---------------------------------------------------------------------------
# slot-batched sessions
# ---------------------------------------------------------------------------


def test_interleaved_slots_with_different_ages(pipe):
    """Two streams fed on disjoint schedules (per-slot valid counts and
    decimator phases) each match their dedicated one-shot decision."""
    xa = jax.random.normal(jax.random.PRNGKey(8), (1, 900))
    xb = jax.random.normal(jax.random.PRNGKey(9), (1, 900))
    pa_ref, pb_ref = pipe.predict(xa), pipe.predict(xb)
    state = pipe.init_session(2)
    ia = ib = 0
    p = None
    sched = [(0, 77), (1, 50), (0, 33), (1, 123), (0, 200), (1, 77),
             (0, 90), (1, 200), (0, 500), (1, 450)]
    for slot, ln in sched:
        chunk = np.zeros((2, ln), np.float32)
        v = np.zeros((2,), np.int32)
        if slot == 0:
            take = min(ln, 900 - ia)
            chunk[0, :take] = np.asarray(xa)[0, ia:ia + take]
            v[0] = take
            ia += take
        else:
            take = min(ln, 900 - ib)
            chunk[1, :take] = np.asarray(xb)[0, ib:ib + take]
            v[1] = take
            ib += take
        p, state = pipe.apply(jnp.asarray(chunk), state,
                              valid=jnp.asarray(v))
    assert (ia, ib) == (900, 900)
    np.testing.assert_allclose(np.asarray(p[0]), np.asarray(pa_ref[0]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(p[1]), np.asarray(pb_ref[0]),
                               atol=1e-4)


def test_masked_slots_are_inert_under_jit(pipe):
    """Inactive/zero-valid slots keep BIT-IDENTICAL registers even when
    their chunk rows hold garbage, and never perturb active slots."""
    app = jax.jit(InFilterPipeline.apply)
    state4 = pipe.init_session(4)
    state4 = set_active(state4, jnp.asarray([1, 3]), False)
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 256)) * 100.0
    valid = jnp.asarray([256, 256, 100, 256], jnp.int32)  # 1,3 inert anyway
    p4, state4b = app(pipe, x, state4, valid=valid)
    # active rows equal a dedicated 2-slot session fed the same data
    rows = jnp.asarray([0, 2])
    p2, state2b = app(pipe, x[rows], pipe.init_session(2),
                      valid=valid[rows])
    np.testing.assert_array_equal(np.asarray(p4[rows]), np.asarray(p2))
    for a, b in zip(jax.tree.leaves(state4b._replace(active=None)),
                    jax.tree.leaves(state2b._replace(active=None))):
        np.testing.assert_array_equal(np.asarray(a)[np.asarray(rows)],
                                      np.asarray(b))
    # inactive rows bit-identical before/after
    idle = np.asarray([1, 3])
    for a, b in zip(jax.tree.leaves(state4), jax.tree.leaves(state4b)):
        np.testing.assert_array_equal(np.asarray(a)[idle],
                                      np.asarray(b)[idle])


def test_quantized_streaming_parity():
    """Unlocked by the running amax: with the stream's peak seen up front
    (first chunk, or a seeded calibration amax), quantized chunked apply()
    matches one-shot predict() — the old chunk-local scaling could not."""
    pipe_q = _pipeline(quant_bits=8)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 1200))
    x = x.at[:, 0].set(4.0)          # global amax lands in the first chunk
    p_one = pipe_q.predict(x)
    state = pipe_q.init_session(2)
    p = None
    for i in range(0, 1200, 160):
        p, state = pipe_q.apply(x[:, i:i + 160], state)
    np.testing.assert_allclose(np.asarray(p), np.asarray(p_one), atol=1e-4)
    # whole signal in ONE session chunk: bit-for-bit with one-shot
    p1, _, s1 = pipe_q.apply(x, pipe_q.init_session(2), return_features=True)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p_one))
    # seeded calibration amax equals the converged running amax
    amax = jnp.max(jnp.abs(x), axis=-1)
    st = pipe_q.init_session(2, amax=amax)
    p_c = None
    for i in range(0, 1200, 100):
        p_c, st = pipe_q.apply(x[:, i:i + 100], st)
    np.testing.assert_allclose(np.asarray(p_c), np.asarray(p_one), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(st.amax), np.asarray(amax))


# ---------------------------------------------------------------------------
# StreamServer lifecycle
# ---------------------------------------------------------------------------


def test_server_lifecycle_interleave_evict_reopen(pipe, tmp_path):
    """open -> feed interleaved -> auto-evict on admission pressure ->
    reopen restores from checkpoint -> decisions match dedicated streams."""
    rng = np.random.default_rng(0)
    xa = rng.standard_normal(900).astype(np.float32)
    xb = rng.standard_normal(900).astype(np.float32)
    xc = rng.standard_normal(400).astype(np.float32)
    ref_a = np.asarray(pipe.predict(jnp.asarray(xa)[None]))[0]
    ref_b = np.asarray(pipe.predict(jnp.asarray(xb)[None]))[0]
    t = [0.0]
    srv = StreamServer(pipe, capacity=2, max_chunk=512,
                       checkpoint_dir=str(tmp_path), clock=lambda: t[0])
    srv.open("a")
    srv.open("b")
    srv.feed([("a", xa[:77]), ("b", xb[:300])])
    t[0] += 1.0
    srv.feed([("b", xb[300:333]), ("a", xa[77:777])])  # a: 700 > 512 splits
    t[0] += 1.0
    srv.open("c")                       # full -> evicts LRU (a) to disk
    assert "a" not in {s.id for s in srv.sessions()}
    srv.feed([("c", xc), ("b", xb[333:900])])
    t[0] += 1.0
    srv.close("c")
    srv.open("a")                       # restores registers + history
    assert srv.session("a").samples_seen == 777
    assert len(srv.session("a").history) == 2
    res = srv.feed([("a", xa[777:900])])
    ra = res[0]
    assert ra.samples_seen == 900
    assert ra.label == int(ref_a.argmax())
    np.testing.assert_allclose(ra.confidence, ref_a[ra.label], atol=1e-4)
    db = srv.session("b").last_decision
    assert db.samples_seen == 900
    assert db.label == int(ref_b.argmax())
    np.testing.assert_allclose(db.confidence, ref_b[db.label], atol=1e-4)


def test_server_close_discards_reopen_starts_fresh(pipe, tmp_path):
    rng = np.random.default_rng(1)
    x = rng.standard_normal(500).astype(np.float32)
    srv = StreamServer(pipe, capacity=1, max_chunk=512,
                       checkpoint_dir=str(tmp_path))
    srv.open("s")
    r1 = srv.feed([("s", x)])[0]
    srv.close("s")                       # discard, not checkpoint
    srv.open("s")
    assert srv.session("s").samples_seen == 0
    r2 = srv.feed([("s", x)])[0]
    assert r2.samples_seen == 500
    np.testing.assert_allclose(r2.confidence, r1.confidence, atol=1e-6)


def test_server_capacity_without_checkpoint_raises(pipe):
    srv = StreamServer(pipe, capacity=1)
    srv.open("one")
    with pytest.raises(RuntimeError, match="capacity"):
        srv.open("two")
    with pytest.raises(RuntimeError, match="checkpoint_dir"):
        srv.evict("one")


def test_server_evict_after_protects_busy_sessions(pipe, tmp_path):
    t = [0.0]
    srv = StreamServer(pipe, capacity=1, evict_after=10.0,
                       checkpoint_dir=str(tmp_path), clock=lambda: t[0])
    srv.open("busy")
    srv.feed([("busy", np.zeros(32, np.float32))])
    t[0] = 5.0                           # idle 5 s < evict_after
    with pytest.raises(RuntimeError, match="capacity"):
        srv.open("newcomer")
    t[0] = 50.0                          # now idle long enough
    srv.open("newcomer")
    assert {s.id for s in srv.sessions()} == {"newcomer"}


def test_server_bucketing_bounds_retraces(pipe):
    """Arbitrary packet lengths compile only O(log L) step variants."""
    srv = StreamServer(pipe, capacity=1, min_chunk=16, max_chunk=256)
    srv.open("s")
    rng = np.random.default_rng(2)
    for n in [1, 5, 17, 31, 33, 47, 63, 65, 100, 129, 200, 255, 256]:
        srv.feed([("s", rng.standard_normal(n).astype(np.float32))])
    assert set(srv.bucket_counts) <= {16, 32, 64, 128, 256}
    # a 700-sample packet splits into max_chunk segments, no new bucket
    srv.feed([("s", rng.standard_normal(700).astype(np.float32))])
    assert set(srv.bucket_counts) <= {16, 32, 64, 128, 256}
    assert srv.session("s").samples_seen == sum(
        [1, 5, 17, 31, 33, 47, 63, 65, 100, 129, 200, 255, 256, 700])


def test_bucket_length():
    assert bucket_length(1, 16, 4096) == 16
    assert bucket_length(16, 16, 4096) == 16
    assert bucket_length(17, 16, 4096) == 32
    assert bucket_length(1000, 16, 4096) == 1024
    assert bucket_length(9000, 16, 4096) == 4096  # clamp; caller splits
    with pytest.raises(ValueError):
        bucket_length(0, 16, 4096)


def test_staging_buffer_reuse_no_cross_wave_leak(pipe):
    """The per-bucket staging buffers are REUSED across waves (slot-
    targeted clears, not fresh np.zeros): rows staged for one wave must
    never leak into a later wave that doesn't re-stage them."""
    rng = np.random.default_rng(4)
    xa = rng.standard_normal(64).astype(np.float32)
    xb = rng.standard_normal(64).astype(np.float32)
    xc = rng.standard_normal(40).astype(np.float32)
    srv = StreamServer(pipe, capacity=3, max_chunk=64)
    ref = StreamServer(pipe, capacity=3, max_chunk=64)
    for s in (srv, ref):
        for sid in ("a", "b", "c"):
            s.open(sid)
    srv.feed([("a", xa), ("b", xb)])     # stages rows 0,1 of bucket 64
    # same bucket, different slot: stale a/b rows must be cleared, and
    # c's decision must equal a server where a/b never fed at all
    r1 = srv.feed([("c", xc)])[0]
    r2 = ref.feed([("c", xc)])[0]
    assert (r1.label, r1.confidence, r1.samples_seen) == \
        (r2.label, r2.confidence, r2.samples_seen)
    # and the buffers really were reused: one staging array per flip, per
    # bucket (double-buffered ring), not one per wave
    assert len(srv._staging[64]) == 2


def test_server_feed_order_and_unknown_session(pipe):
    srv = StreamServer(pipe, capacity=2)
    srv.open("a")
    srv.open("b")
    with pytest.raises(KeyError):
        srv.feed([("ghost", np.zeros(16, np.float32))])
    res = srv.feed([("b", np.zeros(16, np.float32)),
                    ("a", np.zeros(16, np.float32))])
    assert [r.session_id for r in res] == ["b", "a"]


# ---------------------------------------------------------------------------
# slot-axis sharding
# ---------------------------------------------------------------------------


def test_session_specs_shard_slot_axis(pipe):
    from jax.sharding import PartitionSpec as P

    from repro.distributed import sharding as sh
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    state = pipe.init_session(4)
    specs = sh.session_specs(state, mesh)
    assert specs.acc == P(("data",), None)
    assert specs.amax == P(("data",))
    for d in specs.delays:
        assert d == P(("data",), None)


def test_server_with_mesh_matches_unsharded(pipe):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(3)
    chunks = [rng.standard_normal(64).astype(np.float32) for _ in range(3)]
    plain = StreamServer(pipe, capacity=2)
    sharded = StreamServer(pipe, capacity=2, mesh=mesh)
    for srv in (plain, sharded):
        srv.open("s")
    for ch in chunks:
        r0 = plain.feed([("s", ch)])[0]
        r1 = sharded.feed([("s", ch)])[0]
        assert r0.label == r1.label
        np.testing.assert_allclose(r0.confidence, r1.confidence, atol=1e-6)
