"""The Verilog backend: netlist emission, the in-repo cycle simulator,
and the cross-backend differential harness.

The contract under test is the strongest one in the repo: the emitted
netlist — narrow interval-proven registers, one time-multiplexed FSM,
shift/add/compare datapath — must replay the golden ``esc_mp_bisect``
integer programs EXACTLY, against four independent executions: the IR
interpreter, the IR->XLA re-emitter, the compiled C reference, and the
committed golden .npz codes. The simulator itself is held to account
twice over: its vectorized fast path must equal its statement-by-
statement slow path, and when iverilog is installed the same netlist
runs through the real simulator too.

A randomized differential test (conftest sampler: hypothesis when
installed, the deterministic fallback otherwise) drives all four
backends with random ADC codes spanning the quantizer's input range —
parity on the golden vector alone would not catch input-dependent
divergence (saturation paths, bisection trip counts).
"""

import shutil
import subprocess
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, st
from repro.core import fixed
from repro.ir import build_program
from repro.ir import interp as ir_interp
from repro.ir import xla as ir_xla
from repro.ir.alloc import allocate
from repro.ir.cgen import emit_c, emit_rom_mem
from repro.ir.debug import Divergence, first_divergence
from repro.ir.verilog import emit_testbench, emit_verilog
from repro.ir import vsim
from repro.analysis.intervals import Interval

from golden_cases import CASES, GOLDEN_DIR, build_pipeline, make_audio
from test_ir import _run_c

CASE = CASES["esc_mp_bisect"]
CHUNK = CASE["chunk"]


# ---------------------------------------------------------------------------
# fixtures: the golden integer programs + their emitted netlists
# ---------------------------------------------------------------------------


def _netlist(prog):
    """Emit, parse, and bundle a program's netlist with its ROM images."""
    alloc = allocate(prog)
    text = emit_verilog(prog, alloc)
    return SimpleNamespace(
        alloc=alloc, text=text, net=vsim.parse_netlist(text),
        loader=vsim.rom_loader_from_mems(emit_rom_mem(prog)))


@pytest.fixture(scope="module")
def oneshot():
    """Golden one-shot program + netlist, inputs typed from the
    quantizer's code range so registers get real narrow widths."""
    pipe = build_pipeline(CASE)
    x = make_audio(CASE)
    prog = fixed.compile_pipeline(pipe, calibration_audio=x)
    xq = np.asarray(fixed.quantize_signal(prog, jnp.asarray(x)))

    def fn(q):
        return fixed.infer_q(prog, q)

    jaxpr = jax.make_jaxpr(fn)(xq)
    lo, hi = int(xq.min()), int(xq.max())
    ir = build_program(jaxpr, name="oneshot_q",
                       in_intervals=[Interval(lo, hi)])
    expected = [np.asarray(v) for v in fn(xq)]
    return SimpleNamespace(ir=ir, xq=xq, expected=expected,
                           qlo=lo, qhi=hi, **vars(_netlist(ir)))


@pytest.fixture(scope="module")
def session():
    """One golden-chunking session step + netlist (untyped inputs: the
    32-bit carrier path must hold bit-for-bit too)."""
    pipe = build_pipeline(
        dict(CASE, cfg=dict(CASE["cfg"], numerics="fixed")))
    x = make_audio(CASE)
    pipe.calibrate_fixed(x)
    prog = pipe.fixed_program()
    state = pipe.init_session(x.shape[0])
    leaves, treedef = jax.tree_util.tree_flatten(state)
    n_state = len(leaves)
    xq = fixed.quantize_signal(prog, jnp.asarray(x[:, :CHUNK]))
    nv = jnp.full((x.shape[0],), CHUNK, jnp.int32)

    def fn(*flat):
        st_ = jax.tree_util.tree_unflatten(treedef, flat[:n_state])
        st2, p_q, phi_q = fixed.session_step_q(prog, st_, flat[n_state],
                                               flat[n_state + 1])
        return tuple(jax.tree_util.tree_leaves(st2)) + (p_q, phi_q)

    args = tuple(leaves) + (xq, nv)
    jaxpr = jax.make_jaxpr(fn)(*args)
    expected = [np.asarray(v) for v in fn(*args)]
    ir = build_program(jaxpr, name="session_step_q")
    return SimpleNamespace(ir=ir, args=[np.asarray(a) for a in args],
                           expected=expected, **vars(_netlist(ir)))


@pytest.fixture(scope="module")
def small():
    """A small typed program covering the tricky emitter paths — pad,
    dynamic_slice, transpose, scan with carry, reductions, shifts —
    cheap enough for the statement-level slow path and iverilog."""
    def fn(x):
        a = jnp.abs(x)
        b = jnp.where(x > 0, a, -(a >> 1))
        c = jnp.pad(b, ((0, 0), (2, 1)))
        d = jax.lax.dynamic_slice(c, (0, 1), (3, 8))

        def step(carry, col):
            carry = jnp.maximum(carry + col, 0)
            return carry, carry - col

        carry, ys = jax.lax.scan(step, jnp.zeros((3,), jnp.int32), d.T)
        s = jnp.sum(ys, axis=0) + jnp.max(d, axis=1)
        return s, carry

    x0 = np.arange(-12, 12, dtype=np.int32).reshape(3, 8)
    jaxpr = jax.make_jaxpr(fn)(x0)
    ir = build_program(jaxpr, name="small",
                       in_intervals=[Interval(-100, 100)])
    return SimpleNamespace(ir=ir, x0=x0, **vars(_netlist(ir)))


def _assert_all_equal(got, expected):
    assert len(got) == len(expected)
    for g, e in zip(got, expected):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(e))


# ---------------------------------------------------------------------------
# netlist parity: golden programs, bit-for-bit
# ---------------------------------------------------------------------------


def test_netlist_matches_infer_q_and_golden_fixture(oneshot):
    """vsim(program.v) == fixed.infer_q == the committed golden codes."""
    outs = vsim.run_netlist(oneshot.net, [oneshot.xq], oneshot.loader)
    _assert_all_equal(outs, oneshot.expected)
    golden = np.load(f"{GOLDEN_DIR}/esc_mp_bisect.npz")
    np.testing.assert_array_equal(np.asarray(outs[0]),
                                  golden["p_fixed_q"])
    np.testing.assert_array_equal(np.asarray(outs[1]),
                                  golden["phi_fixed_q"])
    np.testing.assert_array_equal(np.asarray(outs[2]),
                                  golden["acc_fixed_q"])


def test_netlist_matches_session_step(session):
    outs = vsim.run_netlist(session.net, session.args, session.loader)
    _assert_all_equal(outs, session.expected)


def test_netlist_matches_c_reference(oneshot, tmp_path):
    """Verilog sim == compiled C on the same program (both derived from
    the IR, independently emitted and executed)."""
    outs = vsim.run_netlist(oneshot.net, [oneshot.xq], oneshot.loader)
    _assert_all_equal(_run_c(oneshot.ir, [oneshot.xq], tmp_path), outs)


# ---------------------------------------------------------------------------
# randomized differential harness: four backends, random ADC codes
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def diff_rig(oneshot, tmp_path_factory):
    """Compile-once executables for the randomized sweep: one jitted XLA
    re-emission, one compiled C binary, one parsed netlist."""
    tmp = tmp_path_factory.mktemp("diffc")
    cc = shutil.which("gcc") or shutil.which("cc")
    exe = None
    if cc is not None:
        src = tmp / "program.c"
        src.write_text(emit_c(oneshot.ir))
        exe = tmp / "program"
        subprocess.run([cc, "-std=c99", "-O1", "-o", str(exe),
                        str(src)], check=True)
    return SimpleNamespace(xla=jax.jit(ir_xla.emit(oneshot.ir)),
                           exe=exe, tmp=tmp)


def _run_c_exe(rig, xq):
    (rig.tmp / "in.bin").write_bytes(
        np.asarray(xq).astype("<i4").tobytes())
    subprocess.run([str(rig.exe), str(rig.tmp / "in.bin"),
                    str(rig.tmp / "out.bin")], check=True)
    return (rig.tmp / "out.bin").read_bytes()


@given(st.integers(0, 2**31 - 1))
def test_random_inputs_all_backends_agree(oneshot, diff_rig, seed):
    """Random ADC codes across the quantizer range: interpreter, XLA
    re-emitter, compiled C and the simulated netlist all land on the
    same integer codes."""
    rng = np.random.default_rng(seed)
    xq = rng.integers(oneshot.qlo, oneshot.qhi + 1,
                      size=oneshot.xq.shape).astype(np.int32)
    want = ir_interp.run(oneshot.ir, [xq])
    _assert_all_equal([np.asarray(v) for v in diff_rig.xla(xq)], want)
    _assert_all_equal(
        vsim.run_netlist(oneshot.net, [xq], oneshot.loader), want)
    if diff_rig.exe is not None:
        raw = _run_c_exe(diff_rig, xq)
        off = 0
        for i, w in zip(oneshot.ir.outputs, want):
            r = oneshot.ir.regs[i]
            got = np.frombuffer(raw, "<i4", r.size, off).reshape(r.shape)
            np.testing.assert_array_equal(got, np.asarray(w))
            off += 4 * r.size


# ---------------------------------------------------------------------------
# the simulator held to account: fast == slow, iverilog when present
# ---------------------------------------------------------------------------


def test_vectorized_equals_slow_path(small):
    fast = vsim.run_netlist(small.net, [small.x0], small.loader)
    slow = vsim.run_netlist(small.net, [small.x0], small.loader,
                            vectorize=False)
    _assert_all_equal(slow, fast)
    _assert_all_equal(fast, ir_interp.run(small.ir, [small.x0]))


@pytest.mark.skipif(not vsim.have_iverilog(),
                    reason="iverilog not installed")
def test_iverilog_matches_interpreter(small):
    outs = vsim.run_iverilog(small.text,
                             emit_testbench(small.ir, small.alloc),
                             [small.x0],
                             rom_mems=emit_rom_mem(small.ir))
    _assert_all_equal(outs, ir_interp.run(small.ir, [small.x0]))


def test_emission_deterministic(small):
    assert emit_verilog(small.ir, small.alloc) == small.text


# ---------------------------------------------------------------------------
# first-divergence localization
# ---------------------------------------------------------------------------


def test_first_divergence_clean_is_none(small):
    assert first_divergence(small.ir, small.net, [small.x0],
                            small.loader) is None


def test_first_divergence_locates_corruption(small):
    """Flip one add to sub in the netlist text: the locator must name a
    concrete state/instruction/register, not just 'outputs differ'."""
    assert "t2 = t0 + t1;" in small.text
    bad = small.text.replace("t2 = t0 + t1;", "t2 = t0 - t1;", 1)
    d = first_divergence(small.ir, bad, [small.x0], small.loader)
    assert isinstance(d, Divergence)
    assert d.reg.startswith("r") and d.flat_index >= 0
    assert d.got != d.want
    assert f"state {d.state}" in str(d)


# ---------------------------------------------------------------------------
# allocator: widths are the interval-proven minima
# ---------------------------------------------------------------------------


def test_allocator_widths_and_report(oneshot):
    alloc = oneshot.alloc
    rom_regs = set(oneshot.ir.rom_of_reg)
    n = bits = 0
    for r in oneshot.ir.regs:
        if r.idx in rom_regs:
            assert alloc.width(r.idx) == 32   # $readmemh image carrier
            continue
        assert alloc.width(r.idx) == r.storage_bits, r.idx
        assert 1 <= alloc.width(r.idx) <= 32
        n += 1
        bits += alloc.width(r.idx) * r.size
    rep = alloc.report["registers"]
    assert rep["count"] == n
    assert rep["bits_allocated"] == bits
    assert sum(rep["width_histogram"].values()) == n
    assert 0.0 <= rep["carrier_saving"] < 1.0
    # typed inputs must make narrowing real, not a no-op
    assert rep["carrier_saving"] > 0.2
    assert alloc.report["datapath"]["adder_sites"] > 0
