"""The typed fixed-point IR (src/repro/ir): round-trip parity on the
golden fixture, census pinning, register typing, and the multiplierless
type-error contract.

The load-bearing checks: lowering the golden ``esc_mp_bisect`` integer
programs (one-shot ``fixed.infer_q`` AND the per-chunk
``fixed.session_step_q``) to the IR and executing them through all three
backends — the pure-Python interpreter, the IR->XLA re-emitter, and the
compiled C reference — must land on EXACTLY the integer codes the jax
program (and the committed golden .npz) produces. Integer arithmetic
either reproduces or it drifted; there is no tolerance anywhere here.
"""

import shutil
import struct
import subprocess
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fixed
from repro.ir import (BuildError, build_program, census_program)
from repro.ir import interp as ir_interp
from repro.ir import xla as ir_xla
from repro.ir.cgen import emit_c, emit_rom_mem
from repro.analysis.legality import census_jaxpr

from golden_cases import CASES, GOLDEN_DIR, build_pipeline, make_audio

CASE = CASES["esc_mp_bisect"]
CHUNK = CASE["chunk"]


# ---------------------------------------------------------------------------
# fixtures: lower the golden case's integer programs once per module
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def oneshot():
    """The golden one-shot integer program, lowered to the IR."""
    pipe = build_pipeline(CASE)
    x = make_audio(CASE)
    prog = fixed.compile_pipeline(pipe, calibration_audio=x)
    xq = fixed.quantize_signal(prog, jnp.asarray(x))

    def fn(q):
        return fixed.infer_q(prog, q)

    jaxpr = jax.make_jaxpr(fn)(xq)
    expected = [np.asarray(v) for v in fn(xq)]   # (p_q, phi_q, s_q)
    ir = build_program(jaxpr, name="oneshot_q")
    return SimpleNamespace(jaxpr=jaxpr, ir=ir, xq=np.asarray(xq),
                           expected=expected)


@pytest.fixture(scope="module")
def session():
    """One golden-chunking step of the int32 session datapath, lowered to
    the IR. Inputs/outputs are the flattened state leaves + chunk + n."""
    pipe = build_pipeline(
        dict(CASE, cfg=dict(CASE["cfg"], numerics="fixed")))
    x = make_audio(CASE)
    pipe.calibrate_fixed(x)
    prog = pipe.fixed_program()
    state = pipe.init_session(x.shape[0])
    leaves, treedef = jax.tree_util.tree_flatten(state)
    n_state = len(leaves)
    xq = fixed.quantize_signal(prog, jnp.asarray(x[:, :CHUNK]))
    nv = jnp.full((x.shape[0],), CHUNK, jnp.int32)

    def fn(*flat):
        st = jax.tree_util.tree_unflatten(treedef, flat[:n_state])
        st2, p_q, phi_q = fixed.session_step_q(prog, st, flat[n_state],
                                               flat[n_state + 1])
        return tuple(jax.tree_util.tree_leaves(st2)) + (p_q, phi_q)

    args = tuple(leaves) + (xq, nv)
    jaxpr = jax.make_jaxpr(fn)(*args)
    expected = [np.asarray(v) for v in fn(*args)]
    ir = build_program(jaxpr, name="session_step_q")
    return SimpleNamespace(jaxpr=jaxpr, ir=ir,
                           args=[np.asarray(a) for a in args],
                           expected=expected)


def _assert_all_equal(got, expected):
    assert len(got) == len(expected)
    for g, e in zip(got, expected):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(e))


# ---------------------------------------------------------------------------
# backend parity: interpreter / XLA re-emitter / compiled C, all exact
# ---------------------------------------------------------------------------


def test_interpreter_matches_infer_q(oneshot):
    _assert_all_equal(ir_interp.run(oneshot.ir, [oneshot.xq]),
                      oneshot.expected)


def test_interpreter_matches_golden_fixture(oneshot):
    """The IR interpreter lands on the COMMITTED golden integer codes —
    not just on what today's jax produces."""
    golden = np.load(f"{GOLDEN_DIR}/esc_mp_bisect.npz")
    p_q, phi_q, s_q = ir_interp.run(oneshot.ir, [oneshot.xq])
    np.testing.assert_array_equal(np.asarray(p_q), golden["p_fixed_q"])
    np.testing.assert_array_equal(np.asarray(phi_q), golden["phi_fixed_q"])
    np.testing.assert_array_equal(np.asarray(s_q), golden["acc_fixed_q"])


def test_interpreter_matches_session_step(session):
    _assert_all_equal(ir_interp.run(session.ir, session.args),
                      session.expected)


def test_xla_emitter_matches_infer_q(oneshot):
    fn = jax.jit(ir_xla.emit(oneshot.ir))
    _assert_all_equal(fn(oneshot.xq), oneshot.expected)


def test_xla_emitter_matches_session_step(session):
    fn = jax.jit(ir_xla.emit(session.ir))
    _assert_all_equal(fn(*session.args), session.expected)


def _run_c(prog, inputs, tmpdir):
    cc = shutil.which("gcc") or shutil.which("cc")
    if cc is None:
        pytest.skip("no C compiler on PATH")
    src = tmpdir / "program.c"
    src.write_text(emit_c(prog))
    exe = tmpdir / "program"
    subprocess.run([cc, "-std=c99", "-O1", "-o", str(exe), str(src)],
                   check=True)
    blob = b""
    for r, v in zip((prog.regs[i] for i in prog.inputs), inputs):
        v = np.asarray(v)
        blob += (v.astype(np.uint8) if r.dtype == "i1"
                 else v.astype("<i4")).tobytes()
    inp, outp = tmpdir / "in.bin", tmpdir / "out.bin"
    inp.write_bytes(blob)
    subprocess.run([str(exe), str(inp), str(outp)], check=True)
    raw = outp.read_bytes()
    outs, off = [], 0
    for i in prog.outputs:
        r = prog.regs[i]
        if r.dtype == "i1":
            n = r.size
            outs.append(np.frombuffer(raw, np.uint8, n, off)
                        .astype(bool).reshape(r.shape))
            off += n
        else:
            n = r.size
            outs.append(np.frombuffer(raw, "<i4", n, off)
                        .reshape(r.shape))
            off += 4 * n
    assert off == len(raw)
    return outs


def test_c_reference_matches_infer_q(oneshot, tmp_path):
    _assert_all_equal(_run_c(oneshot.ir, [oneshot.xq], tmp_path),
                      oneshot.expected)


def test_c_reference_matches_session_step(session, tmp_path):
    _assert_all_equal(_run_c(session.ir, session.args, tmp_path),
                      session.expected)


# ---------------------------------------------------------------------------
# census pinning: the IR census IS the jaxpr-walk census, number for number
# ---------------------------------------------------------------------------


def test_census_pinned_oneshot(oneshot):
    c = census_program(oneshot.ir)
    assert dict(c) == dict(census_jaxpr(oneshot.jaxpr))
    assert c["multiply"] == 0 and c["transcendental_or_div"] == 0
    assert c["add"] > 0 and c["shift"] > 0


def test_census_pinned_session(session):
    assert dict(census_program(session.ir)) == \
        dict(census_jaxpr(session.jaxpr))


def test_census_pinned_pallas_stream():
    """Grid programs lower too (executable=False) and their census —
    including the pallas_call body scaled by the grid product and the
    skipped ``cond`` branches from ``pl.when`` — matches the jaxpr walk."""
    pipe = build_pipeline(
        dict(CASE, cfg=dict(CASE["cfg"], numerics="fixed")), "pallas")
    x = make_audio(CASE)
    pipe.calibrate_fixed(x)
    prog = pipe.fixed_program()
    state = pipe.init_session(x.shape[0])
    xq = fixed.quantize_signal(prog, jnp.asarray(x[:, :CHUNK]))
    nv = jnp.full((x.shape[0],), CHUNK, jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda st, q, v: pipe._cascade_pallas_fixed(prog, st, q, v))(
            state, xq, nv)
    ir = build_program(jaxpr, name="stream_pallas")
    assert not ir.executable
    assert dict(census_program(ir)) == dict(census_jaxpr(jaxpr))
    with pytest.raises(NotImplementedError):
        ir_interp.run(ir, [])
    with pytest.raises(NotImplementedError):
        ir_xla.emit(ir)


# ---------------------------------------------------------------------------
# register typing from the interval pass
# ---------------------------------------------------------------------------


def test_register_typing_from_intervals(oneshot):
    from repro.analysis.intervals import Interval
    pipe = build_pipeline(CASE)
    x = make_audio(CASE)
    prog = fixed.compile_pipeline(pipe, calibration_audio=x)
    sig = Interval(int(prog.signal.qmin), int(prog.signal.qmax))
    ir = build_program(oneshot.jaxpr, name="oneshot_q", in_intervals=[sig])
    typed = [r for r in ir.regs if r.interval is not None]
    assert typed, "intervals did not propagate into the register table"
    for r in typed:
        assert r.required_bits is not None and r.required_bits <= 32
        assert r.interval[0] <= r.interval[1]
    # the table the artifacts serialize is complete and deterministic
    table = ir.register_table()
    assert [row["reg"] for row in table] == list(range(len(ir.regs)))


# ---------------------------------------------------------------------------
# the multiplierless contract is a TYPE ERROR, not a census result
# ---------------------------------------------------------------------------


def test_general_multiply_is_a_build_error():
    a = jnp.arange(8, dtype=jnp.int32)
    with pytest.raises(BuildError, match="mul"):
        build_program(jax.make_jaxpr(lambda u, v: u * v)(a, a),
                      name="bad_mul")


def test_pow2_literal_multiply_folds_to_shift():
    a = jnp.arange(8, dtype=jnp.int32)
    ir = build_program(jax.make_jaxpr(lambda u: u * 8)(a), name="p2")
    shifts = [i for i in ir.body if i.op == "shl"]
    assert len(shifts) == 1 and shifts[0].attrs["imm"] == 3
    assert dict(census_program(ir)).get("shift", 0) >= 1
    np.testing.assert_array_equal(
        np.asarray(ir_interp.run(ir, [np.arange(8, dtype=np.int32)])[0]),
        np.arange(8, dtype=np.int32) * 8)


def test_float_program_is_a_build_error():
    a = jnp.arange(8, dtype=jnp.float32)
    with pytest.raises(BuildError):
        build_program(jax.make_jaxpr(lambda u, v: u / v)(a, a),
                      name="bad_div")


# ---------------------------------------------------------------------------
# ROM artifacts
# ---------------------------------------------------------------------------


def test_rom_mem_files_round_trip(oneshot):
    """Every ROM serializes to a $readmemh file whose words parse back to
    the exact int32 contents (two's complement, 8 hex digits per word)."""
    mems = emit_rom_mem(oneshot.ir)
    assert len(mems) == len(oneshot.ir.roms)
    by_name = {f"{r.name}.mem": r for r in oneshot.ir.roms}
    for fname, text in mems.items():
        rom = by_name[fname]
        words = [w for line in text.splitlines()
                 for w in line.split() if not w.startswith("//")]
        got = np.asarray(
            [struct.unpack(">i", bytes.fromhex(w))[0] for w in words],
            np.int32)
        np.testing.assert_array_equal(
            got, np.asarray(rom.data, np.int32).ravel())
