"""Per-architecture smoke tests (reduced same-family configs): one forward
and one train step on CPU, asserting shapes and finiteness; decode parity
against the parallel forward for decoder archs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch, get_smoke
from repro.distributed.steps import make_train_step
from repro.models import transformer as T
from repro.optim import AdamWConfig

B, S = 2, 64


def _batch(cfg, key, with_labels=False):
    if cfg.audio_frontend:
        b = {"frames": jax.random.normal(key, (B, S, cfg.d_model))}
        if with_labels:
            b["labels"] = jnp.zeros((B, S), jnp.int32)
        return b
    if cfg.vlm_patches:
        return {"tokens": jnp.ones((B, S - cfg.vlm_patches), jnp.int32),
                "patches": jax.random.normal(key, (B, cfg.vlm_patches,
                                                   cfg.d_model))}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch_id", sorted(ARCH_IDS))
def test_forward_shapes_and_finite(arch_id):
    cfg = get_smoke(arch_id)
    params = T.init(cfg, jax.random.PRNGKey(0))
    logits = T.forward(params, cfg, _batch(cfg, jax.random.PRNGKey(1)))
    assert logits.shape[0] == B and logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch_id", sorted(ARCH_IDS))
def test_train_step_decreases_loss(arch_id):
    cfg = get_smoke(arch_id)
    init_state, train_step = make_train_step(
        cfg, AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=30))
    state = init_state(jax.random.PRNGKey(0))
    step = jax.jit(train_step)
    batch = _batch(cfg, jax.random.PRNGKey(1), with_labels=True)
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch_id", [a for a in sorted(ARCH_IDS)
                                     if a != "hubert-xlarge"])
def test_decode_matches_forward(arch_id):
    cfg = get_smoke(arch_id)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=None)  # no-drop
    if cfg.vlm_patches:
        cfg = dataclasses.replace(cfg, vlm_patches=0)  # text-only decode
    params = T.init(cfg, jax.random.PRNGKey(2))
    S_ = 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S_), 0,
                              cfg.vocab_size)
    ref = T.forward(params, cfg, {"tokens": toks}).astype(jnp.float32)
    cache = T.init_cache(cfg, B, S_)
    step = jax.jit(lambda p, t, c, cp: T.decode_step(p, cfg, t, c, cp))
    worst = 0.0
    for i in range(S_):
        lg, cache = step(params, toks[:, i:i + 1], cache,
                         jnp.full((B,), i, jnp.int32))
        worst = max(worst, float(jnp.max(jnp.abs(
            lg[:, 0].astype(jnp.float32) - ref[:, i]))))
    # prefill uses bf16 flash attention (p@v in bf16), decode uses f32
    # softmax against the cache; MoE adds bf16 scatter-order noise that
    # compounds with depth. This test pins the NOISE ENVELOPE only —
    # algorithmic equality is pinned exactly by
    # test_decode_matches_forward_exact_f32 below.
    tol = 0.6 if (cfg.num_experts or cfg.family == "hybrid") else 0.15
    assert worst < tol, worst


@pytest.mark.parametrize("arch_id", [
    "qwen3-8b", "mixtral-8x22b", "mamba2-2.7b",
    pytest.param("jamba-v0.1-52b", marks=pytest.mark.xfail(
        strict=False,
        reason="hybrid SSM+MoE: the decode recurrence reproduces the SSD "
        "scan only to ~4e-6 ulp noise (fine alone — mamba2 passes), but "
        "jamba feeds it into top-2 routing where a near-tied gate flips "
        "and the softmax gate difference amplifies past 1e-4. Verified "
        "num_experts=0 stays <6e-6 at every position; tracked as routing "
        "tie-sensitivity, not an algorithmic decode bug.")),
    "deepseek-moe-16b"])
def test_decode_matches_forward_exact_f32(arch_id):
    """With f32 compute the two paths must agree to float tolerance —
    this pins the algorithm; the bf16 test above pins the noise envelope."""
    cfg = dataclasses.replace(get_smoke(arch_id), moe_capacity_factor=None,
                              compute_dtype="float32")
    params = T.init(cfg, jax.random.PRNGKey(2))
    S_ = 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S_), 0,
                              cfg.vocab_size)
    ref = T.forward(params, cfg, {"tokens": toks}).astype(jnp.float32)
    cache = T.init_cache(cfg, B, S_)
    step = jax.jit(lambda p, t, c, cp: T.decode_step(p, cfg, t, c, cp))
    worst = 0.0
    for i in range(S_):
        lg, cache = step(params, toks[:, i:i + 1], cache,
                         jnp.full((B,), i, jnp.int32))
        worst = max(worst, float(jnp.max(jnp.abs(
            lg[:, 0].astype(jnp.float32) - ref[:, i]))))
    assert worst < 1e-4, worst


def test_encoder_has_no_decode():
    cfg = get_smoke("hubert-xlarge")
    assert not cfg.supports_decode


def test_full_configs_match_assignment():
    """The exact assigned hyperparameters (guards against config drift)."""
    expect = {
        "deepseek-moe-16b": dict(num_layers=28, d_model=2048, num_heads=16,
                                 num_kv_heads=16, vocab_size=102400,
                                 num_experts=64, num_experts_per_tok=6),
        "mixtral-8x22b": dict(num_layers=56, d_model=6144, num_heads=48,
                              num_kv_heads=8, d_ff=16384, vocab_size=32768,
                              num_experts=8, num_experts_per_tok=2),
        "mamba2-2.7b": dict(num_layers=64, d_model=2560, vocab_size=50280,
                            ssm_state=128),
        "jamba-v0.1-52b": dict(num_layers=32, d_model=4096, num_heads=32,
                               num_kv_heads=8, d_ff=14336, vocab_size=65536,
                               num_experts=16, num_experts_per_tok=2),
        "internvl2-2b": dict(num_layers=24, d_model=2048, num_heads=16,
                             num_kv_heads=8, d_ff=8192, vocab_size=92553),
        "hubert-xlarge": dict(num_layers=48, d_model=1280, num_heads=16,
                              num_kv_heads=16, d_ff=5120, vocab_size=504),
        "glm4-9b": dict(num_layers=40, d_model=4096, num_heads=32,
                        num_kv_heads=2, d_ff=13696, vocab_size=151552),
        "qwen3-8b": dict(num_layers=36, d_model=4096, num_heads=32,
                         num_kv_heads=8, d_ff=12288, vocab_size=151936,
                         qk_norm=True),
        "qwen2-72b": dict(num_layers=80, d_model=8192, num_heads=64,
                          num_kv_heads=8, d_ff=29568, vocab_size=152064,
                          qkv_bias=True),
        "command-r-35b": dict(num_layers=40, d_model=8192, num_heads=64,
                              num_kv_heads=8, d_ff=22528, vocab_size=256000),
    }
    for arch_id, fields in expect.items():
        cfg = get_arch(arch_id)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch_id, k, getattr(cfg, k), v)


def test_mp_mode_smoke():
    """The paper's multiplierless MP path as a first-class layer mode."""
    cfg = dataclasses.replace(get_smoke("qwen3-8b"), mp_mode=True,
                              num_layers=1)
    params = T.init(cfg, jax.random.PRNGKey(0))
    logits = T.forward(params, cfg, {"tokens": jnp.ones((1, 8), jnp.int32)})
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
