"""Per-architecture smoke tests (reduced same-family configs): one forward
and one train step on CPU, asserting shapes and finiteness; decode parity
against the parallel forward for decoder archs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch, get_smoke
from repro.distributed.steps import make_train_step
from repro.models import transformer as T
from repro.optim import AdamWConfig

B, S = 2, 64


def _batch(cfg, key, with_labels=False):
    if cfg.audio_frontend:
        b = {"frames": jax.random.normal(key, (B, S, cfg.d_model))}
        if with_labels:
            b["labels"] = jnp.zeros((B, S), jnp.int32)
        return b
    if cfg.vlm_patches:
        return {"tokens": jnp.ones((B, S - cfg.vlm_patches), jnp.int32),
                "patches": jax.random.normal(key, (B, cfg.vlm_patches,
                                                   cfg.d_model))}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch_id", sorted(ARCH_IDS))
def test_forward_shapes_and_finite(arch_id):
    cfg = get_smoke(arch_id)
    params = T.init(cfg, jax.random.PRNGKey(0))
    logits = T.forward(params, cfg, _batch(cfg, jax.random.PRNGKey(1)))
    assert logits.shape[0] == B and logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch_id", sorted(ARCH_IDS))
def test_train_step_decreases_loss(arch_id):
    cfg = get_smoke(arch_id)
    init_state, train_step = make_train_step(
        cfg, AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=30))
    state = init_state(jax.random.PRNGKey(0))
    step = jax.jit(train_step)
    batch = _batch(cfg, jax.random.PRNGKey(1), with_labels=True)
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch_id", [a for a in sorted(ARCH_IDS)
                                     if a != "hubert-xlarge"])
def test_decode_matches_forward(arch_id):
    cfg = get_smoke(arch_id)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=None)  # no-drop
    if cfg.vlm_patches:
        cfg = dataclasses.replace(cfg, vlm_patches=0)  # text-only decode
    params = T.init(cfg, jax.random.PRNGKey(2))
    S_ = 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S_), 0,
                              cfg.vocab_size)
    ref = T.forward(params, cfg, {"tokens": toks}).astype(jnp.float32)
    cache = T.init_cache(cfg, B, S_)
    step = jax.jit(lambda p, t, c, cp: T.decode_step(p, cfg, t, c, cp))
    worst = 0.0
    for i in range(S_):
        lg, cache = step(params, toks[:, i:i + 1], cache,
                         jnp.full((B,), i, jnp.int32))
        worst = max(worst, float(jnp.max(jnp.abs(
            lg[:, 0].astype(jnp.float32) - ref[:, i]))))
    # prefill uses bf16 flash attention (p@v in bf16), decode uses f32
    # softmax against the cache; MoE adds bf16 scatter-order noise that
    # compounds with depth, and under bf16 the ~1e-2 recompute noise can
    # legitimately flip a near-tied top-2 routing choice between the two
    # paths (an O(1) logit delta per flipped token — the fine-grid
    # deterministic selection in models/moe.py removes ulp-level flips,
    # not bf16-level ones). This test pins the NOISE ENVELOPE only —
    # algorithmic equality is pinned exactly (including routing parity for
    # the hybrid) by test_decode_matches_forward_exact_f32 below. The
    # hybrid's SSM decode recurrence feeds that flip-prone routing, so it
    # gets the widest envelope.
    if cfg.family == "hybrid":
        tol = 1.5
    elif cfg.num_experts:
        tol = 0.6
    else:
        tol = 0.15
    assert worst < tol, worst


@pytest.mark.parametrize("arch_id", [
    "qwen3-8b", "mixtral-8x22b", "mamba2-2.7b",
    # jamba was xfailed here (diagnosed as top-2 routing tie flips on
    # ulp-level SSM decode noise). The router now SELECTS experts on a
    # fine deterministic grid (models/moe.py: floor to 2^-10, exact ties
    # to the lowest expert id), and this test asserts prefill/decode pick
    # IDENTICAL experts at every (layer, position) — the structural pin.
    # What remains after routing is pinned is f32 reassociation noise
    # (XLA fuses the expert einsum/softmax differently for the prefill and
    # decode shapes; measured ~1e-3 on identical inputs through this
    # random-init MoE stack), so jamba's scalar tolerance is the measured
    # envelope, not 1e-4.
    "jamba-v0.1-52b",
    "deepseek-moe-16b"])
def test_decode_matches_forward_exact_f32(arch_id):
    """With f32 compute the two paths must agree to float tolerance —
    this pins the algorithm; the bf16 test above pins the noise envelope.
    For the MoE hybrid (jamba) the routing DECISIONS are additionally
    pinned exactly (see the parametrize note)."""
    from repro.models import moe as moe_mod

    cfg = dataclasses.replace(get_smoke(arch_id), moe_capacity_factor=None,
                              compute_dtype="float32")
    is_jamba = arch_id == "jamba-v0.1-52b"
    captured = []
    orig_scores = moe_mod._route_scores
    if is_jamba:
        def capturing_scores(logits):
            jax.debug.callback(
                lambda a: captured.append(np.asarray(a)), logits,
                ordered=True)
            return orig_scores(logits)
        moe_mod._route_scores = capturing_scores

    try:
        params = T.init(cfg, jax.random.PRNGKey(2))
        S_ = 12
        toks = jax.random.randint(jax.random.PRNGKey(3), (B, S_), 0,
                                  cfg.vocab_size)
        ref = T.forward(params, cfg, {"tokens": toks}).astype(jnp.float32)
        jax.block_until_ready(ref)
        prefill_logits = list(captured)
        captured.clear()
        cache = T.init_cache(cfg, B, S_)
        step = jax.jit(lambda p, t, c, cp: T.decode_step(p, cfg, t, c, cp))
        worst = 0.0
        decode_logits = []
        for i in range(S_):
            lg, cache = step(params, toks[:, i:i + 1], cache,
                             jnp.full((B,), i, jnp.int32))
            worst = max(worst, float(jnp.max(jnp.abs(
                lg[:, 0].astype(jnp.float32) - ref[:, i]))))
            jax.block_until_ready(lg)
            decode_logits.append(list(captured))
            captured.clear()
    finally:
        moe_mod._route_scores = orig_scores

    if is_jamba:
        K = cfg.num_experts_per_tok

        def top_set(logits_rows):
            scores = np.asarray(orig_scores(jnp.asarray(logits_rows)))
            # descending stable argsort = lax.top_k's tie order
            return np.sort(np.argsort(-scores, axis=-1,
                                      kind="stable")[:, :K], axis=-1)

        assert prefill_logits, "router capture failed"
        for layer_j, lp in enumerate(prefill_logits):
            lp = lp.reshape(B, S_, -1)
            for i in range(S_):
                sel_pre = top_set(lp[:, i])
                sel_dec = top_set(decode_logits[i][layer_j].reshape(B, -1))
                np.testing.assert_array_equal(
                    sel_pre, sel_dec,
                    err_msg=f"expert selection diverged at moe layer "
                            f"{layer_j}, position {i}")
    tol = 5e-3 if is_jamba else 1e-4
    assert worst < tol, worst


def test_encoder_has_no_decode():
    cfg = get_smoke("hubert-xlarge")
    assert not cfg.supports_decode


def test_full_configs_match_assignment():
    """The exact assigned hyperparameters (guards against config drift)."""
    expect = {
        "deepseek-moe-16b": dict(num_layers=28, d_model=2048, num_heads=16,
                                 num_kv_heads=16, vocab_size=102400,
                                 num_experts=64, num_experts_per_tok=6),
        "mixtral-8x22b": dict(num_layers=56, d_model=6144, num_heads=48,
                              num_kv_heads=8, d_ff=16384, vocab_size=32768,
                              num_experts=8, num_experts_per_tok=2),
        "mamba2-2.7b": dict(num_layers=64, d_model=2560, vocab_size=50280,
                            ssm_state=128),
        "jamba-v0.1-52b": dict(num_layers=32, d_model=4096, num_heads=32,
                               num_kv_heads=8, d_ff=14336, vocab_size=65536,
                               num_experts=16, num_experts_per_tok=2),
        "internvl2-2b": dict(num_layers=24, d_model=2048, num_heads=16,
                             num_kv_heads=8, d_ff=8192, vocab_size=92553),
        "hubert-xlarge": dict(num_layers=48, d_model=1280, num_heads=16,
                              num_kv_heads=16, d_ff=5120, vocab_size=504),
        "glm4-9b": dict(num_layers=40, d_model=4096, num_heads=32,
                        num_kv_heads=2, d_ff=13696, vocab_size=151552),
        "qwen3-8b": dict(num_layers=36, d_model=4096, num_heads=32,
                         num_kv_heads=8, d_ff=12288, vocab_size=151936,
                         qk_norm=True),
        "qwen2-72b": dict(num_layers=80, d_model=8192, num_heads=64,
                          num_kv_heads=8, d_ff=29568, vocab_size=152064,
                          qkv_bias=True),
        "command-r-35b": dict(num_layers=40, d_model=8192, num_heads=64,
                              num_kv_heads=8, d_ff=22528, vocab_size=256000),
    }
    for arch_id, fields in expect.items():
        cfg = get_arch(arch_id)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch_id, k, getattr(cfg, k), v)


def test_mp_mode_smoke():
    """The paper's multiplierless MP path as a first-class layer mode."""
    cfg = dataclasses.replace(get_smoke("qwen3-8b"), mp_mode=True,
                              num_layers=1)
    params = T.init(cfg, jax.random.PRNGKey(0))
    logits = T.forward(params, cfg, {"tokens": jnp.ones((1, 8), jnp.int32)})
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
