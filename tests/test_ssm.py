"""Mamba2 SSD unit tests: chunked == recurrent, gradient finiteness
(regression: masked-exp overflow used to NaN the backward), chunk-size
invariance."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import ssm


def _cfg(arch="mamba2-2.7b"):
    return dataclasses.replace(get_smoke(arch), compute_dtype="float32")


def test_chunked_matches_stepwise_recurrence():
    """The chunked SSD forward equals running the exact decode recurrence
    position by position (state-space duality)."""
    cfg = _cfg()
    p = ssm.init_mamba(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5
    y_chunk = ssm.mamba_block(p, x, cfg, chunk=8)
    cache = ssm.init_ssm_cache(cfg, B)
    ys = []
    for i in range(S):
        y_i, cache = ssm.mamba_decode(p, x[:, i:i + 1], cfg, cache)
        ys.append(y_i)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               rtol=2e-3, atol=2e-3)


def test_chunk_size_invariance():
    cfg = _cfg()
    p = ssm.init_mamba(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model))
    y8 = ssm.mamba_block(p, x, cfg, chunk=8)
    y32 = ssm.mamba_block(p, x, cfg, chunk=32)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "jamba-v0.1-52b"])
def test_gradients_finite(arch):
    """Regression: exp(diff) in the masked upper triangle overflows; the
    old where-after-exp pattern turned that into NaN grads for
    a_log/dt_bias/in_proj on every SSM arch."""
    cfg = _cfg(arch)
    p = ssm.init_mamba(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model)) * 0.5

    def f(p, x):
        return jnp.sum(ssm.mamba_block(p, x, cfg, chunk=cfg.ssm_chunk) ** 2)

    _, g = jax.value_and_grad(f)(p, x)
    for path, leaf in jax.tree_util.tree_flatten_with_path(g)[0]:
        assert bool(jnp.all(jnp.isfinite(leaf))), \
            f"non-finite grad at {jax.tree_util.keystr(path)}"


def test_remat_chunk_scan_matches():
    """cfg.remat=True wraps the chunk scan body in jax.checkpoint; values
    must be identical."""
    cfg = _cfg()
    p = ssm.init_mamba(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 32, cfg.d_model))
    y0 = ssm.mamba_block(p, x, dataclasses.replace(cfg, remat=False), chunk=8)
    y1 = ssm.mamba_block(p, x, dataclasses.replace(cfg, remat=True), chunk=8)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-6)
