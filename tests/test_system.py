"""End-to-end behaviour tests for the paper's system: synthetic acoustic
data -> multirate MP FIR filter bank (feature extractor == kernel) ->
MP kernel machine -> gamma-annealed training -> 8-bit deployment.

This is the paper's full pipeline at reduced scale (CPU-budget): the
benchmarks run the full 16 kHz / 30-filter configuration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.filterbank import FilterBank, FilterBankConfig
from repro.core import trainer
from repro.data.acoustic import make_esc10_like, make_fsdd_like


@pytest.fixture(scope="module")
def esc_small():
    ds = make_esc10_like(per_class_train=6, per_class_test=3,
                         fs=4000.0, seconds=0.5, seed=0)
    cfg = FilterBankConfig(fs=4000.0, num_octaves=4, filters_per_octave=5,
                           mode="mp", gamma_f=4.0)
    fb = FilterBank(cfg)
    feat = jax.jit(fb.accumulate)
    s_tr = feat(jnp.asarray(ds.x_train))
    mu = s_tr.mean(0)
    sd = s_tr.std(0, ddof=1) + 1e-6
    K_tr = (s_tr - mu) / sd
    K_te = (feat(jnp.asarray(ds.x_test)) - mu) / sd
    return ds, K_tr, K_te


def test_mp_in_filter_pipeline_learns(esc_small):
    ds, K_tr, K_te = esc_small
    cfg = trainer.TrainConfig(num_steps=400, lr=0.5, batch_size=60,
                              gamma_anneal_start=4.0, gamma_anneal_steps=150)
    params, losses = trainer.train(K_tr, jnp.asarray(ds.y_train), 10, cfg)
    assert losses[-1] < 0.6 * losses[0]
    train_acc = trainer.evaluate(params, K_tr, jnp.asarray(ds.y_train))
    test_acc = trainer.evaluate(params, K_te, jnp.asarray(ds.y_test))
    assert train_acc > 0.6, train_acc          # 10-class, chance = 0.1
    assert test_acc > 0.4, test_acc


def test_8bit_deployment_holds_accuracy(esc_small):
    """Fig. 8: quantizing weights to 8 bits must not collapse accuracy."""
    ds, K_tr, K_te = esc_small
    cfg = trainer.TrainConfig(num_steps=400, lr=0.5, batch_size=60,
                              quant_bits=8)
    params, _ = trainer.train(K_tr, jnp.asarray(ds.y_train), 10, cfg)
    acc_fp = trainer.evaluate(params, K_te, jnp.asarray(ds.y_test))
    acc_q8 = trainer.evaluate(params, K_te, jnp.asarray(ds.y_test),
                              quant_bits=8)
    assert acc_q8 > acc_fp - 0.15, (acc_fp, acc_q8)


def test_fsdd_speaker_id():
    """Table IV: two-speaker identification should be near-perfect."""
    ds = make_fsdd_like(per_speaker_train=20, per_speaker_test=8,
                        fs=4000.0, seconds=0.4, seed=1)
    cfg_fb = FilterBankConfig(fs=4000.0, num_octaves=4, filters_per_octave=5,
                              mode="mp", gamma_f=4.0)
    fb = FilterBank(cfg_fb)
    feat = jax.jit(fb.accumulate)
    s_tr = feat(jnp.asarray(ds.x_train))
    mu, sd = s_tr.mean(0), s_tr.std(0, ddof=1) + 1e-6
    K_tr = (s_tr - mu) / sd
    K_te = (feat(jnp.asarray(ds.x_test)) - mu) / sd
    params, _ = trainer.train(K_tr, jnp.asarray(ds.y_train), 2,
                              trainer.TrainConfig(num_steps=200, lr=0.5))
    acc = trainer.evaluate(params, K_te, jnp.asarray(ds.y_test))
    assert acc > 0.85, acc


def test_mac_baseline_comparable():
    """The paper's claim: MP approximation delivers accuracy comparable to
    the multiplier-based system. Check MP is within 15 points of MAC."""
    ds = make_esc10_like(per_class_train=6, per_class_test=3,
                        fs=4000.0, seconds=0.5, seed=2)
    accs = {}
    for mode in ("mac", "mp"):
        cfg = FilterBankConfig(fs=4000.0, num_octaves=4, mode=mode,
                               gamma_f=4.0)
        fb = FilterBank(cfg)
        feat = jax.jit(fb.accumulate)
        s_tr = feat(jnp.asarray(ds.x_train))
        mu, sd = s_tr.mean(0), s_tr.std(0, ddof=1) + 1e-6
        K_tr = (s_tr - mu) / sd
        K_te = (feat(jnp.asarray(ds.x_test)) - mu) / sd
        params, _ = trainer.train(K_tr, jnp.asarray(ds.y_train), 10,
                                  trainer.TrainConfig(num_steps=300, lr=0.5))
        accs[mode] = trainer.evaluate(params, K_te, jnp.asarray(ds.y_test))
    assert accs["mp"] > accs["mac"] - 0.15, accs
