"""Shared test fixtures: the hypothesis-or-fallback property sampler.

Clean environments ship no ``hypothesis``; every property-testing module
imports ``given``/``st`` from here (``from conftest import given, st``) so
tier-1 collection and the invariants still run without it. The fallback is
a deterministic sampler seeded per test function (crc32 of the qualname),
covering exactly the strategy surface the suite uses: floats / integers /
booleans / sampled_from / lists-of-floats.

With hypothesis installed you get real shrinking and the registered "ci"
profile (40 examples, no deadline); without it, the same number of
deterministic examples.
"""

import zlib

import numpy as np

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
    settings.register_profile("ci", max_examples=40, deadline=None)
    settings.load_profile("ci")
except ImportError:
    HAVE_HYPOTHESIS = False
    _MAX_EXAMPLES = 40

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample  # rng -> drawn value

    class _st:
        @staticmethod
        def floats(min_value, max_value, allow_nan=False):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(
                lambda rng: options[int(rng.integers(len(options)))])

        @staticmethod
        def lists(elems, min_size=0, max_size=10):
            def sample(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elems.sample(rng) for _ in range(n)]
            return _Strategy(sample)

    st = _st

    class settings:  # noqa: N801 - mirrors hypothesis' decorator surface
        """No-op stand-in for ``@settings(...)`` (profiles have no meaning
        for the deterministic fallback sampler)."""

        def __init__(self, *args, **kwargs):
            self.kwargs = kwargs

        def __call__(self, fn):
            n = self.kwargs.get("max_examples")
            if n is not None:
                fn._fallback_max_examples = n
            return fn

    def given(*strategies):
        def deco(fn):
            import inspect
            params = list(inspect.signature(fn).parameters.values())
            outer = params[:len(params) - len(strategies)]
            strat_names = [p.name for p in params[len(outer):]]

            def wrapper(*args, **kwargs):
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                # @settings may sit above @given (it then annotates the
                # wrapper) or below it (it annotates fn) — honor both
                examples = getattr(wrapper, "_fallback_max_examples",
                                   getattr(fn, "_fallback_max_examples",
                                           _MAX_EXAMPLES))
                for _ in range(examples):
                    drawn = {nm: s.sample(rng)
                             for nm, s in zip(strat_names, strategies)}
                    fn(*args, **kwargs, **drawn)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            # hide the strategy-bound trailing parameters from pytest so
            # fixtures/parametrize compose with @given like with hypothesis
            # (e.g. @pytest.mark.parametrize over a leading argument)
            wrapper.__signature__ = inspect.Signature(outer)
            return wrapper
        return deco
