"""The scan-aware HLO cost analyzer vs known ground truths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze_hlo, parse_module


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    text = _compiled_text(lambda a, b: a @ b, a, b)
    r = analyze_hlo(text)
    assert abs(r["flops"] - 2 * 128 * 256 * 64) / (2 * 128 * 256 * 64) < 0.05


def test_scan_multiplies_by_trip_count():
    """The whole point: a matmul inside a scan of N trips counts N times."""
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

    def fn(w, x):
        def body(x, _):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, None, length=20)
        return y

    r = analyze_hlo(_compiled_text(fn, w, x))
    expect = 20 * 2 * 8 * 64 * 64
    assert r["flops"] > 0.9 * expect, (r["flops"], expect)
    assert r["flops"] < 1.6 * expect, (r["flops"], expect)


def test_nested_scan_trips_compound():
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 32), jnp.float32)

    def fn(w, x):
        def outer(x, _):
            def inner(x, _):
                return x @ w, None
            y, _ = jax.lax.scan(inner, x, None, length=5)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=6)
        return y

    r = analyze_hlo(_compiled_text(fn, w, x))
    expect = 30 * 2 * 4 * 32 * 32
    assert 0.9 * expect < r["flops"] < 1.5 * expect


def test_transcendentals_separate():
    x = jax.ShapeDtypeStruct((1000,), jnp.float32)
    r = analyze_hlo(_compiled_text(lambda x: jnp.exp(x), x))
    assert r["transcendentals"] >= 1000
    assert r["flops"] < 100


def test_bytes_reasonable_for_elementwise():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    r = analyze_hlo(_compiled_text(lambda x: x * 2.0 + 1.0, x))
    # one read + one write of 4MiB, fused: between 8 MiB and ~20 MiB
    assert 0.5 * 8e6 < r["bytes_accessed"] < 3 * 8e6


def test_parse_module_structure():
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    text = _compiled_text(lambda x: (x @ x).sum(), x)
    comps, entry = parse_module(text)
    assert entry is not None
    assert any(i.op == "dot" for instrs in comps.values() for i in instrs)


def test_model_level_flops_against_analytic():
    """Full smoke transformer train step within 2x of 6ND + attention."""
    import dataclasses
    from repro.configs import get_smoke
    from repro.distributed.steps import make_train_step
    from repro.launch import specs as S
    from repro.models import transformer as T
    from repro.optim import AdamWConfig

    cfg = dataclasses.replace(get_smoke("glm4-9b"), num_layers=8)
    cell = S.ShapeCell("t", 128, 8, "train")
    ins = S.input_specs(cfg, cell)
    _, train_step = make_train_step(cfg, AdamWConfig())
    state = S.state_specs(cfg)
    comp = jax.jit(train_step, donate_argnums=(0,)).lower(
        state, ins["batch"]).compile()
    r = analyze_hlo(comp.as_text())
    params = jax.eval_shape(lambda: T.init(cfg, jax.random.PRNGKey(0)))
    six_nd = 6 * T.param_count(params) * 8 * 128
    assert 0.5 * six_nd < r["flops"] < 2.5 * six_nd, (r["flops"], six_nd)
