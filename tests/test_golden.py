"""Golden regression gate: seeded audio -> decision vectors, checked in.

Any drift in the numerics of the deployed path — filter design, MP solver,
reduction order, quantization, streaming registers — fails here LOUDLY with
instructions, instead of surfacing as a silent accuracy shift on hardware.
If a drift is intentional, regenerate with::

    PYTHONPATH=src python scripts/regen_golden.py

and commit the refreshed fixtures with an explanation.
"""

import os

import numpy as np
import pytest

from golden_cases import CASES, GOLDEN_DIR, compute_outputs

# exact-match would overfit to compiler codegen (fixtures must survive jax
# upgrades); 1e-5 is ~100x tighter than any real numerics change we gate on
# (solver swaps and reduction reorders move decisions by >= 1e-4).
# INTEGER outputs (the fixed-point hardware twin's *_fixed_q codes) are
# exempt from that reasoning: integer add/shift/compare arithmetic has no
# codegen wiggle room, so they gate at EXACT equality.
ATOL = 1e-5

_DRIFT_MSG = """

GOLDEN NUMERICS DRIFT in case {name!r}, output {key!r}
  max |delta| = {delta:.3e} (gate: atol={atol})

The audio -> decision path no longer reproduces the checked-in fixture.
If this change is INTENTIONAL (new solver/reduction/filter design), refresh:
    PYTHONPATH=src python scripts/regen_golden.py
and commit tests/golden/*.npz with an explanation. If it is not intentional,
you just caught a numerics regression — do not regenerate over it.
"""


_CACHE = {}


def _outputs(name):
    if name not in _CACHE:
        _CACHE[name] = compute_outputs(CASES[name])
    return _CACHE[name]


@pytest.mark.parametrize("name", sorted(CASES))
def test_golden_fixture(name):
    path = os.path.join(GOLDEN_DIR, f"{name}.npz")
    assert os.path.exists(path), (
        f"missing fixture {path}; generate with "
        "PYTHONPATH=src python scripts/regen_golden.py")
    want = dict(np.load(path))
    got = _outputs(name)
    assert set(got) == set(want), (
        f"{name}: recorded surface changed "
        f"(have {sorted(got)}, fixture has {sorted(want)}) — regenerate")
    for key in sorted(want):
        if np.issubdtype(want[key].dtype, np.integer):
            # the integer twin either reproduces or it drifted — no atol
            assert np.array_equal(got[key], want[key]), \
                _DRIFT_MSG.format(
                    name=name, key=key, atol="exact (integer)",
                    delta=float(np.max(np.abs(
                        got[key].astype(np.int64) -
                        want[key].astype(np.int64)))))
            continue
        delta = float(np.max(np.abs(got[key] - want[key]))) \
            if want[key].size else 0.0
        assert np.allclose(got[key], want[key], atol=ATOL), \
            _DRIFT_MSG.format(name=name, key=key, delta=delta, atol=ATOL)


def test_golden_streams_agree_bitwise():
    """Inside one jax version the two stream impls must match exactly —
    recorded once here so the fixture itself documents the contract."""
    for name in sorted(CASES):
        got = _outputs(name)
        np.testing.assert_array_equal(
            got["p_stream_xla"], got["p_stream_pallas"],
            err_msg=f"{name}: stream impls diverged")
        np.testing.assert_array_equal(
            got["acc_stream_xla"], got["acc_stream_pallas"],
            err_msg=f"{name}: stream accumulators diverged")


def test_golden_fixed_stream_is_bitwise_one_shot():
    """The int32 session step's contract, documented by the fixture itself:
    chunked fixed-point streaming lands on EXACTLY the one-shot integer
    codes — static ADC grid + associative integer accumulation, so there
    is no peak-seen caveat and no atol."""
    for name in sorted(CASES):
        got = _outputs(name)
        np.testing.assert_array_equal(
            got["p_stream_fixed_q"], got["p_fixed_q"],
            err_msg=f"{name}: fixed stream decisions != one-shot codes")
        np.testing.assert_array_equal(
            got["acc_stream_fixed_q"], got["acc_fixed_q"],
            err_msg=f"{name}: fixed stream accumulators != one-shot codes")
