"""InFilterPipeline: one jit-able audio->decision computation, the fused
multi-band kernel, and chunked streaming parity with the one-shot path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernel_machine as km
from repro.core.filterbank import FilterBank, FilterBankConfig
from repro.core.pipeline import InFilterPipeline, StreamingState
from repro.kernels import fir_mp, fir_mp_bank, fir_mp_bank_accumulate
from repro.kernels import ref


def _pipeline(num_octaves=4, filters_per_octave=3, num_classes=5,
              fs=8000.0, **cfg_over) -> InFilterPipeline:
    kw = dict(mode="mp", gamma_f=4.0)
    kw.update(cfg_over)
    cfg = FilterBankConfig(fs=fs, num_octaves=num_octaves,
                           filters_per_octave=filters_per_octave, **kw)
    fb = FilterBank(cfg)
    P = cfg.num_filters
    clf = km.init_params(jax.random.PRNGKey(0), P, num_classes)
    mu = jax.random.normal(jax.random.PRNGKey(1), (P,)) * 0.1 + 1.0
    sigma = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (P,))) + 0.5
    return InFilterPipeline.from_filterbank(fb, clf, mu, sigma)


# ---------------------------------------------------------------------------
# fir_mp_bank kernel (interpret mode on CPU)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,N,F,M", [(1, 64, 1, 4), (3, 300, 5, 16),
                                     (8, 128, 2, 6)])
def test_fir_mp_bank_matches_reference(B, N, F, M):
    """One pallas_call over the whole bank == F independent exact solves."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(B + N + F))
    x = jax.random.normal(k1, (B, N))
    H = jax.random.normal(k2, (F, M)) * 0.3
    y = fir_mp_bank(x, H, 2.0)
    assert y.shape == (B, F, N)
    yr = ref.fir_mp_bank_ref(x, H, 2.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)


def test_fir_mp_bank_bitwise_matches_single_filter_kernel():
    """The bank grid must run the SAME bisection as the per-filter kernel:
    same windows, same operand pairing -> bit-identical band outputs."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(k1, (4, 256))
    H = jax.random.normal(k2, (5, 16)) * 0.3
    y = fir_mp_bank(x, H, 2.0)
    for f in range(H.shape[0]):
        yf = fir_mp(x, H[f], 2.0)
        np.testing.assert_array_equal(np.asarray(y[:, f]), np.asarray(yf))


@pytest.mark.parametrize("B,N,F,M", [(4, 300, 3, 16), (2, 100, 6, 6)])
def test_fir_mp_bank_accumulate_matches_reference(B, N, F, M):
    k1, k2 = jax.random.split(jax.random.PRNGKey(11))
    x = jax.random.normal(k1, (B, N))
    H = jax.random.normal(k2, (F, M)) * 0.3
    s = fir_mp_bank_accumulate(x, H, 2.0)
    assert s.shape == (B, F)
    sr = ref.fir_mp_bank_accumulate_ref(x, H, 2.0)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=1e-5, atol=1e-3)


def test_vectorized_filterbank_matches_per_filter_loop():
    """The stacked-tap octave path reproduces the legacy per-filter loop."""
    from repro.core import mp as mp_mod
    cfg = FilterBankConfig(fs=4000.0, num_octaves=3, filters_per_octave=4,
                           mode="mp", gamma_f=4.0)
    fb = FilterBank(cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 512))
    s_vec = fb.accumulate(x)
    # legacy formulation: one mp_conv1d per filter, Python loop
    s_ref = []
    x_o = x
    for o in range(cfg.num_octaves):
        for p in range(cfg.filters_per_octave):
            h = jnp.asarray(fb.bp_taps[o * cfg.filters_per_octave + p])
            y = mp_mod.mp_conv1d(x_o, h, cfg.gamma_f, exact=False)
            s_ref.append(jnp.sum(jnp.maximum(y, 0.0), -1) * 2.0 ** o)
        if o < cfg.num_octaves - 1:
            lp = jnp.asarray(fb.lp_tap_list[o])
            x_o = mp_mod.mp_conv1d(x_o, lp, cfg.gamma_f, exact=False)[..., ::2]
    s_ref = jnp.stack(s_ref, axis=-1)
    np.testing.assert_allclose(np.asarray(s_vec), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# one-shot pipeline
# ---------------------------------------------------------------------------


def test_predict_jit_compiles_end_to_end():
    """audio (B, N) -> p (B, C) as ONE jit computation, pipeline as pytree."""
    pipe = _pipeline()
    x = jax.random.normal(jax.random.PRNGKey(4), (3, 1024))
    lowered = jax.jit(InFilterPipeline.predict).lower(pipe, x)
    compiled = lowered.compile()      # would raise on non-jittable path
    p = compiled(pipe, x)
    assert p.shape == (3, 5)
    assert bool(jnp.all(jnp.abs(p) <= 1.0 + 1e-5))
    # bound-method jit (captures the pipeline as constants) agrees; constant
    # folding fuses differently, so f32 round-off rather than bit equality
    p2 = jax.jit(pipe.predict)(x)
    np.testing.assert_allclose(np.asarray(p), np.asarray(p2), atol=2e-5)


def test_pipeline_is_pytree_serializable():
    pipe = _pipeline()
    leaves, treedef = jax.tree_util.tree_flatten(pipe)
    assert all(isinstance(l, jax.Array) for l in leaves)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.config == pipe.config
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 512))
    np.testing.assert_array_equal(np.asarray(pipe.predict(x)),
                                  np.asarray(rebuilt.predict(x)))


def test_fit_returns_working_pipeline():
    from repro.core.trainer import TrainConfig
    from repro.data.acoustic import make_esc10_like
    ds = make_esc10_like(per_class_train=3, per_class_test=1,
                         fs=4000.0, seconds=0.25)
    cfg = FilterBankConfig(fs=4000.0, num_octaves=3, filters_per_octave=3,
                           mode="mp", gamma_f=4.0)
    pipe, losses = InFilterPipeline.fit(
        cfg, ds.x_train, ds.y_train, num_classes=10,
        train_cfg=TrainConfig(num_steps=30, lr=0.5))
    assert len(losses) == 30 and losses[-1] <= losses[0] + 1e-3
    p = pipe.predict(jnp.asarray(ds.x_test))
    assert p.shape == (ds.x_test.shape[0], 10)


# ---------------------------------------------------------------------------
# streaming
# ---------------------------------------------------------------------------

N_STREAM = 2000


def _run_stream(pipe, x, chunk_len):
    B, N = x.shape
    state = pipe.init_state(B)
    p = None
    for i in range(0, N, chunk_len):
        state, p = pipe.step(state, x[:, i:i + chunk_len])
    return state, p


@pytest.mark.parametrize("chunk_len", [160, 1000, N_STREAM])
def test_streaming_matches_one_shot(chunk_len):
    """step() over chunks == predict() over the whole clip. The FIR windows
    (and therefore every MP solve) are sample-identical; only accumulator
    summation order differs, so parity is f32-tight."""
    pipe = _pipeline()
    x = jax.random.normal(jax.random.PRNGKey(6), (2, N_STREAM))
    p_one = pipe.predict(x)
    s_one = pipe.features(x) * pipe.sigma + pipe.mu   # raw accumulators
    state, p_stream = _run_stream(pipe, x, chunk_len)
    np.testing.assert_allclose(np.asarray(state.acc), np.asarray(s_one),
                               rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(p_stream), np.asarray(p_one),
                               atol=1e-4)


def test_streaming_odd_chunks_and_tail():
    """Chunk lengths that are odd (decimator phase exercises both parities)
    and do not divide N (short final chunk)."""
    pipe = _pipeline()
    x = jax.random.normal(jax.random.PRNGKey(8), (2, N_STREAM))
    p_one = pipe.predict(x)
    for chunk_len in [77, 333]:
        _, p_stream = _run_stream(pipe, x, chunk_len)
        np.testing.assert_allclose(np.asarray(p_stream), np.asarray(p_one),
                                   atol=1e-4, err_msg=f"chunk={chunk_len}")


def test_streaming_matches_one_shot_pallas():
    """Same parity through the fused Pallas bank kernels (interpret mode)."""
    pipe = _pipeline(num_octaves=2, filters_per_octave=3, fs=4000.0,
                     use_pallas=True)
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 512))
    p_one = pipe.predict(x)
    _, p_stream = _run_stream(pipe, x, 128)
    np.testing.assert_allclose(np.asarray(p_stream), np.asarray(p_one),
                               atol=1e-4)


def test_streaming_mac_mode():
    pipe = _pipeline(num_octaves=3, mode="mac")
    x = jax.random.normal(jax.random.PRNGKey(10), (2, 1000))
    p_one = pipe.predict(x)
    _, p_stream = _run_stream(pipe, x, 160)
    np.testing.assert_allclose(np.asarray(p_stream), np.asarray(p_one),
                               atol=1e-4)


def test_streaming_state_is_fixed_memory():
    """State sizes depend only on (B, config), never on stream length."""
    pipe = _pipeline()
    x = jax.random.normal(jax.random.PRNGKey(12), (2, N_STREAM))
    state0 = pipe.init_state(2)
    state, _ = _run_stream(pipe, x, 250)
    sizes0 = jax.tree.map(lambda a: a.shape, state0)
    sizes1 = jax.tree.map(lambda a: a.shape, state)
    assert sizes0 == sizes1
    assert int(state.consumed[0]) == N_STREAM
    # octave o consumed floor-halves per stage
    n = N_STREAM
    for o in range(1, pipe.config.num_octaves):
        n = (n + 1) // 2
        assert int(state.consumed[o]) == n


def test_step_is_jittable_with_pipeline_argument():
    pipe = _pipeline(num_octaves=3)
    x = jax.random.normal(jax.random.PRNGKey(13), (2, 600))
    p_one = pipe.predict(x)
    step = jax.jit(InFilterPipeline.step)
    state = pipe.init_state(2)
    for i in range(0, 600, 200):
        state, p = step(pipe, state, x[:, i:i + 200])
    np.testing.assert_allclose(np.asarray(p), np.asarray(p_one), atol=1e-4)
