"""Sharding rules, data pipeline, monitor, compression, and a subprocess
mini dry-run on 8 virtual devices."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.data.tokens import TokenStream
from repro.distributed import sharding as sh
from repro.distributed.monitor import StragglerMonitor

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestShardingRules:
    def _mesh(self):
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_param_specs_by_name(self):
        mesh = self._mesh()
        params = {
            "tok_embed": jax.ShapeDtypeStruct((512, 64), jnp.float32),
            "layers": {"attn": {"wq": jax.ShapeDtypeStruct((4, 64, 64),
                                                           jnp.float32)},
                       "norm1": {"scale": jax.ShapeDtypeStruct((64,),
                                                               jnp.float32)}},
        }
        specs = sh.param_specs(params, mesh)
        assert specs["tok_embed"] == P("model", "data")
        assert specs["layers"]["attn"]["wq"] == P(None, "data", "model")
        assert specs["layers"]["norm1"]["scale"] == P()

    def test_sanitize_drops_indivisible(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # axis size 1 divides everything -> kept
        assert sh.sanitize(("data", "model"), (7, 13), mesh) == P("data", "model")

    def test_batch_specs(self):
        mesh = self._mesh()
        batch = {"tokens": jax.ShapeDtypeStruct((8, 128), jnp.int32)}
        specs = sh.batch_specs(batch, mesh)
        assert specs["tokens"] == P(("data",), None)

    def test_moe_expert_weights_sharded_on_trailing(self):
        mesh = self._mesh()
        params = {"ffn": {"wi_gate": jax.ShapeDtypeStruct((3, 8, 16, 32),
                                                          jnp.float32)}}
        specs = sh.param_specs(params, mesh)
        assert specs["ffn"]["wi_gate"] == P(None, None, "data", "model")


class TestTokenStream:
    def test_deterministic(self):
        a = TokenStream(1000, 32, 8, seed=3).batch(7)
        b = TokenStream(1000, 32, 8, seed=3).batch(7)
        np.testing.assert_array_equal(a, b)

    def test_steps_differ(self):
        s = TokenStream(1000, 32, 8, seed=3)
        assert not np.array_equal(s.batch(1), s.batch(2))

    def test_sharding_partition(self):
        """Shards are disjoint rows of the same global batch."""
        full = TokenStream(500, 16, 8, seed=1, num_shards=1, shard=0).batch(5)
        s0 = TokenStream(500, 16, 8, seed=1, num_shards=2, shard=0).batch(5)
        s1 = TokenStream(500, 16, 8, seed=1, num_shards=2, shard=1).batch(5)
        assert s0.shape == (4, 16) and s1.shape == (4, 16)
        assert not np.array_equal(s0, s1)

    def test_in_vocab(self):
        t = TokenStream(100, 64, 4, seed=0).batch(0)
        assert t.min() >= 0 and t.max() < 100


class TestMonitor:
    def test_straggler_detection(self):
        mon = StragglerMonitor(threshold=1.5)
        for step in range(5):
            for h in range(4):
                mon.record(f"h{h}", 1.0 if h != 2 else 2.5, now=step * 10.0)
        assert mon.verdict("h2", now=50.0) == "straggler"
        assert mon.verdict("h0", now=50.0) == "ok"

    def test_stall_detection(self):
        mon = StragglerMonitor(stall_timeout_s=30)
        mon.record("h0", 1.0, now=0.0)
        assert mon.verdict("h0", now=10.0) == "ok"
        assert mon.verdict("h0", now=100.0) == "stall"


class TestCompression:
    def test_quant_dequant_error_feedback(self):
        from repro.distributed.compression import (_quant_dequant_int8,
                                                   compress_state_init)
        x = jax.random.normal(jax.random.PRNGKey(0), (100,))
        q, scale = _quant_dequant_int8(x)
        err = x - q.astype(jnp.float32) * scale
        # error bounded by half LSB
        assert float(jnp.max(jnp.abs(err))) <= float(scale) * 0.5 + 1e-6

    def test_error_feedback_converges(self):
        """Repeated compressed estimates of a CONSTANT gradient converge in
        average thanks to error feedback (the QSGD guarantee)."""
        from repro.distributed.compression import compressed_psum
        g = jax.random.normal(jax.random.PRNGKey(1), (64,)) * 1e-3

        # single-device psum via vmap-free trick: axis over pmap of size 1
        def step(err):
            ghat, err = jax.vmap(
                lambda g, e: compressed_psum(g, e, "i"), axis_name="i")(
                    g[None], err[None])
            return ghat[0], err[0]

        err = jnp.zeros_like(g)
        est = jnp.zeros_like(g)
        n = 50
        for _ in range(n):
            ghat, err = step(err)
            est = est + ghat / n
        assert float(jnp.max(jnp.abs(est - g))) < 2e-4


@pytest.mark.slow
def test_mini_dryrun_subprocess(tmp_path):
    """End-to-end dry-run machinery on 8 virtual devices (mesh 4x2),
    including roofline extraction — the same code path as the 256/512-chip
    run, in miniature."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import jax
from repro.configs import get_smoke
from repro.distributed import sharding as sh
from repro.launch import specs as S
from repro.launch.dryrun import lower_cell, roofline
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = dataclasses.replace(get_smoke("qwen3-8b"), remat=True)
cell = S.ShapeCell("t", 128, 8, "train")
with mesh:
    lowered = lower_cell(cfg, cell, mesh)
    comp = lowered.compile()
r = roofline(comp, comp.as_text(), 8, cfg, cell)
m = comp.memory_analysis()
assert r["hlo_flops_per_device"] > 0
assert r["collective_bytes"]["total"] > 0   # multi-pod must communicate
assert m.temp_size_in_bytes > 0
print("MINI_DRYRUN_OK", r["dominant"])
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=540)
    assert "MINI_DRYRUN_OK" in out.stdout, out.stdout + out.stderr
