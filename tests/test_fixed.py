"""The fixed-point hardware twin (repro.core.fixed): bit-true parity
between the int32 execution and the fake-quant float simulation, LSB
properties of the integer MP bisection, the multiplierless census gate,
and the numerics-mode plumbing through pipeline/filterbank/serving."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fixed
from repro.core import kernel_machine as km
from repro.core import mp as mp_mod
from repro.core.filterbank import (FilterBank, FilterBankConfig,
                                   multirate_accumulate)
from repro.core.pipeline import InFilterPipeline
from repro.core.quant import pow2_spec_for


def _pipeline(num_octaves=3, filters_per_octave=3, num_classes=5,
              fs=8000.0, seed=0, **cfg_over) -> InFilterPipeline:
    kw = dict(mode="mp", gamma_f=4.0)
    kw.update(cfg_over)
    cfg = FilterBankConfig(fs=fs, num_octaves=num_octaves,
                           filters_per_octave=filters_per_octave, **kw)
    fb = FilterBank(cfg)
    P = cfg.num_filters
    clf = km.init_params(jax.random.PRNGKey(seed), P, num_classes)
    mu = jax.random.normal(jax.random.PRNGKey(seed + 1), (P,)) * 0.1
    sigma = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed + 2),
                                      (P,))) + 0.5
    return InFilterPipeline.from_filterbank(fb, clf, mu, sigma)


def _audio(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# the load-bearing contract: int32 execution == fake-quant float simulation,
# bit for bit, at every recorded stage
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,seed", [("mp", 0), ("mp", 7), ("mac", 0)])
def test_int_and_float_carriers_agree_bitwise(mode, seed):
    """The SAME program run on int32 codes and on float arrays carrying
    the codes must produce identical integers at every surface (p, phi,
    accumulators) — shifts floor identically, adds are exact, compares
    agree. This is what makes the integer path *provably* the float
    simulation's hardware twin rather than an approximation of it."""
    x = _audio((3, 400), seed=seed)
    pipe = _pipeline(mode=mode, fixed_amax=float(np.abs(x).max()),
                     numerics="fixed", seed=seed)
    prog = pipe.fixed_program(calibration_audio=x)
    out_i = fixed.infer_q(prog, fixed.quantize_signal(prog, x, "int"))
    out_f = fixed.infer_q(prog, fixed.quantize_signal(prog, x, "float"))
    for a, b, name in zip(out_i, out_f, ["p_q", "phi_q", "s_q"]):
        a = np.asarray(a)
        b = np.asarray(b)
        assert np.issubdtype(a.dtype, np.integer), name
        assert np.issubdtype(b.dtype, np.floating), name
        np.testing.assert_array_equal(a, b.astype(np.int64),
                                      err_msg=f"{name}: carriers diverged")


def test_int_and_float_carriers_agree_under_jit():
    x = _audio((2, 300), seed=3)
    pipe = _pipeline(numerics="fixed", fixed_amax=float(np.abs(x).max()))
    prog = pipe.fixed_program(calibration_audio=x)
    f_int = jax.jit(lambda q: fixed.infer_q(prog, q))
    f_flt = jax.jit(lambda q: fixed.infer_q(prog, q))
    out_i = f_int(fixed.quantize_signal(prog, x, "int"))
    out_f = f_flt(fixed.quantize_signal(prog, x, "float"))
    for a, b in zip(out_i, out_f):
        np.testing.assert_array_equal(np.asarray(a),
                                      np.asarray(b).astype(np.int64))


# ---------------------------------------------------------------------------
# integer MP bisection: LSB-exact root bracketing
# ---------------------------------------------------------------------------


def test_fxp_mp_bisect_is_lsb_exact():
    """The returned z is the smallest grid point with h(z) <= gamma:
    h(z) <= gamma < h(z - 1)."""
    rng = np.random.default_rng(0)
    L = jnp.asarray(rng.integers(-200, 200, size=(64, 9)), jnp.int32)
    for gamma_q in (1, 7, 64, 300):
        z = fixed.fxp_mp_bisect(L, gamma_q, fixed.bisect_iters(gamma_q))
        h = lambda zz: np.sum(np.maximum(np.asarray(L) -
                                         np.asarray(zz)[:, None], 0), -1)
        assert (h(z) <= gamma_q).all()
        assert (h(z - 1) > gamma_q).all()


def test_fxp_mp_bisect_tracks_float_solver_within_one_lsb():
    rng = np.random.default_rng(1)
    spec = pow2_spec_for(None, 10, amax=4.0)
    Lf = jnp.asarray(rng.uniform(-3, 3, size=(32, 8)), jnp.float32)
    Lq = spec.quantize(Lf)
    gamma = 2.0
    gamma_q = int(round(gamma / spec.scale))
    z_q = fixed.fxp_mp_bisect(Lq, gamma_q, fixed.bisect_iters(gamma_q))
    z_f = mp_mod.mp_bisect(spec.dequantize(Lq), gamma)
    err = np.abs(np.asarray(spec.dequantize(z_q)) - np.asarray(z_f))
    assert err.max() <= spec.scale * 1.001


def test_fxp_mpabs_matches_concatenated_bisect():
    rng = np.random.default_rng(2)
    u = jnp.asarray(rng.integers(-300, 300, size=(16, 6)), jnp.int32)
    gamma_q = 40
    it = fixed.bisect_iters(gamma_q)
    z1 = fixed.fxp_mpabs(u, gamma_q, it)
    z2 = fixed.fxp_mp_bisect(jnp.concatenate([u, -u], axis=-1), gamma_q, it)
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))


# ---------------------------------------------------------------------------
# shift/add primitives
# ---------------------------------------------------------------------------


def test_shift_right_floor_semantics_both_carriers():
    q = jnp.asarray([-7, -6, -5, -1, 0, 1, 5, 6, 7], jnp.int32)
    for k in (1, 2, 3):
        want = np.floor(np.asarray(q) / 2.0 ** k)
        np.testing.assert_array_equal(
            np.asarray(fixed.shift_right(q, k)), want)
        np.testing.assert_array_equal(
            np.asarray(fixed.shift_right(q.astype(jnp.float32), k)), want)


def test_rescale_array_shifts_match_scalar():
    q = jnp.asarray([[-33, 17, 1024, -5]], jnp.int32)
    ks = jnp.asarray([2, -1, -3, 0], jnp.int32)
    got = np.asarray(fixed.rescale(q, ks))[0]
    want = [fixed.rescale(q[0, i], int(ks[i])) for i in range(4)]
    np.testing.assert_array_equal(got, np.asarray(want))


def test_fxp_fir_shift_add_equals_integer_convolution():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(-100, 100, size=(2, 50)), jnp.int32)
    h = rng.integers(-127, 128, size=7)
    y = np.asarray(fixed.fxp_fir_shift_add(x, h))
    for b in range(2):
        ref = np.convolve(np.asarray(x)[b], h)[:50]
        np.testing.assert_array_equal(y[b], ref)


def test_csd_reconstructs_value():
    for v in list(range(-130, 131)) + [1023, -1024, 255]:
        assert sum(s << b for s, b in fixed._csd(v)) == v


# ---------------------------------------------------------------------------
# end-to-end: the paper's esc10-mp configuration
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_fixed_predict_runs_esc10_mp_config():
    """Acceptance: predict on the paper's deployed configuration runs end
    to end through the integer path."""
    from repro.configs.esc10_mp import make_pipeline
    x = _audio((2, 16000), seed=5, scale=0.5)
    pipe = make_pipeline(numerics="fixed", fixed_amax=float(np.abs(x).max()))
    p = np.asarray(pipe.apply(jnp.asarray(x)))
    assert p.shape == (2, 10)
    assert np.isfinite(p).all()
    assert np.abs(p).max() <= 1.0 + 1e-6


def test_fixed_close_to_float_with_realistic_standardization():
    """With mu/sigma that are actually the feature statistics (as training
    produces), the 8/10-bit twin must land near the float engine: highly
    correlated phi and mostly-agreeing decisions."""
    x = _audio((12, 600), seed=6)
    pipe = _pipeline(num_octaves=3, filters_per_octave=3)
    s = np.asarray(pipe.apply(jnp.asarray(x), return_features=True)[1]) \
        * np.asarray(pipe.sigma) + np.asarray(pipe.mu)  # undo fake stats
    mu = jnp.asarray(s.mean(0))
    sigma = jnp.asarray(s.std(0, ddof=1) + 1e-6)
    pipe = InFilterPipeline(pipe.config, pipe.bp_taps, pipe.lp_taps,
                            mu, sigma, pipe.clf)
    p_flt, phi_flt = pipe.apply(jnp.asarray(x), return_features=True)
    prog = fixed.compile_pipeline(pipe, calibration_audio=x)
    p_fix, phi_fix = fixed.predict(prog, jnp.asarray(x))
    corr = np.corrcoef(np.asarray(phi_flt).ravel(),
                       np.asarray(phi_fix).ravel())[0, 1]
    assert corr > 0.95, corr
    agree = (np.asarray(p_flt).argmax(1) == np.asarray(p_fix).argmax(1))
    assert agree.mean() >= 0.5, agree


# ---------------------------------------------------------------------------
# the multiplierless gate, as a test (the benchmark asserts it too)
# ---------------------------------------------------------------------------


def test_integer_jaxpr_is_multiplierless():
    from benchmarks.hardware_cost import assert_multiplierless, census
    x = _audio((1, 200), seed=7)
    for mode in ("mp", "mac"):
        pipe = _pipeline(num_octaves=2, filters_per_octave=2, mode=mode,
                         numerics="fixed",
                         fixed_amax=float(np.abs(x).max()))
        prog = pipe.fixed_program()
        xq = fixed.quantize_signal(prog, x)
        c = census(lambda q: fixed.infer_q(prog, q), xq)
        assert_multiplierless(c, f"test-{mode}")
        assert c["add"] > 0 and c["compare"] > 0  # it actually computed


@pytest.mark.parametrize("mode", ["mp", "mac"])
def test_session_step_carriers_agree_bitwise(mode):
    """The integer session step is carrier-generic like every fxp_* kernel:
    int32 registers (the hardware) and float-carried integer registers (the
    fake-quant twin) march through identical chunked states."""
    x = _audio((2, 320), seed=17)
    pipe = _pipeline(mode=mode, numerics="fixed",
                     fixed_amax=float(np.abs(x).max()))
    prog = pipe.fixed_program()
    xq_i = fixed.quantize_signal(prog, jnp.asarray(x), "int")
    xq_f = fixed.quantize_signal(prog, jnp.asarray(x), "float")
    st_i = pipe.init_session(2)
    # carrier registers go float; count/consumed stay int bookkeeping
    st_f = st_i._replace(
        delays=tuple(d.astype(jnp.float32) for d in st_i.delays),
        acc=st_i.acc.astype(jnp.float32),
        amax=st_i.amax.astype(jnp.float32))
    n = jnp.full((2,), 160, jnp.int32)
    for off in (0, 160):
        st_i, p_i, phi_i = fixed.session_step_q(
            prog, st_i, xq_i[:, off:off + 160], n)
        st_f, p_f, phi_f = fixed.session_step_q(
            prog, st_f, xq_f[:, off:off + 160], n)
        np.testing.assert_array_equal(np.asarray(p_i),
                                      np.asarray(p_f).astype(np.int64))
    np.testing.assert_array_equal(np.asarray(st_i.acc),
                                  np.asarray(st_f.acc).astype(np.int64))


# ---------------------------------------------------------------------------
# numerics-mode plumbing
# ---------------------------------------------------------------------------


def test_pipeline_apply_routes_fixed_and_streams_it():
    x = _audio((2, 300), seed=8)
    pipe = _pipeline(numerics="fixed", fixed_amax=float(np.abs(x).max()))
    p, phi = pipe.apply(jnp.asarray(x), return_features=True)
    assert p.shape[0] == 2 and phi.shape[0] == 2
    # dequantized outputs sit exactly on their grids
    prog = pipe.fixed_program()
    np.testing.assert_array_equal(
        np.asarray(p) / prog.out_spec.scale,
        np.round(np.asarray(p) / prog.out_spec.scale))
    # the session path runs the SAME integer program chunk-by-chunk:
    # int32 registers, decisions exactly equal to the one-shot codes
    state = pipe.init_session(2)
    assert state.acc.dtype == jnp.int32
    p_s = None
    for i in range(0, 300, 77):
        p_s, state = pipe.apply(jnp.asarray(x[:, i:i + 77]), state)
    np.testing.assert_array_equal(np.asarray(p_s), np.asarray(p))


def test_fixed_apply_under_jit_raises_with_guidance():
    """jitting apply directly would trace the pipeline leaves into the
    host-side program lowering — the error must say what to do instead."""
    x = _audio((1, 200), seed=11)
    pipe = _pipeline(num_octaves=2, filters_per_octave=2, numerics="fixed")
    with pytest.raises(TypeError, match="fixed_program"):
        jax.jit(InFilterPipeline.apply)(pipe, jnp.asarray(x))
    # the supported pattern: precompile, then jit the program
    prog = pipe.fixed_program()
    p = jax.jit(lambda xx: fixed.predict(prog, xx))(jnp.asarray(x))[0]
    np.testing.assert_array_equal(np.asarray(p),
                                  np.asarray(pipe.apply(jnp.asarray(x))))


def test_fixed_features_rejects_amax_override():
    pipe = _pipeline(num_octaves=2, filters_per_octave=2, numerics="fixed")
    x = jnp.asarray(_audio((1, 200), seed=12))
    with pytest.raises(ValueError, match="fixed_amax"):
        pipe.features(x, amax=jnp.asarray([0.5]))


def test_filterbank_accumulate_routes_fixed():
    x = _audio((2, 300), seed=9)
    cfg = FilterBankConfig(fs=8000.0, num_octaves=3, filters_per_octave=3,
                           mode="mp", gamma_f=4.0, numerics="fixed",
                           fixed_amax=float(np.abs(x).max()))
    fb_fix = FilterBank(cfg)
    fb_flt = FilterBank(cfg._replace(numerics="float"))
    s_fix = np.asarray(fb_fix.accumulate(jnp.asarray(x)))
    s_flt = np.asarray(fb_flt.accumulate(jnp.asarray(x)))
    rel = np.abs(s_fix - s_flt).max() / np.abs(s_flt).max()
    assert rel < 0.15, rel  # 8-bit twin tracks the float bank
    # the float helpers refuse to silently ignore the fixed program
    with pytest.raises(ValueError, match="float engine"):
        multirate_accumulate(jnp.asarray(x), fb_fix.bp_by_octave,
                             fb_fix.lp_filters, cfg)


def test_unknown_numerics_rejected():
    cfg = FilterBankConfig(numerics="int8")
    with pytest.raises(ValueError, match="numerics"):
        FilterBank(cfg)


def test_stream_server_serves_fixed_pipeline():
    """PR 5: the rejection is gone — a fixed-point pipeline streams, the
    server's registers are integer, and stats() reports the live mode."""
    from repro.serving import StreamServer
    pipe = _pipeline(numerics="fixed")
    srv = StreamServer(pipe, capacity=2)
    assert srv.stats()["numerics"] == "fixed"
    assert srv.state.acc.dtype == jnp.int32
    srv.open("s")
    (res,) = srv.feed([("s", _audio((160,), seed=21))])
    p = np.asarray(pipe.apply(jnp.asarray(_audio((160,), seed=21))[None]))[0]
    assert res.label == int(p.argmax())


def test_unsupported_fixed_helper_message_shape():
    """All remaining fixed rejections build here: follow-ups must NAME
    their ROADMAP item explicitly (NotImplementedError); the default is a
    wrong-entry-point redirect (ValueError, no ROADMAP claim)."""
    from repro.core.quant import unsupported_fixed
    err = unsupported_fixed("somewhere", followup="Some open item")
    assert isinstance(err, NotImplementedError)
    assert "ROADMAP.md" in str(err) and "Some open item" in str(err)
    err = unsupported_fixed("an entry point", hint="go there")
    assert isinstance(err, ValueError)
    assert "ROADMAP" not in str(err) and "go there" in str(err)


def test_stream_server_stats_surface_numerics():
    from repro.serving import StreamServer
    pipe = _pipeline()
    srv = StreamServer(pipe, capacity=2)
    assert srv.stats()["numerics"] == "float"


def test_octave_gain_calibration_monotone_grids():
    """Calibrated register grids are never coarser than the ADC grid and
    gains[0] is pinned to 0."""
    x = _audio((4, 500), seed=10)
    pipe = _pipeline(num_octaves=4, fixed_amax=float(np.abs(x).max()),
                     numerics="fixed")
    prog = pipe.fixed_program(calibration_audio=x)
    exps = [st.in_spec.exp for st in prog.bank.octaves]
    assert exps[0] == prog.signal.exp
    assert all(e <= prog.signal.exp for e in exps)


# ---------------------------------------------------------------------------
# the integer Pallas kernels: fir_mp_bank_q / fir_mp_stream_q (PR 6) —
# carrier-generic, bit-for-bit twins of the fxp_* XLA kernels
# ---------------------------------------------------------------------------


@pytest.mark.pallas
@pytest.mark.parametrize("carrier", ["int", "float"])
def test_bank_q_pallas_bitwise_matches_xla_both_carriers(carrier):
    """One-shot inference through the integer Pallas bank kernels equals
    the XLA integer path exactly, on the int32 carrier AND the f32-carried
    codes (the fake-quant twin) — the kernels are carrier-generic like the
    fxp_* ops they fuse."""
    x = _audio((3, 320), seed=4)
    pipe = _pipeline(numerics="fixed", fixed_amax=float(np.abs(x).max()))
    prog = pipe.fixed_program()
    xq = fixed.quantize_signal(prog, jnp.asarray(x), carrier)
    ref = fixed.infer_q(prog, xq)
    out = fixed.infer_q(prog, xq, use_pallas=True)
    for a, b, name in zip(out, ref, ["p_q", "phi_q", "s_q"]):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"{carrier} carrier: {name} diverged (pallas vs xla)")


@pytest.mark.pallas
def test_stream_q_masked_slots_inert_in_kernel():
    """Slots with n == 0 come back with bit-identical registers from the
    int streaming kernel itself (delay slides by 0, accumulator
    contributions are exactly +0, amax is max against zeroed codes) — the
    serving layer's padding rows are inert INSIDE the kernel, not by
    post-masking."""
    from repro.kernels import fir_mp_stream_q

    pipe = _pipeline(numerics="fixed", fixed_amax=3.0)
    prog = pipe.fixed_program()
    S, L = 4, 160
    state = pipe.init_session(S)
    xq = fixed.quantize_signal(prog, jnp.asarray(_audio((S, L), seed=5)))
    n = jnp.asarray([L, 0, 77, 0], jnp.int32)
    pos = jax.lax.broadcasted_iota(jnp.int32, (S, L), 1)
    xq = jnp.where(pos < n[:, None], xq, 0)
    step = jax.jit(lambda q, nn, d, c, a, am:
                   fir_mp_stream_q(prog, q, nn, d, c, a, am))
    delays, consumed, acc, amax = step(xq, n, state.delays, state.consumed,
                                       state.acc, state.amax)
    idle = np.asarray([1, 3])
    for o, (old, new) in enumerate(zip(state.delays, delays)):
        np.testing.assert_array_equal(np.asarray(old)[idle],
                                      np.asarray(new)[idle],
                                      err_msg=f"octave {o} delay moved")
    for o, (old, new) in enumerate(zip(state.consumed, consumed)):
        np.testing.assert_array_equal(np.asarray(old)[idle],
                                      np.asarray(new)[idle],
                                      err_msg=f"octave {o} consumed moved")
    np.testing.assert_array_equal(np.asarray(state.acc)[idle],
                                  np.asarray(acc)[idle])
    np.testing.assert_array_equal(np.asarray(state.amax)[idle],
                                  np.asarray(amax)[idle])
    # the fed slots DID move
    assert not np.array_equal(np.asarray(state.acc)[0], np.asarray(acc)[0])


@pytest.mark.pallas
def test_fixed_pallas_chunk_lengths_zero_and_one():
    """Single-sample chunks stream bit-identically through the int Pallas
    and int XLA steps, and a (S, 0) chunk is a pure readout for both: same
    decision as the last step, no register moves."""
    px = _pipeline(numerics="fixed", fixed_amax=3.0)
    pk = _pipeline(numerics="fixed", fixed_amax=3.0, stream_impl="pallas")
    appx = jax.jit(lambda st, ch, v: px.apply(ch, st, valid=v))
    appk = jax.jit(lambda st, ch, v: pk.apply(ch, st, valid=v))
    x = _audio((2, 5), seed=6)
    sx, sk = px.init_session(2), pk.init_session(2)
    p_x = p_k = None
    for i in range(x.shape[1]):
        ch = jnp.asarray(x[:, i:i + 1])
        v = jnp.ones((2,), jnp.int32)
        p_x, sx = appx(sx, ch, v)
        p_k, sk = appk(sk, ch, v)
        np.testing.assert_array_equal(np.asarray(p_x), np.asarray(p_k),
                                      err_msg=f"length-1 chunk {i}")
    for app, state, p_last in ((appx, sx, p_x), (appk, sk, p_k)):
        p0, state2 = app(state, jnp.zeros((2, 0)),
                         jnp.zeros((2,), jnp.int32))
        np.testing.assert_array_equal(np.asarray(p0), np.asarray(p_last))
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(sx), jax.tree.leaves(sk)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
