"""Filter bank tests: FIR design, multirate structure, MP vs MAC modes,
and the Fig. 4 downsampling claim (low-order filters suffice)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.filterbank import (FilterBank, FilterBankConfig,
                                   design_bandpass, design_lowpass, greenwood,
                                   _mac_fir)
from repro.data.acoustic import chirp


def freq_response(h, freqs, fs):
    n = np.arange(len(h))
    return np.array([abs(np.sum(h * np.exp(-2j * np.pi * f / fs * n)))
                     for f in freqs])


class TestFIRDesign:
    def test_lowpass_passes_dc_blocks_high(self):
        h = design_lowpass(31, 500.0, 8000.0)
        r = freq_response(h, [0.0, 100.0, 3000.0], 8000.0)
        assert r[0] > 0.95 and r[1] > 0.8 and r[2] < 0.15

    def test_bandpass_peaks_in_band(self):
        h = design_bandpass(63, 800.0, 1200.0, 8000.0)
        r_in = freq_response(h, [1000.0], 8000.0)[0]
        r_out = freq_response(h, [100.0, 3500.0], 8000.0)
        assert r_in > 0.7
        assert (r_out < 0.2).all()

    def test_greenwood_monotone(self):
        f = greenwood(np.linspace(0, 1, 10), 100, 8000)
        assert (np.diff(f) > 0).all()
        assert abs(f[0] - 100) < 1 and abs(f[-1] - 8000) < 1

    def test_mac_fir_equals_numpy_convolve(self):
        x = np.random.default_rng(0).standard_normal((2, 50)).astype(np.float32)
        h = np.random.default_rng(1).standard_normal(7).astype(np.float32)
        y = np.asarray(_mac_fir(jnp.asarray(x), jnp.asarray(h)))
        for b in range(2):
            ref = np.convolve(x[b], h)[:50]
            np.testing.assert_allclose(y[b], ref, atol=1e-4)


class TestMultirate:
    def test_downsampling_keeps_low_order_selective(self):
        """Fig. 4: with octave downsampling, 16-tap filters resolve low
        bands that would need ~200 taps at the full rate."""
        fs = 8000.0
        cfg = FilterBankConfig(fs=fs, num_octaves=4, filters_per_octave=3,
                               mode="mac")
        fb = FilterBank(cfg)
        n = int(fs)
        # a low tone (octave 4 territory) vs a high tone
        t = np.arange(n) / fs
        low = np.sin(2 * np.pi * 300 * t).astype(np.float32)[None]
        high = np.sin(2 * np.pi * 3000 * t).astype(np.float32)[None]
        s_low = np.asarray(fb.accumulate(jnp.asarray(low)))[0]
        s_high = np.asarray(fb.accumulate(jnp.asarray(high)))[0]
        # the strongest response to the low tone must come from a later
        # octave than to the high tone
        assert fb.octave_of[int(s_low.argmax())] > \
            fb.octave_of[int(s_high.argmax())]

    def test_chirp_sweeps_across_filters(self):
        """Chirp response (the Fig. 4 experiment): as frequency rises, the
        peak filter index must move towards earlier octaves."""
        fs = 8000.0
        cfg = FilterBankConfig(fs=fs, num_octaves=3, filters_per_octave=4,
                               mode="mac")
        fb = FilterBank(cfg)
        n = 2048
        lowc = chirp(n, fs, 150, 400)[None]
        highc = chirp(n, fs, 2200, 3800)[None]
        o_low = fb.octave_of[int(np.argmax(fb.accumulate(jnp.asarray(lowc))[0]))]
        o_high = fb.octave_of[int(np.argmax(fb.accumulate(jnp.asarray(highc))[0]))]
        assert o_low > o_high


class TestMPFilterBank:
    def test_mp_mode_tracks_mac_ordering(self):
        """MP approximation distorts gains (Fig. 6) but must preserve which
        bands are active — that is what training relies on."""
        fs = 4000.0
        x = jnp.asarray(np.random.default_rng(2)
                        .standard_normal((4, 1024)).astype(np.float32))
        mac = FilterBank(FilterBankConfig(fs=fs, num_octaves=3, mode="mac"))
        mp_ = FilterBank(FilterBankConfig(fs=fs, num_octaves=3, mode="mp",
                                          gamma_f=4.0))
        s_mac = np.asarray(mac.accumulate(x))
        s_mp = np.asarray(mp_.accumulate(x))
        for b in range(4):
            corr = np.corrcoef(s_mac[b], s_mp[b])[0, 1]
            assert corr > 0.5, corr

    def test_features_standardized(self):
        fs = 4000.0
        fb = FilterBank(FilterBankConfig(fs=fs, num_octaves=2, mode="mac"))
        x = jnp.asarray(np.random.default_rng(3)
                        .standard_normal((16, 512)).astype(np.float32))
        phi, mu, sigma = fb.features(x)
        np.testing.assert_allclose(np.asarray(phi.mean(0)), 0.0, atol=1e-4)
        np.testing.assert_allclose(np.asarray(phi.std(0, ddof=1)), 1.0,
                                   atol=1e-2)

    def test_quantized_taps(self):
        cfg = FilterBankConfig(fs=4000.0, num_octaves=2, quant_bits=8,
                               mode="mac")
        fb = FilterBank(cfg)
        for h in fb.bp_taps:
            u = np.unique(h)
            assert len(u) <= 256
