"""StreamServer serving-contract regressions: pow2 chunk-bound
validation, poisoned donated state after a failed step, normalized
unknown-session errors, and the bucket-ladder retrace bound.

Companion to tests/test_serving.py (lifecycle/parity); this file pins the
CONTRACT fixes: every constructor/lookup misuse fails loudly, with the
documented message shape, before it can cost a slot, a compile-cache
entry, or — worst — silently continue on donated-away register state.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernel_machine as km
from repro.core.filterbank import FilterBank, FilterBankConfig
from repro.core.pipeline import InFilterPipeline
from repro.serving import StreamServer, bucket_length


def _pipeline() -> InFilterPipeline:
    cfg = FilterBankConfig(fs=8000.0, num_octaves=3, filters_per_octave=3,
                           mode="mp", gamma_f=4.0)
    fb = FilterBank(cfg)
    P = cfg.num_filters
    clf = km.init_params(jax.random.PRNGKey(0), P, 5)
    mu = jax.random.normal(jax.random.PRNGKey(1), (P,)) * 0.1 + 1.0
    sigma = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (P,))) + 0.5
    return InFilterPipeline.from_filterbank(fb, clf, mu, sigma)


@pytest.fixture(scope="module")
def pipe():
    return _pipeline()


# ---------------------------------------------------------------------------
# pow2 validation at construction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", [
    dict(min_chunk=24, max_chunk=256),     # non-pow2 min
    dict(min_chunk=16, max_chunk=3000),    # non-pow2 max
    dict(min_chunk=48, max_chunk=96),      # both
])
def test_non_pow2_chunk_bounds_rejected(pipe, kw):
    with pytest.raises(ValueError, match="power of two"):
        StreamServer(pipe, capacity=2, **kw)


def test_pow2_chunk_bounds_accepted(pipe):
    srv = StreamServer(pipe, capacity=2, min_chunk=16, max_chunk=256)
    assert (srv.min_chunk, srv.max_chunk) == (16, 256)
    # degenerate single-bucket ladder is legal too
    StreamServer(pipe, capacity=2, min_chunk=64, max_chunk=64)


def test_non_pow2_rejection_beats_other_work(pipe):
    # the constructor must fail BEFORE compiling/allocating session state
    with pytest.raises(ValueError, match="power of two"):
        StreamServer(pipe, capacity=2, min_chunk=100, max_chunk=100)


# ---------------------------------------------------------------------------
# bucket-ladder property: the O(log) retrace bound, checked exhaustively
# ---------------------------------------------------------------------------


def test_bucket_length_distinct_bucket_bound():
    """For ANY stream of lengths, pow2 bounds admit at most
    log2(max/min) + 1 distinct buckets — the compiled-variant bound the
    server's docstring promises."""
    rng = np.random.default_rng(0)
    for _ in range(200):
        lo = 2 ** int(rng.integers(0, 8))
        hi = lo * 2 ** int(rng.integers(0, 8))
        ns = rng.integers(1, 4 * hi, size=500)
        buckets = {bucket_length(int(n), lo, hi) for n in ns}
        assert len(buckets) <= int(math.log2(hi // lo)) + 1
        for b in buckets:
            assert lo <= b <= hi and (b & (b - 1)) == 0


def test_bucket_length_covers_and_clamps():
    assert bucket_length(1, 16, 256) == 16
    assert bucket_length(17, 16, 256) == 32
    assert bucket_length(256, 16, 256) == 256
    assert bucket_length(10_000, 16, 256) == 256   # clamped: feed() splits
    with pytest.raises(ValueError):
        bucket_length(0, 16, 256)


# ---------------------------------------------------------------------------
# poisoned donated state after a failed step
# ---------------------------------------------------------------------------


def test_step_failure_poisons_server(pipe):
    srv = StreamServer(pipe, capacity=2, min_chunk=16, max_chunk=64)
    srv.open("a")
    srv.feed([("a", np.zeros(32, np.float32))])      # healthy first

    boom = RuntimeError("device OOM")

    def bad_step(p, state, chunk, valid):
        raise boom

    srv._step = bad_step
    # chunk of 160 with max_chunk=64 -> 3 waves; the failure happens on
    # wave 1 and must name it
    with pytest.raises(RuntimeError, match=r"wave 1") as ei:
        srv.feed([("a", np.zeros(160, np.float32))])
    assert ei.value.__cause__ is boom

    # every subsequent feed/open fails loudly, still naming the wave —
    # the donated state is gone, silently continuing would serve garbage
    with pytest.raises(RuntimeError, match="poisoned") as ei:
        srv.feed([("a", np.zeros(32, np.float32))])
    assert "wave 1" in str(ei.value)
    with pytest.raises(RuntimeError, match="poisoned"):
        srv.open("b")


def test_step_failure_mid_multi_wave_names_later_wave(pipe):
    srv = StreamServer(pipe, capacity=2, min_chunk=16, max_chunk=64)
    srv.open("a")
    real_step = srv._step
    calls = {"n": 0}

    def flaky_step(p, state, chunk, valid):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("transient")
        return real_step(p, state, chunk, valid)

    srv._step = flaky_step
    # 3 segments -> wave 2 of THIS feed() call fails (first wave absorbed)
    with pytest.raises(RuntimeError, match=r"wave 2"):
        srv.feed([("a", np.zeros(192, np.float32))])
    with pytest.raises(RuntimeError, match="poisoned"):
        srv.feed([("a", np.zeros(16, np.float32))])


def test_healthy_server_is_not_poisoned(pipe):
    srv = StreamServer(pipe, capacity=2, min_chunk=16, max_chunk=64)
    srv.open("a")
    srv.feed([("a", np.zeros(200, np.float32))])     # multi-wave, fine
    srv.feed([("a", np.zeros(16, np.float32))])
    assert srv.stats()["steps_run"] >= 2


# ---------------------------------------------------------------------------
# normalized unknown-session errors: one shape everywhere
# ---------------------------------------------------------------------------


def test_unknown_session_error_shape_is_uniform(pipe, tmp_path):
    srv = StreamServer(pipe, capacity=2, min_chunk=16, max_chunk=64,
                       checkpoint_dir=str(tmp_path))
    srv.open("real")
    for call in (lambda: srv.session("ghost"),
                 lambda: srv.close("ghost"),
                 lambda: srv.evict("ghost"),
                 lambda: srv.feed([("ghost", np.zeros(16, np.float32))])):
        with pytest.raises(KeyError, match=r"session 'ghost' is not open"):
            call()
    # the known session still works after each failed lookup
    srv.feed([("real", np.zeros(16, np.float32))])


def test_evict_unknown_session_reports_session_not_checkpoint_dir(pipe):
    # no checkpoint_dir AND unknown id: the session lookup must win —
    # "needs checkpoint_dir" for a non-resident id was a misdiagnosis
    srv = StreamServer(pipe, capacity=2, min_chunk=16, max_chunk=64)
    with pytest.raises(KeyError, match=r"session 'ghost' is not open"):
        srv.evict("ghost")
    # a RESIDENT session without a manager still gets the RuntimeError
    srv.open("real")
    with pytest.raises(RuntimeError, match="checkpoint_dir"):
        srv.evict("real")
