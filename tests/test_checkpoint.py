"""Checkpoint manager: roundtrip, atomicity, GC, resharding restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "b": jnp.zeros((4,))},
            "opt": {"mu": jnp.ones((8, 4)), "count": jnp.asarray(3)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    state = _state()
    mgr.save(10, state)
    restored, step = mgr.restore(jax.tree.map(jnp.zeros_like, state))
    assert step == 10
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(1, _state())
    mgr.wait()
    assert mgr.latest_step() == 1


def test_keep_last_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2, async_save=False)
    for s in [1, 2, 3, 4]:
        mgr.save(s, _state())
    assert mgr.all_steps() == [3, 4]


def test_no_tmp_dir_left_behind(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(5, _state())
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_restore_latest_of_many(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=5, async_save=False)
    for s in [2, 7, 4]:
        st = _state()
        st["params"]["w"] = st["params"]["w"] + s
        mgr.save(s, st)
    restored, step = mgr.restore(_state())
    assert step == 7


def test_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _state())
    bad = _state()
    bad["params"]["w"] = jnp.zeros((3, 3))
    with pytest.raises(ValueError, match="shape mismatch"):
        mgr.restore(bad)


def test_resharding_restore(tmp_path):
    """Save under one mesh, restore under a different one (elastic)."""
    from jax.sharding import PartitionSpec as P
    mesh_a = jax.make_mesh((1, 1), ("data", "model"))
    mesh_b = jax.make_mesh((1, 1), ("model", "data"))
    state = _state()
    specs = {"params": {"w": P("data", None), "b": P()},
             "opt": {"mu": P(None, "model"), "count": P()}}
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(3, state, mesh=mesh_a, specs=specs)
    restored, _ = mgr.restore(state, mesh=mesh_b, specs=specs)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_named_roundtrip_with_meta(tmp_path):
    """Named objects (serving sessions): atomic save, meta side data,
    overwrite, delete."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    row = {"acc": jnp.arange(4.0), "count": jnp.asarray(7)}
    assert not mgr.has_named("session-a")
    mgr.save_named("session-a", row, meta={"history": [[100, 3, 0.5]]})
    assert mgr.has_named("session-a")
    got, meta = mgr.restore_named("session-a",
                                  jax.tree.map(jnp.zeros_like, row))
    for a, b in zip(jax.tree.leaves(row), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert meta == {"history": [[100, 3, 0.5]]}
    # overwrite wins; step-indexed listing is unaffected by named entries
    mgr.save_named("session-a", jax.tree.map(lambda a: a + 1, row))
    got2, meta2 = mgr.restore_named("session-a", row)
    np.testing.assert_array_equal(np.asarray(got2["acc"]),
                                  np.asarray(row["acc"]) + 1)
    assert meta2 is None
    assert mgr.all_steps() == []
    mgr.delete_named("session-a")
    assert not mgr.has_named("session-a")
    with pytest.raises(FileNotFoundError):
        mgr.restore_named("session-a", row)
    with pytest.raises(ValueError, match="checkpoint name"):
        mgr.save_named("../evil", row)


def test_training_resume_continues_loss(tmp_path):
    """End-to-end: 10 steps, ckpt, new process-state, resume, loss continues
    (integration of manager + steps + data determinism)."""
    from repro.configs import get_smoke
    from repro.data.tokens import TokenStream
    from repro.distributed.steps import make_train_step
    from repro.optim import AdamWConfig
    import dataclasses

    cfg = dataclasses.replace(get_smoke("qwen3-8b"), num_layers=2)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    init_state, train_step = make_train_step(cfg, opt)
    step_fn = jax.jit(train_step)
    stream = TokenStream(cfg.vocab_size, 32, 4, seed=1)
    mgr = CheckpointManager(str(tmp_path), async_save=False)

    state = init_state(jax.random.PRNGKey(0))
    for s in range(10):
        state, m = step_fn(state, {"tokens": jnp.asarray(stream.batch(s))})
    loss10 = float(m["loss"])
    mgr.save(10, state)

    state2 = init_state(jax.random.PRNGKey(42))  # different init
    state2, start = mgr.restore(state2)
    assert start == 10
    state2, m2 = step_fn(state2, {"tokens": jnp.asarray(stream.batch(10))})
    assert abs(float(m2["loss"]) - loss10) < 1.0  # continues, no reset spike
