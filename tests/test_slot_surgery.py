"""Slot-surgery helpers on SessionState: clear_slots / set_active /
take_slot / put_slot — the host-side admission bookkeeping StreamServer
leans on (previously only covered indirectly through server lifecycles)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernel_machine as km
from repro.core.filterbank import FilterBank, FilterBankConfig
from repro.core.pipeline import (InFilterPipeline, clear_slots, put_slot,
                                 set_active, take_slot)


@pytest.fixture(scope="module")
def pipe():
    cfg = FilterBankConfig(fs=8000.0, num_octaves=3, filters_per_octave=2,
                           bp_taps=8, lp_taps=4, mode="mp", gamma_f=4.0)
    fb = FilterBank(cfg)
    P = cfg.num_filters
    clf = km.init_params(jax.random.PRNGKey(0), P, 4)
    return InFilterPipeline.from_filterbank(fb, clf, jnp.zeros((P,)),
                                            jnp.ones((P,)))


@pytest.fixture()
def fed_state(pipe):
    """A 4-slot session with distinct per-slot history in every register."""
    state = pipe.init_session(4)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 300))
    valid = jnp.asarray([300, 123, 57, 10], jnp.int32)  # distinct ages
    _, state = pipe.apply(x, state, valid=valid)
    return state


def _rows(state, idx):
    return [np.asarray(leaf)[np.asarray(idx)]
            for leaf in jax.tree.leaves(state)]


def test_clear_slots_zeroes_only_target_rows(fed_state):
    cleared = clear_slots(fed_state, [1, 3])
    # target rows: every register zeroed (active untouched by contract)
    for d in cleared.delays:
        assert not np.asarray(d[1]).any() and not np.asarray(d[3]).any()
    for c in cleared.consumed:
        assert int(c[1]) == 0 and int(c[3]) == 0
    for leaf in (cleared.acc, cleared.amax, cleared.count):
        assert not np.asarray(leaf)[np.asarray([1, 3])].any()
    np.testing.assert_array_equal(np.asarray(cleared.active),
                                  np.asarray(fed_state.active))
    # bystander rows bit-identical
    for a, b in zip(_rows(fed_state, [0, 2]), _rows(cleared, [0, 2])):
        np.testing.assert_array_equal(a, b)


def test_cleared_slot_behaves_like_fresh_session(pipe, fed_state):
    """After clear_slots, feeding a slot reproduces a brand-new stream
    bit-for-bit — no leakage from the previous tenant."""
    cleared = clear_slots(fed_state, [2])
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 128))
    p_reuse, st_reuse = pipe.apply(x, cleared)
    p_fresh, st_fresh = pipe.apply(x, pipe.init_session(4))
    np.testing.assert_array_equal(np.asarray(p_reuse[2]),
                                  np.asarray(p_fresh[2]))
    for a, b in zip(_rows(st_reuse, [2]), _rows(st_fresh, [2])):
        np.testing.assert_array_equal(a, b)


def test_set_active_flips_only_the_mask(fed_state):
    off = set_active(fed_state, [0, 2], False)
    assert not bool(off.active[0]) and not bool(off.active[2])
    assert bool(off.active[1]) and bool(off.active[3])
    for a, b in zip(jax.tree.leaves(fed_state._replace(active=None)),
                    jax.tree.leaves(off._replace(active=None))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    on = set_active(off, [0], True)
    assert bool(on.active[0]) and not bool(on.active[2])


def test_take_put_round_trip_is_identity(fed_state):
    """take_slot -> put_slot back into the same slot leaves the whole
    session bit-identical (the eviction/restore fast path)."""
    row = take_slot(fed_state, 1)
    # row tree is unbatched: leading S axis stripped everywhere
    assert row.acc.shape == fed_state.acc.shape[1:]
    assert row.delays[0].shape == fed_state.delays[0].shape[1:]
    back = put_slot(fed_state, 1, row)
    for a, b in zip(jax.tree.leaves(fed_state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_take_put_round_trip_under_jit(fed_state):
    take1 = jax.jit(lambda st: take_slot(st, 1))
    put1 = jax.jit(lambda st, row: put_slot(st, 1, row))
    back = put1(fed_state, take1(fed_state))
    for a, b in zip(jax.tree.leaves(fed_state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_put_slot_transplants_between_slots(pipe, fed_state):
    """Moving slot 0's registers into slot 3 makes slot 3 continue slot 0's
    stream: subsequent decisions match feeding the original slot."""
    row = take_slot(fed_state, 0)
    moved = put_slot(fed_state, 3, row)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 64))
    chunk = jnp.broadcast_to(x[0], (4, 64))       # same audio everywhere
    p_src, _ = pipe.apply(chunk, fed_state)
    p_dst, _ = pipe.apply(chunk, moved)
    np.testing.assert_array_equal(np.asarray(p_dst[3]), np.asarray(p_src[0]))


def test_surgery_composes_with_streaming_parity(pipe, fed_state):
    """clear + reactivate + transplant, then feed: both stream impls see
    the surgically edited state identically (bit-for-bit)."""
    cfg_k = pipe.config._replace(stream_impl="pallas")
    pipe_k = InFilterPipeline(cfg_k, pipe.bp_taps, pipe.lp_taps, pipe.mu,
                              pipe.sigma, pipe.clf)
    st = clear_slots(fed_state, [1])
    st = put_slot(st, 2, take_slot(st, 0))
    st = set_active(st, [3], False)
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 77))
    p_x, st_x = pipe.apply(x, st)
    p_k, st_k = pipe_k.apply(x, st)
    np.testing.assert_array_equal(np.asarray(p_x), np.asarray(p_k))
    for a, b in zip(jax.tree.leaves(st_x), jax.tree.leaves(st_k)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
