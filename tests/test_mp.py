"""Property tests for the Margin Propagation primitive (paper eq. 2-9).

The invariants below are exactly the reverse-water-filling definition and
the algebraic identities the hardware relies on."""

import jax
import jax.numpy as jnp
import numpy as np

# hypothesis when installed, the deterministic fallback sampler otherwise —
# shared by every property-testing module (see tests/conftest.py)
from conftest import given, st

from repro.core import mp as M


def _arr(data, shape):
    return jnp.asarray(np.asarray(data, dtype=np.float32).reshape(shape))


arrays = st.lists(st.floats(-50, 50, allow_nan=False),
                  min_size=2, max_size=64)
gammas = st.floats(0.01, 100.0, allow_nan=False)


class TestWaterFillingInvariant:
    @given(arrays, gammas)
    def test_constraint_satisfied(self, data, gamma):
        """sum_i [L_i - z]_+ == gamma — the defining equation."""
        L = jnp.asarray(np.asarray(data, np.float32))[None, :]
        z = M.mp_exact(L, gamma)
        h = jnp.sum(jnp.maximum(L - z[:, None], 0.0), axis=-1)
        np.testing.assert_allclose(np.asarray(h), gamma,
                                   rtol=2e-4, atol=2e-4)

    @given(arrays, gammas)
    def test_bisect_converges_to_exact(self, data, gamma):
        L = jnp.asarray(np.asarray(data, np.float32))[None, :]
        z_e = M.mp_exact(L, gamma)
        z_b = M.mp_bisect(L, gamma, iters=40)
        np.testing.assert_allclose(np.asarray(z_b), np.asarray(z_e),
                                   rtol=1e-4, atol=1e-4)

    @given(arrays, gammas, st.floats(-20, 20))
    def test_shift_equivariance(self, data, gamma, c):
        """MP(L + c, gamma) == MP(L, gamma) + c (hardware: DC offsets pass
        through untouched)."""
        L = jnp.asarray(np.asarray(data, np.float32))[None, :]
        z1 = M.mp_exact(L + c, gamma)
        z2 = M.mp_exact(L, gamma) + c
        np.testing.assert_allclose(np.asarray(z1), np.asarray(z2),
                                   rtol=1e-4, atol=1e-3)

    @given(arrays, gammas, st.floats(0.1, 8.0))
    def test_scale_equivariance(self, data, gamma, a):
        """MP(a*L, a*gamma) == a*MP(L, gamma) (shift-based scaling works)."""
        L = jnp.asarray(np.asarray(data, np.float32))[None, :]
        z1 = M.mp_exact(a * L, a * gamma)
        z2 = a * M.mp_exact(L, gamma)
        np.testing.assert_allclose(np.asarray(z1), np.asarray(z2),
                                   rtol=2e-4, atol=2e-3)

    @given(arrays, gammas)
    def test_monotone_in_gamma(self, data, gamma):
        """z strictly decreases as gamma grows (more water, lower level)."""
        L = jnp.asarray(np.asarray(data, np.float32))[None, :]
        z1 = M.mp_exact(L, gamma)
        z2 = M.mp_exact(L, gamma * 2.0)
        assert float(z2[0]) < float(z1[0]) + 1e-5

    @given(arrays, gammas)
    def test_bounds(self, data, gamma):
        """max(L) - gamma <= z <= max(L)."""
        L = jnp.asarray(np.asarray(data, np.float32))[None, :]
        z = float(M.mp_exact(L, gamma)[0])
        mx = float(jnp.max(L))
        assert mx - gamma - 1e-3 <= z <= mx + 1e-3

    @given(arrays, gammas)
    def test_permutation_invariance(self, data, gamma):
        L = np.asarray(data, np.float32)
        z1 = float(M.mp_exact(jnp.asarray(L)[None], gamma)[0])
        rng = np.random.default_rng(0)
        Lp = rng.permutation(L)
        z2 = float(M.mp_exact(jnp.asarray(Lp)[None], gamma)[0])
        np.testing.assert_allclose(z1, z2, rtol=1e-5, atol=1e-5)


class TestNewtonSolver:
    """The fast software solver: monotone Newton on the convex piecewise-
    linear constraint must agree with the exact sort-based solution."""

    @given(arrays, gammas)
    def test_newton_matches_exact(self, data, gamma):
        L = jnp.asarray(np.asarray(data, np.float32))[None, :]
        z_n = M.mp_newton(L, gamma)
        z_e = M.mp_exact(L, gamma)
        np.testing.assert_allclose(np.asarray(z_n), np.asarray(z_e),
                                   rtol=1e-4, atol=1e-4)

    @given(arrays, gammas)
    def test_newton_never_overshoots(self, data, gamma):
        """Each tangent step stays LEFT of the root (convexity) — the
        invariant that makes a fixed iteration count safe."""
        L = jnp.asarray(np.asarray(data, np.float32))[None, :]
        for iters in (1, 3, 6, 12):
            z = float(M.mp_newton(L, gamma, iters=iters)[0])
            z_e = float(M.mp_exact(L, gamma)[0])
            assert z <= z_e + 1e-3 * max(1.0, abs(z_e))

    @given(st.integers(2, 32), gammas)
    def test_mpabs_newton_equals_concat_definition(self, d, gamma):
        u = jax.random.normal(jax.random.PRNGKey(d), (3, d)) * 3
        z1 = M.mpabs_newton(u, gamma)
        z2 = M.mp_exact(jnp.concatenate([u, -u], -1), gamma)
        np.testing.assert_allclose(np.asarray(z1), np.asarray(z2),
                                   rtol=1e-4, atol=1e-4)


class TestGradients:
    def test_grad_matches_finite_difference(self):
        key = jax.random.PRNGKey(0)
        L = jax.random.normal(key, (5, 17))
        g = 2.0
        f = lambda L: M.mp_exact(L, g).sum()
        an = jax.grad(f)(L)
        eps = 1e-3
        for (i, j) in [(0, 0), (2, 5), (4, 16)]:
            fd = (f(L.at[i, j].add(eps)) - f(L.at[i, j].add(-eps))) / (2 * eps)
            np.testing.assert_allclose(float(fd), float(an[i, j]),
                                       rtol=0.05, atol=1e-3)

    def test_gamma_grad(self):
        L = jax.random.normal(jax.random.PRNGKey(1), (3, 9))
        f = lambda g: M.mp_exact(L, g).sum()
        an = float(jax.grad(f)(1.5))
        eps = 1e-3
        fd = (f(1.5 + eps) - f(1.5 - eps)) / (2 * eps)
        np.testing.assert_allclose(fd, an, rtol=0.05, atol=1e-3)

    def test_grad_is_subgradient_structure(self):
        """dz/dL_i = 1{L_i > z}/k: nonneg, sums to 1 per row."""
        L = jax.random.normal(jax.random.PRNGKey(2), (4, 12))
        g = jax.jacrev(lambda L: M.mp_exact(L, 1.0))(L)
        # jacrev gives (4, 4, 12); take diagonal rows
        J = np.asarray(g)[np.arange(4), np.arange(4)]
        assert (J >= 0).all()
        np.testing.assert_allclose(J.sum(-1), 1.0, rtol=1e-5)


class TestMultiplierlessOps:
    @given(st.integers(2, 32), gammas)
    def test_mpabs_equals_concat_definition(self, d, gamma):
        u = jax.random.normal(jax.random.PRNGKey(d), (3, d))
        z1 = M.mpabs(u, gamma, exact=True)
        z2 = M.mp_exact(jnp.concatenate([u, -u], -1), gamma)
        np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), atol=1e-5)

    def test_mp_dot_approximates_dot_for_small_gamma_regime(self):
        """Paper Fig. 6: the approximation tracks the true inner product in
        sign/ordering even with distortion. Check rank correlation."""
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (64, 16)) * 0.5
        w = jax.random.normal(jax.random.PRNGKey(4), (16,)) * 0.5
        approx = np.asarray(M.mp_dot(x, w, 1.0))
        exact = np.asarray(x @ w)
        # Spearman-ish: correlation of ranks
        ra = np.argsort(np.argsort(approx))
        re = np.argsort(np.argsort(exact))
        corr = np.corrcoef(ra, re)[0, 1]
        assert corr > 0.8, corr

    def test_mp_linear_blocked_consistency(self):
        x = jax.random.normal(jax.random.PRNGKey(5), (4, 8))
        w = jax.random.normal(jax.random.PRNGKey(6), (8, 300))
        y1 = M.mp_linear(x, w, 1.0, block_out=128)
        y2 = M.mp_linear(x, w, 1.0, block_out=512)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)

    def test_mp_conv1d_matches_windows(self):
        x = jax.random.normal(jax.random.PRNGKey(7), (2, 50))
        h = jax.random.normal(jax.random.PRNGKey(8), (5,)) * 0.3
        y = M.mp_conv1d(x, h, 1.0)
        # manual check at position n: window [x_{n-4}..x_n] (zero padded)
        xp = np.asarray(jnp.pad(x, ((0, 0), (4, 0))))
        for n in [0, 3, 20, 49]:
            win = xp[:, n:n + 5]
            ref = M.mp_dot(jnp.asarray(win), h[::-1], 1.0)
            np.testing.assert_allclose(np.asarray(y[:, n]), np.asarray(ref),
                                       atol=1e-5)


class TestQuant:
    def test_fake_quant_8bit_precision(self):
        from repro.core.quant import fake_quant
        x = jax.random.normal(jax.random.PRNGKey(0), (100,))
        xq = fake_quant(x, 8)
        assert float(jnp.max(jnp.abs(x - xq))) < float(jnp.max(jnp.abs(x))) / 100
        # STE gradient passes through (the amax element sits exactly on the
        # clip boundary where jnp.maximum tie-splits to 0.5 — expected)
        g = np.asarray(jax.grad(lambda x: fake_quant(x, 8).sum())(x))
        assert (g >= 0.5 - 1e-6).all() and (g <= 1.0 + 1e-6).all()
        assert (g == 1.0).mean() > 0.95
