"""Golden regression case specs, shared by the fixture test
(tests/test_golden.py) and the regenerator (scripts/regen_golden.py).

Each case pins a full audio -> decision path: a seeded synthetic clip, a
pipeline configuration (taps and classifier weights derive deterministically
from the seed), a one-shot pass, and a fixed-chunking streamed pass through
BOTH stream impls. The expected outputs live in tests/golden/<name>.npz;
inputs are regenerated from the seed so fixtures stay tiny.
"""

from __future__ import annotations

import os

import numpy as np

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")

# (name, config overrides, audio shape, chunking)
CASES = {
    "esc_mp_f32": dict(
        cfg=dict(fs=8000.0, num_octaves=3, filters_per_octave=3,
                 mode="mp", gamma_f=4.0),
        shape=(2, 1000), chunk=160, seed=7,
    ),
    "esc_mp_quant8": dict(
        cfg=dict(fs=8000.0, num_octaves=3, filters_per_octave=3,
                 mode="mp", gamma_f=4.0, quant_bits=8),
        shape=(2, 1000), chunk=160, seed=11,
    ),
    "esc_mp_bisect": dict(
        cfg=dict(fs=4000.0, num_octaves=2, filters_per_octave=2,
                 mode="mp", gamma_f=4.0, solver="bisect"),
        shape=(1, 600), chunk=77, seed=13,
    ),
}


def build_pipeline(case: dict, stream_impl: str = "xla"):
    import jax
    import jax.numpy as jnp

    from repro.core import kernel_machine as km
    from repro.core.filterbank import FilterBank, FilterBankConfig
    from repro.core.pipeline import InFilterPipeline

    cfg = FilterBankConfig(**case["cfg"])._replace(stream_impl=stream_impl)
    fb = FilterBank(cfg)
    P = cfg.num_filters
    clf = km.init_params(jax.random.PRNGKey(case["seed"]), P, 5)
    mu = jax.random.normal(jax.random.PRNGKey(case["seed"] + 1), (P,)) * 0.1
    sigma = jnp.abs(
        jax.random.normal(jax.random.PRNGKey(case["seed"] + 2), (P,))) + 0.5
    return InFilterPipeline.from_filterbank(fb, clf, mu, sigma)


def make_audio(case: dict) -> np.ndarray:
    rng = np.random.default_rng(case["seed"])
    x = rng.standard_normal(case["shape"]).astype(np.float32)
    x[:, 0] = 2.5          # known peak: quantized streaming is calibrated
    return x


def compute_outputs(case: dict) -> dict:
    """The recorded surface: one-shot p/phi, streamed p (both impls), the
    final streamed accumulator registers, and the fixed-point hardware
    twin's INTEGER codes — one-shot (p/phi/accumulators) AND streamed
    through the int32 session step, via BOTH the XLA cascade
    (``*_stream_fixed_q``) and the int Pallas kernel
    (``*_stream_fixed_pallas_q``). The float entries gate with a small
    atol; every ``*_fixed*_q`` int entry must match EXACTLY — integer
    arithmetic either reproduces or it drifted."""
    import jax.numpy as jnp

    from repro.core import fixed

    x = jnp.asarray(make_audio(case))
    out = {}
    for impl in ("xla", "pallas"):
        pipe = build_pipeline(case, impl)
        if impl == "xla":
            p, phi = pipe.apply(x, return_features=True)
            out["p_oneshot"] = np.asarray(p)
            out["phi_oneshot"] = np.asarray(phi)
            # the integer twin: calibrated on this clip, default 8/10-bit
            prog = fixed.compile_pipeline(
                pipe, calibration_audio=np.asarray(x))
            p_q, phi_q, s_q = fixed.infer_q(
                prog, fixed.quantize_signal(prog, x))
            out["p_fixed_q"] = np.asarray(p_q, np.int32)
            out["phi_fixed_q"] = np.asarray(phi_q, np.int32)
            out["acc_fixed_q"] = np.asarray(s_q, np.int32)
            # int32 session streaming: same taps, same calibrated program
            # (pinned via calibrate_fixed), fed in the case's chunking —
            # must land on the SAME integer codes as the one-shot rows
            pipe_fx = build_pipeline(
                dict(case, cfg=dict(case["cfg"], numerics="fixed")), impl)
            pipe_fx.calibrate_fixed(np.asarray(x))
            state = pipe_fx.init_session(x.shape[0])
            p_s = None
            for i in range(0, x.shape[1], case["chunk"]):
                p_s, state = pipe_fx.apply(x[:, i:i + case["chunk"]], state)
            out["p_stream_fixed_q"] = np.asarray(
                np.round(np.asarray(p_s) / prog.out_spec.scale), np.int32)
            out["acc_stream_fixed_q"] = np.asarray(state.acc, np.int32)
        else:
            # int32 session streaming through the int PALLAS kernel
            # (fir_mp_stream_q): same calibrated program, same chunking —
            # the recorded codes must be IDENTICAL to the *_stream_fixed_q
            # rows above (and the one-shot rows): three paths, one answer
            pipe_fx = build_pipeline(
                dict(case, cfg=dict(case["cfg"], numerics="fixed")), impl)
            pipe_fx.calibrate_fixed(np.asarray(x))
            scale = pipe_fx.fixed_program().out_spec.scale
            state = pipe_fx.init_session(x.shape[0])
            p_s = None
            for i in range(0, x.shape[1], case["chunk"]):
                p_s, state = pipe_fx.apply(x[:, i:i + case["chunk"]], state)
            out["p_stream_fixed_pallas_q"] = np.asarray(
                np.round(np.asarray(p_s) / scale), np.int32)
            out["acc_stream_fixed_pallas_q"] = np.asarray(state.acc,
                                                          np.int32)
        state = pipe.init_session(x.shape[0],
                                  amax=jnp.max(jnp.abs(x), axis=-1))
        p_s = None
        for i in range(0, x.shape[1], case["chunk"]):
            p_s, state = pipe.apply(x[:, i:i + case["chunk"]], state)
        out[f"p_stream_{impl}"] = np.asarray(p_s)
        out[f"acc_stream_{impl}"] = np.asarray(state.acc)
    return out
