"""Cross-artifact consistency: the committed hardware artifacts must
agree with each other and with the static-analysis report.

Three generators write overlapping facts about the same programs:
``scripts/analyze.py`` (ANALYSIS.json: worst-case intervals),
``scripts/emit_ir.py`` (ir.json: the typed register table; alloc.json:
the width allocation the netlist declares). Each is drift-gated against
regeneration, but that only proves self-consistency — this file pins the
artifacts AGAINST EACH OTHER, from the committed files alone, so a
convention change in one generator (a different width rounding, a
dropped register) fails loudly naming the register instead of shipping a
netlist whose declared widths no longer match the proven intervals.
"""

import json
import os

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
IR_DIR = os.path.join(REPO, "artifacts", "ir")

TARGETS = ("oneshot_q", "session_step_q", "oneshot_q_pallas",
           "stream_pallas")
EXECUTABLE = ("oneshot_q", "session_step_q")


def _load(target, fname):
    with open(os.path.join(IR_DIR, target, fname)) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def analysis():
    with open(os.path.join(REPO, "ANALYSIS.json")) as f:
        return json.load(f)


def _min_signed_bits(lo, hi):
    n_hi = hi.bit_length() + 1 if hi >= 0 else 1
    n_lo = (-lo - 1).bit_length() + 1 if lo < 0 else 1
    return max(n_lo, n_hi, 1)


@pytest.mark.parametrize("target", TARGETS)
def test_required_bits_are_the_interval_minima(target):
    """Every typed register's committed ``required_bits`` is EXACTLY the
    minimal two's-complement width of its committed interval — the
    invariant the netlist's register declarations stand on."""
    doc = _load(target, "ir.json")
    checked = 0
    for rec in doc["registers"]:
        if rec["interval"] is None:
            assert rec["required_bits"] is None, \
                f"{target} r{rec['reg']}: width without an interval"
            continue
        lo, hi = rec["interval"]
        want = _min_signed_bits(int(lo), int(hi))
        assert rec["required_bits"] == want, (
            f"{target} r{rec['reg']}: committed required_bits="
            f"{rec['required_bits']} but interval [{lo}, {hi}] needs "
            f"{want}")
        checked += 1
    assert checked > 0, f"{target}: no typed registers in ir.json"


@pytest.mark.parametrize("target", TARGETS)
def test_ir_json_consistent_with_analysis_json(target, analysis):
    """The register table's worst case equals the static-analysis
    gate's: same max width, same headroom."""
    doc = _load(target, "ir.json")
    gate = analysis["targets"][target]["intervals"]
    widths = [r["required_bits"] for r in doc["registers"]
              if r["required_bits"] is not None and r["dtype"] == "i32"]
    assert max(widths) == gate["max_required_bits"], (
        f"{target}: ir.json worst register needs {max(widths)} bits, "
        f"ANALYSIS.json proves {gate['max_required_bits']}")
    assert 32 - max(widths) == gate["min_headroom_bits"]


@pytest.mark.parametrize("target", TARGETS)
def test_alloc_json_consistent_with_ir_json(target):
    """The allocator report prices exactly the registers ir.json
    declares: element totals and width histogram close the books."""
    doc = _load(target, "ir.json")
    rep = _load(target, "alloc.json")
    assert rep["program"] == target
    regs = rep["registers"]
    total_elems = sum(int(np.prod(r["shape"])) if r["shape"] else 1
                      for r in doc["registers"])
    rom_words = rep["roms"]["words"]
    assert regs["elements"] + rom_words == total_elems, (
        f"{target}: alloc.json prices {regs['elements']} register "
        f"elements + {rom_words} ROM words but ir.json declares "
        f"{total_elems}")
    assert regs["count"] + rep["roms"]["count"] == doc["num_registers"]
    assert sum(regs["width_histogram"].values()) == regs["count"]
    assert rep["roms"]["count"] == doc["num_roms"]
    assert rep["roms"]["bits_stored"] == 32 * rom_words
    assert rep["roms"]["bits_minimal"] <= rep["roms"]["bits_stored"]
    # widths never exceed the carrier; the histogram keys are widths
    assert all(1 <= int(w) <= 32 for w in regs["width_histogram"])
    assert regs["bits_allocated"] <= regs["bits_carrier"]


@pytest.mark.parametrize("target", EXECUTABLE)
def test_netlist_declares_the_allocated_widths(target):
    """program.v's memory declarations carry the alloc.json histogram:
    count the ``reg signed [W-1:0]`` declarations per width and compare
    (i1 registers are the unsigned 1-bit memories)."""
    import re
    rep = _load(target, "alloc.json")
    with open(os.path.join(IR_DIR, target, "program.v")) as f:
        text = f.read()
    decl = re.compile(
        r"^\s*reg(?:\s+signed\s+\[(\d+):0\])?\s+(r\d+)\s*\[", re.M)
    hist: dict = {}
    for m in decl.finditer(text):
        w = int(m.group(1)) + 1 if m.group(1) else 1
        hist[str(w)] = hist.get(str(w), 0) + 1
    want = dict(rep["registers"]["width_histogram"])
    # ROM-backed registers are $readmemh memories, not r<i> declarations,
    # so the netlist histogram must equal the allocator's exactly
    assert hist == want, (
        f"{target}: program.v declares widths {hist}, alloc.json "
        f"allocated {want}")
