"""MP kernel machine classifier (paper eq. 2-7) + training tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernel_machine as km
from repro.core import trainer
from repro.core.mp import mp_exact


def _params(P=6, C=3, seed=0, gamma1=4.0):
    return km.init_params(jax.random.PRNGKey(seed), P, C, gamma1=gamma1)


class TestForward:
    def test_output_range_and_identity(self):
        """p = p+ - p- with p+ + p- = 1 (gamma_n = 1) implies
        p == clip(z+ - z-, -1, 1)."""
        p0 = _params()
        K = jax.random.normal(jax.random.PRNGKey(1), (10, 6))
        p = km.forward(p0, K)
        assert float(jnp.max(jnp.abs(p))) <= 1.0 + 1e-5
        # recompute z+ and z- manually and check the clip identity
        wp = jax.nn.relu(p0.w_pos)
        wn = jax.nn.relu(p0.w_neg)
        g1 = jnp.exp(p0.log_gamma1)
        Kp, Kn = K[:, :, None], -K[:, :, None]
        ops_p = jnp.concatenate(
            [wp[None] + Kp, wn[None] + Kn,
             jnp.broadcast_to(p0.b_pos[None, None], (10, 1, 3))], 1)
        ops_n = jnp.concatenate(
            [wn[None] + Kp, wp[None] + Kn,
             jnp.broadcast_to(p0.b_neg[None, None], (10, 1, 3))], 1)
        zp = mp_exact(jnp.moveaxis(ops_p, 1, -1), g1)
        zn = mp_exact(jnp.moveaxis(ops_n, 1, -1), g1)
        np.testing.assert_allclose(np.asarray(p),
                                   np.clip(np.asarray(zp - zn), -1, 1),
                                   atol=1e-5)

    def test_sign_swap_antisymmetry(self):
        """Swapping (w+, w-) and (b+, b-) exchanges the eq. (3)/(4) operand
        multisets, so z+ and z- trade places and p flips sign — the
        differential-pair symmetry the hardware relies on."""
        p0 = _params()
        K = jax.random.normal(jax.random.PRNGKey(2), (4, 6))
        p1 = km.forward(p0, K)
        p_sw = p0._replace(w_pos=p0.w_neg, w_neg=p0.w_pos,
                           b_pos=p0.b_neg, b_neg=p0.b_pos)
        p2 = km.forward(p_sw, K)
        np.testing.assert_allclose(np.asarray(p1), -np.asarray(p2), atol=1e-5)

    def test_negated_kernel_with_swap_is_identity(self):
        """Negating K AND swapping the differential weights reproduces the
        same operand multisets (bias zero at init): p unchanged."""
        p0 = _params()
        K = jax.random.normal(jax.random.PRNGKey(5), (4, 6))
        p_sw = p0._replace(w_pos=p0.w_neg, w_neg=p0.w_pos,
                           b_pos=p0.b_neg, b_neg=p0.b_pos)
        np.testing.assert_allclose(np.asarray(km.forward(p0, K)),
                                   np.asarray(km.forward(p_sw, -K)),
                                   atol=1e-5)

    def test_baseline_decision_function(self):
        w = jax.random.normal(jax.random.PRNGKey(3), (6, 3))
        b = jnp.zeros((3,))
        K = jax.random.normal(jax.random.PRNGKey(4), (5, 6))
        np.testing.assert_allclose(np.asarray(km.forward_baseline(w, b, K)),
                                   np.asarray(K @ w), atol=1e-6)


class TestTraining:
    def _blobs(self, n=40, P=8, C=3, seed=0):
        rng = np.random.default_rng(seed)
        centers = rng.standard_normal((C, P)) * 2.0
        X, y = [], []
        for c in range(C):
            X.append(centers[c] + 0.5 * rng.standard_normal((n, P)))
            y.extend([c] * n)
        X = np.concatenate(X).astype(np.float32)
        y = np.asarray(y)
        perm = rng.permutation(len(y))
        return jnp.asarray(X[perm]), jnp.asarray(y[perm])

    def test_training_reaches_high_accuracy_on_blobs(self):
        K, y = self._blobs()
        cfg = trainer.TrainConfig(num_steps=250, lr=0.5, batch_size=64,
                                  gamma_anneal_start=4.0,
                                  gamma_anneal_steps=100)
        params, losses = trainer.train(K, y, 3, cfg)
        acc = trainer.evaluate(params, K, y)
        assert acc > 0.9, acc
        assert losses[-1] < losses[0]

    def test_quantization_aware_training_8bit(self):
        """Fig. 8: 8-bit fixed point holds accuracy."""
        K, y = self._blobs(seed=1)
        cfg = trainer.TrainConfig(num_steps=250, lr=0.5, batch_size=64,
                                  quant_bits=8)
        params, _ = trainer.train(K, y, 3, cfg)
        acc = trainer.evaluate(params, K, y, quant_bits=8)
        assert acc > 0.85, acc

    def test_gamma_annealing_improves_over_none(self):
        K, y = self._blobs(seed=2)
        accs = {}
        for start in (1.0, 4.0):
            cfg = trainer.TrainConfig(num_steps=150, lr=0.5,
                                      gamma_anneal_start=start,
                                      gamma_anneal_steps=75, seed=3)
            p, _ = trainer.train(K, y, 3, cfg)
            accs[start] = trainer.evaluate(p, K, y)
        # annealing should not hurt (paper: it mitigates approx error)
        assert accs[4.0] >= accs[1.0] - 0.05, accs
