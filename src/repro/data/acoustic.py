"""Synthetic acoustic datasets standing in for ESC-10 and FSDD (offline env).

ESC-10-like: ten structurally distinct environmental sound classes built
from the same ingredients as the real ones (band-limited noise, periodic
impulses, chirps, harmonic stacks, AM noise). Each sample is a 1-second clip
(paper trims ESC-10 clips to 1 s) at a configurable rate with per-sample
random variation (pitch, rate, SNR) so the task is non-trivial.

FSDD-like: two synthetic "speakers" saying digits — formant-synthesized
vowel-ish tones whose formant layout differs per speaker; the task is
speaker ID as in Table IV.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

__all__ = ["AcousticDataset", "make_esc10_like", "make_fsdd_like", "chirp",
           "ESC10_CLASSES"]

ESC10_CLASSES = [
    "dog", "rain", "sea_waves", "crying_baby", "clock_tick",
    "person_sneeze", "helicopter", "chainsaw", "rooster", "fire_crackling",
]


class AcousticDataset(NamedTuple):
    x_train: np.ndarray  # (M, N) float32 in [-1, 1]
    y_train: np.ndarray  # (M,) int
    x_test: np.ndarray
    y_test: np.ndarray
    class_names: list


def chirp(n: int, fs: float, f0: float, f1: float, amp: float = 1.0) -> np.ndarray:
    """Linear chirp used for the filter-bank gain-response figures (Fig. 4/6)."""
    t = np.arange(n) / fs
    k = (f1 - f0) / (n / fs)
    return (amp * np.sin(2 * np.pi * (f0 * t + 0.5 * k * t * t))).astype(np.float32)


def _bandnoise(rng, n, fs, f_lo, f_hi):
    x = rng.standard_normal(n + 256)
    X = np.fft.rfft(x)
    f = np.fft.rfftfreq(len(x), 1 / fs)
    X[(f < f_lo) | (f > f_hi)] = 0
    return np.fft.irfft(X)[:n]


def _impulse_train(rng, n, fs, rate_hz, decay, carrier=None):
    y = np.zeros(n)
    period = int(fs / rate_hz)
    phase = rng.integers(0, period)
    t = np.arange(n)
    for start in range(phase, n, period):
        m = n - start
        env = np.exp(-np.arange(m) / (decay * fs))
        y[start:] += env
    if carrier:
        y = y * np.sin(2 * np.pi * carrier * t / fs)
    return y


def _harmonic(rng, n, fs, f0, nharm, jitter=0.0):
    t = np.arange(n) / fs
    y = np.zeros(n)
    for h in range(1, nharm + 1):
        f = f0 * h * (1 + jitter * rng.standard_normal())
        if f < fs / 2:
            y += np.sin(2 * np.pi * f * t + rng.uniform(0, 2 * np.pi)) / h
    return y


def _synth_class(rng: np.random.Generator, cls: str, n: int, fs: float) -> np.ndarray:
    j = lambda lo, hi: rng.uniform(lo, hi)
    if cls == "dog":  # repeated barks: AM band noise bursts 400-900 Hz
        y = _bandnoise(rng, n, fs, j(300, 500), j(800, 1200))
        y *= _impulse_train(rng, n, fs, j(2, 4), 0.06)
    elif cls == "rain":  # broadband noise, mild high-freq tilt
        y = _bandnoise(rng, n, fs, j(800, 1500), fs / 2 * 0.95)
    elif cls == "sea_waves":  # low-freq AM broadband noise
        y = _bandnoise(rng, n, fs, 50, j(1200, 2500))
        t = np.arange(n) / fs
        y *= 0.6 + 0.4 * np.sin(2 * np.pi * j(0.2, 0.5) * t)
    elif cls == "crying_baby":  # harmonic sweep ~350-600 Hz fundamental
        y = _harmonic(rng, n, fs, j(350, 600), 8, 0.01)
        t = np.arange(n) / fs
        y *= 0.5 + 0.5 * np.sin(2 * np.pi * j(1.0, 2.0) * t) ** 2
    elif cls == "clock_tick":  # sharp periodic clicks ~2 Hz, bright
        y = _impulse_train(rng, n, fs, j(1.8, 2.2), 0.004, carrier=j(2500, 4500))
    elif cls == "person_sneeze":  # single broadband burst
        y = _bandnoise(rng, n, fs, j(200, 400), j(3000, 6000))
        c = rng.integers(n // 4, 3 * n // 4)
        env = np.exp(-((np.arange(n) - c) ** 2) / (2 * (0.05 * fs) ** 2))
        y *= env
    elif cls == "helicopter":  # low-rate rotor thump + low band noise
        y = _impulse_train(rng, n, fs, j(10, 14), 0.02, carrier=j(80, 160))
        y += 0.3 * _bandnoise(rng, n, fs, 40, 400)
    elif cls == "chainsaw":  # dense harmonic buzz ~100 Hz + noise
        y = _harmonic(rng, n, fs, j(90, 130), 20, 0.02)
        y += 0.4 * _bandnoise(rng, n, fs, 500, 4000)
    elif cls == "rooster":  # rising-falling harmonic whoop
        f0 = j(500, 800)
        sweep = chirp(n, fs, f0, f0 * j(1.5, 2.0))
        y = sweep + 0.5 * _harmonic(rng, n, fs, f0, 4, 0.02)
    elif cls == "fire_crackling":  # sparse random crackles
        y = np.zeros(n)
        for _ in range(rng.integers(10, 30)):
            c = rng.integers(0, n - 200)
            y[c:c + 200] += np.exp(-np.arange(200) / 30.0) * rng.standard_normal()
        y += 0.15 * _bandnoise(rng, n, fs, 100, 2000)
    else:
        raise ValueError(cls)
    y = y + 10 ** (-j(15, 25) / 20) * rng.standard_normal(n)  # noise floor
    y = y / (np.max(np.abs(y)) + 1e-9)
    return y.astype(np.float32)


def make_esc10_like(per_class_train: int = 24, per_class_test: int = 8,
                    fs: float = 16000.0, seconds: float = 1.0,
                    seed: int = 0) -> AcousticDataset:
    rng = np.random.default_rng(seed)
    n = int(fs * seconds)
    xs_tr, ys_tr, xs_te, ys_te = [], [], [], []
    for ci, cls in enumerate(ESC10_CLASSES):
        for _ in range(per_class_train):
            xs_tr.append(_synth_class(rng, cls, n, fs)); ys_tr.append(ci)
        for _ in range(per_class_test):
            xs_te.append(_synth_class(rng, cls, n, fs)); ys_te.append(ci)
    perm = rng.permutation(len(xs_tr))
    x_tr = np.stack(xs_tr)[perm]; y_tr = np.asarray(ys_tr)[perm]
    return AcousticDataset(x_tr, y_tr, np.stack(xs_te), np.asarray(ys_te),
                           list(ESC10_CLASSES))


def make_fsdd_like(per_speaker_train: int = 40, per_speaker_test: int = 12,
                   fs: float = 8000.0, seconds: float = 0.5,
                   seed: int = 1) -> AcousticDataset:
    """Two synthetic speakers; task = speaker identification (Table IV)."""
    rng = np.random.default_rng(seed)
    n = int(fs * seconds)
    # speaker-specific formant layouts (Hz)
    speakers = {
        0: dict(f0=(110, 140), formants=[(600, 80), (1100, 120), (2400, 160)]),
        1: dict(f0=(190, 240), formants=[(750, 90), (1500, 130), (2900, 170)]),
    }

    def sample(spk):
        sp = speakers[spk]
        f0 = rng.uniform(*sp["f0"])
        t = np.arange(n) / fs
        src = np.zeros(n)
        for h in range(1, int(fs / 2 / f0)):
            src += np.sin(2 * np.pi * f0 * h * t + rng.uniform(0, 2 * np.pi)) / h
        X = np.fft.rfft(src)
        f = np.fft.rfftfreq(n, 1 / fs)
        shape = np.zeros_like(f)
        for fc, bw in sp["formants"]:
            fc_j = fc * rng.uniform(0.93, 1.07)
            shape += np.exp(-0.5 * ((f - fc_j) / bw) ** 2)
        y = np.fft.irfft(X * (0.05 + shape), n)
        y += 10 ** (-20 / 20) * rng.standard_normal(n)
        return (y / (np.max(np.abs(y)) + 1e-9)).astype(np.float32)

    xs_tr, ys_tr, xs_te, ys_te = [], [], [], []
    for spk in speakers:
        for _ in range(per_speaker_train):
            xs_tr.append(sample(spk)); ys_tr.append(spk)
        for _ in range(per_speaker_test):
            xs_te.append(sample(spk)); ys_te.append(spk)
    perm = rng.permutation(len(xs_tr))
    return AcousticDataset(np.stack(xs_tr)[perm], np.asarray(ys_tr)[perm],
                           np.stack(xs_te), np.asarray(ys_te),
                           ["speaker_0", "speaker_1"])
