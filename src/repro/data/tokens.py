"""Deterministic synthetic token pipeline for LM training.

Design mirrors a production loader: the stream is addressed by (step, shard)
so any host can regenerate exactly its shard for any step — restart after a
failure needs no loader state in the checkpoint beyond the step counter, and
elastic rescaling (different shard count) re-partitions deterministically.

Tokens follow a Zipf-ish unigram draw mixed with short repeated motifs so a
model can actually reduce loss (tests train a ~1M-param model on it).
"""

from __future__ import annotations

import numpy as np

__all__ = ["TokenStream"]


class TokenStream:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, num_shards: int = 1, shard: int = 0):
        assert global_batch % num_shards == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.global_batch = global_batch
        self.local_batch = global_batch // num_shards
        self.seed = seed
        self.num_shards = num_shards
        self.shard = shard
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._p = p / p.sum()

    def batch(self, step: int) -> np.ndarray:
        """(local_batch, seq) int32, deterministic in (seed, step, shard)."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.shard)
        toks = rng.choice(self.vocab, size=(self.local_batch, self.seq),
                          p=self._p).astype(np.int32)
        # plant motifs: short ngrams repeated later in the sequence, giving
        # in-context structure (loss below unigram entropy is learnable)
        max_motif = min(12, max(self.seq // 4, 2))
        for b in range(self.local_batch):
            n_motif = rng.integers(2, 6)
            for _ in range(n_motif):
                L = int(rng.integers(2, max_motif)) if max_motif > 2 else 2
                if self.seq - 2 * L <= 0 or self.seq - L <= 0:
                    continue
                src = int(rng.integers(0, self.seq - 2 * L))
                dst = int(rng.integers(src + L, self.seq - L))
                toks[b, dst:dst + L] = toks[b, src:src + L]
        return toks
