"""Fault-tolerant checkpointing.

Guarantees that matter at 1000-node scale:
  * atomicity: write to ``step_XXXX.tmp/`` then os.rename — a crash mid-save
    never corrupts the latest restorable checkpoint;
  * async save: the host thread snapshots device arrays (device_get) and a
    background thread does the file I/O, so the train loop only blocks for
    the DMA, not the disk;
  * resharding restore: the manifest records the mesh + PartitionSpecs the
    ckpt was saved under; restore accepts a *different* mesh and re-shards
    via device_put (elastic scaling: resume a 512-chip run on 256 chips);
  * keep-last-k GC, with ``latest`` resolution by manifest step;
  * leaf addressing by flattened tree path, robust to dict ordering.

Multi-host note: in a true multi-host deployment each host writes only the
shards it owns (addressable_shards); here every array is fully addressable
so we write whole arrays — the manifest format already carries the sharding
metadata a per-shard writer needs.

Besides the step-indexed train checkpoints, the manager stores NAMED
objects (``save_named``/``restore_named``) — small atomic key-value
snapshots used by the serving layer to park evicted stream sessions
(per-slot DSP registers + decision history) so a reopened session resumes
exactly where it left off.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        elif hasattr(e, "name"):
            parts.append(str(e.name))
        else:
            parts.append(str(e))
    return "/".join(parts)


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: Any, mesh=None, specs=None) -> str:
        """Snapshot state (blocking only for device->host) and persist."""
        leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(state)
        host_leaves = [(_path_str(p), np.asarray(jax.device_get(x)))
                       for p, x in leaves_with_paths]
        manifest = {
            "step": int(step),
            "time": time.time(),
            "mesh_shape": list(mesh.devices.shape) if mesh is not None else None,
            "mesh_axes": list(mesh.axis_names) if mesh is not None else None,
            "leaves": [
                {"path": p, "shape": list(a.shape), "dtype": str(a.dtype),
                 "spec": self._spec_str(specs, p)}
                for p, a in host_leaves
            ],
        }
        self.wait()  # one in-flight save at a time
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_leaves, manifest),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host_leaves, manifest)
        return self._step_dir(step)

    def _spec_str(self, specs, path: str) -> Optional[str]:
        if specs is None:
            return None
        flat = {_path_str(p): s
                for p, s in jax.tree_util.tree_flatten_with_path(
                    specs, is_leaf=lambda x: isinstance(
                        x, jax.sharding.PartitionSpec))[0]}
        s = flat.get(path)
        return str(s) if s is not None else None

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def _write(self, step: int, host_leaves, manifest):
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for i, (path, arr) in enumerate(host_leaves):
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_last] if self.keep_last else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- named objects (serving sessions etc.) -------------------------------

    @staticmethod
    def _check_name(name: str) -> str:
        if not name or not all(ch.isalnum() or ch in "-_." for ch in name):
            raise ValueError(f"checkpoint name {name!r}: use [A-Za-z0-9._-]")
        return name

    def _named_dir(self, name: str) -> str:
        return os.path.join(self.dir, f"named_{self._check_name(name)}")

    def _resolve_named(self, name: str) -> str | None:
        """Directory currently holding ``name``: the published dir, or the
        ``.old`` version if a crash landed mid-publish (see save_named)."""
        d = self._named_dir(name)
        if os.path.isdir(d):
            return d
        if os.path.isdir(d + ".old"):
            return d + ".old"
        return None

    def has_named(self, name: str) -> bool:
        return self._resolve_named(name) is not None

    def save_named(self, name: str, state: Any, meta: Optional[dict] = None):
        """Atomically persist a small pytree under a string key. ``meta`` is
        arbitrary JSON-serializable side data (e.g. a session's decision
        history). Synchronous: named objects are tiny (KBs)."""
        leaves_with_paths, _ = jax.tree_util.tree_flatten_with_path(state)
        host_leaves = [(_path_str(p), np.asarray(jax.device_get(x)))
                       for p, x in leaves_with_paths]
        manifest = {
            "name": name,
            "time": time.time(),
            "meta": meta,
            "leaves": [{"path": p, "shape": list(a.shape),
                        "dtype": str(a.dtype)} for p, a in host_leaves],
        }
        final = self._named_dir(name)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for i, (_, arr) in enumerate(host_leaves):
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        # publish without a destroy-then-rename window: move the old version
        # aside first, so a crash at any point leaves either the old or the
        # new object under the key — never neither (a vanished session would
        # silently restart from cleared registers on reopen)
        old = final + ".old"
        if os.path.exists(old):
            shutil.rmtree(old)
        if os.path.exists(final):
            os.rename(final, old)
        os.rename(tmp, final)  # atomic publish
        if os.path.exists(old):
            shutil.rmtree(old)
        return final

    def restore_named(self, name: str, state_like: Any):
        """Load a named object into the structure of ``state_like``.
        Returns ``(state, meta)``."""
        d = self._resolve_named(name)
        if d is None:
            raise FileNotFoundError(f"no named checkpoint {name!r} in "
                                    f"{self.dir}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_path = {leaf["path"]: i for i, leaf in enumerate(manifest["leaves"])}
        leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(
            state_like)
        new_leaves = []
        for p, like in leaves_with_paths:
            key = _path_str(p)
            if key not in by_path:
                raise KeyError(f"named checkpoint {name!r} missing leaf {key}")
            arr = np.load(os.path.join(d, f"leaf_{by_path[key]:05d}.npy"))
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"expected {like.shape}")
            if np.dtype(arr.dtype) != np.dtype(like.dtype):
                # named objects promise bit-exact resume; a silent cast
                # (e.g. f32 session row into an f16 server) breaks that
                raise ValueError(
                    f"dtype mismatch for {key}: ckpt {arr.dtype} vs "
                    f"expected {np.dtype(like.dtype)}")
            new_leaves.append(jax.device_put(arr))
        return (jax.tree_util.tree_unflatten(treedef, new_leaves),
                manifest.get("meta"))

    def delete_named(self, name: str) -> None:
        shutil.rmtree(self._named_dir(name), ignore_errors=True)
        shutil.rmtree(self._named_dir(name) + ".old", ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self) -> list:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like: Any, step: Optional[int] = None,
                mesh=None, specs=None) -> tuple[Any, int]:
        """Restore into the structure of `state_like` (abstract or concrete).

        If mesh+specs are given, leaves are device_put with the NEW sharding
        regardless of the mesh the checkpoint was written on (resharding
        restore). Returns (state, step).
        """
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_path = {leaf["path"]: i for i, leaf in enumerate(manifest["leaves"])}

        leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(
            state_like)
        shardings = None
        if mesh is not None and specs is not None:
            spec_leaves = jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(
                    x, jax.sharding.PartitionSpec))[0]
            shardings = {_path_str(p): jax.sharding.NamedSharding(mesh, s)
                         for p, s in spec_leaves}

        new_leaves = []
        for p, like in leaves_with_paths:
            key = _path_str(p)
            if key not in by_path:
                raise KeyError(f"checkpoint {d} missing leaf {key}")
            arr = np.load(os.path.join(d, f"leaf_{by_path[key]:05d}.npy"))
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"expected {like.shape}")
            if shardings is not None and key in shardings:
                new_leaves.append(jax.device_put(arr, shardings[key]))
            else:
                new_leaves.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, new_leaves), step
