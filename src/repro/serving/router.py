"""Routing tier: stream id -> server shard -> slot.

One admission API in front of N ``StreamServer`` shards. The shard for a
stream is a STABLE hash of its id (crc32, not Python's salted ``hash``),
so a session always lands on the same shard across processes and restarts
— which is what lets an evicted session find its parked checkpoint again:
each shard parks into its own ``checkpoint_dir`` subdirectory
(``shard-00``, ``shard-01``, ...).

All shards serve the SAME pipeline through one shared compiled step
(:func:`repro.serving.server.make_batched_step`), so N shards cost one
compile per chunk bucket, not N. Capacity scales linearly with shard
count while decisions stay bit-for-bit those of a single server holding
the same sessions: the slot-batched step is row-parallel, so a stream's
registers never depend on its co-tenants, its slot, or the shard's
capacity.

Backpressure is per shard: admission pressure on a full shard evicts that
shard's least-recently-fed idle session into its checkpoint store (or
raises, if there is nowhere to park — exactly the single-server
contract), and ``stats()`` surfaces per-shard residency/queue depth so a
hot shard is visible before it starts refusing streams.
"""

from __future__ import annotations

import dataclasses
import os
import zlib
from typing import Iterable, List, Optional, Union

from repro.core.pipeline import InFilterPipeline
from repro.serving.server import StreamServer, make_batched_step
from repro.serving.session import FeedRequest, FeedResult, Session

__all__ = ["StreamRouter", "RouterTicket", "shard_of"]


def shard_of(session_id: str, num_shards: int) -> int:
    """Deterministic stream-id -> shard mapping (stable across runs)."""
    return zlib.crc32(session_id.encode("utf-8")) % num_shards


@dataclasses.dataclass
class RouterTicket:
    """Handle for one router ``submit()``: per-shard sub-tickets plus the
    request positions each covers, resolved back into request order."""
    n_requests: int
    parts: list                       # [(shard_idx, FeedTicket, [pos, ...])]
    results: Optional[List[FeedResult]] = None

    @property
    def done(self) -> bool:
        return self.results is not None

    def _try_assemble(self) -> None:
        if self.results is not None:
            return
        if not all(t.done for _, t, _ in self.parts):
            return
        out: list = [None] * self.n_requests
        for _, ticket, positions in self.parts:
            for res, pos in zip(ticket.results, positions):
                out[pos] = res
        self.results = out


class StreamRouter:
    """N ``StreamServer`` shards behind one admission/feed API.

    Parameters mirror ``StreamServer`` (they are applied per shard);
    ``capacity`` is PER SHARD, so total residency is
    ``num_shards * capacity``. ``checkpoint_dir`` (if given) fans out into
    one subdirectory per shard so eviction under churn works exactly as on
    a single server — per shard.
    """

    def __init__(self, pipeline: InFilterPipeline, num_shards: int = 2,
                 capacity: int = 64, *,
                 checkpoint_dir: Optional[str] = None, **server_kw):
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        self.num_shards = num_shards
        self.pipeline = pipeline
        step = server_kw.pop("step_fn", None) or make_batched_step(pipeline)
        self._shards = []
        for k in range(num_shards):
            ck = None
            if checkpoint_dir is not None:
                ck = os.path.join(checkpoint_dir, f"shard-{k:02d}")
                os.makedirs(ck, exist_ok=True)
            self._shards.append(
                StreamServer(pipeline, capacity, checkpoint_dir=ck,
                             step_fn=step, **server_kw))
        self._tickets: List[RouterTicket] = []   # outstanding (not done)

    # -- admission / lifecycle ----------------------------------------------

    def shard_of(self, session_id: str) -> int:
        return shard_of(session_id, self.num_shards)

    def shard(self, k: int) -> StreamServer:
        return self._shards[k]

    @property
    def shards(self) -> list:
        return list(self._shards)

    def open(self, session_id: str) -> Session:
        k = self.shard_of(session_id)
        try:
            return self._shards[k].open(session_id)
        except RuntimeError as e:
            # per-shard backpressure, named: a full shard is THIS shard
            # being full — other shards may have room, but the id is pinned
            # to its hash (its checkpoints live here)
            raise RuntimeError(f"shard {k}: {e}") from e

    def close(self, session_id: str, *, checkpoint: bool = False) -> Session:
        return self._shards[self.shard_of(session_id)].close(
            session_id, checkpoint=checkpoint)

    def evict(self, session_id: str) -> Session:
        return self._shards[self.shard_of(session_id)].evict(session_id)

    def session(self, session_id: str) -> Session:
        return self._shards[self.shard_of(session_id)].session(session_id)

    def sessions(self) -> list:
        out = []
        for srv in self._shards:
            out.extend(srv.sessions())
        return out

    def is_open(self, session_id: str) -> bool:
        return session_id in self._shards[self.shard_of(session_id)]

    def __contains__(self, session_id: str) -> bool:
        return self.is_open(session_id)

    def stats(self) -> dict:
        per = [s.stats() for s in self._shards]
        return {
            "num_shards": self.num_shards,
            "capacity": sum(p["capacity"] for p in per),
            "resident": sum(p["resident"] for p in per),
            "steps_run": sum(p["steps_run"] for p in per),
            "queued_requests": sum(p["queued_requests"] for p in per),
            "poisoned": {k: p["poisoned"] for k, p in enumerate(per)
                         if p["poisoned"] is not None} or None,
            "shards": per,
        }

    # -- feeding -------------------------------------------------------------

    def _split(self, requests) -> list:
        """Group requests by shard, preserving per-shard submit order and
        remembering each request's global position. Validates atomically
        ACROSS shards (unknown session / bad chunk raises before anything
        is enqueued anywhere)."""
        import numpy as np
        by_shard: dict[int, list] = {}
        n = 0
        for pos, r in enumerate(requests):
            if isinstance(r, FeedRequest):
                sid, chunk = r.session_id, r.chunk
            else:
                sid, chunk = r
            k = self.shard_of(sid)
            srv = self._shards[k]
            srv._check_poisoned()
            if sid not in srv:
                raise KeyError(f"session {sid!r} is not open")
            arr = np.asarray(chunk)
            if arr.ndim != 1:
                raise ValueError(
                    f"chunk for {sid!r} must be 1-D (samples,), got shape "
                    f"{arr.shape}")
            if arr.shape[0] == 0:
                raise ValueError(f"empty chunk for session {sid!r}")
            by_shard.setdefault(k, []).append((pos, sid, chunk))
            n = pos + 1
        return [(k, batch, n) for k, batch in sorted(by_shard.items())]

    def feed(self, requests: Iterable[Union[FeedRequest, tuple]]) -> list:
        """Synchronous feed across shards; results in request order."""
        ticket = self.submit(requests)
        self.drain()
        return ticket.results

    def feed_async(self, requests) -> RouterTicket:
        return self.submit(requests)

    def submit(self,
               requests: Iterable[Union[FeedRequest, tuple]]) -> RouterTicket:
        """Route each request to its shard's coalescing queue; returns a
        ``RouterTicket`` resolving to one ``FeedResult`` per request in
        request order at the next ``drain()``/ready ``poll()``."""
        groups = self._split(list(requests))
        n = max((g[2] for g in groups), default=0)
        parts = []
        for k, batch, _ in groups:
            sub = self._shards[k].submit([(sid, chunk)
                                          for _, sid, chunk in batch])
            parts.append((k, sub, [pos for pos, _, _ in batch]))
        ticket = RouterTicket(n_requests=n, parts=parts)
        if not parts:
            ticket.results = []
        else:
            self._tickets.append(ticket)
        return ticket

    def poll(self, ticket: RouterTicket) -> Optional[list]:
        if ticket.done:
            return ticket.results
        for k, sub, _ in ticket.parts:
            self._shards[k].poll(sub)
        ticket._try_assemble()
        if ticket.done:
            self._tickets = [t for t in self._tickets if not t.done]
            return ticket.results
        return None

    def drain(self) -> list:
        """Drain every shard, then assemble every outstanding router
        ticket. Returns all results resolved by this drain (shard-major
        order; use the tickets for request-order results)."""
        out = []
        for srv in self._shards:
            out.extend(srv.drain())
        for t in self._tickets:
            t._try_assemble()
        self._tickets = [t for t in self._tickets if not t.done]
        return out
