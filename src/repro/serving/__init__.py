"""Session-oriented stream serving for the in-filter classifier.

``StreamServer`` multiplexes many logical sensor streams (acoupi-style
long-lived recording sessions) onto the fixed slot capacity of one
slot-batched :class:`~repro.core.pipeline.SessionState`, so feeding S
streams costs ONE compiled donated-state step per chunk bucket. The feed
hot path is asynchronous and pipelined — ``submit()``/``feed_async()``
queue requests for coalesced dispatch, ``drain()`` is the sync point —
and ``StreamRouter`` scales residency across N shards behind one
admission API (stream id -> shard -> slot).
"""

from repro.serving.session import (Decision, FeedRequest, FeedResult,
                                   FeedTicket, Session)
from repro.serving.server import (StreamServer, bucket_length,
                                  make_batched_step)
from repro.serving.router import RouterTicket, StreamRouter, shard_of

__all__ = ["StreamServer", "StreamRouter", "Session", "Decision",
           "FeedRequest", "FeedResult", "FeedTicket", "RouterTicket",
           "bucket_length", "make_batched_step", "shard_of"]
