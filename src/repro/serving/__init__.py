"""Session-oriented stream serving for the in-filter classifier.

``StreamServer`` multiplexes many logical sensor streams (acoupi-style
long-lived recording sessions) onto the fixed slot capacity of one
slot-batched :class:`~repro.core.pipeline.SessionState`, so feeding S
streams costs ONE compiled donated-state step per chunk bucket.
"""

from repro.serving.session import (Decision, FeedRequest, FeedResult,
                                   Session)
from repro.serving.server import StreamServer, bucket_length

__all__ = ["StreamServer", "Session", "Decision", "FeedRequest",
           "FeedResult", "bucket_length"]
