"""StreamServer: many logical sensor streams, one compiled step per chunk.

Slot model: the server owns a slot-batched ``SessionState`` with fixed
capacity S. ``open()`` pins a session to a free slot (evicting the
least-recently-fed idle session to the checkpoint store when full),
``feed()`` absorbs chunks for any subset of resident sessions in ONE jitted
donated-state call per chunk bucket, and ``close()``/``evict()`` release the
slot — an evicted session's DSP registers and decision history are parked in
the named-checkpoint store, so reopening resumes bit-exactly.

Retrace bounding: arbitrary packet lengths are padded up to the next power
of two (clamped to ``[min_chunk, max_chunk]``; longer packets split), so at
most O(log max_chunk) step variants ever compile, no matter what lengths
sensors send.

Scale-out: pass ``mesh=`` to shard the slot axis over the mesh's data axes
(see ``repro.distributed.sharding.session_specs``); capacity then scales
linearly with device count while the host-side API is unchanged.
"""

from __future__ import annotations

import time
from typing import Iterable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pipeline as pl
from repro.core.pipeline import InFilterPipeline, SessionState
from repro.serving.session import Decision, FeedRequest, FeedResult, Session

__all__ = ["StreamServer", "bucket_length"]


def bucket_length(n: int, min_chunk: int, max_chunk: int) -> int:
    """Next power of two >= n, clamped to [min_chunk, max_chunk]."""
    if n <= 0:
        raise ValueError(f"chunk length must be positive, got {n}")
    b = min_chunk
    while b < n:
        b <<= 1
    return min(b, max_chunk)


def _batched_step(pipe: InFilterPipeline, state: SessionState,
                  chunk: jax.Array, valid: jax.Array):
    state, p, _ = pipe._session_step(state, chunk, valid)
    return state, p


class StreamServer:
    """Multiplex logical sensor streams onto fixed slot capacity.

    Parameters
    ----------
    pipeline:       the deployable ``InFilterPipeline``. Its config's
                    ``stream_impl`` picks the donated batch step's hot path
                    ("xla" or the stateful "pallas" streaming kernel —
                    bit-identical decisions either way). Its
                    ``numerics`` picks the engine: "float" (f32 registers)
                    or "fixed" — the bit-true int32 hardware twin, whose
                    streamed decisions are bit-for-bit equal to one-shot
                    ``pipeline.apply(x)`` under any chunking and under
                    EITHER stream_impl (the int Pallas kernel matches the
                    int XLA step register-for-register;
                    ``stats()["numerics"]`` reports the live mode).
    capacity:       number of slots S (streams resident at once).
    max_chunk:      largest per-call chunk; longer packets are split.
                    Must be a power of two (validated at construction).
    min_chunk:      smallest pad bucket (tiny packets share one variant).
                    Must be a power of two — the bucket ladder doubles
                    from ``min_chunk`` to ``max_chunk``, giving at most
                    ``log2(max_chunk / min_chunk) + 1`` compiled variants.
    dtype:          register/sample dtype; incoming chunks are cast to it
                    explicitly (the session dtype never drifts mid-stream).
    evict_after:    seconds of idleness before a resident session may be
                    auto-evicted to make room; ``None`` = any idle session.
    checkpoint_dir: where evicted sessions are parked; required for
                    eviction/reopen (without it a full server raises).
    mesh:           optional ``jax.sharding.Mesh`` — shard the slot axis
                    over the mesh's data axes.
    clock:          injectable monotonic clock (tests).
    """

    def __init__(self, pipeline: InFilterPipeline, capacity: int = 64, *,
                 max_chunk: int = 4096, min_chunk: int = 16,
                 dtype=jnp.float32, evict_after: Optional[float] = None,
                 checkpoint_dir: Optional[str] = None, mesh=None,
                 max_history: int = 64, clock=None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if not (0 < min_chunk <= max_chunk):
            raise ValueError("need 0 < min_chunk <= max_chunk")
        # BOTH bounds must be powers of two: bucket_length doubles up from
        # min_chunk, so a non-pow2 min makes every bucket non-pow2 (novel
        # compiled variants per length) and a non-pow2 max clamps the top
        # bucket off the pow2 grid — either way the O(log max/min) retrace
        # bound quietly stops holding. Fail at construction, not after the
        # compile cache has already ballooned.
        for bname, v in (("min_chunk", min_chunk), ("max_chunk", max_chunk)):
            if v & (v - 1):
                raise ValueError(
                    f"{bname} must be a power of two, got {v} (the pad-"
                    "bucket ladder doubles from min_chunk to max_chunk)")
        # fail at construction, not on the first feed(): the Pallas
        # streaming kernel has no MAC-mode variant
        if pipeline.config.stream_impl == "pallas" \
                and pipeline.config.mode != "mp":
            raise ValueError(
                "stream_impl='pallas' requires an MP-mode pipeline "
                f"(got mode={pipeline.config.mode!r})")
        self.pipeline = pipeline
        self.capacity = capacity
        self.max_chunk = max_chunk
        self.min_chunk = min_chunk
        self.dtype = jnp.dtype(dtype)
        self.evict_after = evict_after
        self._clock = clock if clock is not None else time.monotonic
        self._mesh = mesh
        self._state = pipeline.init_session(
            capacity, dtype, active=np.zeros((capacity,), bool))
        self._chunk_sharding = None
        self._valid_sharding = None
        if mesh is not None:
            from repro.distributed import sharding as sh
            self._state = sh.shard_session(self._state, mesh)
            dp = sh.data_axes(mesh)
            self._chunk_sharding = jax.sharding.NamedSharding(
                mesh, sh.sanitize((dp, None), (capacity, max_chunk), mesh))
            self._valid_sharding = jax.sharding.NamedSharding(
                mesh, sh.sanitize((dp,), (capacity,), mesh))
        if pipeline.config.numerics == "fixed":
            # the integer program lowers HOST-side (concrete ROMs/shift
            # tables), so the pipeline cannot ride along as a traced pytree
            # argument the way the float step's weights do. Precompile once
            # and jit a closure over the concrete pipeline: the step's only
            # traced inputs are the donated integer registers + the chunk.
            pipeline.fixed_program()
            fixed_step = jax.jit(
                lambda state, chunk, valid: _batched_step(
                    pipeline, state, chunk, valid),
                donate_argnums=(0,))
            self._step = lambda pipe, state, chunk, valid: \
                fixed_step(state, chunk, valid)
        else:
            self._step = jax.jit(_batched_step, donate_argnums=(1,))
        self._free = list(range(capacity - 1, -1, -1))  # pop() -> slot 0 first
        self._sessions: dict[str, Session] = {}
        self._manager = None
        if checkpoint_dir is not None:
            from repro.checkpoint import CheckpointManager
            self._manager = CheckpointManager(checkpoint_dir,
                                              async_save=False)
        self._max_history = max_history
        self.bucket_counts: dict[int, int] = {}  # bucket length -> steps run
        self.steps_run = 0
        # set when a donated step call raised mid-feed: the failed call
        # consumed the slot-batched state's buffers, so every resident
        # session's registers are gone — the description names the wave
        self._poisoned: Optional[str] = None

    # -- introspection -------------------------------------------------------

    @property
    def state(self) -> SessionState:
        return self._state

    def session(self, session_id: str) -> Session:
        try:
            return self._sessions[session_id]
        except KeyError:
            raise KeyError(f"session {session_id!r} is not open") from None

    def sessions(self) -> list:
        return sorted(self._sessions.values(), key=lambda s: s.slot)

    def stats(self) -> dict:
        return {
            "capacity": self.capacity,
            "resident": len(self._sessions),
            "free_slots": len(self._free),
            "steps_run": self.steps_run,
            "stream_impl": self.pipeline.config.stream_impl,
            # operators must be able to tell a fixed-point deployment
            # preview from the float path at a glance
            "numerics": self.pipeline.config.numerics,
            "buckets": dict(sorted(self.bucket_counts.items())),
        }

    # -- admission -----------------------------------------------------------

    def open(self, session_id: str) -> Session:
        """Admit a stream. If a checkpoint for this id exists (prior
        eviction), the session resumes from it bit-exactly; otherwise the
        slot starts from the cleared-register state. Holds for BOTH
        numerics modes — an evicted fixed-mode session's integer registers
        round-trip the named-checkpoint store losslessly (dtype-checked),
        so a reopened int32 stream continues bit-for-bit."""
        self._check_poisoned()
        if session_id in self._sessions:
            raise ValueError(f"session {session_id!r} already open")
        # validate at admission (checkpoint-name charset), BEFORE any state
        # changes — a bad id must not cost a slot or surface mid-lifecycle
        if not session_id or not all(ch.isalnum() or ch in "-_."
                                     for ch in session_id):
            raise ValueError(
                f"session id {session_id!r}: use [A-Za-z0-9._-]")
        slot = self._acquire_slot()
        try:
            now = self._clock()
            sess = Session(id=session_id, slot=slot, opened_at=now,
                           last_fed=now, max_history=self._max_history)
            self._state = pl.clear_slots(self._state, np.asarray([slot]))
            name = self._ckpt_name(session_id)
            if self._manager is not None and self._manager.has_named(name):
                row_like = pl.take_slot(self._state, slot)
                row, meta = self._manager.restore_named(name, row_like)
                self._state = pl.put_slot(self._state, slot, row)
                if meta:
                    sess.load_meta(meta)
            self._state = pl.set_active(self._state, np.asarray([slot]),
                                        True)
        except Exception:
            self._free.append(slot)  # failed admission must not leak a slot
            raise
        self._sessions[session_id] = sess
        return sess

    def close(self, session_id: str, *, checkpoint: bool = False) -> Session:
        """Release a session's slot. ``checkpoint=True`` parks its state
        (float or integer registers alike) for a later ``open`` (same as
        eviction); otherwise any parked copy is discarded — a future
        ``open`` of this id starts fresh."""
        if session_id not in self._sessions:
            raise KeyError(f"session {session_id!r} is not open")
        sess = self._sessions.pop(session_id)
        if checkpoint:
            self._park(sess)
        elif self._manager is not None:
            self._manager.delete_named(self._ckpt_name(session_id))
        self._state = pl.set_active(self._state,
                                    np.asarray([sess.slot]), False)
        self._free.append(sess.slot)
        return sess

    def evict(self, session_id: str) -> Session:
        """Park a resident session in the checkpoint store and free its
        slot. Requires ``checkpoint_dir``. An unknown id is reported as
        such (the same ``KeyError`` shape every lookup raises) BEFORE the
        checkpoint-manager check — "no checkpoint_dir" for a session that
        isn't even resident was a misdiagnosis."""
        if session_id not in self._sessions:
            raise KeyError(f"session {session_id!r} is not open")
        if self._manager is None:
            raise RuntimeError("evict() needs checkpoint_dir")
        return self.close(session_id, checkpoint=True)

    def _park(self, sess: Session) -> None:
        if self._manager is None:
            raise RuntimeError("session checkpointing needs checkpoint_dir")
        row = pl.take_slot(self._state, sess.slot)
        self._manager.save_named(self._ckpt_name(sess.id), row,
                                 meta=sess.meta())

    def _check_poisoned(self) -> None:
        if self._poisoned is not None:
            raise RuntimeError(
                f"server is poisoned: {self._poisoned}. The failed step "
                "consumed the donated slot-batched state, so every "
                "resident session's registers are unrecoverable — build "
                "a new StreamServer and reopen sessions from their "
                "checkpoints")

    @staticmethod
    def _ckpt_name(session_id: str) -> str:
        return f"session-{session_id}"

    def _acquire_slot(self) -> int:
        if self._free:
            return self._free.pop()
        if self._manager is None:
            raise RuntimeError(
                f"server at capacity ({self.capacity}) and no "
                "checkpoint_dir to evict into")
        now = self._clock()
        lru = min(self._sessions.values(), key=lambda s: s.last_fed)
        if self.evict_after is not None and \
                now - lru.last_fed < self.evict_after:
            raise RuntimeError(
                f"server at capacity ({self.capacity}); least-recent "
                f"session {lru.id!r} idle {now - lru.last_fed:.1f}s < "
                f"evict_after={self.evict_after}s")
        self.evict(lru.id)
        return self._free.pop()

    # -- the hot path --------------------------------------------------------

    def feed(self, requests: Iterable[Union[FeedRequest, tuple]]) -> list:
        """Absorb one chunk per request; return one ``FeedResult`` per
        request, in request order.

        Each request is a ``FeedRequest`` or ``(session_id, chunk)`` with a
        1-D chunk. Chunks longer than ``max_chunk`` are split; several
        requests for the SAME session in one call are applied in order.
        Everything that can share a compiled call does: per wave, all
        pending segments are padded into one (S, L_bucket) batch with
        per-slot valid counts, and absent/inactive slots ride along inertly.

        Chunks are always float audio regardless of numerics: a fixed-mode
        server quantizes onto its static ADC grid inside the step, and its
        decisions equal one-shot inference on the concatenated audio
        bit-for-bit (a float server matches to f32 round-off, bit-for-bit
        under ``quant_bits`` once the running amax has seen the peak).
        """
        self._check_poisoned()
        reqs = []
        for r in requests:
            if isinstance(r, FeedRequest):
                sid, chunk = r.session_id, r.chunk
            else:
                sid, chunk = r
            if sid not in self._sessions:
                raise KeyError(f"session {sid!r} is not open")
            chunk = np.asarray(chunk, dtype=self.dtype)
            if chunk.ndim != 1:
                raise ValueError(
                    f"chunk for {sid!r} must be 1-D (samples,), got shape "
                    f"{chunk.shape}")
            if chunk.shape[0] == 0:
                raise ValueError(f"empty chunk for session {sid!r}")
            segs = [chunk[i:i + self.max_chunk]
                    for i in range(0, chunk.shape[0], self.max_chunk)]
            reqs.append((sid, segs))
        if not reqs:
            return []

        last_p: dict[int, tuple] = {}  # request index -> (label, conf)
        pending = [list(segs) for _, segs in reqs]
        wave_no = 0
        while any(pending):
            wave_no += 1
            wave, seen, finals = [], set(), []
            for i, (sid, _) in enumerate(reqs):
                if pending[i] and sid not in seen:
                    wave.append((i, sid, pending[i].pop(0)))
                    seen.add(sid)
                    if not pending[i]:
                        finals.append((i, sid))
            L = bucket_length(max(seg.shape[0] for _, _, seg in wave),
                              self.min_chunk, self.max_chunk)
            batch = np.zeros((self.capacity, L), dtype=self.dtype)
            valid = np.zeros((self.capacity,), dtype=np.int32)
            for _, sid, seg in wave:
                slot = self._sessions[sid].slot
                batch[slot, :seg.shape[0]] = seg
                valid[slot] = seg.shape[0]
            chunk_dev, valid_dev = jnp.asarray(batch), jnp.asarray(valid)
            if self._chunk_sharding is not None:
                chunk_dev = jax.device_put(chunk_dev, self._chunk_sharding)
                valid_dev = jax.device_put(valid_dev, self._valid_sharding)
            # the step donates self._state: if the call raises, the old
            # buffers are already consumed and there is no state to roll
            # back to — mid-multi-wave the earlier waves are absorbed and
            # the rest never ran, so no resident register set is
            # trustworthy. Poison the server (feed/open fail loudly from
            # here on, naming this wave) rather than limping on with a
            # half-stepped or invalidated state.
            try:
                self._state, p = self._step(self.pipeline, self._state,
                                            chunk_dev, valid_dev)
            except Exception as e:
                self._poisoned = (
                    f"step raised {type(e).__name__} on wave {wave_no} of "
                    f"a feed() call (bucket {L}, sessions "
                    f"{sorted(sid for _, sid, _ in wave)})")
                raise RuntimeError(
                    f"feed() failed: {self._poisoned}; the donated session "
                    "state was consumed by the failed call — the server "
                    "is now poisoned") from e
            self.steps_run += 1
            self.bucket_counts[L] = self.bucket_counts.get(L, 0) + 1
            # host readback (a device sync) only when some request ends on
            # this wave — intermediate split-segment waves stay async so
            # the donated step chain pipelines
            if finals:
                p_host = np.asarray(p)
                for i, sid in finals:
                    slot = self._sessions[sid].slot
                    label = int(np.argmax(p_host[slot]))
                    last_p[i] = (sid, label, float(p_host[slot, label]))

        now = self._clock()
        results = []
        for i, (sid, label, conf) in sorted(last_p.items()):
            sess = self._sessions[sid]
            # samples_seen advances by the WHOLE request, recorded once on
            # its final segment's decision
            total = sess.samples_seen + sum(s.shape[0] for s in reqs[i][1])
            d = Decision(samples_seen=total, label=label, confidence=conf)
            sess.record(d, now)
            results.append(FeedResult(session_id=sid, label=label,
                                      confidence=conf,
                                      samples_seen=total))
        return results
