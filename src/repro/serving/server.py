"""StreamServer: many logical sensor streams, one compiled step per chunk.

Slot model: the server owns a slot-batched ``SessionState`` with fixed
capacity S. ``open()`` pins a session to a free slot (evicting the
least-recently-fed idle session to the checkpoint store when full),
``feed()`` absorbs chunks for any subset of resident sessions in ONE jitted
donated-state call per chunk bucket, and ``close()``/``evict()`` release the
slot — an evicted session's DSP registers and decision history are parked in
the named-checkpoint store, so reopening resumes bit-exactly.

Retrace bounding: arbitrary packet lengths are padded up to the next power
of two (clamped to ``[min_chunk, max_chunk]``; longer packets split), so at
most O(log max_chunk) step variants ever compile, no matter what lengths
sensors send.

Async feed pipeline: ``feed()`` is a synchronous wrapper over a pipelined
hot path — ``submit()`` validates and enqueues requests (optionally
dispatching on a coalescing watermark/deadline), dispatch stages each wave
into one of two pre-allocated host buffers per bucket (slot-targeted
clears, reuse gated on the wave that last read the buffer) and launches
the donated step WITHOUT reading decisions back, and ``drain()`` is the
only host-device sync point: it blocks once, vectorizes the decision
readback, and resolves every outstanding ``FeedTicket``. Many callers'
small submits coalesce into one compiled call per wave instead of one
full-capacity step each. Decisions are bit-for-bit what the synchronous
path returns — ``feed()`` IS ``submit()`` + ``drain()``.

Scale-out: pass ``mesh=`` to shard the slot axis over the mesh's data axes
(see ``repro.distributed.sharding.session_specs``); capacity then scales
linearly with device count while the host-side API is unchanged. For
host-side sharding — N servers behind one admission API — see
``repro.serving.router.StreamRouter``.
"""

from __future__ import annotations

import time
from typing import Iterable, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pipeline as pl
from repro.core.pipeline import InFilterPipeline, SessionState
from repro.serving.session import (Decision, FeedRequest, FeedResult,
                                   FeedTicket, Session)

__all__ = ["StreamServer", "bucket_length", "make_batched_step"]


def bucket_length(n: int, min_chunk: int, max_chunk: int) -> int:
    """Next power of two >= n, clamped to [min_chunk, max_chunk]."""
    if n <= 0:
        raise ValueError(f"chunk length must be positive, got {n}")
    b = min_chunk
    while b < n:
        b <<= 1
    return min(b, max_chunk)


def _batched_step(pipe: InFilterPipeline, state: SessionState,
                  chunk: jax.Array, valid: jax.Array):
    state, p, _ = pipe._session_step(state, chunk, valid)
    return state, p


def make_batched_step(pipeline: InFilterPipeline):
    """Compile the donated-state session step for ``pipeline``.

    Returns a callable ``(pipe, state, chunk, valid) -> (state, p)`` with a
    uniform signature across numerics modes. A ``StreamServer`` builds one
    per instance by default; pass the SAME callable to several servers
    (``step_fn=``) to share one compile cache across shards — the
    ``StreamRouter`` does exactly that, so N shards cost one compile per
    chunk bucket, not N.
    """
    if pipeline.config.numerics == "fixed":
        # the integer program lowers HOST-side (concrete ROMs/shift
        # tables), so the pipeline cannot ride along as a traced pytree
        # argument the way the float step's weights do. Precompile once
        # and jit a closure over the concrete pipeline: the step's only
        # traced inputs are the donated integer registers + the chunk.
        pipeline.fixed_program()
        fixed_step = jax.jit(
            lambda state, chunk, valid: _batched_step(
                pipeline, state, chunk, valid),
            donate_argnums=(0,))
        return lambda pipe, state, chunk, valid: \
            fixed_step(state, chunk, valid)
    return jax.jit(_batched_step, donate_argnums=(1,))


class _StageBuffer:
    """One host-side staging buffer of a per-bucket double-buffer pair.

    ``inflight`` holds the decision array of the last wave staged from this
    buffer: blocking on it before reuse proves the donated step that read
    the buffer has fully executed, so rewriting the rows is safe even if
    the host->device transfer was zero-copy. Two buffers per bucket give
    the classic depth-2 pipeline: stage wave k+1 while the device still
    chews on wave k.
    """

    __slots__ = ("batch", "valid", "dirty", "inflight")

    def __init__(self, capacity: int, length: int, dtype):
        self.batch = np.zeros((capacity, length), dtype)
        self.valid = np.zeros((capacity,), np.int32)
        self.dirty: list = []          # slots written by the last wave
        self.inflight = None           # that wave's decision array


class _Pending:
    """One submitted request riding the coalescing queue."""

    __slots__ = ("ticket", "pos", "sid", "segs", "total", "label", "conf")

    def __init__(self, ticket, pos, sid, segs, total):
        self.ticket = ticket
        self.pos = pos                 # index within the ticket
        self.sid = sid
        self.segs = segs               # max_chunk-bounded segments
        self.total = total             # original chunk length in samples
        self.label = None
        self.conf = None


class StreamServer:
    """Multiplex logical sensor streams onto fixed slot capacity.

    Parameters
    ----------
    pipeline:       the deployable ``InFilterPipeline``. Its config's
                    ``stream_impl`` picks the donated batch step's hot path
                    ("xla" or the stateful "pallas" streaming kernel —
                    bit-identical decisions either way). Its
                    ``numerics`` picks the engine: "float" (f32 registers)
                    or "fixed" — the bit-true int32 hardware twin, whose
                    streamed decisions are bit-for-bit equal to one-shot
                    ``pipeline.apply(x)`` under any chunking and under
                    EITHER stream_impl (the int Pallas kernel matches the
                    int XLA step register-for-register;
                    ``stats()["numerics"]`` reports the live mode).
    capacity:       number of slots S (streams resident at once).
    max_chunk:      largest per-call chunk; longer packets are split.
                    Must be a power of two (validated at construction).
    min_chunk:      smallest pad bucket (tiny packets share one variant).
                    Must be a power of two — the bucket ladder doubles
                    from ``min_chunk`` to ``max_chunk``, giving at most
                    ``log2(max_chunk / min_chunk) + 1`` compiled variants.
    dtype:          register/sample dtype; incoming chunks are cast to it
                    explicitly (the session dtype never drifts mid-stream).
    evict_after:    seconds of idleness before a resident session may be
                    auto-evicted to make room; ``None`` = any idle session.
    checkpoint_dir: where evicted sessions are parked; required for
                    eviction/reopen (without it a full server raises).
    mesh:           optional ``jax.sharding.Mesh`` — shard the slot axis
                    over the mesh's data axes.
    clock:          injectable monotonic clock (tests).
    coalesce_watermark: auto-dispatch threshold for the async queue: once
                    this many requests are pending, ``submit()`` launches
                    the waves (staging + donated step, NO readback — the
                    host never blocks). ``None`` (default) dispatches only
                    at ``drain()``/deadline.
    coalesce_deadline: max seconds a queued request may wait before the
                    next ``submit()``/``poll()`` dispatches the queue.
                    Checked cooperatively on API calls — there is no
                    background thread.
    step_fn:        a compiled step from :func:`make_batched_step` built
                    for this same pipeline — pass one callable to several
                    servers to share its compile cache (the router's N
                    shards compile each chunk bucket once, not N times).
    """

    def __init__(self, pipeline: InFilterPipeline, capacity: int = 64, *,
                 max_chunk: int = 4096, min_chunk: int = 16,
                 dtype=jnp.float32, evict_after: Optional[float] = None,
                 checkpoint_dir: Optional[str] = None, mesh=None,
                 max_history: int = 64, clock=None,
                 coalesce_watermark: Optional[int] = None,
                 coalesce_deadline: Optional[float] = None,
                 step_fn=None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if not (0 < min_chunk <= max_chunk):
            raise ValueError("need 0 < min_chunk <= max_chunk")
        # BOTH bounds must be powers of two: bucket_length doubles up from
        # min_chunk, so a non-pow2 min makes every bucket non-pow2 (novel
        # compiled variants per length) and a non-pow2 max clamps the top
        # bucket off the pow2 grid — either way the O(log max/min) retrace
        # bound quietly stops holding. Fail at construction, not after the
        # compile cache has already ballooned.
        for bname, v in (("min_chunk", min_chunk), ("max_chunk", max_chunk)):
            if v & (v - 1):
                raise ValueError(
                    f"{bname} must be a power of two, got {v} (the pad-"
                    "bucket ladder doubles from min_chunk to max_chunk)")
        # fail at construction, not on the first feed(): the Pallas
        # streaming kernel has no MAC-mode variant
        if pipeline.config.stream_impl == "pallas" \
                and pipeline.config.mode != "mp":
            raise ValueError(
                "stream_impl='pallas' requires an MP-mode pipeline "
                f"(got mode={pipeline.config.mode!r})")
        self.pipeline = pipeline
        self.capacity = capacity
        self.max_chunk = max_chunk
        self.min_chunk = min_chunk
        self.dtype = jnp.dtype(dtype)
        self.evict_after = evict_after
        self._clock = clock if clock is not None else time.monotonic
        self._mesh = mesh
        self._state = pipeline.init_session(
            capacity, dtype, active=np.zeros((capacity,), bool))
        self._chunk_sharding = None
        self._valid_sharding = None
        if mesh is not None:
            from repro.distributed import sharding as sh
            self._state = sh.shard_session(self._state, mesh)
            dp = sh.data_axes(mesh)
            self._chunk_sharding = jax.sharding.NamedSharding(
                mesh, sh.sanitize((dp, None), (capacity, max_chunk), mesh))
            self._valid_sharding = jax.sharding.NamedSharding(
                mesh, sh.sanitize((dp,), (capacity,), mesh))
        self._step = step_fn if step_fn is not None \
            else make_batched_step(pipeline)
        self._free = list(range(capacity - 1, -1, -1))  # pop() -> slot 0 first
        self._sessions: dict[str, Session] = {}
        self._manager = None
        if checkpoint_dir is not None:
            from repro.checkpoint import CheckpointManager
            self._manager = CheckpointManager(checkpoint_dir,
                                              async_save=False)
        self._max_history = max_history
        self.bucket_counts: dict[int, int] = {}  # bucket length -> steps run
        self.steps_run = 0
        # set when a donated step call raised mid-feed: the failed call
        # consumed the slot-batched state's buffers, so every resident
        # session's registers are gone — the description names the wave
        self._poisoned: Optional[str] = None
        # -- async feed pipeline state --
        self.coalesce_watermark = coalesce_watermark
        self.coalesce_deadline = coalesce_deadline
        self._staging: dict[int, list] = {}   # bucket L -> [_StageBuffer]*2
        self._stage_flip: dict[int, int] = {}
        self._queue: List[_Pending] = []      # submitted, not yet dispatched
        self._queue_since: Optional[float] = None
        self._dispatched: List[_Pending] = []  # dispatched, not yet resolved
        # per dispatched wave with at least one finishing request:
        # (decision device array, [(pending, slot), ...])
        self._inflight: list = []

    # -- introspection -------------------------------------------------------

    @property
    def state(self) -> SessionState:
        return self._state

    def session(self, session_id: str) -> Session:
        try:
            return self._sessions[session_id]
        except KeyError:
            raise KeyError(f"session {session_id!r} is not open") from None

    def sessions(self) -> list:
        return sorted(self._sessions.values(), key=lambda s: s.slot)

    def is_open(self, session_id: str) -> bool:
        return session_id in self._sessions

    def __contains__(self, session_id: str) -> bool:
        return session_id in self._sessions

    def stats(self) -> dict:
        total = sum(self.bucket_counts.values())
        return {
            "capacity": self.capacity,
            "resident": len(self._sessions),
            "free_slots": len(self._free),
            "steps_run": self.steps_run,
            "stream_impl": self.pipeline.config.stream_impl,
            # operators must be able to tell a fixed-point deployment
            # preview from the float path at a glance
            "numerics": self.pipeline.config.numerics,
            "buckets": dict(sorted(self.bucket_counts.items())),
            # which pad buckets actually absorb the traffic — a ladder rung
            # with a high hit rate and a lot of padding is a resize lever
            "bucket_steps_total": total,
            "bucket_hit_rate": {L: round(c / total, 4) for L, c in
                                sorted(self.bucket_counts.items())}
            if total else {},
            # a poisoned server must be visible from monitoring, not only
            # from the next call's RuntimeError: None = healthy, else the
            # diagnosis string naming the failed wave
            "poisoned": self._poisoned,
            # async feed pipeline depth
            "queued_requests": len(self._queue),
            "unresolved_requests": len(self._dispatched),
            "inflight_waves": len(self._inflight),
            "coalesce_watermark": self.coalesce_watermark,
            "coalesce_deadline": self.coalesce_deadline,
        }

    # -- admission -----------------------------------------------------------

    def open(self, session_id: str) -> Session:
        """Admit a stream. If a checkpoint for this id exists (prior
        eviction), the session resumes from it bit-exactly; otherwise the
        slot starts from the cleared-register state. Holds for BOTH
        numerics modes — an evicted fixed-mode session's integer registers
        round-trip the named-checkpoint store losslessly (dtype-checked),
        so a reopened int32 stream continues bit-for-bit."""
        self._check_poisoned()
        # flush the async queue first: admission may evict the LRU session,
        # and the victim choice / parked registers must reflect every feed
        # submitted so far (exactly as if they had been synchronous)
        self._flush_pending()
        if session_id in self._sessions:
            raise ValueError(f"session {session_id!r} already open")
        # validate at admission (checkpoint-name charset), BEFORE any state
        # changes — a bad id must not cost a slot or surface mid-lifecycle
        if not session_id or not all(ch.isalnum() or ch in "-_."
                                     for ch in session_id):
            raise ValueError(
                f"session id {session_id!r}: use [A-Za-z0-9._-]")
        slot = self._acquire_slot()
        try:
            now = self._clock()
            sess = Session(id=session_id, slot=slot, opened_at=now,
                           last_fed=now, max_history=self._max_history)
            self._state = pl.clear_slots(self._state, np.asarray([slot]))
            name = self._ckpt_name(session_id)
            if self._manager is not None and self._manager.has_named(name):
                row_like = pl.take_slot(self._state, slot)
                row, meta = self._manager.restore_named(name, row_like)
                self._state = pl.put_slot(self._state, slot, row)
                if meta:
                    sess.load_meta(meta)
            self._state = pl.set_active(self._state, np.asarray([slot]),
                                        True)
        except Exception:
            self._free.append(slot)  # failed admission must not leak a slot
            raise
        self._sessions[session_id] = sess
        return sess

    def close(self, session_id: str, *, checkpoint: bool = False) -> Session:
        """Release a session's slot. ``checkpoint=True`` parks its state
        (float or integer registers alike) for a later ``open`` (same as
        eviction); otherwise any parked copy is discarded — a future
        ``open`` of this id starts fresh."""
        # absorb + resolve any queued feeds for this session before its
        # registers are parked/discarded — closing must not drop submitted
        # chunks (the sync path can't, so the async path may not either)
        self._flush_pending()
        if session_id not in self._sessions:
            raise KeyError(f"session {session_id!r} is not open")
        sess = self._sessions.pop(session_id)
        if checkpoint:
            self._park(sess)
        elif self._manager is not None:
            self._manager.delete_named(self._ckpt_name(session_id))
        self._state = pl.set_active(self._state,
                                    np.asarray([sess.slot]), False)
        self._free.append(sess.slot)
        return sess

    def evict(self, session_id: str) -> Session:
        """Park a resident session in the checkpoint store and free its
        slot. Requires ``checkpoint_dir``. An unknown id is reported as
        such (the same ``KeyError`` shape every lookup raises) BEFORE the
        checkpoint-manager check — "no checkpoint_dir" for a session that
        isn't even resident was a misdiagnosis."""
        if session_id not in self._sessions:
            raise KeyError(f"session {session_id!r} is not open")
        if self._manager is None:
            raise RuntimeError("evict() needs checkpoint_dir")
        return self.close(session_id, checkpoint=True)

    def _park(self, sess: Session) -> None:
        if self._manager is None:
            raise RuntimeError("session checkpointing needs checkpoint_dir")
        row = pl.take_slot(self._state, sess.slot)
        self._manager.save_named(self._ckpt_name(sess.id), row,
                                 meta=sess.meta())

    def _check_poisoned(self) -> None:
        if self._poisoned is not None:
            raise RuntimeError(
                f"server is poisoned: {self._poisoned}. The failed step "
                "consumed the donated slot-batched state, so every "
                "resident session's registers are unrecoverable — build "
                "a new StreamServer and reopen sessions from their "
                "checkpoints")

    @staticmethod
    def _ckpt_name(session_id: str) -> str:
        return f"session-{session_id}"

    def _acquire_slot(self) -> int:
        if self._free:
            return self._free.pop()
        if self._manager is None:
            raise RuntimeError(
                f"server at capacity ({self.capacity}) and no "
                "checkpoint_dir to evict into")
        now = self._clock()
        lru = min(self._sessions.values(), key=lambda s: s.last_fed)
        if self.evict_after is not None and \
                now - lru.last_fed < self.evict_after:
            raise RuntimeError(
                f"server at capacity ({self.capacity}); least-recent "
                f"session {lru.id!r} idle {now - lru.last_fed:.1f}s < "
                f"evict_after={self.evict_after}s")
        self.evict(lru.id)
        return self._free.pop()

    # -- the hot path --------------------------------------------------------

    def feed(self, requests: Iterable[Union[FeedRequest, tuple]]) -> list:
        """Absorb one chunk per request; return one ``FeedResult`` per
        request, in request order.

        Each request is a ``FeedRequest`` or ``(session_id, chunk)`` with a
        1-D chunk. Chunks longer than ``max_chunk`` are split; several
        requests for the SAME session in one call are applied in order.
        Everything that can share a compiled call does: per wave, all
        pending segments are padded into one (S, L_bucket) batch with
        per-slot valid counts, and absent/inactive slots ride along inertly.

        Chunks are always float audio regardless of numerics: a fixed-mode
        server quantizes onto its static ADC grid inside the step, and its
        decisions equal one-shot inference on the concatenated audio
        bit-for-bit (a float server matches to f32 round-off, bit-for-bit
        under ``quant_bits`` once the running amax has seen the peak).

        This is the synchronous wrapper over the async pipeline: exactly
        ``submit(requests)`` + ``drain()`` — same staging buffers, same
        waves, same readback — so its decisions are bit-for-bit identical
        to the ``submit``/``poll``/``drain`` path by construction. Any
        requests already queued by earlier ``submit()`` calls are flushed
        (in their submit order) by the same drain.
        """
        ticket = self.submit(requests)
        self.drain()
        return ticket.results

    def feed_async(self,
                   requests: Iterable[Union[FeedRequest, tuple]]
                   ) -> FeedTicket:
        """Alias of :meth:`submit` — the asynchronous ``feed()``."""
        return self.submit(requests)

    def submit(self,
               requests: Iterable[Union[FeedRequest, tuple]]) -> FeedTicket:
        """Enqueue one chunk per request; return a ``FeedTicket`` that
        resolves at the next drain point.

        Validation is atomic: every request is checked (open session, 1-D
        non-empty chunk) BEFORE any is enqueued, so a bad batch never
        half-submits. Requests accumulate across callers — per session
        FIFO, across sessions coalesced — and dispatch (staging + donated
        step launch, no readback) happens when ``coalesce_watermark``
        requests are pending, when a queued request is older than
        ``coalesce_deadline``, or at the latest inside ``drain()``.
        """
        self._check_poisoned()
        entries = []
        for r in requests:
            if isinstance(r, FeedRequest):
                sid, chunk = r.session_id, r.chunk
            else:
                sid, chunk = r
            if sid not in self._sessions:
                raise KeyError(f"session {sid!r} is not open")
            chunk = np.asarray(chunk, dtype=self.dtype)
            if chunk.ndim != 1:
                raise ValueError(
                    f"chunk for {sid!r} must be 1-D (samples,), got shape "
                    f"{chunk.shape}")
            if chunk.shape[0] == 0:
                raise ValueError(f"empty chunk for session {sid!r}")
            segs = [chunk[i:i + self.max_chunk]
                    for i in range(0, chunk.shape[0], self.max_chunk)]
            entries.append((sid, segs, chunk.shape[0]))
        ticket = FeedTicket(n_requests=len(entries))
        if not entries:
            ticket.results = []
            return ticket
        for pos, (sid, segs, total) in enumerate(entries):
            self._queue.append(_Pending(ticket, pos, sid, segs, total))
        if self._queue_since is None:
            self._queue_since = self._clock()
        if self.coalesce_watermark is not None \
                and len(self._queue) >= self.coalesce_watermark:
            self._dispatch()
        elif self._deadline_expired():
            self._dispatch()
        return ticket

    def poll(self, ticket: FeedTicket) -> Optional[list]:
        """Non-blocking progress check: the ticket's results if they are
        ready, else ``None``.

        "Ready" means every wave carrying one of the ticket's final
        segments has finished on device — ``poll`` never waits for the
        device, but it does advance the pipeline cooperatively: it
        dispatches the queue when the coalescing deadline has expired, and
        it resolves finished waves (a cheap readback of already-computed
        decisions). Use ``drain()`` to block until resolution instead.
        """
        if ticket.done:
            return ticket.results
        self._check_poisoned()
        if self._deadline_expired():
            self._dispatch()
        if self._inflight and all(
                p.is_ready() for p, _ in self._inflight):
            self._resolve()
        return ticket.results if ticket.done else None

    def drain(self) -> list:
        """The pipeline's sync point: dispatch everything still queued,
        block until the device has produced every outstanding decision,
        and resolve all open tickets. Returns the ``FeedResult``s resolved
        by THIS drain, in submit order. A drained server has no queued
        requests, no unresolved tickets, and no in-flight waves."""
        self._check_poisoned()
        self._dispatch()
        return self._resolve()

    def _deadline_expired(self) -> bool:
        return (self.coalesce_deadline is not None
                and self._queue_since is not None
                and self._clock() - self._queue_since
                >= self.coalesce_deadline)

    def _flush_pending(self) -> None:
        """Absorb + resolve everything outstanding before a lifecycle
        mutation (open/close/evict). No-op on a poisoned server — the
        queue is as dead as the registers, and the lifecycle call's own
        poison check owns the error."""
        if self._poisoned is not None:
            return
        if self._queue or self._dispatched or self._inflight:
            self._dispatch()
            self._resolve()

    def _stage_buffer(self, L: int) -> _StageBuffer:
        """Flip to the next staging buffer for bucket ``L``, waiting (only
        if the device is >= 2 waves behind) for the wave that last read it,
        then clearing exactly the slots that wave wrote."""
        ring = self._staging.get(L)
        if ring is None:
            ring = self._staging[L] = [
                _StageBuffer(self.capacity, L, self.dtype) for _ in range(2)]
            self._stage_flip[L] = 0
        k = self._stage_flip[L]
        self._stage_flip[L] = k ^ 1
        buf = ring[k]
        if buf.inflight is not None:
            # the donated step that read this buffer two waves ago: its
            # output being ready proves the input buffer is consumed, so
            # rewriting rows below cannot race the device (and is safe
            # even if the host->device transfer aliased host memory)
            jax.block_until_ready(buf.inflight)
            buf.inflight = None
        if buf.dirty:
            rows = buf.dirty
            buf.batch[rows] = 0
            buf.valid[rows] = 0
            buf.dirty = []
        return buf

    def _dispatch(self) -> None:
        """Run the queued requests' waves: stage each wave into a
        double-buffered host batch and launch the donated step, WITHOUT
        reading decisions back. Wave composition is identical to the
        pre-async serial loop: one segment per session per wave, sessions
        coalesced, bucket = pow2 pad of the wave's longest segment."""
        if not self._queue:
            return
        reqs, self._queue = self._queue, []
        self._queue_since = None
        pending = [list(r.segs) for r in reqs]
        wave_no = 0
        while any(pending):
            wave_no += 1
            wave, seen, finals = [], set(), []
            for i, r in enumerate(reqs):
                if pending[i] and r.sid not in seen:
                    wave.append((r, pending[i].pop(0)))
                    seen.add(r.sid)
                    if not pending[i]:
                        finals.append(r)
            L = bucket_length(max(seg.shape[0] for _, seg in wave),
                              self.min_chunk, self.max_chunk)
            buf = self._stage_buffer(L)
            for r, seg in wave:
                slot = self._sessions[r.sid].slot
                buf.batch[slot, :seg.shape[0]] = seg
                buf.valid[slot] = seg.shape[0]
                buf.dirty.append(slot)
            chunk_dev = jnp.asarray(buf.batch)
            valid_dev = jnp.asarray(buf.valid)
            if self._chunk_sharding is not None:
                chunk_dev = jax.device_put(chunk_dev, self._chunk_sharding)
                valid_dev = jax.device_put(valid_dev, self._valid_sharding)
            # the step donates self._state: if the call raises, the old
            # buffers are already consumed and there is no state to roll
            # back to — mid-multi-wave the earlier waves are absorbed and
            # the rest never ran, so no resident register set is
            # trustworthy. Poison the server (feed/open fail loudly from
            # here on, naming this wave) rather than limping on with a
            # half-stepped or invalidated state.
            try:
                self._state, p = self._step(self.pipeline, self._state,
                                            chunk_dev, valid_dev)
            except Exception as e:
                self._poisoned = (
                    f"step raised {type(e).__name__} on wave {wave_no} of "
                    f"a feed() call (bucket {L}, sessions "
                    f"{sorted(r.sid for r, _ in wave)})")
                raise RuntimeError(
                    f"feed() failed: {self._poisoned}; the donated session "
                    "state was consumed by the failed call — the server "
                    "is now poisoned") from e
            self.steps_run += 1
            self.bucket_counts[L] = self.bucket_counts.get(L, 0) + 1
            # NO host readback here: the decision array rides along
            # asynchronously and gates this buffer's reuse; requests
            # finishing on this wave are read back (vectorized) at the
            # next drain point. Slots are captured now — resolution may
            # happen after this session moved (it cannot close first:
            # close() flushes).
            buf.inflight = p
            if finals:
                self._inflight.append(
                    (p, [(r, self._sessions[r.sid].slot) for r in finals]))
        self._dispatched.extend(reqs)

    def _resolve(self) -> list:
        """Materialize every dispatched request's decision (ONE blocking
        readback per final-bearing wave, argmax vectorized over its
        finishing slots) and resolve tickets in submit order. Bit-for-bit
        the serial path's readback: same per-slot argmax on the same
        decision rows, same samples_seen bookkeeping order."""
        if not self._dispatched:
            return []
        for p_dev, finals in self._inflight:
            p_host = np.asarray(p_dev)          # blocks if not yet ready
            slots = np.asarray([s for _, s in finals])
            rows = p_host[slots]
            labels = np.argmax(rows, axis=1)
            for (r, _), label, row in zip(finals, labels, rows):
                r.label = int(label)
                r.conf = float(row[label])
        self._inflight.clear()
        now = self._clock()
        results = []
        tickets = []
        for r in self._dispatched:
            sess = self._sessions[r.sid]
            # samples_seen advances by the WHOLE request, recorded once on
            # its final segment's decision
            total = sess.samples_seen + r.total
            d = Decision(samples_seen=total, label=r.label,
                         confidence=r.conf)
            sess.record(d, now)
            fr = FeedResult(session_id=r.sid, label=r.label, confidence=r.conf,
                            samples_seen=total)
            results.append(fr)
            if r.ticket.results is None:
                r.ticket.results = [None] * r.ticket.n_requests
                tickets.append(r.ticket)
            r.ticket.results[r.pos] = fr
        self._dispatched.clear()
        # a ticket is dispatched atomically (dispatch flushes the whole
        # queue), so every ticket touched here resolved completely
        assert all(None not in t.results for t in tickets)
        return results
