"""Session bookkeeping dataclasses for the stream server.

A *session* is one long-lived logical sensor stream (one microphone, one
deployment box) pinned to a slot of the slot-batched ``SessionState`` while
resident. The paper's deployment contract — only classified data leaves the
device — makes the decision history the session's entire observable output,
so it is first-class here: every feed appends a :class:`Decision`, and the
history survives eviction/reopen via the named-checkpoint store.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

__all__ = ["Decision", "Session", "FeedRequest", "FeedResult",
           "FeedTicket"]


@dataclasses.dataclass(frozen=True)
class Decision:
    """One classifier readout: the decision from all evidence so far."""
    samples_seen: int
    label: int
    confidence: float


@dataclasses.dataclass
class Session:
    """Host-side record of a resident stream (the device state lives in the
    slot-batched ``SessionState`` on-accelerator)."""
    id: str
    slot: int
    opened_at: float
    last_fed: float
    samples_seen: int = 0
    history: List[Decision] = dataclasses.field(default_factory=list)
    max_history: int = 64

    def record(self, decision: Decision, now: float) -> None:
        self.samples_seen = decision.samples_seen
        self.last_fed = now
        self.history.append(decision)
        if len(self.history) > self.max_history:
            del self.history[: len(self.history) - self.max_history]

    @property
    def last_decision(self) -> Optional[Decision]:
        return self.history[-1] if self.history else None

    def meta(self) -> dict:
        """JSON-serializable side data persisted with an evicted session."""
        return {
            "samples_seen": int(self.samples_seen),
            "history": [[int(d.samples_seen), int(d.label),
                         float(d.confidence)] for d in self.history],
        }

    def load_meta(self, meta: dict) -> None:
        self.samples_seen = int(meta.get("samples_seen", 0))
        self.history = [Decision(int(s), int(l), float(c))
                        for s, l, c in meta.get("history", [])]


@dataclasses.dataclass(frozen=True)
class FeedRequest:
    """One chunk of one session's audio. ``chunk`` is 1-D (samples,)."""
    session_id: str
    chunk: Any


@dataclasses.dataclass(frozen=True)
class FeedResult:
    """Per-request classifier readout after the session absorbed the chunk."""
    session_id: str
    label: int
    confidence: float
    samples_seen: int


@dataclasses.dataclass
class FeedTicket:
    """Handle for one ``submit()``/``feed_async()`` batch.

    The ticket resolves — ``results`` flips from ``None`` to one
    :class:`FeedResult` per request, in request order — when the server
    drains (``drain()``, a ``poll()`` that finds the device done, or any
    lifecycle call that forces a flush). "Result ready" means the decision
    was computed from ALL of the request's chunks: splits and coalesced
    co-tenants included, bit-for-bit what a synchronous ``feed()`` of the
    same requests would have returned.
    """
    n_requests: int
    results: Optional[List[FeedResult]] = None

    @property
    def done(self) -> bool:
        return self.results is not None
