"""The shared jaxpr traversal every analysis pass runs on.

A traced program is a tree of jaxprs: the top level plus sub-jaxprs hidden
inside higher-order primitives (``pjit``/call wrappers, ``scan``/``while``
loops, ``cond`` branches, ``pallas_call`` kernel bodies). Every pass in
this package — and the benchmark census in ``benchmarks/hardware_cost.py``
— walks that tree through ONE function (:func:`walk`), so the legality
gate, the census numbers and the lint can never disagree about what code a
program contains.

The walk is *scaled*: each visited equation carries the number of times it
executes per call (scan length x pallas grid product x ...), which is what
turns a structural walk into an op census.

Census-compatibility quirks (kept deliberately, flag-controlled):

* ``cond`` branches execute at most once each but the pre-refactor census
  skipped them entirely; counting passes keep that behavior
  (``cond_branches=False``) so benchmark trajectories stay comparable,
  while verification passes recurse (``cond_branches=True``) — the gate is
  strictly stronger than the numbers.
* ``while`` bodies have no static trip count. The census skips them
  (nothing in the repo's datapath uses ``while``); verification passes
  visit the body once at the current scale — sound for legality (an
  illegal op is illegal at any trip count), not a count.

``pallas_call`` index-map jaxprs (BlockSpec address arithmetic) are NOT
walked: they compute grid offsets on the scalar core, not datapath values.
"""

from __future__ import annotations

from typing import Callable, Iterator

# call-like primitives whose sub-jaxpr runs exactly once per invocation
CALL_PRIMS = ("pjit", "closed_call", "custom_vjp_call", "custom_jvp_call",
              "remat", "checkpoint")

# jax 0.4.x names the staged-out custom-vjp primitive differently; the
# pre-refactor census treated it as an opaque leaf (counted nothing), so
# counting passes keep that behavior behind ``vjp_jaxpr_bodies`` while
# verification passes recurse into the body
VJP_JAXPR_PRIM = "custom_vjp_call_jaxpr"


def subjaxprs(value) -> Iterator:
    """Yield every plain jaxpr reachable from a param value: handles plain
    ``Jaxpr`` (has ``.eqns``), ``ClosedJaxpr`` (has ``.jaxpr``), and
    lists/tuples of either — ``pallas_call`` stores a plain ``Jaxpr``,
    ``cond`` a tuple of ``ClosedJaxpr``, so attribute order matters."""
    if hasattr(value, "eqns"):
        yield value
    elif hasattr(value, "jaxpr"):
        yield from subjaxprs(value.jaxpr)
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from subjaxprs(v)


def grid_product(eqn) -> int:
    """Number of sequential kernel-body executions of a ``pallas_call``:
    the product of the static grid dimensions."""
    gm = eqn.params.get("grid_mapping")
    steps = 1
    for g in getattr(gm, "grid", ()) or ():
        if isinstance(g, int):
            steps *= g
    return steps


def eqn_source(eqn) -> str:
    """Human-readable source location of an equation (for naming offending
    eqns in reports): ``file.py:123 (fn_name)`` when available."""
    try:
        from jax._src import source_info_util
        frame = source_info_util.user_frame(eqn.source_info)
        if frame is not None:
            fname = frame.file_name.rsplit("/", 1)[-1]
            return f"{fname}:{frame.start_line} ({frame.function_name})"
    except Exception:  # noqa: BLE001 - source info is best-effort decoration
        pass
    return "<unknown>"


def walk(jaxpr, visit: Callable, *, scale: int = 1, path: str = "",
         cond_branches: bool = True, while_bodies: bool = True,
         vjp_jaxpr_bodies: bool = True) -> None:
    """Visit every leaf equation reachable from ``jaxpr``.

    ``visit(eqn, scale, path)`` is called for each non-higher-order
    equation; ``scale`` is how many times it executes per program call and
    ``path`` names the enclosing higher-order chain (for report naming).
    Higher-order primitives are recursed per the module docstring;
    ``cond_branches``/``while_bodies``/``vjp_jaxpr_bodies`` select
    verification vs census semantics.
    """
    kw = dict(cond_branches=cond_branches, while_bodies=while_bodies,
              vjp_jaxpr_bodies=vjp_jaxpr_bodies)
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in CALL_PRIMS or name == VJP_JAXPR_PRIM:
            if name == VJP_JAXPR_PRIM and not vjp_jaxpr_bodies:
                continue
            for sub in eqn.params.values():
                for jx in subjaxprs(sub):
                    walk(jx, visit, scale=scale, path=path, **kw)
            continue
        if name == "pallas_call":
            steps = grid_product(eqn)
            for jx in subjaxprs(eqn.params.get("jaxpr")):
                walk(jx, visit, scale=scale * steps,
                     path=f"{path}/pallas_call[grid={steps}]", **kw)
            continue
        if name == "scan":
            # a zero-length scan's body executes zero times: scale 0 keeps
            # counts exact (the visit still happens, so legality stays
            # conservative about code that is merely never reached)
            length = eqn.params.get("length")
            length = 1 if length is None else int(length)
            for jx in subjaxprs(eqn.params.get("jaxpr")):
                walk(jx, visit, scale=scale * length,
                     path=f"{path}/scan[{length}]", **kw)
            continue
        if name == "while":
            if while_bodies:
                for key in ("cond_jaxpr", "body_jaxpr"):
                    for jx in subjaxprs(eqn.params.get(key)):
                        walk(jx, visit, scale=scale,
                             path=f"{path}/while.{key}", **kw)
            continue
        if name == "cond":
            if cond_branches:
                for i, br in enumerate(eqn.params.get("branches", ())):
                    for jx in subjaxprs(br):
                        walk(jx, visit, scale=scale,
                             path=f"{path}/cond.branch{i}", **kw)
            continue
        visit(eqn, scale, path)
