"""Machine-readable analysis report assembly for ``scripts/analyze.py``.

The report is deterministic (sorted keys, no timestamps, no machine info)
so the committed ``ANALYSIS.json`` artifact diffs meaningfully across PRs:
a changed headroom number IS the review signal, not noise around it.
"""

from __future__ import annotations

import json

from repro.analysis.determinism import lint_determinism
from repro.analysis.intervals import analyze_intervals
from repro.analysis.legality import check_legality

SCHEMA_VERSION = 1


def analyze_target(t, *, top_registers: int = 20) -> dict:
    """Run every applicable pass over one :class:`~repro.analysis.targets.
    Target` and return its report section."""
    section = {
        "numerics": t.numerics,
        "n_samples": t.n_samples,
        "gate": t.gate,
        "assumptions": dict(sorted(t.assumptions.items())),
        "legality": check_legality(t.jaxpr).to_dict(),
        "determinism": lint_determinism(t.jaxpr,
                                        numerics=t.numerics).to_dict(),
    }
    if t.in_intervals is not None:
        section["intervals"] = analyze_intervals(
            t.jaxpr, t.in_intervals).to_dict(top_registers=top_registers)
    return section


def target_ok(section: dict) -> bool:
    """Every pass that ran on this target came back clean."""
    return (section["legality"]["ok"]
            and section["determinism"]["ok"]
            and section.get("intervals", {"ok": True})["ok"])


def build_report(targets, meta: dict, *, top_registers: int = 20) -> dict:
    sections = {t.name: analyze_target(t, top_registers=top_registers)
                for t in targets}
    gate_ok = all(target_ok(s) for name, s in sections.items()
                  if s["gate"])
    return {
        "schema": SCHEMA_VERSION,
        "ok": gate_ok,
        "meta": dict(sorted(meta.items())),
        "targets": sections,
    }


def write_report(path, report: dict) -> None:
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")


def summarize(report: dict) -> str:
    """Human-oriented one-screen summary of a report dict."""
    lines = [f"analysis: {'OK' if report['ok'] else 'FAIL'} "
             f"({report['meta'].get('config', '?')} config)"]
    m = report["meta"]
    if m.get("max_safe_session_samples"):
        lines.append(
            f"  session envelope: acc <= {m['acc_envelope'][1]} over "
            f"{m['envelope_samples']} samples; int32-safe up to "
            f"{m['max_safe_session_samples']} session samples")
    for name, s in report["targets"].items():
        leg = s["legality"]
        det = s["determinism"]
        parts = [f"legality {'ok' if leg['ok'] else 'FAIL'}"
                 f" ({sum(leg['legal_ops'].values())} scaled legal ops)"]
        if "intervals" in s:
            iv = s["intervals"]
            parts.append(
                f"intervals {'ok' if iv['ok'] else 'FAIL'} "
                f"(min headroom {iv['min_headroom_bits']} bits over "
                f"{iv['num_registers']} registers)")
        parts.append(f"determinism {'ok' if det['ok'] else 'FAIL'} "
                     f"({det['num_findings']} findings)")
        flag = "" if s["gate"] else " [informational]"
        lines.append(f"  {name}{flag}: " + "; ".join(parts))
        for v in s["legality"]["violations"][:3]:
            lines.append(f"    illegal op: {v['primitive']} at "
                         f"{v['path']}@{v['source']}")
        for v in s.get("intervals", {}).get("violations", [])[:3]:
            lines.append(f"    overflow: {v['name']} needs "
                         f"{v['required_bits']} bits "
                         f"(interval {v['interval']})")
    return "\n".join(lines)
