"""Determinism lint: bit-parity hazards in traced programs.

The fixed-point pipeline's whole value proposition is bit-exactness with
the hardware twin — every run, every backend, every chunking produces the
SAME int32 words. Two things break that:

* **Float ops reachable in a ``numerics="fixed"`` program.** Float
  arithmetic is where cross-backend divergence lives (FMA contraction,
  flush-to-zero, libm variation). In a fixed program any non-structural
  float op is a leak from the float reference path and is flagged as a
  gating finding.
* **Non-fixed-tree float reductions.** Float addition is not associative:
  ``reduce_sum``/``dot_general``/``conv_general_dilated`` over floats let
  the compiler pick the reduction tree, so re-tiling or re-vectorizing
  changes low bits. On bit-parity-critical paths reductions must either be
  integer (exactly associative: ``fxp_hwr_accumulate``'s masked int sum)
  or a fixed tree (``mp.tree_sum``). Float-target findings are
  informational — the float path is a reference, not a contract.

Comparisons/selects on floats are deterministic (no rounding) and
``reduce_max``/``reduce_min`` are exactly associative, so neither is
flagged.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.analysis import traverse

# ops that move/relabel values without arithmetic — never a parity hazard
_STRUCTURAL = {
    "broadcast_in_dim", "reshape", "squeeze", "expand_dims", "transpose",
    "slice", "dynamic_slice", "dynamic_update_slice", "concatenate",
    "gather", "scatter", "rev", "pad", "convert_element_type",
    "device_put", "copy", "stop_gradient", "iota", "program_id",
    "num_programs", "get", "swap", "select_n", "eq", "ne", "lt", "le",
    "gt", "ge", "and", "or", "xor", "not", "reduce_and", "reduce_or",
    "sign", "is_finite",
}

# float reductions whose tree shape the compiler may choose
_FREE_TREE_REDUCTIONS = {"reduce_sum", "dot_general", "conv_general_dilated",
                         "cumsum"}

# exactly associative at any tree shape, float or int
_EXACT_REDUCTIONS = {"reduce_max", "reduce_min", "argmax", "argmin",
                     "cummax", "cummin"}


def _has_float_io(eqn) -> bool:
    for v in list(eqn.invars) + list(eqn.outvars):
        dtype = getattr(getattr(v, "aval", None), "dtype", None)
        if dtype is not None and np.dtype(dtype).kind == "f":
            return True
    return False


@dataclasses.dataclass(frozen=True)
class DeterminismFinding:
    """One bit-parity hazard."""
    kind: str           # "float_in_fixed" | "free_tree_reduction"
    primitive: str
    path: str
    source: str
    count: int          # executions per program call (scaled)
    gating: bool        # True when it violates the fixed-mode contract

    @property
    def name(self) -> str:
        return f"{self.path}/{self.primitive}@{self.source}"

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["name"] = self.name
        return d


@dataclasses.dataclass(frozen=True)
class DeterminismResult:
    ok: bool                     # no gating findings
    findings: tuple

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "num_findings": len(self.findings),
            "findings": [f.to_dict() for f in self.findings],
        }


def lint_determinism(jaxpr, *, numerics: str = "fixed",
                     max_findings: int = 64) -> DeterminismResult:
    """Lint a traced program (``ClosedJaxpr`` or plain ``Jaxpr``) for
    bit-parity hazards.

    ``numerics="fixed"`` applies the hardware-twin contract: ANY
    non-structural float op is a gating finding. ``numerics="float"``
    only reports free-tree float reductions, as informational findings.
    """
    findings: list = []

    def visit(eqn, scale, path):
        if len(findings) >= max_findings:
            return
        name = eqn.primitive.name
        if name in _STRUCTURAL or name in _EXACT_REDUCTIONS:
            return
        if not _has_float_io(eqn):
            return  # integer ops are exact at any evaluation order
        if name in _FREE_TREE_REDUCTIONS:
            findings.append(DeterminismFinding(
                kind="free_tree_reduction", primitive=name, path=path,
                source=traverse.eqn_source(eqn), count=scale,
                gating=(numerics == "fixed")))
        elif numerics == "fixed":
            findings.append(DeterminismFinding(
                kind="float_in_fixed", primitive=name, path=path,
                source=traverse.eqn_source(eqn), count=scale,
                gating=True))

    traverse.walk(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr,
                  visit, cond_branches=True, while_bodies=True)
    return DeterminismResult(
        ok=not any(f.gating for f in findings), findings=tuple(findings))
