"""Worst-case interval analysis: prove every register fits its bitwidth.

An abstract interpreter over jaxprs in the interval domain. Each traced
value is summarized by one closed interval ``[lo, hi]`` covering EVERY
element it can take for ANY program input inside the declared input
intervals (the ADC range ``FixedPointSpec.qmin/qmax`` and the session
register assumptions in ``targets.py``). Bounds are exact Python integers
(arbitrary precision), so the question "does this intermediate fit int32"
is answered by arithmetic, not sampling.

Design choices, in order of load-bearing-ness:

* **Concrete unrolling.** ``scan`` bodies (the 11-iteration MP bisection,
  the blocked FIR solves) unroll up to ``scan_unroll_limit`` iterations,
  and ``pallas_call`` grids unroll per grid step in row-major order with
  CONCRETE ``program_id`` values — so ``pl.when(b == 0)`` init/flush
  predicates resolve exactly and scratch accumulators are bounded by the
  real number of grid steps. Loops beyond the limit fall back to a
  join-until-stable fixpoint with widening to ``[-inf, inf]`` — sound,
  never silently optimistic.
* **Rect-keyed ref cells.** Pallas ``MemRef``s (inputs, outputs, VMEM
  scratch) are mutable cells keyed by the static/resolved index rects of
  their ``get``/``swap`` ops: a full-rect write replaces (strong update),
  an exact-rect write replaces that rect, anything unresolvable joins into
  everything it might touch (weak update). This keeps per-filter partial
  accumulator rows (``part_s[pl.ds(f, 1), :]``) independent instead of
  smearing all filters into one growing hull.
* **Every integer outvar is a register.** Each visited equation records
  the worst-case interval of its integer outputs, the required two's-
  complement bits, and the headroom against the carrier dtype. An interval
  escaping the dtype's representable range is an overflow violation naming
  the equation (primitive, source line, enclosing loop path). The
  per-record table is the static bitwidth column the ROADMAP Pareto
  search consumes.

Float values flow through the same interpreter (so mixed programs don't
crash) but get no bitwidth records: the overflow proof is about the
integer carrier.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import re
from typing import NamedTuple, Optional

import numpy as np

INF = float("inf")


def _isinf(v) -> bool:
    return isinstance(v, float) and math.isinf(v)


class Interval(NamedTuple):
    """Closed interval; bounds are exact ints for integer values (or
    +-inf), floats for float values."""
    lo: object
    hi: object

    def join(self, other: "Interval") -> "Interval":
        return Interval(min(self.lo, other.lo), max(self.hi, other.hi))

    @property
    def concrete(self) -> bool:
        return self.lo == self.hi and not isinstance(self.lo, float)

    def __repr__(self) -> str:  # compact report form
        return f"[{self.lo}, {self.hi}]"


TOP = Interval(-INF, INF)
BOOL = Interval(0, 1)


def signed_bits(iv: Interval) -> object:
    """Smallest two's-complement width holding every value in ``iv``:
    ``n`` with ``-2**(n-1) <= lo`` and ``hi <= 2**(n-1) - 1``. Infinite
    bounds need infinite bits."""
    if _isinf(iv.lo) or _isinf(iv.hi):
        return INF
    lo, hi = int(iv.lo), int(iv.hi)
    n_hi = hi.bit_length() + 1 if hi >= 0 else 1
    n_lo = (-lo - 1).bit_length() + 1 if lo < 0 else 1
    return max(n_lo, n_hi, 1)


def carrier_bits(iv: Interval, *, unsigned: bool = False) -> object:
    """Smallest register width of the carrier's signedness family holding
    every value in ``iv``: two's-complement for signed carriers, plain
    binary for unsigned ones (a negative bound fits no unsigned width)."""
    if _isinf(iv.lo) or _isinf(iv.hi):
        return INF
    if unsigned:
        if iv.lo < 0:
            return INF
        return max(int(iv.hi).bit_length(), 1)
    return signed_bits(iv)


def _json_bound(v):
    return None if _isinf(v) else int(v)


def _dtype_bits(dtype) -> Optional[int]:
    """Carrier width for integer dtypes; None for float/bool (no overflow
    semantics to check)."""
    d = np.dtype(dtype)
    if d.kind in ("i", "u"):
        return d.itemsize * 8
    return None


def _dtype_range(dtype) -> Interval:
    d = np.dtype(dtype)
    if d.kind == "b":
        return BOOL
    if d.kind in ("i", "u"):
        info = np.iinfo(d)
        return Interval(int(info.min), int(info.max))
    return TOP


def _from_value(val) -> Interval:
    """Interval of a concrete constant (literal or jaxpr const)."""
    arr = np.asarray(val)
    if arr.size == 0:
        return Interval(0, 0)
    if arr.dtype.kind in ("i", "u", "b"):
        return Interval(int(arr.min()), int(arr.max()))
    lo, hi = float(arr.min()), float(arr.max())
    if math.isnan(lo) or math.isnan(hi):
        return TOP
    return Interval(lo, hi)


# ---------------------------------------------------------------------------
# mutable cells for pallas MemRefs
# ---------------------------------------------------------------------------


_SLICE_RE = re.compile(r"Slice\[\((\d+|None), (\d+), (\d+)\)\]")


def _parse_indexer(tree_param, ndim: int):
    """Decode the static part of a ``get``/``swap`` NDIndexer PyTreeDef:
    a list of ``(start|None, size)`` per dim (None = dynamic start, which
    consumes one index invar), or None when the structure isn't the plain
    all-slices form (integer indexing, multiple indexers, strides != 1)."""
    dims = _SLICE_RE.findall(str(tree_param))
    if len(dims) != ndim:
        return None
    out = []
    for start, size, stride in dims:
        if stride != "1":
            return None
        out.append((None if start == "None" else int(start), int(size)))
    return out


def _rects_overlap(a, b) -> bool:
    return all(s1 < e2 and s2 < e1 for (s1, e1), (s2, e2) in zip(a, b))


def _rect_contains(outer, inner) -> bool:
    return all(s1 <= s2 and e2 <= e1
               for (s1, e1), (s2, e2) in zip(outer, inner))


class RefCell:
    """Interval state of one MemRef: a background hull plus strong-updated
    rects. ``background=None`` means never-written: a read that no
    recorded write covers is a read-before-write (real UB in a pallas
    kernel) and is reported by the interpreter."""

    def __init__(self, shape, dtype, background: Optional[Interval]):
        self.shape = tuple(int(d) for d in shape)
        self.dtype = dtype
        self.background = background
        self.rects: dict = {}

    def _full_rect(self):
        return tuple((0, d) for d in self.shape)

    def resolve_rect(self, tree_param, idx_vals):
        """Static+concrete index rect of an access, or None (unresolvable
        -> weak semantics). ``idx_vals`` are the evaluated intervals of the
        dynamic index operands, consumed in order."""
        dims = _parse_indexer(tree_param, len(self.shape))
        if dims is None:
            return None
        rect, k = [], 0
        for (start, size) in dims:
            if start is None:
                if k >= len(idx_vals):
                    return None
                iv = idx_vals[k]
                k += 1
                if not iv.concrete:
                    return None
                start = int(iv.lo)
            rect.append((start, start + size))
        if k != len(idx_vals):
            return None
        return tuple(rect)

    def read(self, rect) -> Optional[Interval]:
        """Join of everything the accessed rect can contain. ``None``
        means the rect is provably unwritten (read-before-write)."""
        if rect is None:
            rect = self._full_rect()
        out = None
        for r, iv in self.rects.items():
            if _rects_overlap(r, rect):
                out = iv if out is None else out.join(iv)
        covered = any(_rect_contains(r, rect) for r in self.rects)
        if not covered and self.background is not None:
            out = (self.background if out is None
                   else out.join(self.background))
        return out

    def write(self, rect, value: Interval) -> None:
        if rect is None:
            # unresolvable target: the write may land anywhere (weak)
            self.background = (value if self.background is None
                               else self.background.join(value))
            for r in self.rects:
                self.rects[r] = self.rects[r].join(value)
            return
        if rect == self._full_rect():
            self.background = value
            self.rects = {}
            return
        self.rects[rect] = value

    def hull(self) -> Interval:
        out = self.background
        for iv in self.rects.values():
            out = iv if out is None else out.join(iv)
        return out if out is not None else Interval(0, 0)

    def snapshot(self):
        return (self.background, dict(self.rects))

    def restore(self, snap) -> None:
        self.background, rects = snap
        self.rects = dict(rects)

    def join_state(self, snap) -> None:
        bg, rects = snap
        if self.background is None:
            self.background = bg
        elif bg is not None:
            self.background = self.background.join(bg)
        for r, iv in rects.items():
            self.rects[r] = iv if r not in self.rects \
                else self.rects[r].join(iv)


# ---------------------------------------------------------------------------
# records + results
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RegisterRecord:
    """Worst-case summary of one traced equation's integer output."""
    name: str          # path/primitive@source
    primitive: str
    path: str
    source: str
    dtype_bits: int
    lo: object
    hi: object
    visits: int = 1
    unsigned: bool = False

    @property
    def required_bits(self) -> object:
        return carrier_bits(Interval(self.lo, self.hi),
                            unsigned=self.unsigned)

    @property
    def headroom_bits(self) -> object:
        r = self.required_bits
        return -INF if r == INF else self.dtype_bits - r

    def to_dict(self) -> dict:
        rb = self.required_bits
        return {
            "name": self.name,
            "dtype_bits": self.dtype_bits,
            "interval": [_json_bound(self.lo), _json_bound(self.hi)],
            "required_bits": _json_bound(rb),
            "headroom_bits": (None if rb == INF
                              else int(self.dtype_bits - rb)),
            "visits": self.visits,
        }


@dataclasses.dataclass(frozen=True)
class OverflowViolation:
    """One integer intermediate whose worst case exceeds its carrier."""
    name: str
    primitive: str
    source: str
    dtype_bits: int
    required_bits: object
    lo: object
    hi: object

    def to_dict(self) -> dict:
        return {
            "name": self.name, "primitive": self.primitive,
            "source": self.source, "dtype_bits": self.dtype_bits,
            "required_bits": _json_bound(self.required_bits),
            "interval": [_json_bound(self.lo), _json_bound(self.hi)],
        }


@dataclasses.dataclass
class IntervalResult:
    """Everything the pass proved about one target program."""
    ok: bool
    violations: list
    registers: list                  # RegisterRecord, sorted by headroom
    out_intervals: list              # Interval per program output
    min_headroom_bits: object
    max_required_bits: object
    unsupported: list                # primitives handled conservatively
    # per-equation records keyed ``(path, id(eqn))`` — the lookup the IR
    # builder (repro.ir.build) uses to type registers; only valid while
    # the analyzed jaxpr objects are alive (same-process consumption)
    records_by_eqn: dict = dataclasses.field(default_factory=dict)

    def to_dict(self, *, top_registers: int = 20) -> dict:
        return {
            "ok": self.ok,
            "min_headroom_bits": _json_bound(self.min_headroom_bits),
            "max_required_bits": _json_bound(self.max_required_bits),
            "num_registers": len(self.registers),
            "violations": [v.to_dict() for v in self.violations],
            "tightest_registers": [r.to_dict()
                                   for r in self.registers[:top_registers]],
            "out_intervals": [[_json_bound(iv.lo), _json_bound(iv.hi)]
                              for iv in self.out_intervals],
            "unsupported_primitives": sorted(set(self.unsupported)),
        }


# ---------------------------------------------------------------------------
# transfer functions
# ---------------------------------------------------------------------------


def _mul_iv(a: Interval, b: Interval) -> Interval:
    cands = []
    for x in (a.lo, a.hi):
        for y in (b.lo, b.hi):
            if (_isinf(x) and y == 0) or (_isinf(y) and x == 0):
                cands.append(0)
            else:
                cands.append(x * y)
    return Interval(min(cands), max(cands))


def _shift_right_iv(a: Interval, k: Interval) -> Interval:
    if _isinf(a.lo) or _isinf(a.hi):
        return TOP
    klo = 0 if _isinf(k.lo) else max(int(k.lo), 0)
    khi = 63 if _isinf(k.hi) else max(int(k.hi), 0)
    cands = [int(x) >> kk for x in (a.lo, a.hi) for kk in (klo, khi)]
    return Interval(min(cands), max(cands))


def _shift_left_iv(a: Interval, k: Interval) -> Interval:
    if _isinf(a.lo) or _isinf(a.hi) or _isinf(k.hi):
        return TOP
    klo = 0 if _isinf(k.lo) else max(int(k.lo), 0)
    khi = max(int(k.hi), 0)
    cands = [int(x) << kk for x in (a.lo, a.hi) for kk in (klo, khi)]
    return Interval(min(cands), max(cands))


def _bitwise_iv(a: Interval, b: Interval) -> Interval:
    """AND/OR/XOR stay within the wider operand's two's-complement width."""
    if a.lo >= 0 and b.lo >= 0 and not (_isinf(a.hi) or _isinf(b.hi)):
        # n-bit nonneg operands produce an n-bit nonneg result
        n = max(int(a.hi), int(b.hi)).bit_length()
        return Interval(0, (1 << n) - 1 if n else 0)
    na, nb = signed_bits(a), signed_bits(b)
    if na == INF or nb == INF:
        return TOP
    n = max(na, nb)
    return Interval(-(1 << (n - 1)), (1 << (n - 1)) - 1)


def _cmp(op, a: Interval, b: Interval) -> Interval:
    """Comparison to a bool interval, resolved when operands are disjoint."""
    if op == "lt":
        if a.hi < b.lo:
            return Interval(1, 1)
        if a.lo >= b.hi:
            return Interval(0, 0)
    elif op == "le":
        if a.hi <= b.lo:
            return Interval(1, 1)
        if a.lo > b.hi:
            return Interval(0, 0)
    elif op == "gt":
        if a.lo > b.hi:
            return Interval(1, 1)
        if a.hi <= b.lo:
            return Interval(0, 0)
    elif op == "ge":
        if a.lo >= b.hi:
            return Interval(1, 1)
        if a.hi < b.lo:
            return Interval(0, 0)
    elif op == "eq":
        if a.concrete and b.concrete and a.lo == b.lo:
            return Interval(1, 1)
        if a.hi < b.lo or b.hi < a.lo:
            return Interval(0, 0)
    elif op == "ne":
        if a.concrete and b.concrete and a.lo == b.lo:
            return Interval(0, 0)
        if a.hi < b.lo or b.hi < a.lo:
            return Interval(1, 1)
    return BOOL


def _reduced_elems(eqn) -> int:
    shape = getattr(eqn.invars[0].aval, "shape", ())
    m = 1
    for a in eqn.params.get("axes", ()):
        m *= shape[a]
    return m


def _sum_iv(x: Interval, m: int) -> Interval:
    """Sum of ``m`` elements each in ``x``."""
    if m <= 0:
        return Interval(0, 0)
    return Interval(x.lo * m, x.hi * m)


# ---------------------------------------------------------------------------
# the interpreter
# ---------------------------------------------------------------------------


class _Analyzer:
    def __init__(self, *, scan_unroll_limit: int = 64,
                 grid_unroll_limit: int = 4096,
                 fixpoint_iters: int = 64):
        self.scan_unroll_limit = scan_unroll_limit
        self.grid_unroll_limit = grid_unroll_limit
        self.fixpoint_iters = fixpoint_iters
        self.records: dict = {}
        self.violations: list = []
        self.unsupported: list = []
        self._pid_stack: list = []   # concrete program_id per grid axis
        self._grid_stack: list = []  # static grid tuple

    # -- environment ------------------------------------------------------

    def _read(self, env, v):
        from jax._src.core import Literal
        if isinstance(v, Literal):
            return _from_value(v.val)
        return env[v]

    def _name(self, eqn, path) -> str:
        from repro.analysis.traverse import eqn_source
        return f"{path}/{eqn.primitive.name}@{eqn_source(eqn)}"

    def _check_and_record(self, eqn, path, iv: Interval, outvar) -> None:
        dtype = getattr(outvar.aval, "dtype", None)
        if dtype is None:
            return
        bits = _dtype_bits(dtype)
        if bits is None:
            return
        unsigned = np.dtype(dtype).kind == "u"
        from repro.analysis.traverse import eqn_source
        key = (path, id(eqn))
        rec = self.records.get(key)
        if rec is None:
            self.records[key] = RegisterRecord(
                name=self._name(eqn, path),
                primitive=eqn.primitive.name, path=path,
                source=eqn_source(eqn), dtype_bits=bits,
                lo=iv.lo, hi=iv.hi, unsigned=unsigned)
        else:
            rec.lo = min(rec.lo, iv.lo)
            rec.hi = max(rec.hi, iv.hi)
            rec.visits += 1
        rng = _dtype_range(dtype)
        if iv.lo < rng.lo or iv.hi > rng.hi:
            self.violations.append(OverflowViolation(
                name=self._name(eqn, path),
                primitive=eqn.primitive.name, source=eqn_source(eqn),
                dtype_bits=bits,
                required_bits=carrier_bits(iv, unsigned=unsigned),
                lo=iv.lo, hi=iv.hi))

    def _bind_outs(self, eqn, env, path, outs) -> None:
        # NB: Interval is itself a tuple — test it before the sequence case
        if isinstance(outs, Interval) or not isinstance(outs, (list, tuple)):
            outs = [outs]
        for v, iv in zip(eqn.outvars, outs):
            env[v] = iv
            if isinstance(iv, Interval):
                self._check_and_record(eqn, path, iv, v)

    # -- jaxpr evaluation --------------------------------------------------

    def eval_closed(self, closed, in_vals, path=""):
        consts = [c if isinstance(c, (Interval, RefCell))
                  else _from_value(c) for c in closed.consts]
        return self.eval_jaxpr(closed.jaxpr, consts + list(in_vals), path)

    def eval_jaxpr(self, jaxpr, in_vals, path=""):
        env = {}
        allvars = list(jaxpr.constvars) + list(jaxpr.invars)
        if len(allvars) != len(in_vals):
            raise ValueError(
                f"arity mismatch at {path or '<top>'}: {len(allvars)} "
                f"vars, {len(in_vals)} values")
        for v, val in zip(allvars, in_vals):
            env[v] = val
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if name in ("pjit", "closed_call", "custom_vjp_call",
                        "custom_jvp_call", "custom_vjp_call_jaxpr",
                        "remat", "checkpoint"):
                self._eval_call(eqn, env, path)
            elif name == "scan":
                self._eval_scan(eqn, env, path)
            elif name == "while":
                self._eval_while(eqn, env, path)
            elif name == "cond":
                self._eval_cond(eqn, env, path)
            elif name == "pallas_call":
                self._eval_pallas(eqn, env, path)
            elif name == "get":
                self._bind_outs(eqn, env, path,
                                self._eval_get(eqn, env, path))
            elif name == "swap":
                self._bind_outs(eqn, env, path,
                                self._eval_swap(eqn, env, path))
            else:
                self._bind_outs(eqn, env, path,
                                self._eval_leaf(eqn, env, path))
        return [self._read(env, v) for v in jaxpr.outvars]

    # -- leaf ops ----------------------------------------------------------

    IDENTITY = {
        "broadcast_in_dim", "reshape", "squeeze", "expand_dims",
        "transpose", "rev", "slice", "gather", "copy", "device_put",
        "stop_gradient", "reduce_max", "reduce_min", "cummax", "cummin",
        "reduce_precision", "dynamic_slice",
    }

    def _eval_leaf(self, eqn, env, path):
        name = eqn.primitive.name
        ins = [self._read(env, v) for v in eqn.invars]

        if name in self.IDENTITY:
            return [ins[0]] * len(eqn.outvars)
        if name == "dynamic_update_slice":
            return ins[0].join(ins[1])
        if name == "concatenate":
            out = ins[0]
            for iv in ins[1:]:
                out = out.join(iv)
            return out
        if name == "pad":
            return ins[0].join(ins[1])
        if name == "add":
            return Interval(ins[0].lo + ins[1].lo, ins[0].hi + ins[1].hi)
        if name == "sub":
            return Interval(ins[0].lo - ins[1].hi, ins[0].hi - ins[1].lo)
        if name == "neg":
            return Interval(-ins[0].hi, -ins[0].lo)
        if name == "mul":
            return _mul_iv(ins[0], ins[1])
        if name == "max":
            return Interval(max(ins[0].lo, ins[1].lo),
                            max(ins[0].hi, ins[1].hi))
        if name == "min":
            return Interval(min(ins[0].lo, ins[1].lo),
                            min(ins[0].hi, ins[1].hi))
        if name == "abs":
            lo, hi = ins[0]
            return Interval(0 if lo <= 0 <= hi else min(abs(lo), abs(hi)),
                            max(abs(lo), abs(hi)))
        if name == "sign":
            lo, hi = ins[0]
            return Interval(-1 if lo < 0 else (1 if lo > 0 else 0),
                            1 if hi > 0 else (-1 if hi < 0 else 0))
        if name == "clamp":
            lo_b, x, hi_b = ins
            t = Interval(max(x.lo, lo_b.lo), max(x.hi, lo_b.hi))
            return Interval(min(t.lo, hi_b.lo), min(t.hi, hi_b.hi))
        if name in ("gt", "lt", "ge", "le", "eq", "ne"):
            return _cmp(name, ins[0], ins[1])
        if name == "select_n":
            pred, cases = ins[0], ins[1:]
            if pred.concrete and 0 <= int(pred.lo) < len(cases):
                return cases[int(pred.lo)]
            lo = 0 if _isinf(pred.lo) else max(int(pred.lo), 0)
            hi = len(cases) - 1 if _isinf(pred.hi) \
                else min(int(pred.hi), len(cases) - 1)
            out = cases[lo]
            for c in cases[lo + 1:hi + 1]:
                out = out.join(c)
            return out
        if name == "shift_left":
            return _shift_left_iv(ins[0], ins[1])
        if name == "shift_right_arithmetic":
            return _shift_right_iv(ins[0], ins[1])
        if name == "shift_right_logical":
            if ins[0].lo >= 0:
                return _shift_right_iv(ins[0], ins[1])
            return _dtype_range(eqn.outvars[0].aval.dtype)
        if name in ("and", "or", "xor"):
            if np.dtype(eqn.outvars[0].aval.dtype).kind == "b":
                return BOOL
            if name == "and" and ins[0].lo >= 0 and ins[1].lo >= 0:
                # nonneg AND clears bits: x & y <= min(x, y)
                return Interval(0, min(ins[0].hi, ins[1].hi))
            return _bitwise_iv(ins[0], ins[1])
        if name == "not":
            if np.dtype(eqn.outvars[0].aval.dtype).kind == "b":
                return BOOL
            return Interval(-ins[0].hi - 1, -ins[0].lo - 1)
        if name == "reduce_sum":
            return _sum_iv(ins[0], _reduced_elems(eqn))
        if name == "cumsum":
            shape = getattr(eqn.invars[0].aval, "shape", ())
            m = shape[eqn.params.get("axis", 0)] if shape else 1
            # prefix sums: hull over k in 1..m partial sums (linear in k)
            s1, sm = _sum_iv(ins[0], 1), _sum_iv(ins[0], m)
            return s1.join(sm)
        if name in ("reduce_and", "reduce_or"):
            return BOOL
        if name in ("argmax", "argmin"):
            return Interval(0, max(_reduced_elems(eqn) - 1, 0))
        if name == "iota":
            shape = eqn.params.get("shape", ())
            dim = eqn.params.get("dimension", 0)
            n = shape[dim] if shape else 1
            return Interval(0, max(int(n) - 1, 0))
        if name == "convert_element_type":
            return self._convert(eqn, ins[0])
        if name == "program_id":
            axis = eqn.params.get("axis", 0)
            if self._pid_stack and self._pid_stack[-1] is not None:
                v = self._pid_stack[-1][axis]
                return Interval(v, v)
            if self._grid_stack:
                return Interval(0, max(self._grid_stack[-1][axis] - 1, 0))
            return Interval(0, 0)
        if name == "num_programs":
            axis = eqn.params.get("axis", 0)
            g = self._grid_stack[-1][axis] if self._grid_stack else 1
            return Interval(g, g)
        if name == "dot_general":
            lhs_shape = eqn.invars[0].aval.shape
            ((lc, _), _) = eqn.params["dimension_numbers"]
            m = 1
            for d in lc:
                m *= lhs_shape[d]
            return _sum_iv(_mul_iv(ins[0], ins[1]), m)
        if name == "conv_general_dilated":
            rhs = eqn.invars[1].aval.shape
            k_elems = 1
            for d in rhs:
                k_elems *= d
            m = max(k_elems // max(rhs[0], 1), 1)
            return _sum_iv(_mul_iv(ins[0], ins[1]), m)
        if name == "integer_pow":
            y = eqn.params.get("y", 1)
            if _isinf(ins[0].lo) or _isinf(ins[0].hi):
                return TOP
            cands = [x ** y for x in (ins[0].lo, ins[0].hi)]
            if y % 2 == 0 and ins[0].lo <= 0 <= ins[0].hi:
                cands.append(0)
            return Interval(min(cands), max(cands))
        if name == "rem":
            a, b = ins
            if _isinf(b.lo) or _isinf(b.hi) or (b.lo <= 0 <= b.hi):
                return TOP
            m = max(abs(int(b.lo)), abs(int(b.hi))) - 1
            return Interval(-m if a.lo < 0 else 0, m if a.hi > 0 else 0)
        if name == "exp":
            lo = 0.0 if _isinf(ins[0].lo) else math.exp(min(ins[0].lo, 700))
            hi = INF if _isinf(ins[0].hi) else math.exp(min(ins[0].hi, 700))
            return Interval(lo, hi)
        if name == "tanh":
            return Interval(-1.0, 1.0)
        if name == "logistic":
            return Interval(0.0, 1.0)
        if name in ("sqrt", "rsqrt", "log", "div", "pow", "erf", "sin",
                    "cos", "floor", "ceil", "round", "nextafter",
                    "square", "is_finite", "sort"):
            # float-path ops: no integer overflow semantics to prove
            self.unsupported.append(name)
            return [TOP] * len(eqn.outvars)

        self.unsupported.append(name)
        return [_dtype_range(getattr(v.aval, "dtype", np.float32))
                for v in eqn.outvars]

    def _convert(self, eqn, x: Interval) -> Interval:
        dtype = eqn.params.get("new_dtype", eqn.outvars[0].aval.dtype)
        if _dtype_bits(dtype) is None:
            return x
        if isinstance(x.lo, float) or isinstance(x.hi, float):
            if _isinf(x.lo) or _isinf(x.hi):
                return _dtype_range(dtype)
            x = Interval(int(math.floor(x.lo)), int(math.ceil(x.hi)))
        # int narrowing wraps in XLA: a wrap IS an overflow event, which
        # _check_and_record reports (the pre-clamp interval escapes the
        # target range); continue with the full target range so downstream
        # stays sound
        rng = _dtype_range(dtype)
        if x.lo < rng.lo or x.hi > rng.hi:
            return x  # reported at the record step; caller sees true hull
        return x

    # -- higher-order ops --------------------------------------------------

    def _eval_call(self, eqn, env, path):
        closed = (eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                  or eqn.params.get("fun_jaxpr"))
        ins = [self._read(env, v) for v in eqn.invars]
        sub = f"{path}/{eqn.primitive.name}"
        if hasattr(closed, "consts"):
            outs = self.eval_closed(closed, ins, sub)
        else:
            outs = self.eval_jaxpr(closed, ins, sub)
        self._bind_outs(eqn, env, path, outs)

    def _eval_scan(self, eqn, env, path):
        p = eqn.params
        closed = p["jaxpr"]
        # length 0 is a real case (zero-length chunk programs): the body
        # never runs, the carry out IS the carry in, and the stacked ys are
        # empty arrays (bound to [0, 0] below via the ys-None fallback)
        length = p.get("length")
        length = 1 if length is None else int(length)
        n_consts, n_carry = p["num_consts"], p["num_carry"]
        ins = [self._read(env, v) for v in eqn.invars]
        consts = ins[:n_consts]
        carry = list(ins[n_consts:n_consts + n_carry])
        xs = ins[n_consts + n_carry:]
        n_ys = len(eqn.outvars) - n_carry
        ys = [None] * n_ys
        spath = f"{path}/scan[{length}]"

        def step(cur):
            outs = self.eval_closed(closed, consts + cur + xs, spath)
            return outs[:n_carry], outs[n_carry:]

        def join_ys(acc, new):
            return [b if a is None else a.join(b) for a, b in zip(acc, new)]

        if length <= self.scan_unroll_limit:
            for _ in range(length):
                carry, y = step(carry)
                ys = join_ys(ys, y)
        else:
            stable = False
            for _ in range(self.fixpoint_iters):
                new_carry, y = step(carry)
                ys = join_ys(ys, y)
                joined = [a.join(b) for a, b in zip(carry, new_carry)]
                if all(a.lo == j.lo and a.hi == j.hi
                       for a, j in zip(carry, joined)):
                    stable = True
                    break
                carry = joined
            if not stable:
                carry = [TOP] * len(carry)
                carry, y = step(carry)
                ys = join_ys(ys, y)
        outs = carry + [y if y is not None else Interval(0, 0) for y in ys]
        self._bind_outs(eqn, env, path, outs)

    def _eval_while(self, eqn, env, path):
        p = eqn.params
        cond_n, body_n = p["cond_nconsts"], p["body_nconsts"]
        body = p["body_jaxpr"]
        ins = [self._read(env, v) for v in eqn.invars]
        body_consts = ins[cond_n:cond_n + body_n]
        carry = list(ins[cond_n + body_n:])
        wpath = f"{path}/while"
        stable = False
        for _ in range(self.fixpoint_iters):
            outs = self.eval_closed(body, body_consts + carry, wpath)
            joined = [a.join(b) for a, b in zip(carry, outs)]
            if all(a.lo == j.lo and a.hi == j.hi
                   for a, j in zip(carry, joined)):
                stable = True
                break
            carry = joined
        if not stable:
            carry = [TOP] * len(carry)
            self.eval_closed(body, body_consts + carry, wpath)
        self._bind_outs(eqn, env, path, carry)

    def _eval_cond(self, eqn, env, path):
        branches = eqn.params["branches"]
        ins = [self._read(env, v) for v in eqn.invars]
        index, ops = ins[0], ins[1:]
        if index.concrete:
            lo = hi = max(0, min(int(index.lo), len(branches) - 1))
        else:
            lo = 0 if _isinf(index.lo) else max(int(index.lo), 0)
            hi = len(branches) - 1 if _isinf(index.hi) \
                else min(int(index.hi), len(branches) - 1)
        cells = [o for o in ops if isinstance(o, RefCell)]
        snaps = [c.snapshot() for c in cells]
        end_states: list = []
        outs_join = None
        for b in range(lo, hi + 1):
            for c, s in zip(cells, snaps):
                c.restore(s)
            outs = self.eval_closed(branches[b], ops,
                                    f"{path}/cond.branch{b}")
            end_states.append([c.snapshot() for c in cells])
            if outs_join is None:
                outs_join = list(outs)
            else:
                outs_join = [a.join(o) if isinstance(a, Interval) else a
                             for a, o in zip(outs_join, outs)]
        for i, c in enumerate(cells):
            c.restore(end_states[0][i])
            for st in end_states[1:]:
                c.join_state(st[i])
        self._bind_outs(eqn, env, path, outs_join or [])

    def _eval_pallas(self, eqn, env, path):
        gm = eqn.params["grid_mapping"]
        grid = tuple(int(g) for g in (getattr(gm, "grid", ()) or ()))
        inner = eqn.params["jaxpr"]
        ins = [self._read(env, v) for v in eqn.invars]
        n_index = int(getattr(gm, "num_index_operands", 0) or 0)
        n_outputs = int(getattr(gm, "num_outputs", len(eqn.outvars))
                        or len(eqn.outvars))
        n_inputs_attr = getattr(gm, "num_inputs", None)
        n_inputs = (int(n_inputs_attr) if n_inputs_attr is not None
                    else len(ins) - n_index)
        # kernel invars: [index scalars, input refs, output refs, scratch]
        cells = []
        for i, kv in enumerate(inner.invars):
            aval = kv.aval
            shape = tuple(getattr(aval, "shape", ()))
            dtype = getattr(aval, "dtype", np.int32)
            if i < n_index:
                cells.append(ins[i])           # scalar prefetch: a value
            elif i < n_index + n_inputs:
                cells.append(RefCell(shape, dtype, ins[i]))
            else:
                cells.append(RefCell(shape, dtype, None))
        steps = 1
        for g in grid:
            steps *= g
        ppath = f"{path}/pallas_call"
        self._grid_stack.append(grid or (1,))
        if 0 < steps <= self.grid_unroll_limit:
            for pid in (itertools.product(*[range(g) for g in grid])
                        if grid else [()]):
                self._pid_stack.append(tuple(pid) if pid else (0,))
                self.eval_jaxpr(inner, cells, ppath)
                self._pid_stack.pop()
        else:
            self._pid_stack.append(None)
            stable = False
            for _ in range(self.fixpoint_iters):
                before = [c.hull() if isinstance(c, RefCell) else c
                          for c in cells]
                self.eval_jaxpr(inner, cells, ppath)
                after = [c.hull() if isinstance(c, RefCell) else c
                         for c in cells]
                if all((not isinstance(b, Interval))
                       or (b.lo == a.lo and b.hi == a.hi)
                       for b, a in zip(before, after)):
                    stable = True
                    break
            if not stable:
                # still-growing ref state after fixpoint_iters: widen every
                # cell to TOP (mirroring _eval_scan's carry fallback — ref
                # writes are strong updates, so no per-cell stability
                # argument survives non-convergence) and run the body once
                # more so reads of the widened state are recorded as
                # violations instead of the loop exiting optimistically
                for c in cells:
                    if isinstance(c, RefCell):
                        c.background = TOP
                        c.rects = {}
                self.eval_jaxpr(inner, cells, ppath)
            self._pid_stack.pop()
        self._grid_stack.pop()
        out_cells = cells[n_index + n_inputs:n_index + n_inputs + n_outputs]
        outs = [c.hull() if isinstance(c, RefCell) else c
                for c in out_cells]
        self._bind_outs(eqn, env, path, outs)

    def _eval_get(self, eqn, env, path):
        ref = env[eqn.invars[0]]
        idx = [self._read(env, v) for v in eqn.invars[1:]]
        rect = ref.resolve_rect(eqn.params.get("tree"), idx)
        out = ref.read(rect)
        if out is None:
            self.violations.append(OverflowViolation(
                name=f"{self._name(eqn, path)} (read-before-write)",
                primitive="get",
                source=self._name(eqn, path).rsplit("@", 1)[-1],
                dtype_bits=_dtype_bits(ref.dtype) or 0,
                required_bits=INF, lo=-INF, hi=INF))
            out = _dtype_range(ref.dtype)
        return out

    def _eval_swap(self, eqn, env, path):
        from jax._src.core import DropVar
        ref = env[eqn.invars[0]]
        val = self._read(env, eqn.invars[1])
        idx = [self._read(env, v) for v in eqn.invars[2:]]
        rect = ref.resolve_rect(eqn.params.get("tree"), idx)
        old = ref.read(rect)
        ref.write(rect, val)
        if old is None:
            # plain stores lower to swap with a DropVar result: writing an
            # unwritten ref is fine, it's only a read-before-write when the
            # old value is actually consumed
            if all(isinstance(v, DropVar) for v in eqn.outvars):
                return val
            self.violations.append(OverflowViolation(
                name=f"{self._name(eqn, path)} (read-before-write)",
                primitive="swap",
                source=self._name(eqn, path).rsplit("@", 1)[-1],
                dtype_bits=_dtype_bits(ref.dtype) or 0,
                required_bits=INF, lo=-INF, hi=INF))
            old = _dtype_range(ref.dtype)
        return old


def analyze_intervals(closed_jaxpr, in_intervals, *,
                      scan_unroll_limit: int = 64,
                      grid_unroll_limit: int = 4096) -> IntervalResult:
    """Run worst-case interval analysis over a ``ClosedJaxpr``.

    ``in_intervals`` is one :class:`Interval` per flattened program input
    (same order as ``jaxpr.invars`` — i.e. ``jax.tree_util.tree_leaves``
    order of the traced arguments). Returns an :class:`IntervalResult`
    whose ``ok`` proves every integer intermediate fits its carrier dtype
    for every input in the declared intervals.
    """
    a = _Analyzer(scan_unroll_limit=scan_unroll_limit,
                  grid_unroll_limit=grid_unroll_limit)
    outs = a.eval_closed(closed_jaxpr, list(in_intervals))
    regs = sorted(a.records.values(),
                  key=lambda r: (r.headroom_bits
                                 if not _isinf(r.headroom_bits)
                                 else -10**9))
    heads = [r.headroom_bits for r in regs]
    reqs = [r.required_bits for r in regs]
    return IntervalResult(
        ok=not a.violations, violations=a.violations, registers=regs,
        out_intervals=[o if isinstance(o, Interval) else TOP
                       for o in outs],
        min_headroom_bits=min(heads) if heads else INF,
        max_required_bits=max(reqs) if reqs else 0,
        unsupported=a.unsupported,
        records_by_eqn=dict(a.records))
