"""Op-legality pass (the generalized multiplierless verifier) and the
compatibility census.

Legality is the paper's primitive-set contract as a whitelist: the integer
datapath may contain adds/subtracts, shifts, compares/selects, bitwise
logic, data movement — and NOTHING else. A multiply is legal only when it
is a shift in disguise: a binary ``mul`` whose multiplier operand is a
literal with every element a nonzero power of two (the pre-refactor
``_literal_pow2`` accepted any pow2 literal invar and only inspected its
first element — the fixed classifier here is what ``hardware_cost.py``
now uses too). Violations come back as named equations with source
locations, and unlike the census the verifier recurses into ``cond``
branches and ``while`` bodies: the gate sees strictly more code than the
counter.

The census (:func:`census_jaxpr`) is the same traversal run in counting
mode, preserving the pre-refactor semantics EXACTLY (cond/while bodies
skipped, reductions count consumed-minus-produced elements, MAC ops count
out-elems x contraction) so committed benchmark numbers do not move.
"""

from __future__ import annotations

import dataclasses
import math
from collections import Counter
from typing import Optional

import numpy as np

from repro.analysis import traverse

CensusCounter = Counter

MUL_OPS = {"mul"}
ADD_OPS = {"add", "sub", "neg"}
CMP_OPS = {"max", "min", "gt", "lt", "ge", "le", "select_n", "eq", "abs",
           "sign", "clamp"}
SHIFT_OPS = {"shift_left", "shift_right_arithmetic", "shift_right_logical"}
# reductions lower to one op per consumed element (an adder/comparator tree)
REDUCE_ADD_OPS = {"reduce_sum"}
REDUCE_CMP_OPS = {"reduce_max", "reduce_min"}

# ops the FPGA datapath also realizes without a multiplier but that the
# census puts in no cost bucket (bitwise logic, index compares)
BITWISE_OPS = {"and", "or", "xor", "not", "ne"}

# value movement / layout: wiring, not arithmetic
STRUCTURAL_OPS = {
    "broadcast_in_dim", "reshape", "squeeze", "expand_dims", "transpose",
    "slice", "dynamic_slice", "dynamic_update_slice", "concatenate",
    "gather", "rev", "pad", "convert_element_type", "device_put", "copy",
    "stop_gradient", "iota", "program_id", "num_programs", "get", "swap",
    "reduce_and", "reduce_or", "argmax", "argmin",
}

LEGAL_OPS = (ADD_OPS | CMP_OPS | SHIFT_OPS | REDUCE_ADD_OPS
             | REDUCE_CMP_OPS | BITWISE_OPS | STRUCTURAL_OPS)


def _is_literal(v) -> bool:
    from jax._src.core import Literal
    return isinstance(v, Literal)


def _all_pow2(val) -> bool:
    """True when every element of ``val`` is a nonzero power of two (of
    either sign) — the multiplier values a shifter can realize."""
    try:
        flat = np.ravel(np.asarray(val))
    except Exception:  # noqa: BLE001 - non-array literal: not a shift
        return False
    if flat.size == 0:
        return False
    for x in flat:
        x = float(abs(x))
        if x == 0 or abs(math.log2(x) % 1.0) >= 1e-9:
            return False
    return True


def literal_pow2_multiplicand(eqn) -> bool:
    """True when ``eqn`` is a binary ``mul`` that hardware realizes as a
    shift: EXACTLY one operand is a literal, and every element of that
    literal is a nonzero power of two.

    This is the fixed form of the old ``hardware_cost._literal_pow2``,
    which (a) returned True if ANY literal invar was pow2 — even an
    operand that wasn't the multiplier — and (b) inspected only the
    literal's first element, so a ``[4.0, 3.0]`` tap vector would have
    been misclassified as a pure shift.
    """
    if eqn.primitive.name not in MUL_OPS or len(eqn.invars) != 2:
        return False
    lits = [v for v in eqn.invars if _is_literal(v)]
    if len(lits) != 1:
        return False
    return _all_pow2(lits[0].val)


# ---------------------------------------------------------------------------
# counting mode: the benchmark census (pre-refactor semantics, pinned)
# ---------------------------------------------------------------------------


def _out_elems(eqn) -> int:
    tot = 0
    for v in eqn.outvars:
        if hasattr(v.aval, "shape"):
            n = 1
            for d in v.aval.shape:
                n *= d
            tot += n
    return tot


def _in_elems(eqn) -> int:
    v = eqn.invars[0]
    n = 1
    for d in getattr(v.aval, "shape", ()):
        n *= d
    return n


def census_jaxpr(jaxpr) -> Counter:
    """Count hardware ops in a traced jaxpr (multiply/add/compare/shift/
    transcendental_or_div buckets), scaled by loop lengths and pallas grid
    products. ``jaxpr`` is a ``ClosedJaxpr`` or plain ``Jaxpr``."""
    counts: Counter = Counter()

    def visit(eqn, scale, path):
        name = eqn.primitive.name
        n = _out_elems(eqn)
        if name == "conv_general_dilated":
            # MACs: out elems x kernel taps (per output channel)
            rhs = eqn.invars[1].aval.shape
            k_elems = 1
            for d in rhs:
                k_elems *= d
            taps = max(k_elems // max(rhs[0], 1), 1)
            counts["multiply"] += n * taps * scale
            counts["add"] += n * taps * scale
        elif name == "dot_general":
            # MACs: out elems x contraction size
            lhs = eqn.invars[0].aval.shape
            ((lc, _), _) = eqn.params["dimension_numbers"]
            contract = 1
            for d in lc:
                contract *= lhs[d]
            counts["multiply"] += n * contract * scale
            counts["add"] += n * contract * scale
        elif name in MUL_OPS:
            if literal_pow2_multiplicand(eqn):
                counts["shift"] += n * scale
            else:
                counts["multiply"] += n * scale
        elif name in ADD_OPS:
            counts["add"] += n * scale
        elif name in CMP_OPS:
            counts["compare"] += n * scale
        elif name in SHIFT_OPS:
            counts["shift"] += n * scale
        elif name in REDUCE_ADD_OPS:
            counts["add"] += max(_in_elems(eqn) - n, 0) * scale
        elif name in REDUCE_CMP_OPS:
            counts["compare"] += max(_in_elems(eqn) - n, 0) * scale
        elif name in ("exp", "log", "tanh", "logistic", "rsqrt", "sqrt",
                      "div", "integer_pow", "pow"):
            counts["transcendental_or_div"] += n * scale

    traverse.walk(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr,
                  visit, cond_branches=False, while_bodies=False,
                  vjp_jaxpr_bodies=False)
    return counts


def census(fn, *args) -> Counter:
    """Trace ``fn(*args)`` and census its jaxpr (the drop-in replacement
    for the old ``benchmarks.hardware_cost.census``)."""
    import jax
    return census_jaxpr(jax.make_jaxpr(fn)(*args))


def assert_multiplierless(c: Counter, tag: str) -> None:
    """The hard gate: the integer hardware twin's jaxpr must contain ZERO
    multiplies (pow2-literal scalings count as shifts) and ZERO divides —
    the paper's primitive set is add/subtract/shift/compare only."""
    bad = {k: c[k] for k in ("multiply", "transcendental_or_div") if c[k]}
    if bad:
        raise AssertionError(
            f"{tag}: the integer jaxpr is NOT multiplierless: {bad} "
            "(a float multiply or divide leaked into the fixed-point path)")


# ---------------------------------------------------------------------------
# verification mode: the whitelist gate with named violations
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LegalityViolation:
    """One op outside the multiplierless primitive set."""
    primitive: str
    path: str
    source: str
    count: int          # executions per program call (scaled)
    reason: str

    @property
    def name(self) -> str:
        return f"{self.path}/{self.primitive}@{self.source}"


@dataclasses.dataclass(frozen=True)
class LegalityResult:
    """Verifier output: ``ok`` plus the scaled op census the whitelist
    admitted (``legal_ops``) and every violation, named."""
    ok: bool
    violations: tuple
    legal_ops: Counter

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "legal_ops": dict(sorted(self.legal_ops.items())),
            "violations": [dataclasses.asdict(v) for v in self.violations],
        }


def check_legality(jaxpr, *, max_violations: int = 64) -> LegalityResult:
    """Run the op-legality pass over a traced program (``ClosedJaxpr`` or
    plain ``Jaxpr``), recursing into cond branches and while bodies."""
    violations: list = []
    legal: Counter = Counter()

    def visit(eqn, scale, path):
        name = eqn.primitive.name
        if name in MUL_OPS:
            if literal_pow2_multiplicand(eqn):
                legal["shift"] += _out_elems(eqn) * scale
            elif len(violations) < max_violations:
                violations.append(LegalityViolation(
                    primitive=name, path=path,
                    source=traverse.eqn_source(eqn),
                    count=_out_elems(eqn) * scale,
                    reason="multiply whose multiplier is not a pow2 "
                           "literal — needs a hardware multiplier"))
            return
        if name in LEGAL_OPS:
            if name in ADD_OPS or name in REDUCE_ADD_OPS:
                legal["add"] += (_out_elems(eqn) * scale
                                 if name in ADD_OPS else
                                 max(_in_elems(eqn) - _out_elems(eqn), 0)
                                 * scale)
            elif name in CMP_OPS or name in REDUCE_CMP_OPS:
                legal["compare"] += (_out_elems(eqn) * scale
                                     if name in CMP_OPS else
                                     max(_in_elems(eqn) - _out_elems(eqn), 0)
                                     * scale)
            elif name in SHIFT_OPS:
                legal["shift"] += _out_elems(eqn) * scale
            return
        if len(violations) < max_violations:
            violations.append(LegalityViolation(
                primitive=name, path=path, source=traverse.eqn_source(eqn),
                count=_out_elems(eqn) * scale,
                reason="primitive outside the add/sub/shift/compare/"
                       "select/bitwise whitelist"))

    traverse.walk(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr,
                  visit, cond_branches=True, while_bodies=True)
    return LegalityResult(ok=not violations, violations=tuple(violations),
                          legal_ops=legal)


def assert_legal(jaxpr, tag: str,
                 result: Optional[LegalityResult] = None) -> LegalityResult:
    """Run (or take) a legality result and raise with the first named
    offending equations on failure."""
    r = result if result is not None else check_legality(jaxpr)
    if not r.ok:
        names = "; ".join(v.name for v in r.violations[:5])
        raise AssertionError(
            f"{tag}: {len(r.violations)} op(s) outside the multiplierless "
            f"whitelist: {names}")
    return r
