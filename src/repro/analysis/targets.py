"""The standard analysis targets: the deployed integer programs, traced,
with their documented worst-case input assumptions.

Each :class:`Target` pairs a traced ``ClosedJaxpr`` with one
:class:`~repro.analysis.intervals.Interval` per flattened program input.
The assumptions are the deployment contract, not guesses:

* **ADC codes** are ``FixedPointSpec.qmin..qmax`` by construction — the
  quantizer clamps (``quantize_signal``), exactly like the hardware ADC
  saturates. The proof covers EVERY signal, not sampled audio.
* **Delay-line registers** hold each octave's 8-bit signal-register codes
  (``OctaveStage.in_spec``) — written only by the clamped requantizers.
* **Session accumulators** are bounded by the 1-second one-shot envelope:
  per octave, (octave samples in 1 s) x (band full-scale) x 2^acc_shift.
  Integer accumulation grows without bound in an endless session, so the
  proof is explicitly "sessions totalling <= 1 s of audio" — the paper's
  per-utterance deployment. :func:`session_envelope` also reports the
  closed-form maximum session length before any int32 accumulator can
  overflow, which the analyze report and benchmarks surface.
* **Sample counters** (``consumed``/``count``) are bounded by
  ``SESSION_BOUND`` (2^30 samples ~ 18 h at 16 kHz) — far past the
  accumulator-safe envelope, so the counters are never the binding
  constraint.
"""

from __future__ import annotations

import dataclasses
from fractions import Fraction

from repro.analysis.intervals import BOOL, Interval

# counter registers: 2^30 octave samples (~18 h @ 16 kHz) — generous
# headroom past any accumulator-safe session length
SESSION_BOUND = 1 << 30

INT32_MAX = (1 << 31) - 1

# one 10 ms sensor packet at 16 kHz — the deployment chunk the FPGA (and
# benchmarks/hardware_cost.py) processes per step
CHUNK_LEN = 160


@dataclasses.dataclass
class Target:
    """One traced program plus its analysis contract."""
    name: str
    jaxpr: object                     # ClosedJaxpr
    numerics: str                     # "fixed" | "float"
    n_samples: int                    # input samples per call (for rates)
    in_intervals: list | None         # None: skip the interval pass
    assumptions: dict                 # input name -> contract, for the report
    gate: bool                        # violations fail scripts/analyze.py


def _fixed_pipeline(smoke: bool, *, stream_impl: str = "xla",
                    numerics: str = "fixed", seed: int = 0):
    from repro.configs.esc10_mp import make_pipeline
    return make_pipeline(smoke=smoke, seed=seed, stream_impl=stream_impl,
                         numerics=numerics)


def _signal_iv(prog) -> Interval:
    s = prog.signal
    return Interval(int(s.qmin), int(s.qmax))


def _shift_int(v: int, k: int) -> int:
    return v << k if k >= 0 else v >> (-k)


def session_envelope(prog, n_envelope: int) -> dict:
    """Closed-form session accumulator bounds.

    Per octave ``o`` the accumulator gains at most
    ``band_spec.qmax << acc_shift`` per octave sample (HWR output is
    nonnegative and clamped), and a length-``N`` session delivers
    ``ceil(N / 2**o)`` octave samples. Returns the worst-case ``acc``
    interval for sessions totalling ``n_envelope`` input samples, plus the
    maximum session length (in input samples) before ANY band's int32
    accumulator can overflow.
    """
    acc_hi = 0
    max_safe = None
    for o, st in enumerate(prog.bank.octaves):
        qmax = int(st.band_spec.qmax)
        shift = int(st.acc_shift)
        n_o = -(-n_envelope // (1 << o))          # ceil
        acc_hi = max(acc_hi, _shift_int(n_o * qmax, shift))
        # growth per INPUT sample for this octave's bands
        g = Fraction(qmax * 2 ** max(shift, 0),
                     2 ** (o + max(-shift, 0)))
        safe_o = int(Fraction(INT32_MAX) / g) if g > 0 else None
        if safe_o is not None:
            max_safe = safe_o if max_safe is None else min(max_safe, safe_o)
    return {
        "acc_interval": Interval(0, acc_hi),
        "envelope_samples": n_envelope,
        "max_safe_session_samples": max_safe,
    }


def _session_inputs(prog, state, chunk_len: int, acc_iv: Interval):
    """Interval pytree matching ``(state, chunk_q, n)`` and flatten it in
    jax's leaf order (what the traced jaxpr's invars use)."""
    import jax

    sig = _signal_iv(prog)
    amax_hi = max(abs(sig.lo), sig.hi)
    counter = Interval(0, SESSION_BOUND)
    ivs_state = state._replace(
        delays=tuple(Interval(int(prog.bank.octaves[o].in_spec.qmin),
                              int(prog.bank.octaves[o].in_spec.qmax))
                     for o in range(len(state.delays))),
        consumed=tuple(counter for _ in state.consumed),
        acc=acc_iv,
        amax=Interval(0, amax_hi),
        count=counter,
        active=BOOL,
    )
    tree = (ivs_state, sig, Interval(0, chunk_len))
    return jax.tree_util.tree_leaves(
        tree, is_leaf=lambda x: isinstance(x, Interval))


def build_targets(smoke: bool = False) -> tuple:
    """Build the standard target set. Returns ``(targets, meta)`` where
    ``meta`` carries the session envelope figures for the report."""
    import jax
    import jax.numpy as jnp

    from repro.core import fixed

    n = 1600 if smoke else 16000               # 1 s of audio (0.4 s smoke)
    pipe = _fixed_pipeline(smoke)
    prog = pipe.fixed_program()
    sig = _signal_iv(prog)
    env = session_envelope(prog, n)
    acc_iv = env["acc_interval"]

    targets = []

    # -- one-shot integer program (the compiled esc10_mp fixed path) ------
    xq = jnp.zeros((1, n), jnp.int32)
    jaxpr_oneshot = jax.make_jaxpr(lambda q: fixed.infer_q(prog, q))(xq)
    adc = (f"ADC codes in [{sig.lo}, {sig.hi}] "
           f"(FixedPointSpec {prog.signal.bits}-bit, clamped quantizer)")
    targets.append(Target(
        name="oneshot_q", jaxpr=jaxpr_oneshot, numerics="fixed",
        n_samples=n, in_intervals=[sig], assumptions={"xq": adc},
        gate=True))

    # -- one-shot through the fused int Pallas bank kernels ---------------
    jaxpr_pl = jax.make_jaxpr(
        lambda q: fixed.infer_q(prog, q, use_pallas=True))(xq)
    targets.append(Target(
        name="oneshot_q_pallas", jaxpr=jaxpr_pl, numerics="fixed",
        n_samples=n, in_intervals=[sig], assumptions={"xq": adc},
        gate=True))

    # -- per-chunk integer session step (the deployed datapath) -----------
    session_assumptions = {
        "chunk_q": adc,
        "delays[o]": "octave signal-register codes (OctaveStage.in_spec, "
                     "written only by the clamped requantizers)",
        "consumed/count": f"<= {SESSION_BOUND} octave samples "
                          "(~18 h @ 16 kHz)",
        "acc": f"within the {n}-sample one-shot envelope "
               f"{acc_iv!r}; max int32-safe session = "
               f"{env['max_safe_session_samples']} input samples",
        "amax": "running max |ADC code| (telemetry)",
        "n": f"valid counts in [0, {CHUNK_LEN}]",
    }
    state = pipe.init_session(1)
    chunk = jnp.zeros((1, CHUNK_LEN), jnp.int32)
    nv = jnp.zeros((1,), jnp.int32)
    jaxpr_step = jax.make_jaxpr(
        lambda st, q, v: fixed.session_step_q(prog, st, q, v))(
            state, chunk, nv)
    targets.append(Target(
        name="session_step_q", jaxpr=jaxpr_step, numerics="fixed",
        n_samples=CHUNK_LEN,
        in_intervals=_session_inputs(prog, state, CHUNK_LEN, acc_iv),
        assumptions=session_assumptions, gate=True))

    # -- per-chunk step through the stateful int Pallas kernel ------------
    pipe_pl = _fixed_pipeline(smoke, stream_impl="pallas")
    prog_pl = pipe_pl.fixed_program()
    state_pl = pipe_pl.init_session(1)
    jaxpr_spl = jax.make_jaxpr(
        lambda st, q, v: pipe_pl._cascade_pallas_fixed(prog_pl, st, q, v))(
            state_pl, chunk, nv)
    targets.append(Target(
        name="stream_pallas", jaxpr=jaxpr_spl, numerics="fixed",
        n_samples=CHUNK_LEN,
        in_intervals=_session_inputs(prog_pl, state_pl, CHUNK_LEN, acc_iv),
        assumptions=session_assumptions, gate=True))

    # -- float reference path: determinism lint only (informational) ------
    pipe_f = _fixed_pipeline(smoke, numerics="float")
    x = jnp.zeros((1, n), jnp.float32)
    jaxpr_f = jax.make_jaxpr(pipe_f.apply)(x)
    targets.append(Target(
        name="float_oneshot", jaxpr=jaxpr_f, numerics="float",
        n_samples=n, in_intervals=None,
        assumptions={"x": "float32 audio (reference path — lint only)"},
        gate=False))

    meta = {
        "config": "smoke" if smoke else "full",
        "envelope_samples": env["envelope_samples"],
        "acc_envelope": [int(acc_iv.lo), int(acc_iv.hi)],
        "max_safe_session_samples": env["max_safe_session_samples"],
        "session_bound_counter": SESSION_BOUND,
        "chunk_len": CHUNK_LEN,
    }
    return targets, meta
