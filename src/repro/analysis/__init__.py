"""Static analysis over traced jaxprs: the verification layer for the
multiplierless datapath.

The paper's hardware claim (0 DSPs, <1K slices) is a claim about the
*deployed representation*: every primitive is an add/sub/shift/compare and
every register fits its declared bitwidth. This package proves both
properties on the traced integer programs instead of sampling them:

``traverse``
    One shared jaxpr walk (recursing through ``pjit``, ``scan``, ``cond``,
    ``while``, ``pallas_call`` and friends) that every pass — and the
    benchmark census — runs on, so the gate and the numbers can't diverge.
``legality``
    Op-legality pass (the generalized multiplierless verifier) plus the
    compatibility census that ``benchmarks/hardware_cost.py`` re-exports.
``intervals``
    Worst-case interval analysis: abstract interpretation from the ADC
    range through FIR partials, HWR accumulators and the MP bisection,
    proving every intermediate fits its integer dtype for ANY input and
    reporting per-register required bitwidths.
``determinism``
    Lint for bit-parity hazards: non-fixed-tree float reductions and float
    ops reachable in a ``numerics="fixed"`` program.
``targets``
    The standard analysis targets (one-shot ``infer_q``, per-chunk
    ``session_step_q``, both int Pallas kernels) with their documented
    input assumptions.
``report``
    Machine-readable report assembly for ``scripts/analyze.py``.
"""

from repro.analysis.legality import (  # noqa: F401
    CensusCounter,
    assert_multiplierless,
    census,
    census_jaxpr,
    check_legality,
    literal_pow2_multiplicand,
)
from repro.analysis.intervals import (  # noqa: F401
    Interval,
    IntervalResult,
    analyze_intervals,
)
from repro.analysis.determinism import lint_determinism  # noqa: F401
from repro.analysis.traverse import subjaxprs, walk  # noqa: F401
