"""Multi-pod dry-run: prove the distribution config lowers + compiles for
every (architecture x input shape x mesh) cell, and extract roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
        --out results/dryrun.json

The XLA host-device override below MUST run before any other import touches
jax (device count locks on first init). It is local to this entry point:
tests and benches see the real single device.
"""

import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, get_arch                    # noqa: E402
from repro.distributed import sharding as sh                    # noqa: E402
from repro.distributed.steps import make_train_step             # noqa: E402
from repro.launch.hlo_cost import analyze_hlo                   # noqa: E402
from repro.launch import specs as S                             # noqa: E402
from repro.launch.mesh import HW, make_production_mesh          # noqa: E402
from repro.models import transformer as T                       # noqa: E402
from repro.optim import AdamWConfig                             # noqa: E402

def model_flops(cfg: T.ArchConfig, cell: S.ShapeCell) -> float:
    """6*N*D (dense) / 6*N_active*D; decode counts D = new tokens only.
    Train counts fwd+bwd (3x fwd); prefill/decode count fwd (2*N*D)."""
    n_active = T.active_param_count(cfg, S.params_specs(cfg))
    if cfg.tie_embeddings is False and not cfg.audio_frontend:
        pass  # full param count already includes head
    tokens = cell.global_batch * (1 if cell.kind == "decode" else cell.seq_len)
    mult = 6.0 if cell.kind == "train" else 2.0
    return mult * n_active * tokens


def pick_accum(cfg: T.ArchConfig, cell: S.ShapeCell, mesh) -> int:
    """Microbatch count for train cells: smallest power of two such that
    the estimated per-device activation footprint fits comfortably in a
    16 GiB v5e. Estimate: residual-stream bytes x layers x a family factor
    calibrated against measured memory_analysis (dense ~2.5, MoE ~6 for
    dispatch buffers, SSM ~3 after chunk-remat, hybrid ~5)."""
    if cell.kind != "train":
        return 1
    n_data = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n_data *= mesh.shape[a]
    b_loc = max(cell.global_batch // n_data, 1)
    stream = b_loc * cell.seq_len * cfg.d_model * 2
    k = {"dense": 2.5, "vlm": 2.5, "audio": 2.5,
         "moe": 6.0, "ssm": 3.0, "hybrid": 5.0}[cfg.family]
    est = stream * cfg.num_layers * k
    accum = 1
    while est / accum > 10e9 and accum < min(16, b_loc):
        accum *= 2
    return accum


def lower_cell(cfg: T.ArchConfig, cell: S.ShapeCell, mesh, accum: int = 1):
    """Returns the jax Lowered for one cell on one mesh."""
    ins = S.input_specs(cfg, cell)
    if cell.kind == "train":
        _, train_step = make_train_step(cfg, AdamWConfig(), accum=accum)
        state = S.state_specs(cfg)
        state_shardings = sh.tree_shardings(
            sh.param_specs(state, mesh), mesh)
        batch_shardings = sh.tree_shardings(
            sh.batch_specs(ins["batch"], mesh), mesh)
        fn = jax.jit(train_step,
                     in_shardings=(state_shardings, batch_shardings),
                     donate_argnums=(0,))
        return fn.lower(state, ins["batch"])
    if cell.kind == "prefill":
        params = S.params_specs(cfg)
        p_shard = sh.tree_shardings(sh.param_specs(params, mesh), mesh)
        b_shard = sh.tree_shardings(sh.batch_specs(ins["batch"], mesh), mesh)
        fwd = lambda p, b: T.forward(p, cfg, b)
        fn = jax.jit(fwd, in_shardings=(p_shard, b_shard))
        return fn.lower(params, ins["batch"])
    # decode
    params = S.params_specs(cfg)
    p_shard = sh.tree_shardings(sh.param_specs(params, mesh), mesh)
    c_shard = sh.tree_shardings(sh.cache_specs(ins["cache"], mesh), mesh)
    t_shard = sh.tree_shardings(sh.batch_specs(
        {"tokens": ins["tokens"], "cur_pos": ins["cur_pos"]}, mesh), mesh)
    step = lambda p, t, c, cp: T.decode_step(p, cfg, t, c, cp)
    fn = jax.jit(step,
                 in_shardings=(p_shard, t_shard["tokens"], c_shard,
                               t_shard["cur_pos"]),
                 donate_argnums=(2,))
    return fn.lower(params, ins["tokens"], ins["cache"], ins["cur_pos"])


def roofline(compiled, hlo_text: str, n_chips: int, cfg, cell) -> dict:
    """Three roofline terms from the compiled SPMD module.

    The scan-aware analyzer (repro.launch.hlo_cost) multiplies while bodies
    by their known trip counts — XLA's own HloCostAnalysis visits each body
    once, which under-counts scan-over-layers models by ~L. All quantities
    are PER DEVICE (the module is the per-device program); the terms divide
    by per-chip peaks accordingly. ``xla_cost_analysis`` records XLA's raw
    numbers for reference.
    """
    per_dev = analyze_hlo(hlo_text)
    flops = per_dev["flops"]
    coll = per_dev["collective_bytes"]
    # Memory model: the CPU-backend module fuses far less than TPU XLA, so
    # summing operand+output bytes per instruction ("unfused") massively
    # overstates TPU HBM traffic. The headline memory term assumes producer-
    # consumer fusion: every materialized tensor is written once and read
    # once (2 x sum of outputs) plus the entry arguments read once. The
    # unfused number is recorded alongside as the pessimistic bound.
    mem_args = compiled.memory_analysis().argument_size_in_bytes
    membytes = 2.0 * per_dev["bytes_out"] + mem_args
    membytes_unfused = per_dev["bytes_accessed"]
    t_compute = flops / HW.PEAK_FLOPS_BF16
    t_memory = membytes / HW.HBM_BW
    t_coll = coll["total"] / HW.ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, cell)  # global
    global_flops = flops * n_chips
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jaxlibs: one dict per device
        ca = ca[0] if ca else {}
    return {
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": membytes,
        "hlo_bytes_per_device_unfused": membytes_unfused,
        "memory_s_unfused": membytes_unfused / HW.HBM_BW,
        "transcendentals_per_device": per_dev["transcendentals"],
        "collective_bytes": coll,
        **terms,
        "dominant": dominant,
        "model_flops": mf,
        "useful_flops_ratio": (mf / global_flops) if global_flops else None,
        "bound_step_s": max(terms.values()),
        "roofline_fraction": (t_compute / max(terms.values())
                              if max(terms.values()) > 0 else None),
        "xla_cost_analysis": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        },
    }


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             arch_overrides=None) -> dict:
    cfg = get_arch(arch_id)
    if arch_overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **arch_overrides)
    cell = S.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rec = {"arch": arch_id, "shape": shape_name,
           "mesh": "x".join(map(str, mesh.devices.shape)),
           "n_chips": n_chips}
    accum = pick_accum(cfg, cell, mesh)
    rec["grad_accum"] = accum
    t0 = time.time()
    try:
        with mesh:
            lowered = lower_cell(cfg, cell, mesh, accum=accum)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            hlo = compiled.as_text()
            rec.update(
                status="ok",
                lower_s=round(t_lower, 1),
                compile_s=round(t_compile, 1),
                memory=dict(
                    argument_bytes=int(mem.argument_size_in_bytes),
                    output_bytes=int(mem.output_size_in_bytes),
                    temp_bytes=int(mem.temp_size_in_bytes),
                    gen_code_bytes=int(mem.generated_code_size_in_bytes),
                ),
                roofline=roofline(compiled, hlo, n_chips, cfg, cell),
            )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc(limit=20))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCH_IDS), default=None)
    ap.add_argument("--shape", choices=sorted(S.SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    jobs = []
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        for arch_id in ARCH_IDS:
            cfg = get_arch(arch_id)
            for shape_name, status, reason in S.cell_table(cfg):
                for mp in meshes:
                    if status == "run":
                        jobs.append((arch_id, shape_name, mp))
                    else:
                        print(f"SKIP {arch_id} x {shape_name}: {reason}")
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        jobs = [(args.arch, args.shape, mp) for mp in meshes]

    results = []
    for arch_id, shape_name, mp in jobs:
        rec = run_cell(arch_id, shape_name, multi_pod=mp)
        results.append(rec)
        tag = "OK " if rec["status"] == "ok" else "FAIL"
        extra = ""
        if rec["status"] == "ok":
            r = rec["roofline"]
            extra = (f" dom={r['dominant']} comp={r['compute_s']:.4f}s "
                     f"mem={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s "
                     f"frac={r['roofline_fraction']:.2f}")
        else:
            extra = " " + rec["error"][:160]
        print(f"{tag} {arch_id:18s} {shape_name:12s} mesh={rec['mesh']}{extra}",
              flush=True)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    n_fail = sum(r["status"] != "ok" for r in results)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
