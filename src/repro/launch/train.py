"""Training launcher: sharded LM training with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --smoke \
        --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --resume auto

Production posture (documented, exercised at host scale here):
  * mesh from --mesh (host mesh locally, make_production_mesh on a pod);
  * deterministic data shards addressed by (step, shard) — restart needs
    only the step counter (see data/tokens.py);
  * CheckpointManager: atomic + async + keep-last-k; --resume auto restores
    the latest checkpoint, including onto a different mesh (elastic);
  * StragglerMonitor EWMA on step times;
  * optional int8 error-feedback gradient compression on the pod axis
    (--compress-grads, multi-pod meshes only);
  * microbatching/grad-accumulation via --accum.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCH_IDS, get_arch, get_smoke
from repro.data.tokens import TokenStream
from repro.distributed import sharding as sh
from repro.distributed.monitor import StragglerMonitor
from repro.distributed.steps import TrainState, make_train_step
from repro.launch.mesh import make_host_mesh
from repro.optim import AdamWConfig


def build_argparser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCH_IDS), required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient accumulation microbatches")
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", choices=["auto", "never"], default="auto")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mp-mode", action="store_true",
                    help="run linear layers through the multiplierless MP path")
    return ap


def main(argv=None):
    args = build_argparser().parse_args(argv)
    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    if args.mp_mode:
        cfg = dataclasses.replace(cfg, mp_mode=True)
    assert not cfg.audio_frontend or True  # audio uses frames, handled below

    mesh = make_host_mesh(args.mesh_data, args.mesh_model)
    opt = AdamWConfig(lr=args.lr, warmup_steps=args.warmup,
                      total_steps=args.steps)
    init_state, train_step = make_train_step(cfg, opt, accum=args.accum)

    key = jax.random.PRNGKey(args.seed)
    state = init_state(key)
    specs = sh.param_specs(state, mesh)
    state = jax.device_put(state, sh.tree_shardings(specs, mesh))

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if ckpt and args.resume == "auto" and ckpt.latest_step() is not None:
        state, start_step = ckpt.restore(state, mesh=mesh, specs=specs)
        print(f"resumed from step {start_step}")

    stream = TokenStream(cfg.vocab_size, args.seq,
                         args.batch * args.accum, seed=args.seed)
    jit_step = jax.jit(train_step, donate_argnums=(0,))
    monitor = StragglerMonitor()
    rng = np.random.default_rng(args.seed)

    losses = []
    for step in range(start_step, args.steps):
        toks = stream.batch(step)
        if cfg.audio_frontend:
            frames = rng.standard_normal(
                (toks.shape[0], args.seq, cfg.d_model)).astype(np.float32)
            batch = {"frames": jnp.asarray(frames),
                     "labels": jnp.asarray(toks % cfg.vocab_size)}
        elif cfg.vlm_patches:
            p = min(cfg.vlm_patches, args.seq // 2)
            cfg_p = dataclasses.replace(cfg, vlm_patches=p)
            patches = rng.standard_normal(
                (toks.shape[0], p, cfg.d_model)).astype(np.float32)
            batch = {"tokens": jnp.asarray(toks[:, : args.seq - p]),
                     "patches": jnp.asarray(patches)}
            if step == start_step:
                init_state, train_step2 = make_train_step(cfg_p, opt)
                jit_step = jax.jit(train_step2, donate_argnums=(0,))
        else:
            batch = {"tokens": jnp.asarray(toks)}
        t0 = time.time()
        state, metrics = jit_step(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        monitor.record("host0", dt)
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f} ms "
                  f"stragglers={monitor.stragglers()}")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, state, mesh=mesh, specs=specs)
    if ckpt:
        ckpt.save(args.steps, state, mesh=mesh, specs=specs)
        ckpt.wait()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
