"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

No device allocation: everything here is abstract. The same specs drive the
dry-run (.lower().compile()), the roofline accounting, and the launcher's
shape validation.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T

__all__ = ["SHAPES", "ShapeCell", "input_specs", "state_specs", "cell_table",
           "runnable_cells"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _fwd_batch_specs(cfg: T.ArchConfig, B: int, S: int, with_labels: bool):
    """Batch specs for a full-sequence pass (train / prefill)."""
    if cfg.audio_frontend:
        b = {"frames": _sds((B, S, cfg.d_model), jnp.bfloat16)}
        if with_labels:
            b["labels"] = _sds((B, S), jnp.int32)
        return b
    if cfg.vlm_patches:
        return {"tokens": _sds((B, S - cfg.vlm_patches), jnp.int32),
                "patches": _sds((B, cfg.vlm_patches, cfg.d_model),
                                jnp.bfloat16)}
    return {"tokens": _sds((B, S), jnp.int32)}


def cache_len_for(cfg: T.ArchConfig, seq_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def input_specs(cfg: T.ArchConfig, shape: ShapeCell) -> dict:
    """Abstract inputs for the cell's step function."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": _fwd_batch_specs(cfg, B, S, with_labels=True)}
    if shape.kind == "prefill":
        return {"batch": _fwd_batch_specs(cfg, B, S, with_labels=False)}
    # decode: one new token against a cache of length S
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, B, cache_len_for(cfg, S)))
    return {
        "tokens": _sds((B, 1), jnp.int32),
        "cache": cache,
        "cur_pos": _sds((B,), jnp.int32),
    }


def state_specs(cfg: T.ArchConfig):
    """Abstract TrainState (params + adam moments + step)."""
    from repro.distributed.steps import make_train_step
    from repro.optim import AdamWConfig
    init_state, _ = make_train_step(cfg, AdamWConfig())
    return jax.eval_shape(lambda: init_state(jax.random.PRNGKey(0)))


def params_specs(cfg: T.ArchConfig):
    return jax.eval_shape(lambda: T.init(cfg, jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# cell enumeration with documented skips
# ---------------------------------------------------------------------------


def cell_table(cfg: T.ArchConfig):
    """[(shape_name, status, reason)] for one arch. status: run | skip."""
    rows = []
    for name, cell in SHAPES.items():
        if cell.kind == "decode" and not cfg.supports_decode:
            rows.append((name, "skip", "encoder-only: no decode step"))
        elif name == "long_500k" and not cfg.subquadratic:
            rows.append((name, "skip",
                         "pure full attention: 512k dense decode does not "
                         "fit HBM; arch defines no sparse variant"))
        else:
            rows.append((name, "run", ""))
    return rows


def runnable_cells(cfg: T.ArchConfig):
    return [name for name, status, _ in cell_table(cfg) if status == "run"]
