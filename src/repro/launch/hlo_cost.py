"""Scan-aware cost analysis over compiled HLO text.

Why this exists: ``compiled.cost_analysis()`` (XLA HloCostAnalysis) visits a
``while`` body ONCE, so any model built with scan-over-layers (ours — the
thing that keeps 80-layer HLO small) under-counts FLOPs/bytes/collectives by
the trip count (verified: flops for glm4 smoke barely change from 2 to 16
layers). The compiled HLO text carries ``backend_config=
{"known_trip_count":{"n":"80"}}`` on each while op, so an exact fix is to
re-walk the module and multiply while-body costs by their trip counts —
including nested scans (flash-attention q/kv chunk loops, SSD chunk loops)
that sit inside the layer loop.

Cost model (deliberate divergences from HloCostAnalysis, documented):
  * flops: 2*prod(out)*prod(contract) per dot; 1/elem for elementwise;
    transcendentals tracked separately.
  * bytes: operands + outputs per instruction; fusions count only their
    boundary (internal traffic stays in registers/VMEM); gather /
    dynamic-(update-)slice count only the *touched* slice, not the full
    buffer (in-place cache updates would otherwise dwarf everything).
  * collectives: output bytes per op type, multiplied by enclosing trip
    counts; ``-start`` counted, ``-done`` free.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Optional

__all__ = ["analyze_hlo", "CostResult"]

_TYPE_BYTES = {"f64": 8, "f32": 4, "s32": 4, "u32": 4, "bf16": 2, "f16": 2,
               "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "s64": 8,
               "u64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
               "s4": 1, "u4": 1, "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "not", "clamp",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "sign",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "remainder", "atan2", "is-finite",
}
_TRANSCENDENTAL = {"exponential", "exponential-minus-one", "log", "log-plus-one",
                   "tanh", "rsqrt", "sqrt", "power", "logistic", "sine",
                   "cosine", "tan", "erf", "cbrt", "expm1"}
_FREE = {"parameter", "tuple", "get-tuple-element", "bitcast", "after-all",
         "constant", "iota", "partition-id", "replica-id", "opt-barrier",
         "domain"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast")


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    """Total (elements, bytes) over every array in a (possibly tuple) shape."""
    elems = 0
    nbytes = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _TYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _TYPE_BYTES[dt]
    return elems, nbytes


def _array_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str           # output shape string (may be tuple)
    op: str
    operands: list
    attrs: str           # raw trailing attribute text


@dataclasses.dataclass
class CostResult:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes_accessed: float = 0.0
    bytes_out: float = 0.0   # outputs only: basis of the fusion-adjusted model
    collective_bytes: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "CostResult", mult: float = 1.0):
        self.flops += other.flops * mult
        self.transcendentals += other.transcendentals * mult
        self.bytes_accessed += other.bytes_accessed * mult
        self.bytes_out += other.bytes_out * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] += v * mult

    def as_dict(self) -> dict:
        coll = dict(self.collective_bytes)
        coll["total"] = sum(coll.values())
        return {"flops": self.flops, "transcendentals": self.transcendentals,
                "bytes_accessed": self.bytes_accessed,
                "bytes_out": self.bytes_out,
                "collective_bytes": coll}


# instruction line:  %name = SHAPE op(...), attrs   (comments pre-stripped)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\)|\S+?))\s+"
    r"([\w\-]+)\((.*?)\)(.*)$")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%?([\w.\-]+)")
_PCT_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _parse_operands(operand_str: str) -> list:
    """Operand names from an instruction's argument list.

    Operands are printed with their full type, e.g.
    ``dot(f32[128,256]{1,0} %Arg_0.1, f32[256,64]{1,0} %Arg_1.2)`` — only the
    ``%``-prefixed tokens are names; matching every identifier would return
    ``f32`` as operand 0 and break the dot/convolution shape lookups. Dumps
    without ``%`` sigils fall back to the permissive scan (harmless for byte
    accounting: unknown tokens simply miss the symbol table)."""
    if "%" in operand_str:
        return _PCT_OPERAND_RE.findall(operand_str)
    return [mo.group(1) for mo in _OPERAND_RE.finditer(operand_str)]


def parse_module(text: str) -> tuple[dict, Optional[str]]:
    """-> ({comp_name: [Instr]}, entry_name).

    Computation headers sit at column 0 (``%name (...) -> ... {`` or
    ``ENTRY ...``); instructions are indented. ``/*index=n*/`` comments are
    stripped before matching (they otherwise break the shape grammar)."""
    comps: dict[str, list[Instr]] = {}
    entry = None
    cur: Optional[list] = None
    for raw in text.splitlines():
        line = _COMMENT_RE.sub("", raw.rstrip())
        if not line or line.startswith("HloModule"):
            continue
        if not line[0].isspace() and line.endswith("{"):
            token = line.split()[1] if line.startswith("ENTRY") else line.split()[0]
            name = token.lstrip("%").split("(")[0]
            comps[name] = []
            cur = comps[name]
            if line.startswith("ENTRY"):
                entry = name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape, op, operand_str, attrs = m.groups()
        operands = _parse_operands(operand_str)
        cur.append(Instr(name, shape, op, operands, attrs))
    return comps, entry


class _Analyzer:
    def __init__(self, comps: dict):
        self.comps = comps
        self.symtab = {c: {i.name: i.shape for i in instrs}
                       for c, instrs in comps.items()}
        self._memo: dict[str, CostResult] = {}

    def computation_cost(self, comp_name: str) -> CostResult:
        if comp_name in self._memo:
            return self._memo[comp_name]
        total = CostResult()
        # memoize BEFORE recursion to break accidental cycles (none expected)
        self._memo[comp_name] = total
        for ins in self.comps.get(comp_name, []):
            total.add(self.instr_cost(ins, comp_name))
        return total

    def _operand_bytes(self, ins: Instr, comp: str) -> float:
        st = self.symtab[comp]
        b = 0
        for o in ins.operands:
            sh = st.get(o)
            if sh:
                b += _shape_elems_bytes(sh)[1]
        return b

    def instr_cost(self, ins: Instr, comp: str) -> CostResult:
        r = CostResult()
        op = ins.op
        out_elems, out_bytes = _shape_elems_bytes(ins.shape)

        if op in _FREE or op.endswith("-done"):
            return r

        if op == "while":
            trip = 1
            m = _TRIP_RE.search(ins.attrs)
            if m:
                trip = int(m.group(1))
            body = _BODY_RE.search(ins.attrs)
            if body:
                r.add(self.computation_cost(body.group(1)), mult=trip)
            r.bytes_accessed += out_bytes  # loop-carried tuple once
            return r

        if op == "fusion":
            callee = _CALLS_RE.search(ins.attrs)
            if callee:
                inner = self.computation_cost(callee.group(1))
                r.flops += inner.flops
                r.transcendentals += inner.transcendentals
                # internal bytes stay on-chip; boundary traffic only
            r.bytes_accessed += out_bytes + self._operand_bytes(ins, comp)
            r.bytes_out += out_bytes
            return r

        if op in ("call", "async-start"):
            callee = _TO_APPLY_RE.search(ins.attrs) or _CALLS_RE.search(ins.attrs)
            if callee:
                r.add(self.computation_cost(callee.group(1)))
            return r

        if op == "conditional":
            branches = re.findall(r"branch_computations=\{([^}]*)\}", ins.attrs)
            names = []
            if branches:
                names = _OPERAND_RE.findall(branches[0])
            else:
                names = [m.group(1) for m in
                         re.finditer(r"(?:true|false)_computation=%?([\w.\-]+)",
                                     ins.attrs)]
            sub = [self.computation_cost(n) for n in names]
            if sub:
                worst = max(sub, key=lambda c: c.flops)
                r.add(worst)
            r.bytes_accessed += out_bytes
            return r

        base_op = op.replace("-start", "")
        if base_op in _COLLECTIVES:
            r.collective_bytes[base_op] += out_bytes
            r.bytes_accessed += out_bytes + self._operand_bytes(ins, comp)
            r.bytes_out += out_bytes
            return r

        if op == "dot":
            lhs_shape = self.symtab[comp].get(ins.operands[0], "")
            lhs_dims = _array_dims(lhs_shape)
            cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
            contract = 1
            if cdims and lhs_dims:
                for d in cdims.group(1).split(","):
                    if d:
                        contract *= lhs_dims[int(d)]
            r.flops += 2.0 * out_elems * contract
            r.bytes_accessed += out_bytes + self._operand_bytes(ins, comp)
            r.bytes_out += out_bytes
            return r

        if op == "convolution":
            # rare here; approximate via kernel size
            rhs_shape = self.symtab[comp].get(ins.operands[1], "")
            k_elems = _shape_elems_bytes(rhs_shape)[0]
            r.flops += 2.0 * out_elems * max(k_elems, 1) ** 0.5
            r.bytes_accessed += out_bytes + self._operand_bytes(ins, comp)
            return r

        if op in ("gather", "dynamic-slice"):
            r.bytes_accessed += 2 * out_bytes  # touched slice read + written
            r.bytes_out += out_bytes
            return r
        if op in ("dynamic-update-slice", "scatter"):
            upd = ins.operands[1] if len(ins.operands) > 1 else None
            upd_bytes = 0
            if upd:
                sh = self.symtab[comp].get(upd)
                if sh:
                    upd_bytes = _shape_elems_bytes(sh)[1]
            r.bytes_accessed += 2 * upd_bytes
            r.bytes_out += upd_bytes
            if op == "scatter":
                r.flops += out_elems  # combiner adds
            return r

        if op == "sort":
            dims = _array_dims(ins.shape)
            n = dims[-1] if dims else 1
            r.flops += out_elems * max(math.log2(max(n, 2)), 1.0)
            r.bytes_accessed += out_bytes + self._operand_bytes(ins, comp)
            r.bytes_out += out_bytes
            return r

        if op in _TRANSCENDENTAL:
            r.transcendentals += out_elems
            r.bytes_accessed += out_bytes + self._operand_bytes(ins, comp)
            r.bytes_out += out_bytes
            return r

        if op == "copy":
            # XLA-CPU inserts loop-carried buffer copies that TPU's buffer
            # forwarding elides; count the write, not the read. Excluded from
            # the fusion-adjusted model entirely.
            r.bytes_accessed += out_bytes
            return r

        if op in _ELEMENTWISE or op in ("reduce", "reduce-window", "map",
                                        "convert", "broadcast", "reshape",
                                        "transpose", "concatenate",
                                        "pad", "slice", "reverse", "rng",
                                        "rng-bit-generator", "cumsum",
                                        "clz", "popcnt", "real", "imag"):
            if op in _ELEMENTWISE or op in ("reduce", "reduce-window", "map"):
                r.flops += out_elems if op not in ("reduce", "reduce-window") \
                    else out_elems + self._operand_elems(ins, comp)
            r.bytes_accessed += out_bytes + self._operand_bytes(ins, comp)
            r.bytes_out += out_bytes
            return r

        # unknown op: count bytes, no flops
        r.bytes_accessed += out_bytes + self._operand_bytes(ins, comp)
        r.bytes_out += out_bytes
        return r

    def _operand_elems(self, ins: Instr, comp: str) -> float:
        st = self.symtab[comp]
        n = 0
        for o in ins.operands:
            sh = st.get(o)
            if sh:
                n += _shape_elems_bytes(sh)[0]
        return n


def analyze_hlo(text: str) -> dict:
    """Full-module scan-aware cost. Returns flops / transcendentals /
    bytes_accessed / collective_bytes (all PER DEVICE for SPMD modules)."""
    comps, entry = parse_module(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    az = _Analyzer(comps)
    return az.computation_cost(entry).as_dict()
