"""Elastic scaling: resume a run on a different mesh shape.

Demonstrates the full cycle at host scale (the same code path a pod-scale
deployment takes, since CheckpointManager.restore re-sharding is
mesh-agnostic):

    python -m repro.launch.elastic --arch qwen3-8b --ckpt-dir /tmp/el

1. train N steps on mesh A (e.g. 1x1), checkpoint;
2. "lose" devices: rebuild mesh B (e.g. 2x1 -> 1x1 or vice versa);
3. restore the checkpoint with mesh B shardings (device_put re-shards);
4. continue training; verify the loss curve continues smoothly.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import ARCH_IDS, get_smoke
from repro.data.tokens import TokenStream
from repro.distributed import sharding as sh
from repro.distributed.steps import make_train_step
from repro.launch.mesh import make_host_mesh
from repro.optim import AdamWConfig


def run_phase(cfg, mesh, ckpt, stream, start, steps, opt):
    init_state, train_step = make_train_step(cfg, opt)
    state = init_state(jax.random.PRNGKey(0))
    specs = sh.param_specs(state, mesh)
    if ckpt.latest_step() is not None:
        state, start = ckpt.restore(state, mesh=mesh, specs=specs)
    else:
        state = jax.device_put(state, sh.tree_shardings(specs, mesh))
    jit_step = jax.jit(train_step, donate_argnums=(0,))
    losses = []
    for step in range(start, start + steps):
        batch = {"tokens": jnp.asarray(stream.batch(step))}
        state, metrics = jit_step(state, batch)
        losses.append(float(metrics["loss"]))
    ckpt.save(start + steps, state, mesh=mesh, specs=specs)
    ckpt.wait()
    return losses, start + steps


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCH_IDS), default="qwen3-8b")
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--steps-per-phase", type=int, default=20)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch)
    ckpt = CheckpointManager(args.ckpt_dir, keep_last=2)
    stream = TokenStream(cfg.vocab_size, 64, 8, seed=0)
    opt = AdamWConfig(lr=1e-3, warmup_steps=5,
                      total_steps=3 * args.steps_per_phase)

    n = len(jax.devices())
    mesh_a = make_host_mesh(data=min(2, n), model=1)
    mesh_b = make_host_mesh(data=1, model=min(2, n))

    l1, step = run_phase(cfg, mesh_a, ckpt, stream, 0,
                         args.steps_per_phase, opt)
    print(f"phase A (mesh {mesh_a.devices.shape}): "
          f"loss {l1[0]:.4f} -> {l1[-1]:.4f}")
    l2, step = run_phase(cfg, mesh_b, ckpt, stream, step,
                         args.steps_per_phase, opt)
    print(f"phase B (mesh {mesh_b.devices.shape}, resharded): "
          f"loss {l2[0]:.4f} -> {l2[-1]:.4f}")
    assert l2[0] < l1[0] + 0.5, "loss should continue, not reset"
    print("elastic rescale OK")
    return l1, l2


if __name__ == "__main__":
    main()
