"""Serving launcher: LLM decode AND acoustic stream sessions, one CLI.

LLM decode (batched autoregressive, sharded KV cache):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --batch 4 --prompt-len 16 --gen 32

Request flow: a batch of prompts is prefetched (prefill via the forward
pass teacher-forcing the prompt tokens through decode_step slots), then
tokens are generated one step at a time with the jitted serve_step. The
cache is donated across steps (no per-token reallocation).

Acoustic stream serving (the paper's deployment: only classified data
leaves the device):

    PYTHONPATH=src python -m repro.launch.serve --arch esc10-mp --smoke \
        --streams 16 --chunk 160 --rounds 25

Many logical sensor streams are multiplexed onto one slot-batched
``StreamServer``: each round feeds one sensor packet per stream, and all
resident streams advance in ONE compiled donated-state step.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch, get_smoke

ACOUSTIC_ARCH = "esc10-mp"


def _serve_acoustic(args):
    from repro.configs.esc10_mp import make_pipeline
    from repro.serving import StreamRouter, StreamServer

    pipe = make_pipeline(smoke=args.smoke, seed=args.seed,
                         stream_impl=args.stream_impl,
                         numerics=args.numerics,
                         fixed_amax=args.fixed_amax)
    fs = pipe.config.fs
    # chunk bounds must be powers of two (the server's bucket-ladder
    # contract): round the packet length up to the bucket it pads into
    max_chunk = max(16, 1 << (args.chunk - 1).bit_length())
    if args.shards > 1:
        server = StreamRouter(pipe, num_shards=args.shards,
                              capacity=args.streams, max_chunk=max_chunk)
    else:
        server = StreamServer(pipe, capacity=args.streams,
                              max_chunk=max_chunk)
    rng = np.random.default_rng(args.seed)
    ids = [f"mic-{i:03d}" for i in range(args.streams)]
    for sid in ids:
        server.open(sid)
    # synthetic sensors: band-limited-ish noise, one phase offset per stream
    audio = rng.standard_normal(
        (args.streams, args.rounds * args.chunk)).astype(np.float32)

    callers = max(1, min(4, args.streams))
    t0 = time.time()
    results = []
    for r in range(args.rounds):
        sl = slice(r * args.chunk, (r + 1) * args.chunk)
        reqs = [(sid, audio[i, sl]) for i, sid in enumerate(ids)]
        if args.use_async:
            # G independent callers coalesce into shared waves; one
            # drain resolves the round (decisions bitwise == sync feed)
            tickets = [server.submit(reqs[g::callers])
                       for g in range(callers)]
            server.drain()
            results = [res for t in tickets for res in t.results]
        else:
            results = server.feed(reqs)
    state = server.shards[0].state if args.shards > 1 else server.state
    jax.block_until_ready(state.acc)
    wall = time.time() - t0
    fed = args.streams * args.rounds
    print(f"arch={ACOUSTIC_ARCH} streams={args.streams} "
          f"chunk={args.chunk} ({args.chunk / fs * 1e3:.0f} ms) "
          f"rounds={args.rounds} shards={args.shards} "
          f"async={args.use_async} "
          f"numerics={pipe.config.numerics}")  # float engine vs the fixed-
    # point hardware twin (stats() repeats it so operators can tell a
    # deployment preview from the float path mid-flight)
    print(f"served {fed} chunks in {wall*1e3:.0f} ms "
          f"({fed / max(wall, 1e-9):.0f} chunks/s, "
          f"{fed * args.chunk / max(wall, 1e-9) / 1e6:.2f} Msamples/s, "
          f"stats={server.stats()})")
    for res in results[:4]:
        print(f"  {res.session_id}: label={res.label} "
              f"confidence={res.confidence:+.3f} "
              f"samples={res.samples_seen}")
    return results


def _serve_decode(args):
    from repro.distributed.steps import make_serve_step
    from repro.models import transformer as T

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    assert cfg.supports_decode, f"{cfg.name} is encoder-only"
    assert not cfg.vlm_patches, "serve demo uses text-only prompts"

    key = jax.random.PRNGKey(args.seed)
    params = T.init(cfg, key)
    B = args.batch
    total = args.prompt_len + args.gen
    cache_len = total if cfg.sliding_window is None \
        else min(total, cfg.sliding_window)
    cache = T.init_cache(cfg, B, cache_len)
    serve_step = jax.jit(make_serve_step(cfg, args.temperature),
                         donate_argnums=(2,), static_argnums=())

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size, (B, args.prompt_len))

    # prefill: feed prompt tokens through decode slots (teacher forcing)
    t0 = time.time()
    for i in range(args.prompt_len):
        pos = jnp.full((B,), i, jnp.int32)
        nxt, _, cache = serve_step(params, jnp.asarray(prompts[:, i:i+1],
                                                       jnp.int32), cache, pos)
    prefill_s = time.time() - t0

    # generate
    t0 = time.time()
    tok = nxt
    gen = []
    for i in range(args.gen):
        pos = jnp.full((B,), args.prompt_len + i, jnp.int32)
        key, sk = jax.random.split(key)
        tok, logits, cache = serve_step(params, tok, cache, pos, sk)
        gen.append(np.asarray(tok))
    gen_s = time.time() - t0
    gen_arr = np.concatenate(gen, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill {prefill_s*1e3:.0f} ms, decode {gen_s*1e3:.0f} ms "
          f"({args.gen*B/max(gen_s,1e-9):.1f} tok/s)")
    print("sample generation:", gen_arr[0][:16].tolist())
    return gen_arr


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCH_IDS) + [ACOUSTIC_ARCH],
                    required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    # LLM decode knobs
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    # acoustic stream knobs
    ap.add_argument("--streams", type=int, default=16,
                    help="esc10-mp: concurrent sensor sessions (slots)")
    ap.add_argument("--chunk", type=int, default=160,
                    help="esc10-mp: sensor packet length in samples")
    ap.add_argument("--rounds", type=int, default=25,
                    help="esc10-mp: packets fed per stream")
    ap.add_argument("--async", dest="use_async", action="store_true",
                    help="esc10-mp: feed through the coalescing "
                         "submit()/drain() pipeline (4 virtual callers "
                         "per round) instead of synchronous feed() — "
                         "decisions are bit-for-bit identical")
    ap.add_argument("--shards", type=int, default=1,
                    help="esc10-mp: >1 serves through a StreamRouter "
                         "with this many StreamServer shards (stream id "
                         "-> crc32 shard; shared compiled step)")
    ap.add_argument("--stream-impl", choices=["xla", "pallas"],
                    default="xla",
                    help="esc10-mp: session-step hot path — 'pallas' runs "
                         "the stateful fir_mp_stream kernel (VMEM-carried "
                         "delay lines; interpret mode off-TPU)")
    ap.add_argument("--numerics", choices=["float", "fixed"],
                    default="float",
                    help="esc10-mp: 'fixed' serves the bit-true int32 "
                         "hardware twin — integer session registers, "
                         "streamed decisions bit-for-bit equal to one-shot "
                         "inference, through either --stream-impl "
                         "('pallas' runs the VMEM-resident int kernel "
                         "fir_mp_stream_q, bit-identical to 'xla')")
    ap.add_argument("--fixed-amax", type=float, default=None,
                    help="esc10-mp: ADC full-scale for --numerics fixed "
                         "(default: the config's static 1.0; the synthetic "
                         "sensors here peak around 4, so pass ~4.0 to "
                         "avoid saturating the demo)")
    args = ap.parse_args(argv)

    if args.arch == ACOUSTIC_ARCH:
        return _serve_acoustic(args)
    return _serve_decode(args)


if __name__ == "__main__":
    main()
