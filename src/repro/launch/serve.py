"""Serving launcher: batched autoregressive decode with a sharded KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --batch 4 --prompt-len 16 --gen 32

Request flow: a batch of prompts is prefetched (prefill via the forward
pass teacher-forcing the prompt tokens through decode_step slots), then
tokens are generated one step at a time with the jitted serve_step. The
cache is donated across steps (no per-token reallocation).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch, get_smoke
from repro.distributed.steps import make_serve_step
from repro.models import transformer as T


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCH_IDS), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    assert cfg.supports_decode, f"{cfg.name} is encoder-only"
    assert not cfg.vlm_patches, "serve demo uses text-only prompts"

    key = jax.random.PRNGKey(args.seed)
    params = T.init(cfg, key)
    B = args.batch
    total = args.prompt_len + args.gen
    cache_len = total if cfg.sliding_window is None \
        else min(total, cfg.sliding_window)
    cache = T.init_cache(cfg, B, cache_len)
    serve_step = jax.jit(make_serve_step(cfg, args.temperature),
                         donate_argnums=(2,), static_argnums=())

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size, (B, args.prompt_len))
    out_tokens = [prompts]

    # prefill: feed prompt tokens through decode slots (teacher forcing)
    t0 = time.time()
    tok = jnp.asarray(prompts[:, :1], jnp.int32)
    for i in range(args.prompt_len):
        pos = jnp.full((B,), i, jnp.int32)
        nxt, _, cache = serve_step(params, jnp.asarray(prompts[:, i:i+1],
                                                       jnp.int32), cache, pos)
    prefill_s = time.time() - t0

    # generate
    t0 = time.time()
    tok = nxt
    gen = []
    for i in range(args.gen):
        pos = jnp.full((B,), args.prompt_len + i, jnp.int32)
        key, sk = jax.random.split(key)
        tok, logits, cache = serve_step(params, tok, cache, pos, sk)
        gen.append(np.asarray(tok))
    gen_s = time.time() - t0
    gen_arr = np.concatenate(gen, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill {prefill_s*1e3:.0f} ms, decode {gen_s*1e3:.0f} ms "
          f"({args.gen*B/max(gen_s,1e-9):.1f} tok/s)")
    print("sample generation:", gen_arr[0][:16].tolist())
    return gen_arr


if __name__ == "__main__":
    main()
