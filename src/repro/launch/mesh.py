"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked on first use).

Target: TPU v5e pods. Single pod = 16x16 = 256 chips with axes
('data', 'model'); multi-pod = 2 pods = 512 chips with ('pod', 'data',
'model') where 'pod' carries pure data parallelism over the slower
inter-pod links (its gradient all-reduce is the only traffic that crosses
pods, once per step, overlappable with the tail of backward).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"))


class HW:
    """TPU v5e hardware constants used by the roofline model."""
    PEAK_FLOPS_BF16 = 197e12      # per chip
    HBM_BW = 819e9                # bytes/s per chip
    ICI_BW = 50e9                 # bytes/s per link (~per-direction)
    HBM_BYTES = 16 * 2 ** 30      # 16 GiB
    VMEM_BYTES = 128 * 2 ** 20
