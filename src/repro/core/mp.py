"""Margin Propagation (MP) primitives — the paper's core contribution.

The MP function ``z = MP(L, gamma)`` is defined implicitly by the *reverse
water-filling* constraint (Gu [40], Chakrabartty & Cauwenberghs [26]):

    sum_i [L_i - z]_+  =  gamma,        gamma > 0

Two solvers are provided:

* :func:`mp_exact` — closed form via sort/cumsum/threshold-count. This is the
  mathematically exact solution (identical to the threshold in a simplex
  projection of ``L`` onto the scaled simplex ``{p >= 0, sum p = gamma}``).
  Differentiable through a ``custom_vjp`` using the known subgradient
  ``dz/dL_i = 1{L_i > z} / |support|``, ``dz/dgamma = -1/|support|``.
  Used for training (the paper trains *through* the MP approximation).

* :func:`mp_bisect` — the hardware-faithful iterative solver: bisection on
  ``z`` inside ``[max(L) - gamma, max(L)]`` using only add/subtract/compare
  and halving (a shift in fixed point). A fixed iteration count makes it a
  static ``fori_loop`` — this is what the Pallas TPU kernels implement
  (no sort needed; sorts are expensive on the TPU VPU, compares are cheap).

Multiplierless inner products (paper eq. 9): for ``u = w + x``, ``v = w - x``
(elementwise),

    w.x  ~=  mpabs(u, gamma) - mpabs(v, gamma),
    mpabs(u, gamma) := MP([u; -u], gamma)

since ``[w+ + x+, w- + x-] = [u; -u]`` and ``[w+ + x-, w- + x+] = [v; -v]``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "tree_sum",
    "mp_exact",
    "mp",
    "mp_bisect",
    "mp_newton",
    "mpabs",
    "mpabs_newton",
    "mp_dot",
    "mp_linear",
    "mp_conv1d",
    "mp_conv1d_bank",
    "DEFAULT_BISECT_ITERS",
    "DEFAULT_NEWTON_ITERS",
]

DEFAULT_BISECT_ITERS = 26  # |interval| * 2^-26 < 1e-7 * gamma: fp32-parity
DEFAULT_NEWTON_ITERS = 12  # monotone Newton: lands exactly on the root
                           # segment; 12 steps beat bisect-26 empirically


def tree_sum(h: jax.Array) -> jax.Array:
    """Sum over the last axis as a FIXED pairwise halving tree.

    ``jnp.sum`` lowers to a reduce HLO whose internal association order is a
    codegen detail — it can change with the surrounding fusion context, so
    two graphs computing "the same" f32 sum of identical operands may differ
    by ulps. The streaming-parity contract (XLA session step == Pallas
    streaming kernel, bit for bit; single-chunk streaming == one-shot)
    needs every float reduction on that path to be an explicit add DAG that
    XLA must evaluate as written. Zero-padding to a power of two is exact:
    every operand fed in is >= +0.0 or the pad lanes only ever add +0.0.

    Cost: log2(n) strided vector adds — on par with a reduce, and the
    fixed-iteration solvers were already bandwidth-bound on the operands.
    """
    n = h.shape[-1]
    if n == 0:
        return jnp.zeros(h.shape[:-1], h.dtype)
    p = 1
    while p < n:
        p <<= 1
    if p != n:
        h = jnp.pad(h, [(0, 0)] * (h.ndim - 1) + [(0, p - n)])
    while h.shape[-1] > 1:
        h = h[..., 0::2] + h[..., 1::2]
    return h[..., 0]


# ---------------------------------------------------------------------------
# Exact solver (sort based) with custom VJP
# ---------------------------------------------------------------------------


def _mp_exact_fwd_impl(L: jax.Array, gamma: jax.Array) -> jax.Array:
    """Exact reverse water-filling along the last axis.

    L: (..., m); gamma: broadcastable to (...,). Returns z: (...,).
    """
    m = L.shape[-1]
    # sort descending
    s = jnp.flip(jnp.sort(L, axis=-1), axis=-1)
    cs = jnp.cumsum(s, axis=-1)
    k = jnp.arange(1, m + 1, dtype=L.dtype)
    gamma_b = jnp.asarray(gamma, dtype=L.dtype)[..., None]
    z_k = (cs - gamma_b) / k
    # support size k* = #{k : s_k > z_k}; monotone as in simplex projection.
    valid = s > z_k
    k_star = jnp.maximum(jnp.sum(valid, axis=-1), 1)
    cs_sel = jnp.take_along_axis(cs, (k_star - 1)[..., None], axis=-1)[..., 0]
    z = (cs_sel - jnp.asarray(gamma, dtype=L.dtype)) / k_star.astype(L.dtype)
    return z


@jax.custom_vjp
def mp_exact(L: jax.Array, gamma: jax.Array) -> jax.Array:
    """z = MP(L, gamma) along the last axis (exact, differentiable)."""
    return _mp_exact_fwd_impl(L, gamma)


def _mp_exact_fwd(L, gamma):
    z = _mp_exact_fwd_impl(L, gamma)
    return z, (L, z)


def _mp_exact_bwd(res, g):
    L, z = res
    support = (L > z[..., None]).astype(L.dtype)
    k = jnp.maximum(jnp.sum(support, axis=-1), 1.0)
    dL = g[..., None] * support / k[..., None]
    # dz/dgamma = -1/k ; reduce to gamma's shape via broadcasting rules.
    dgamma_full = -g / k
    dgamma = dgamma_full.sum()  # gamma is scalar in all our uses
    return dL, jnp.asarray(dgamma, dtype=jnp.result_type(dgamma_full))


mp_exact.defvjp(_mp_exact_fwd, _mp_exact_bwd)

# Public alias: `mp` is the trainable exact form.
mp = mp_exact


def mp_bisect(
    L: jax.Array,
    gamma: jax.Array,
    iters: int = DEFAULT_BISECT_ITERS,
) -> jax.Array:
    """Hardware-faithful MP via bisection (add/compare/shift only).

    The constraint function h(z) = sum_i [L_i - z]_+ is continuous, strictly
    decreasing where positive. h(max L) = 0 <= gamma and at
    z = max(L) - gamma the max element alone contributes gamma, so the root
    lies in [max(L) - gamma, max(L)].
    """
    gamma = jnp.asarray(gamma, dtype=L.dtype)
    hi = jnp.max(L, axis=-1)
    lo = hi - gamma

    def body(_, state):
        lo, hi = state
        mid = (lo + hi) * jnp.asarray(0.5, L.dtype)  # shift in fixed point
        h = tree_sum(jnp.maximum(L - mid[..., None], 0))
        too_low = h > gamma  # z too small -> move lo up
        lo = jnp.where(too_low, mid, lo)
        hi = jnp.where(too_low, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return (lo + hi) * jnp.asarray(0.5, L.dtype)


def mp_newton(
    L: jax.Array,
    gamma: jax.Array,
    iters: int = DEFAULT_NEWTON_ITERS,
) -> jax.Array:
    """MP via monotone Newton on the water-filling constraint.

    h(z) = sum_i [L_i - z]_+ is convex, piecewise linear, decreasing with
    slope -k(z) where k = |{i : L_i > z}|. Starting LEFT of the root
    (z0 = max L - gamma, where h >= gamma) every Newton step
    ``z += (h(z) - gamma)/k`` jumps to its tangent's root: the tangent
    under-estimates h (convexity), so the iterate never overshoots and is
    monotone increasing; once it reaches the root's linear segment the
    tangent IS h and it lands exactly. ~12 fixed steps beat 26 bisections
    both in accuracy and wall time — at the price of a divide, so this is
    the fast SOFTWARE solver; ``mp_bisect`` remains the hardware-faithful
    add/compare/shift reference.
    """
    gamma = jnp.asarray(gamma, dtype=L.dtype)
    z = jnp.max(L, axis=-1) - gamma

    def body(_, z):
        zc = z[..., None]
        s = tree_sum(jnp.maximum(L - zc, 0))
        k = jnp.sum(L > zc, axis=-1).astype(L.dtype)  # int count: exact
        return z + (s - gamma) / jnp.maximum(k, 1.0)

    return jax.lax.fori_loop(0, iters, body, z)


def mpabs_newton(
    u: jax.Array,
    gamma: jax.Array,
    iters: int = DEFAULT_NEWTON_ITERS,
) -> jax.Array:
    """MP([u; -u], gamma) via monotone Newton (see ``mp_newton``), without
    materializing the concatenation: h(z) over [u; -u] splits into the
    |u| branch plus the -|u| branch (active only when z < -min|u|)."""
    gamma = jnp.asarray(gamma, dtype=u.dtype)
    a = jnp.abs(u)
    z = jnp.max(a, axis=-1) - gamma

    def body(_, z):
        zc = z[..., None]
        s = (tree_sum(jnp.maximum(a - zc, 0))
             + tree_sum(jnp.maximum(-a - zc, 0)))
        k = (jnp.sum(a > zc, axis=-1)
             + jnp.sum(-a > zc, axis=-1)).astype(u.dtype)  # int counts
        return z + (s - gamma) / jnp.maximum(k, 1.0)

    return jax.lax.fori_loop(0, iters, body, z)


# ---------------------------------------------------------------------------
# Multiplierless inner products
# ---------------------------------------------------------------------------


def mpabs(u: jax.Array, gamma: jax.Array, exact: bool = True,
          iters: int = DEFAULT_BISECT_ITERS) -> jax.Array:
    """MP([u; -u], gamma) along the last axis, without materializing [u;-u].

    Materialization-free for the bisect path: h(z) over [u;-u] equals
    sum [u - z]_+ + sum [-u - z]_+. For the exact path we concatenate (the
    training path; XLA fuses it).
    """
    if exact:
        return mp_exact(jnp.concatenate([u, -u], axis=-1), gamma)
    gamma = jnp.asarray(gamma, dtype=u.dtype)
    a = jnp.abs(u)  # |u| = max(u, -u): compare/select, allowed primitive
    hi = jnp.max(a, axis=-1)
    lo = hi - gamma

    def body(_, state):
        lo, hi = state
        mid = (lo + hi) * jnp.asarray(0.5, u.dtype)
        h = (tree_sum(jnp.maximum(u - mid[..., None], 0))
             + tree_sum(jnp.maximum(-u - mid[..., None], 0)))
        too_low = h > gamma
        lo = jnp.where(too_low, mid, lo)
        hi = jnp.where(too_low, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return (lo + hi) * jnp.asarray(0.5, u.dtype)


def mp_dot(x: jax.Array, w: jax.Array, gamma: jax.Array,
           exact: bool = True) -> jax.Array:
    """Multiplierless approximation of the inner product <x, w> (eq. 9).

    x, w: (..., d) broadcast-compatible. Returns (...,).
    """
    u = w + x
    v = w - x
    return mpabs(u, gamma, exact=exact) - mpabs(v, gamma, exact=exact)


def mp_linear(
    x: jax.Array,
    w: jax.Array,
    gamma: jax.Array,
    b: Optional[jax.Array] = None,
    exact: bool = True,
    block_out: int = 128,
) -> jax.Array:
    """Multiplierless matrix-vector/matrix product: (..., d) @ (d, out).

    Each output scalar y[..., o] = mpabs(w[:,o] + x) - mpabs(w[:,o] - x).
    Blocks over the output dim to bound the (..., block_out, d) intermediate.
    This is the pure-jnp reference path; the Pallas kernel
    (repro.kernels.mp_linear) is the TPU production path.
    """
    d, out = w.shape
    assert x.shape[-1] == d, (x.shape, w.shape)

    def block(wb):  # wb: (d, bo)
        u = wb.T + x[..., None, :]  # (..., bo, d)
        v = wb.T - x[..., None, :]
        return mpabs(u, gamma, exact=exact) - mpabs(v, gamma, exact=exact)

    if out <= block_out:
        y = block(w)
    else:
        pad = (-out) % block_out
        wp = jnp.pad(w, ((0, 0), (0, pad)))
        nb = wp.shape[1] // block_out
        wblocks = wp.reshape(d, nb, block_out).transpose(1, 0, 2)
        y = jax.lax.map(lambda wb: block(wb), wblocks)  # (nb, ..., bo)
        y = jnp.moveaxis(y, 0, -2).reshape(*x.shape[:-1], nb * block_out)
        y = y[..., :out]
    if b is not None:
        y = y + b
    return y


def mp_conv1d(
    x: jax.Array,
    h: jax.Array,
    gamma: jax.Array,
    exact: bool = True,
    solver: str = "newton",
    pad: bool = True,
) -> jax.Array:
    """Multiplierless FIR filtering (paper eq. 8 + 9): y(n) = MP-dot(h, x[n-M+1..n]).

    x: (..., N) signal; h: (M,) taps. 'Valid' part is y[M-1:]; we left-pad
    with zeros so y has the same length as x (matches streaming hardware that
    starts from zeroed register banks). ``pad=False`` computes ONLY the
    valid positions ((..., N-M+1) output, window n = x[n..n+M-1]) — the
    streaming hot path, whose delay-line splice already supplies the
    history, uses this to skip solves that would be sliced away. Window
    contents are identical either way, so the shared positions match
    bitwise. With exact=False, ``solver`` picks the fixed-iteration scheme:
    "newton" (fast software default) or "bisect" (the hardware's
    add/compare/shift loop).
    """
    M = h.shape[0]
    if pad:
        xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(M - 1, 0)])
        n_out = x.shape[-1]
    else:
        xp = x
        n_out = x.shape[-1] - M + 1
    # windows: (..., n_out, M) — window n holds x[n-M+1..n] with taps
    # reversed to implement the convolution sum h(k) x(n-k).
    idx = jnp.arange(n_out)[:, None] + jnp.arange(M)[None, :]
    win = xp[..., idx]  # gather windows
    hr = h[::-1]
    if exact:
        return mp_dot(win, hr, gamma, exact=True)
    return _mp_dot_fast(win, hr, gamma, solver)


def _mp_dot_fast(x: jax.Array, w: jax.Array, gamma, solver: str) -> jax.Array:
    """Fast-solver mp_dot for the (non-differentiable) feature-extraction
    hot path: same eq. 9 operand pairing, fixed-iteration solver."""
    if solver == "newton":
        return mpabs_newton(w + x, gamma) - mpabs_newton(w - x, gamma)
    if solver == "bisect":
        return (mpabs(w + x, gamma, exact=False)
                - mpabs(w - x, gamma, exact=False))
    raise ValueError(f"unknown MP solver: {solver!r}")


def mp_conv1d_bank(
    x: jax.Array,
    H: jax.Array,
    gamma: jax.Array,
    exact: bool = True,
    chunk_n: Optional[int] = 1024,
    solver: str = "newton",
    pad: bool = True,
) -> jax.Array:
    """Multi-filter MP FIR: x (..., N), H (F, M) -> y (..., F, N).

    The (N, M) window gather is built ONCE and broadcast against all F tap
    rows (filter axis leading: (F, B, N, M) keeps the MP solve operands in
    the same layout a per-filter vmap produces, which XLA:CPU vectorizes
    measurably better than a (B, F, N, M) broadcast). Long signals are
    solved in ``chunk_n``-sample blocks via lax.map so the fixed-iteration
    solve re-reads cache-resident operands instead of streaming the full
    (F, B, N, M) tensor from DRAM each iteration. Window contents are
    unchanged by chunking, so results match ``mp_conv1d(x, H[f], gamma)``
    exactly per band. ``pad=False``: valid positions only, (..., F, N-M+1)
    (see ``mp_conv1d``).
    """
    F, M = H.shape
    lead = x.shape[:-1]
    N = x.shape[-1]
    x2 = x.reshape(-1, N)
    B = x2.shape[0]
    hr = H[:, ::-1].reshape(F, 1, 1, M)
    n_out = N if pad else N - M + 1

    def solve(win):  # (B, Q, M) -> (F, B, Q)
        if exact:
            return mp_dot(win[None], hr, gamma, exact=True)
        return _mp_dot_fast(win[None], hr, gamma, solver)

    if chunk_n is None or n_out <= chunk_n:
        xp = jnp.pad(x2, ((0, 0), (M - 1, 0))) if pad else x2
        idx = jnp.arange(n_out)[:, None] + jnp.arange(M)[None, :]
        y = solve(xp[:, idx])                          # (F, B, n_out)
    else:
        Q = chunk_n
        xp = jnp.pad(x2, ((0, 0), (M - 1, 0))) if pad else x2
        # right-pad so every Q-block of output positions has a full segment
        n_blocks = -(-n_out // Q)
        xp = jnp.pad(xp, ((0, 0), (0, n_blocks * Q + M - 1 - xp.shape[1])))
        idx = jnp.arange(Q)[:, None] + jnp.arange(M)[None, :]

        def one(start):  # windows for output positions [start, start+Q)
            seg = jax.lax.dynamic_slice_in_dim(xp, start, Q + M - 1, axis=1)
            return solve(seg[:, idx])

        ys = jax.lax.map(one, jnp.arange(n_blocks) * Q)  # (nc, F, B, Q)
        y = jnp.moveaxis(ys, 0, 2).reshape(F, B, n_blocks * Q)[..., :n_out]
    return jnp.moveaxis(y, 0, 1).reshape(*lead, F, n_out)
