"""Multirate FIR filter bank used as feature extractor AND kernel (paper §III-C/D).

Structure (Fig. 3): the input (fs = 16 kHz) feeds octave 1's band-pass
filters directly; a low-pass anti-aliasing filter + ÷2 downsampler feeds each
successive octave. Every octave holds `filters_per_octave` band-pass FIR
filters with cutoffs equally spaced inside the octave (optionally
Greenwood-warped). Downsampling keeps every band-pass at a fixed low order
(M = 16 taps) instead of orders up to 200 (Fig. 4).

Per-filter kernel value (Appendix A):
    B_p(n) = FIR(x, h_p)         -- MP domain (eq. 9) or MAC baseline
    d_p(n) = max(0, B_p(n))      -- HWR
    s_p    = sum_n d_p(n)        -- accumulate over the clip
    Phi_p  = (s_p - mu_p)/sigma_p  -- standardized over the training set

The filters are PRECOMPUTED constants (paper: "coefficients are precomputed
and provided as inputs"); only the classifier trains, absorbing the MP
approximation error. Feature extraction therefore uses the fast
non-differentiable solver path (monotone-Newton water-filling; see
`repro.core.mp.mp_newton`) rather than the differentiable exact solve.
"""

from __future__ import annotations

import functools
from typing import Literal, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mp as mp_mod
from repro.core.quant import fake_quant

__all__ = [
    "FilterBankConfig",
    "FilterBank",
    "STREAM_BLOCK",
    "accumulate_block_len",
    "hwr_accumulate",
    "design_lowpass",
    "design_bandpass",
    "greenwood",
    "single_fir",
    "single_fir_valid",
    "bank_fir",
    "bank_fir_valid",
    "bank_accumulate",
    "quant_signal",
    "multirate_band_outputs",
    "multirate_accumulate",
]

# ---------------------------------------------------------------------------
# Blocked HWR accumulation (shared reduction order)
# ---------------------------------------------------------------------------

# Every path that sums HWR'd band outputs over the position axis — one-shot
# accumulate, the XLA session step, and the Pallas streaming kernel's
# grid-carried accumulator — reduces in the SAME order: per-row sums over
# fixed-length blocks of ``accumulate_block_len(l)`` positions, then one
# sequential add per block. f32 addition is non-associative, so a shared
# order is what makes "single-chunk streaming == one-shot" and
# "Pallas streaming == XLA streaming" BIT-exact rather than merely close
# (XLA's whole-axis reduce uses an unspecified tree that a blockwise
# accumulator cannot reproduce).
STREAM_BLOCK = 512


def accumulate_block_len(n: int) -> int:
    """Accumulation block length for a position axis of length ``n``: the
    next power of two, clamped to [2, STREAM_BLOCK]. Always even, so the
    ÷2 decimator's kept-sample alignment is constant within a block."""
    b = 2
    while b < n and b < STREAM_BLOCK:
        b <<= 1
    return b


def hwr_accumulate(y: jax.Array, valid: jax.Array | None = None) -> jax.Array:
    """s = sum_p HWR(y[..., p]) with the shared blocked reduction order.

    ``valid`` (optional, shape broadcastable to ``y.shape[:-1]``, passed to
    this function WITH the trailing axis already dropped — e.g. ``n[:, None]``
    for a (S, F, l) bank output) masks positions >= valid to exactly +0.0
    before summing, so masked tails and the zero-padding to a whole number
    of blocks contribute identical (no-op) terms.
    """
    l = y.shape[-1]
    h = jnp.maximum(y, 0.0)
    if valid is not None:
        pos = jax.lax.broadcasted_iota(jnp.int32, y.shape, y.ndim - 1)
        h = jnp.where(pos < jnp.asarray(valid)[..., None], h, 0.0)
    if l == 0:
        return jnp.zeros(y.shape[:-1], y.dtype)
    lb = accumulate_block_len(l)
    nb = -(-l // lb)
    h = jnp.pad(h, [(0, 0)] * (y.ndim - 1) + [(0, nb * lb - l)])
    h = h.reshape(*y.shape[:-1], nb, lb)
    s = mp_mod.tree_sum(h)            # per-block fixed-tree sums
    out = s[..., 0]
    for k in range(1, nb):            # sequential adds, ascending blocks
        out = out + s[..., k]
    return out


# ---------------------------------------------------------------------------
# FIR design (windowed sinc; no scipy available/needed)
# ---------------------------------------------------------------------------


def _hamming(M: int) -> np.ndarray:
    n = np.arange(M)
    return 0.54 - 0.46 * np.cos(2 * np.pi * n / (M - 1))


def design_lowpass(num_taps: int, cutoff: float, fs: float) -> np.ndarray:
    """Windowed-sinc low-pass FIR, cutoff in Hz."""
    fc = cutoff / fs  # normalized (cycles/sample)
    n = np.arange(num_taps) - (num_taps - 1) / 2.0
    h = 2 * fc * np.sinc(2 * fc * n)
    h = h * _hamming(num_taps)
    return (h / h.sum()).astype(np.float32)  # unity DC gain


def design_bandpass(num_taps: int, f_lo: float, f_hi: float, fs: float) -> np.ndarray:
    """Band-pass as difference of two low-passes, Hamming windowed."""
    n = np.arange(num_taps) - (num_taps - 1) / 2.0
    h = (2 * (f_hi / fs) * np.sinc(2 * (f_hi / fs) * n)
         - 2 * (f_lo / fs) * np.sinc(2 * (f_lo / fs) * n))
    h = h * _hamming(num_taps)
    # normalize peak gain at center frequency to ~1
    fc = (f_lo + f_hi) / 2.0
    w = 2 * np.pi * fc / fs
    gain = np.abs(np.sum(h * np.exp(-1j * w * np.arange(num_taps))))
    return (h / max(gain, 1e-6)).astype(np.float32)


def greenwood(x: np.ndarray, fmin: float = 100.0, fmax: float = 8000.0) -> np.ndarray:
    """Greenwood cochlear frequency-position map scaled to [fmin, fmax].

    f(x) = A (10^(a x) - k), x in [0,1]; constants from Greenwood (1990)
    (A=165.4, a=2.1, k=0.88 for human), rescaled to the requested range.
    """
    A, a, k = 165.4, 2.1, 0.88
    raw = A * (10 ** (a * x) - k)
    lo, hi = raw.min(), raw.max()
    return fmin + (raw - lo) * (fmax - fmin) / (hi - lo)


# ---------------------------------------------------------------------------
# Filtering primitives (array-in/array-out; shared by FilterBank and
# repro.core.pipeline — both the one-shot and the streaming path call these,
# which is what keeps chunked step() bit-compatible with predict())
# ---------------------------------------------------------------------------


def single_fir(x: jax.Array, h: jax.Array, cfg: "FilterBankConfig") -> jax.Array:
    """x: (B, N), h: (M,) -> (B, N). MP or MAC per config."""
    if cfg.mode == "mac":
        return _mac_fir(x, h)
    if cfg.use_pallas:
        from repro.kernels import fir_mp  # lazy: keeps core import light
        return fir_mp(x, h, cfg.gamma_f)
    return mp_mod.mp_conv1d(x, h, cfg.gamma_f, exact=False, solver=cfg.solver)


def bank_fir(x: jax.Array, taps: jax.Array, cfg: "FilterBankConfig") -> jax.Array:
    """Whole-octave band-pass: x (B, N), taps (F, M) -> (B, F, N).

    One stacked-tap invocation per octave: a single pallas_call (grid over
    batch x filter, shared VMEM signal block) or a single broadcast window
    solve — never a Python loop of per-filter calls."""
    if cfg.mode == "mac":
        return _mac_fir_bank(x, taps)
    if cfg.use_pallas:
        from repro.kernels import fir_mp_bank
        return fir_mp_bank(x, taps, cfg.gamma_f)
    return mp_mod.mp_conv1d_bank(x, taps, cfg.gamma_f, exact=False,
                                 solver=cfg.solver)


def single_fir_valid(x: jax.Array, h: jax.Array,
                     cfg: "FilterBankConfig") -> jax.Array:
    """Valid-mode FIR: x (B, N), h (M,) -> (B, N-M+1); window p covers
    x[p..p+M-1], no zero-padding. The streaming hot path splices its
    delay-line history in front of the chunk and uses this to skip the
    solves the padded form would compute and immediately slice away.
    Shared positions match the padded form bitwise."""
    M = h.shape[0]
    if cfg.mode == "mac":
        return _mac_fir(x, h)[..., M - 1:]
    if cfg.use_pallas:
        from repro.kernels import fir_mp
        return fir_mp(x, h, cfg.gamma_f)[..., M - 1:]
    return mp_mod.mp_conv1d(x, h, cfg.gamma_f, exact=False,
                            solver=cfg.solver, pad=False)


def bank_fir_valid(x: jax.Array, taps: jax.Array,
                   cfg: "FilterBankConfig") -> jax.Array:
    """Valid-mode whole-octave band-pass: x (B, N), taps (F, M) ->
    (B, F, N-M+1). See ``single_fir_valid``."""
    M = taps.shape[-1]
    if cfg.mode == "mac":
        return _mac_fir_bank(x, taps)[..., M - 1:]
    if cfg.use_pallas:
        from repro.kernels import fir_mp_bank
        return fir_mp_bank(x, taps, cfg.gamma_f)[..., M - 1:]
    return mp_mod.mp_conv1d_bank(x, taps, cfg.gamma_f, exact=False,
                                 solver=cfg.solver, pad=False)


def bank_accumulate(x: jax.Array, taps: jax.Array,
                    cfg: "FilterBankConfig") -> jax.Array:
    """s_p = sum_n HWR(B_p(n)) for one octave: x (B, N), taps (F, M) -> (B, F).

    MP+pallas fuses FIR+HWR+accumulate in the kernel (one HBM read of the
    signal -> F scalars); other modes reduce the bank output."""
    if cfg.mode == "mp" and cfg.use_pallas:
        from repro.kernels import fir_mp_bank_accumulate
        return fir_mp_bank_accumulate(x, taps, cfg.gamma_f)
    y = bank_fir(x, taps, cfg)
    return hwr_accumulate(y)


def quant_signal(x: jax.Array, cfg: "FilterBankConfig",
                 amax: jax.Array | None = None) -> jax.Array:
    """Symmetric per-stream signal quantization (no-op without quant_bits).

    Each batch row is an independent sensor stream, so the scale is that
    row's own amax — never the batch-global max, which would couple streams
    through a shared ADC range. ``amax`` overrides the per-row max; the
    session streaming path passes its running amax (shape ``(S,)``) so that
    chunked deployment quantizes exactly like the one-shot path.
    """
    if cfg.quant_bits is None:
        return x
    if amax is None:
        amax = jax.lax.stop_gradient(
            jnp.max(jnp.abs(x), axis=-1, keepdims=True))
    else:
        amax = jnp.asarray(amax)
        if amax.ndim == x.ndim - 1:
            amax = amax[..., None]
    return fake_quant(x, cfg.quant_bits, amax=amax)


def _require_float_numerics(cfg: "FilterBankConfig", fn: str) -> None:
    if cfg.numerics == "fixed":
        from repro.core.quant import unsupported_fixed
        raise unsupported_fixed(
            fn,
            hint="this is the float engine and ignores the fixed-point "
                 "program; go through FilterBank.accumulate or "
                 "InFilterPipeline.apply/predict (repro.core.fixed)")
    if cfg.numerics != "float":
        raise ValueError(f"unknown numerics {cfg.numerics!r}: "
                         "expected 'float' or 'fixed'")


def multirate_band_outputs(x: jax.Array, bp_taps, lp_taps,
                           cfg: "FilterBankConfig",
                           amax: jax.Array | None = None) -> list:
    """Raw band-pass outputs per octave: list of (B, F, N/2^o) arrays."""
    _require_float_numerics(cfg, "multirate_band_outputs")
    x = quant_signal(x, cfg, amax)
    outs = []
    x_o = x
    for o in range(cfg.num_octaves):
        outs.append(bank_fir(x_o, bp_taps[o], cfg))
        if o < cfg.num_octaves - 1:
            x_o = single_fir(x_o, lp_taps[o], cfg)[..., ::2]  # LP + decimate
    return outs


def multirate_accumulate(x: jax.Array, bp_taps, lp_taps,
                         cfg: "FilterBankConfig",
                         amax: jax.Array | None = None) -> jax.Array:
    """Full-bank accumulator readout: x (B, N) -> s (B, P).

    Octave o has N/2^o samples; renormalize by 2^o so every band contributes
    at the same scale (the FPGA's per-band accumulators are read out raw, but
    the STD stage removes scale anyway; renormalizing keeps the pre-STD
    dynamic range uniform for fixed-point analysis)."""
    _require_float_numerics(cfg, "multirate_accumulate")
    x = quant_signal(x, cfg, amax)
    parts = []
    x_o = x
    for o in range(cfg.num_octaves):
        parts.append(bank_accumulate(x_o, bp_taps[o], cfg) * (2.0 ** o))
        if o < cfg.num_octaves - 1:
            x_o = single_fir(x_o, lp_taps[o], cfg)[..., ::2]
    return jnp.concatenate(parts, axis=-1)


# ---------------------------------------------------------------------------
# Filter bank
# ---------------------------------------------------------------------------


class FilterBankConfig(NamedTuple):
    fs: float = 16000.0
    num_octaves: int = 6
    filters_per_octave: int = 5
    bp_taps: int = 16          # paper: BP window size 16 (order 15)
    lp_taps: int = 6           # paper: LP window size 6
    mode: Literal["mp", "mac"] = "mp"
    gamma_f: float = 4.0       # MP parameter for the filtering operation
    use_pallas: bool = False   # route MP FIR through the fused Pallas
    # kernels (float, or the integer bank kernels under numerics="fixed")
    spacing: Literal["octave", "greenwood"] = "octave"
    quant_bits: int | None = None  # quantize taps + signal (Fig. 8 sweep)
    solver: Literal["newton", "bisect"] = "newton"  # non-exact MP scheme:
    # newton = fast software path; bisect = the FPGA's add/compare/shift loop
    # (use for hardware op censuses; the one-shot Pallas kernels always
    # bisect; the streaming kernel honors this field)
    stream_impl: Literal["xla", "pallas"] = "xla"  # session-step hot path:
    # xla = splice [delay, chunk] in XLA per octave; pallas = fir_mp_stream,
    # a stateful kernel carrying delay lines / accumulators / running amax
    # in VMEM scratch across grid steps (bit-identical to xla in interpret
    # mode when use_pallas is False — both run the same solver math)
    numerics: Literal["float", "fixed"] = "float"  # execution numerics:
    # float = f32 arrays (optionally fake-quant under quant_bits, the QAT
    # proxy); fixed = the bit-true int32 hardware twin (repro.core.fixed):
    # power-of-two-scale fixed point, add/sub/shift/compare only — 8-bit
    # signals/weights, 10-bit internal path per paper §V. Both one-shot AND
    # session streaming, under EITHER stream_impl (integer registers,
    # chunked decisions bit-for-bit equal to one-shot from the first chunk
    # — docs/numerics.md); stream_impl="pallas" runs the VMEM-resident
    # integer kernel fir_mp_stream_q, bit-identical to the XLA step.
    fixed_amax: float = 1.0    # fixed mode: ADC full-scale calibration (a
    # STATIC power-of-two-snapped range; inputs beyond it saturate, exactly
    # like the hardware front end)

    @property
    def num_filters(self) -> int:
        return self.num_octaves * self.filters_per_octave


class FilterBank:
    """Precomputed multirate filter bank. Call `features(x)` on (B, N) audio."""

    def __init__(self, config: FilterBankConfig):
        if config.numerics not in ("float", "fixed"):
            raise ValueError(f"unknown numerics {config.numerics!r}: "
                             "expected 'float' or 'fixed'")
        if config.numerics == "fixed" and config.mode not in ("mp", "mac"):
            raise ValueError(
                f"numerics='fixed' has no {config.mode!r}-mode datapath")
        self.config = config
        self._fixed_bank = None   # lazy compile_bank cache (fixed numerics)
        c = config
        # Octave o (0-indexed) covers [nyq/2^(o+1), nyq/2^o] at rate fs/2^o.
        nyq = c.fs / 2.0
        self.bp_taps: list[np.ndarray] = []   # per filter, grouped by octave
        self.octave_of: list[int] = []
        for o in range(c.num_octaves):
            f_hi, f_lo = nyq / (2 ** o), nyq / (2 ** (o + 1))
            rate = c.fs / (2 ** o)
            if c.spacing == "octave":
                edges = np.linspace(f_lo, f_hi, c.filters_per_octave + 1)
            else:
                edges = greenwood(np.linspace(0, 1, c.filters_per_octave + 1),
                                  f_lo, f_hi)
            for p in range(c.filters_per_octave):
                h = design_bandpass(c.bp_taps, edges[p], edges[p + 1], rate)
                self.bp_taps.append(h)
                self.octave_of.append(o)
        # Anti-aliasing LP for each ÷2 stage, cutoff at fs_stage/4.
        self.lp_tap_list = [
            design_lowpass(c.lp_taps, (c.fs / 2 ** o) / 4.0, c.fs / 2 ** o)
            for o in range(c.num_octaves - 1)
        ]
        if c.quant_bits is not None:
            self.bp_taps = [np.asarray(fake_quant(jnp.asarray(h), c.quant_bits))
                            for h in self.bp_taps]
            self.lp_tap_list = [np.asarray(fake_quant(jnp.asarray(h), c.quant_bits))
                                for h in self.lp_tap_list]
        # stacked per-octave taps: (filters_per_octave, bp_taps)
        self._bp_by_octave = tuple(
            jnp.stack([jnp.asarray(self.bp_taps[o * c.filters_per_octave + p])
                       for p in range(c.filters_per_octave)])
            for o in range(c.num_octaves)
        )
        self._lp = tuple(jnp.asarray(h) for h in self.lp_tap_list)

    @property
    def bp_by_octave(self) -> tuple:
        """Stacked (F, M) band-pass taps per octave (kernel-ready)."""
        return self._bp_by_octave

    @property
    def lp_filters(self) -> tuple:
        """Anti-aliasing low-pass taps per ÷2 stage."""
        return self._lp

    def band_outputs(self, x: jax.Array) -> list[jax.Array]:
        """Raw band-pass outputs per octave (list of (B, F, N_o) arrays)."""
        return multirate_band_outputs(x, self._bp_by_octave, self._lp,
                                      self.config)

    def fixed_bank(self):
        """The compiled integer filter-bank program (numerics='fixed'):
        static int32 taps + per-stage fixed-point formats, built once from
        this bank's float taps. See ``repro.core.fixed.compile_bank``."""
        if self._fixed_bank is None:
            from repro.core import fixed
            self._fixed_bank = fixed.compile_bank(
                self.config, [np.asarray(t) for t in self._bp_by_octave],
                [np.asarray(t) for t in self._lp])
        return self._fixed_bank

    def accumulate(self, x: jax.Array) -> jax.Array:
        """s_p = sum_n HWR(B_p(n)) for every filter. x: (B, N) -> (B, P).

        With ``numerics='fixed'`` this runs the bit-true int32 datapath
        (add/sub/shift/compare only) and dequantizes the 32-bit
        accumulators; otherwise the float engine."""
        if self.config.numerics == "fixed":
            from repro.core import fixed
            bank = self.fixed_bank()
            xq = fixed.quantize_signal(bank, x)
            return bank.acc.dequantize(fixed.bank_accumulate_q(
                bank, xq, use_pallas=self.config.use_pallas))
        return multirate_accumulate(x, self._bp_by_octave, self._lp,
                                    self.config)

    def features(self, x: jax.Array, mu: jax.Array | None = None,
                 sigma: jax.Array | None = None):
        """Kernel vector Phi (B, P). If mu/sigma are None they are computed
        from x (training); pass the training statistics at inference."""
        s = self.accumulate(x)
        if mu is None:
            mu = jnp.mean(s, axis=0)
            sigma = jnp.std(s, axis=0, ddof=1) + 1e-6
        phi = (s - mu) / sigma
        return phi, mu, sigma


def _mac_fir(x: jax.Array, h: jax.Array) -> jax.Array:
    """Baseline multiplier-based FIR via conv (causal, zero initial state)."""
    M = h.shape[0]
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(M - 1, 0)])
    return jax.lax.conv_general_dilated(
        xp[:, None, :], h[::-1][None, None, :],
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NCH", "OIH", "NCH"))[:, 0, :]


def _mac_fir_bank(x: jax.Array, H: jax.Array) -> jax.Array:
    """Multiplier baseline for a whole octave: one conv with F output
    channels. x (B, N), H (F, M) -> (B, F, N)."""
    M = H.shape[1]
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(M - 1, 0)])
    return jax.lax.conv_general_dilated(
        xp[:, None, :], H[:, ::-1][:, None, :],
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NCH", "OIH", "NCH"))
