"""Multirate FIR filter bank used as feature extractor AND kernel (paper §III-C/D).

Structure (Fig. 3): the input (fs = 16 kHz) feeds octave 1's band-pass
filters directly; a low-pass anti-aliasing filter + ÷2 downsampler feeds each
successive octave. Every octave holds `filters_per_octave` band-pass FIR
filters with cutoffs equally spaced inside the octave (optionally
Greenwood-warped). Downsampling keeps every band-pass at a fixed low order
(M = 16 taps) instead of orders up to 200 (Fig. 4).

Per-filter kernel value (Appendix A):
    B_p(n) = FIR(x, h_p)         -- MP domain (eq. 9) or MAC baseline
    d_p(n) = max(0, B_p(n))      -- HWR
    s_p    = sum_n d_p(n)        -- accumulate over the clip
    Phi_p  = (s_p - mu_p)/sigma_p  -- standardized over the training set

The filters are PRECOMPUTED constants (paper: "coefficients are precomputed
and provided as inputs"); only the classifier trains, absorbing the MP
approximation error. Feature extraction therefore uses the fast
non-differentiable `mp_bisect` path.
"""

from __future__ import annotations

import functools
from typing import Literal, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mp as mp_mod
from repro.core.quant import fake_quant

__all__ = [
    "FilterBankConfig",
    "FilterBank",
    "design_lowpass",
    "design_bandpass",
    "greenwood",
]


# ---------------------------------------------------------------------------
# FIR design (windowed sinc; no scipy available/needed)
# ---------------------------------------------------------------------------


def _hamming(M: int) -> np.ndarray:
    n = np.arange(M)
    return 0.54 - 0.46 * np.cos(2 * np.pi * n / (M - 1))


def design_lowpass(num_taps: int, cutoff: float, fs: float) -> np.ndarray:
    """Windowed-sinc low-pass FIR, cutoff in Hz."""
    fc = cutoff / fs  # normalized (cycles/sample)
    n = np.arange(num_taps) - (num_taps - 1) / 2.0
    h = 2 * fc * np.sinc(2 * fc * n)
    h = h * _hamming(num_taps)
    return (h / h.sum()).astype(np.float32)  # unity DC gain


def design_bandpass(num_taps: int, f_lo: float, f_hi: float, fs: float) -> np.ndarray:
    """Band-pass as difference of two low-passes, Hamming windowed."""
    n = np.arange(num_taps) - (num_taps - 1) / 2.0
    h = (2 * (f_hi / fs) * np.sinc(2 * (f_hi / fs) * n)
         - 2 * (f_lo / fs) * np.sinc(2 * (f_lo / fs) * n))
    h = h * _hamming(num_taps)
    # normalize peak gain at center frequency to ~1
    fc = (f_lo + f_hi) / 2.0
    w = 2 * np.pi * fc / fs
    gain = np.abs(np.sum(h * np.exp(-1j * w * np.arange(num_taps))))
    return (h / max(gain, 1e-6)).astype(np.float32)


def greenwood(x: np.ndarray, fmin: float = 100.0, fmax: float = 8000.0) -> np.ndarray:
    """Greenwood cochlear frequency-position map scaled to [fmin, fmax].

    f(x) = A (10^(a x) - k), x in [0,1]; constants from Greenwood (1990)
    (A=165.4, a=2.1, k=0.88 for human), rescaled to the requested range.
    """
    A, a, k = 165.4, 2.1, 0.88
    raw = A * (10 ** (a * x) - k)
    lo, hi = raw.min(), raw.max()
    return fmin + (raw - lo) * (fmax - fmin) / (hi - lo)


# ---------------------------------------------------------------------------
# Filter bank
# ---------------------------------------------------------------------------


class FilterBankConfig(NamedTuple):
    fs: float = 16000.0
    num_octaves: int = 6
    filters_per_octave: int = 5
    bp_taps: int = 16          # paper: BP window size 16 (order 15)
    lp_taps: int = 6           # paper: LP window size 6
    mode: Literal["mp", "mac"] = "mp"
    gamma_f: float = 4.0       # MP parameter for the filtering operation
    use_pallas: bool = False   # route MP FIR through the fused Pallas kernel
    spacing: Literal["octave", "greenwood"] = "octave"
    quant_bits: int | None = None  # quantize taps + signal (Fig. 8 sweep)

    @property
    def num_filters(self) -> int:
        return self.num_octaves * self.filters_per_octave


class FilterBank:
    """Precomputed multirate filter bank. Call `features(x)` on (B, N) audio."""

    def __init__(self, config: FilterBankConfig):
        self.config = config
        c = config
        # Octave o (0-indexed) covers [nyq/2^(o+1), nyq/2^o] at rate fs/2^o.
        nyq = c.fs / 2.0
        self.bp_taps: list[np.ndarray] = []   # per filter, grouped by octave
        self.octave_of: list[int] = []
        for o in range(c.num_octaves):
            f_hi, f_lo = nyq / (2 ** o), nyq / (2 ** (o + 1))
            rate = c.fs / (2 ** o)
            if c.spacing == "octave":
                edges = np.linspace(f_lo, f_hi, c.filters_per_octave + 1)
            else:
                edges = greenwood(np.linspace(0, 1, c.filters_per_octave + 1),
                                  f_lo, f_hi)
            for p in range(c.filters_per_octave):
                h = design_bandpass(c.bp_taps, edges[p], edges[p + 1], rate)
                self.bp_taps.append(h)
                self.octave_of.append(o)
        # Anti-aliasing LP for each ÷2 stage, cutoff at fs_stage/4.
        self.lp_tap_list = [
            design_lowpass(c.lp_taps, (c.fs / 2 ** o) / 4.0, c.fs / 2 ** o)
            for o in range(c.num_octaves - 1)
        ]
        if c.quant_bits is not None:
            self.bp_taps = [np.asarray(fake_quant(jnp.asarray(h), c.quant_bits))
                            for h in self.bp_taps]
            self.lp_tap_list = [np.asarray(fake_quant(jnp.asarray(h), c.quant_bits))
                                for h in self.lp_tap_list]
        # stacked per-octave taps: (filters_per_octave, bp_taps)
        self._bp_by_octave = [
            jnp.stack([jnp.asarray(self.bp_taps[o * c.filters_per_octave + p])
                       for p in range(c.filters_per_octave)])
            for o in range(c.num_octaves)
        ]
        self._lp = [jnp.asarray(h) for h in self.lp_tap_list]

    # -- filtering primitives ------------------------------------------------

    def _fir(self, x: jax.Array, h: jax.Array) -> jax.Array:
        """x: (B, N), h: (M,) -> (B, N). MP or MAC per config."""
        if self.config.mode == "mac":
            return _mac_fir(x, h)
        if self.config.use_pallas:
            from repro.kernels import fir_mp  # lazy: keeps core import light
            return fir_mp(x, h, self.config.gamma_f)
        return mp_mod.mp_conv1d(x, h, self.config.gamma_f, exact=False)

    def band_outputs(self, x: jax.Array) -> list[jax.Array]:
        """Raw band-pass outputs per filter (list of (B, N_o) arrays)."""
        c = self.config
        if c.quant_bits is not None:
            x = fake_quant(x, c.quant_bits)
        outs: list[jax.Array] = []
        x_o = x
        for o in range(c.num_octaves):
            taps = self._bp_by_octave[o]  # (F, M)
            y = jax.vmap(lambda h: self._fir(x_o, h))(taps)  # (F, B, N_o)
            outs.extend([y[p] for p in range(taps.shape[0])])
            if o < c.num_octaves - 1:
                x_o = self._fir(x_o, self._lp[o])[..., ::2]  # LP + decimate
        return outs

    def accumulate(self, x: jax.Array) -> jax.Array:
        """s_p = sum_n HWR(B_p(n)) for every filter. x: (B, N) -> (B, P).

        Octave o has N/2^o samples; we renormalize by 2^o so every band
        contributes at the same scale (the FPGA's per-band accumulators are
        read out raw, but the STD stage removes scale anyway; renormalizing
        keeps the pre-STD dynamic range uniform for fixed-point analysis).
        """
        outs = self.band_outputs(x)
        s = []
        for p, y in enumerate(outs):
            o = self.octave_of[p]
            s.append(jnp.sum(jnp.maximum(y, 0.0), axis=-1) * (2.0 ** o))
        return jnp.stack(s, axis=-1)

    def features(self, x: jax.Array, mu: jax.Array | None = None,
                 sigma: jax.Array | None = None):
        """Kernel vector Phi (B, P). If mu/sigma are None they are computed
        from x (training); pass the training statistics at inference."""
        s = self.accumulate(x)
        if mu is None:
            mu = jnp.mean(s, axis=0)
            sigma = jnp.std(s, axis=0, ddof=1) + 1e-6
        phi = (s - mu) / sigma
        return phi, mu, sigma


def _mac_fir(x: jax.Array, h: jax.Array) -> jax.Array:
    """Baseline multiplier-based FIR via conv (causal, zero initial state)."""
    M = h.shape[0]
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(M - 1, 0)])
    return jax.lax.conv_general_dilated(
        xp[:, None, :], h[::-1][None, None, :],
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NCH", "OIH", "NCH"))[:, 0, :]
