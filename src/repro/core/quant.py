"""Fixed-point quantization utilities (paper §V, Fig. 8).

The FPGA datapath is 8-bit fixed point with a 10-bit internal path. Two
levels of fidelity live here:

* :class:`QuantSpec` + ``fake_quant`` — the QAT proxy: values are
  round(x / s) clamped to [-(2^(b-1)), 2^(b-1)-1], stored as float carrying
  integer values so kernels remain dtype-uniform (the "counters + adders"
  semantics of the paper; MP only ever adds/compares these, so no precision
  explosion — §III-A). ``fake_quant`` is the straight-through-estimator used
  for quantization-aware training of the MP system.

* :class:`FixedPointSpec` — the hardware-twin type: a symmetric fixed-point
  format whose scale is constrained to a POWER OF TWO (``2**exp``), so every
  rescale between formats is a bit shift and the whole datapath can execute
  in int32 with only add/subtract/shift/compare (see ``repro.core.fixed``).
  ``pow2_spec_for`` snaps a tensor's range to the nearest covering
  power-of-two scale.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "QuantSpec",
    "FixedPointSpec",
    "quantize",
    "dequantize",
    "fake_quant",
    "spec_for",
    "pow2_spec_for",
    "unsupported_fixed",
]


def unsupported_fixed(feature: str, *, hint: str | None = None,
                      followup: str | None = None) -> Exception:
    """The one way this repo says "numerics='fixed' has no path here".

    Every surface that rejects the fixed-point mode builds its exception
    here, so rejections stay consistent. ``hint`` redirects to the surface
    that DOES support fixed numerics; ``followup`` names the ROADMAP.md
    open item that will remove the rejection, and a caller that claims one
    must name it explicitly — the default (``None``) is a permanent
    redirect: the caller is simply the wrong entry point, not a missing
    feature.

    Returns the exception (``NotImplementedError`` for follow-ups,
    ``ValueError`` for wrong-entry-point redirects) — callers ``raise`` it.
    """
    msg = f"{feature} does not support numerics='fixed'"
    if hint:
        msg += f": {hint}"
    if followup:
        msg += (f" — the int32 path here is the {followup!r} follow-up "
                "in ROADMAP.md")
        return NotImplementedError(msg)
    return ValueError(msg)


class QuantSpec(NamedTuple):
    bits: int
    scale: float  # LSB size

    @property
    def qmin(self) -> int:
        return -(1 << (self.bits - 1))

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1


class FixedPointSpec(NamedTuple):
    """Symmetric fixed point with a power-of-two LSB: value = q * 2**exp.

    ``q`` is a signed integer in [qmin, qmax]. Because the scale is a power
    of two, converting between two specs is a pure bit shift (left shift to
    a finer exp — exact; right shift to a coarser exp — floor rounding),
    which is what makes the integer datapath in ``repro.core.fixed``
    multiplierless end to end.
    """
    bits: int
    exp: int  # scale = 2.0 ** exp (exp may be negative: fractional LSBs)

    @property
    def qmin(self) -> int:
        return -(1 << (self.bits - 1))

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1

    @property
    def scale(self) -> float:
        return math.ldexp(1.0, self.exp)

    @property
    def amax(self) -> float:
        """Largest representable magnitude."""
        return self.qmax * self.scale

    def quantize(self, x, dtype=jnp.int32) -> jax.Array:
        """Round-to-nearest onto the grid, saturating clamp; int32 codes."""
        q = jnp.round(jnp.asarray(x) * (1.0 / self.scale))
        return jnp.clip(q, self.qmin, self.qmax).astype(dtype)

    def dequantize(self, q) -> jax.Array:
        """Exact (power-of-two) rescale of integer codes back to float."""
        return jnp.asarray(q).astype(jnp.float32) * self.scale


def _amax_of(x) -> float:
    """max |x| with degenerate handling shared by the spec builders:
    empty and all-zero tensors get amax = 1.0 (so quantize(0) == 0 and the
    scale stays sane), non-finite input is rejected loudly instead of
    producing a NaN/overflowing scale.

    Host-side on purpose (numpy): the spec builders run during program
    lowering, which must work even while a jit trace is active (a jnp op
    here would be staged into the trace and the float() below would see a
    tracer). A traced argument still fails loudly — np.asarray refuses
    tracers."""
    import numpy as np
    x = np.asarray(x)
    if x.size == 0:
        return 1.0
    amax = float(np.max(np.abs(x)))
    if not math.isfinite(amax):
        raise ValueError(
            f"spec_for: tensor has non-finite values (max |x| = {amax})")
    return amax if amax > 0 else 1.0


def spec_for(x: jax.Array, bits: int) -> QuantSpec:
    """Symmetric per-tensor spec covering max |x|.

    Degenerate tensors (empty, all-zero, or a single value) are handled:
    empty/all-zero fall back to amax = 1.0; a single-value tensor gets the
    spec that places that value exactly at qmax.
    """
    if bits < 2:
        raise ValueError(f"spec_for: need bits >= 2, got {bits}")
    return QuantSpec(bits=bits, scale=_amax_of(x) / ((1 << (bits - 1)) - 1))


def pow2_spec_for(x, bits: int, amax: float | None = None) -> FixedPointSpec:
    """Smallest power-of-two-scale spec covering max |x| (or ``amax``).

    exp = ceil(log2(amax / qmax)): the finest power-of-two LSB whose qmax
    still reaches amax. Shares ``spec_for``'s degenerate handling.
    """
    if bits < 2:
        raise ValueError(f"pow2_spec_for: need bits >= 2, got {bits}")
    if amax is None:
        amax = _amax_of(x)
    if not (math.isfinite(amax) and amax > 0):
        raise ValueError(f"pow2_spec_for: need finite amax > 0, got {amax}")
    qmax = (1 << (bits - 1)) - 1
    exp = math.ceil(math.log2(amax / qmax) - 1e-12)
    # guard the float log against landing one LSB short of covering amax
    while math.ldexp(qmax, exp) < amax:
        exp += 1
    return FixedPointSpec(bits=bits, exp=exp)


def quantize(x: jax.Array, spec: QuantSpec) -> jax.Array:
    q = jnp.round(x / spec.scale)
    return jnp.clip(q, spec.qmin, spec.qmax)


def dequantize(q: jax.Array, spec: QuantSpec) -> jax.Array:
    return q * spec.scale


@jax.custom_vjp
def _ste_round(x):
    return jnp.round(x)


def _ste_fwd(x):
    return jnp.round(x), None


def _ste_bwd(_, g):
    return (g,)


_ste_round.defvjp(_ste_fwd, _ste_bwd)


def fake_quant(x: jax.Array, bits: int,
               amax: float | jax.Array | None = None) -> jax.Array:
    """Quantize-dequantize with straight-through gradient (QAT).

    ``amax`` sets the symmetric range; it may be a scalar or an array that
    broadcasts against ``x`` (e.g. a per-stream ``(S, 1)`` running amax in
    the session streaming path). ``None`` falls back to the tensor's own
    max — correct for weights, NOT deployment-faithful for signal batches
    (it couples independent streams through one shared scale).
    """
    if amax is None:
        amax = jax.lax.stop_gradient(jnp.max(jnp.abs(x)))
    amax = jnp.asarray(amax)
    amax = jnp.where(amax > 0, amax, jnp.ones((), amax.dtype))
    scale = amax / ((1 << (bits - 1)) - 1)
    q = _ste_round(x / scale)
    q = jnp.clip(q, -(1 << (bits - 1)), (1 << (bits - 1)) - 1)
    return q * scale
