"""Fixed-point quantization utilities (paper §V, Fig. 8).

The FPGA datapath is 8-bit fixed point with a 10-bit internal path. We
simulate symmetric fixed point Q(s, bits): values are round(x / s) clamped to
[-(2^(b-1)), 2^(b-1)-1], stored as float carrying integer values so kernels
remain dtype-uniform (the "counters + adders" semantics of the paper; MP only
ever adds/compares these, so no precision explosion — §III-A).

`fake_quant` is the straight-through-estimator used for quantization-aware
training of the MP system (forward quantized, gradient passes through).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["QuantSpec", "quantize", "dequantize", "fake_quant", "spec_for"]


class QuantSpec(NamedTuple):
    bits: int
    scale: float  # LSB size

    @property
    def qmin(self) -> int:
        return -(1 << (self.bits - 1))

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1


def spec_for(x: jax.Array, bits: int) -> QuantSpec:
    """Symmetric per-tensor spec covering max |x|."""
    amax = float(jnp.max(jnp.abs(x)))
    amax = amax if amax > 0 else 1.0
    return QuantSpec(bits=bits, scale=amax / ((1 << (bits - 1)) - 1))


def quantize(x: jax.Array, spec: QuantSpec) -> jax.Array:
    q = jnp.round(x / spec.scale)
    return jnp.clip(q, spec.qmin, spec.qmax)


def dequantize(q: jax.Array, spec: QuantSpec) -> jax.Array:
    return q * spec.scale


@jax.custom_vjp
def _ste_round(x):
    return jnp.round(x)


def _ste_fwd(x):
    return jnp.round(x), None


def _ste_bwd(_, g):
    return (g,)


_ste_round.defvjp(_ste_fwd, _ste_bwd)


def fake_quant(x: jax.Array, bits: int,
               amax: float | jax.Array | None = None) -> jax.Array:
    """Quantize-dequantize with straight-through gradient (QAT).

    ``amax`` sets the symmetric range; it may be a scalar or an array that
    broadcasts against ``x`` (e.g. a per-stream ``(S, 1)`` running amax in
    the session streaming path). ``None`` falls back to the tensor's own
    max — correct for weights, NOT deployment-faithful for signal batches
    (it couples independent streams through one shared scale).
    """
    if amax is None:
        amax = jax.lax.stop_gradient(jnp.max(jnp.abs(x)))
    amax = jnp.asarray(amax)
    amax = jnp.where(amax > 0, amax, jnp.ones((), amax.dtype))
    scale = amax / ((1 << (bits - 1)) - 1)
    q = _ste_round(x / scale)
    q = jnp.clip(q, -(1 << (bits - 1)), (1 << (bits - 1)) - 1)
    return q * scale
