"""Template-based kernel machine classifier in the MP domain (paper §III-B).

Decision function (baseline, eq. 1):      f(x) = w^T K + b
MP domain (eq. 2-7):
    z+ = MP([w+ + K+, w- + K-, b+], gamma1)
    z- = MP([w+ + K-, w- + K+, b-], gamma1)
    z  = MP([z+, z-], gamma_n),  gamma_n = 1
    p+ = [z+ - z]_+ ;  p- = [z- - z]_+ ;  p = p+ - p-

with K+ = K, K- = -K, w = w+ - w- (w+, w- >= 0 stored separately as in the
hardware ROMs). `p` lives in [-1, 1] and p+ + p- = 1 by the reverse
water-filling property with gamma_n = 1, so p acts as a signed confidence.

All classifier math goes through `mp_exact` so gradients flow (the paper's
"integrated training using MP-based approximation").
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.mp import mp_exact, mp_newton
from repro.core.quant import FixedPointSpec

__all__ = ["MPKernelMachineParams", "init_params", "forward",
           "forward_baseline", "quantize_params"]


class MPKernelMachineParams(NamedTuple):
    w_pos: jax.Array   # (P, C) nonnegative
    w_neg: jax.Array   # (P, C)
    b_pos: jax.Array   # (C,)
    b_neg: jax.Array   # (C,)
    log_gamma1: jax.Array  # scalar, gamma1 = softplus-free exp for positivity


def init_params(key: jax.Array, num_templates: int, num_classes: int,
                gamma1: float = 8.0) -> MPKernelMachineParams:
    k1, k2 = jax.random.split(key)
    scale = 0.5
    return MPKernelMachineParams(
        w_pos=jax.random.uniform(k1, (num_templates, num_classes)) * scale,
        w_neg=jax.random.uniform(k2, (num_templates, num_classes)) * scale,
        b_pos=jnp.zeros((num_classes,)),
        b_neg=jnp.zeros((num_classes,)),
        log_gamma1=jnp.log(jnp.asarray(gamma1)),
    )


def forward(params: MPKernelMachineParams, K: jax.Array,
            gamma_scale: float = 1.0, exact: bool = True) -> jax.Array:
    """K: (B, P) kernel vector -> p: (B, C) signed confidence in [-1, 1].

    gamma_scale multiplies gamma1 — the handle used by gamma annealing
    (anneal from a large, nearly-linear MP towards the target gamma).

    ``exact=False`` solves the MP reductions with the fixed-iteration
    monotone-Newton scheme instead of the sort-based closed form — the
    non-differentiable inference hot path (the serving readout runs it for
    every slot on every chunk; sorts are the slow part on CPU and would be
    on the TPU VPU too). Training keeps the default exact solver for its
    custom VJP.
    """
    wp = jax.nn.relu(params.w_pos)  # keep the ROM entries nonnegative
    wn = jax.nn.relu(params.w_neg)
    gamma1 = jnp.exp(params.log_gamma1) * gamma_scale
    Kp = K[:, :, None]          # (B, P, 1)
    Kn = -K[:, :, None]
    solve = mp_exact if exact else mp_newton

    # operand lists: 2P + 1 entries reduced by MP along the last axis
    def z_of(a, b, bias):  # a, b: (P, C); pairs (a_i + K_i, b_i - K_i)
        ops = jnp.concatenate([a[None] + Kp, b[None] + Kn], axis=1)  # (B,2P,C)
        bias_col = jnp.broadcast_to(bias[None, None, :],
                                    (ops.shape[0], 1, ops.shape[2]))
        ops = jnp.concatenate([ops, bias_col], axis=1)  # (B, 2P+1, C)
        return solve(jnp.moveaxis(ops, 1, -1), gamma1)  # (B, C)

    z_pos = z_of(wp, wn, params.b_pos)      # MP([w+ + K, w- - K, b+])
    z_neg = z_of(wn, wp, params.b_neg)      # MP([w+ - K, w- + K, b-])
    # normalize: z = MP([z+, z-], gamma_n=1)
    z = solve(jnp.stack([z_pos, z_neg], axis=-1), 1.0)
    p_pos = jax.nn.relu(z_pos - z)
    p_neg = jax.nn.relu(z_neg - z)
    return p_pos - p_neg


def quantize_params(params: MPKernelMachineParams,
                    rom_spec: FixedPointSpec,
                    operand_spec: FixedPointSpec):
    """Integer ROM contents for the fixed-point hardware twin
    (``repro.core.fixed``): w+/w- are relu'd (the hardware ROMs store
    nonnegative entries, exactly as ``forward`` enforces), quantized onto
    the 8-bit ``rom_spec`` grid, then shift-aligned onto the 10-bit
    ``operand_spec`` grid the MP adders run at (power-of-two scales, so the
    alignment is a bit shift). Biases quantize directly at operand scale.
    Returns ``(wp_q, wn_q, bpos_q, bneg_q)`` int32 arrays at
    ``operand_spec.exp``.

    HOST-side lowering (numpy, concrete params only): program compilation
    must be able to run while a jit trace is active — e.g. the lazy
    ``fixed_program()`` cache populating inside a jitted closure's first
    session step — and any jnp op here would be staged into that trace."""
    import numpy as np

    k = rom_spec.exp - operand_spec.exp

    def quant(x, spec):
        # f32 multiply-by-reciprocal, exactly like FixedPointSpec.quantize
        # on device — the ROM codes must not depend on which host lowered
        # them (pow2 reciprocals are exact; round is half-to-even in both)
        q = np.round(np.asarray(x, np.float32)
                     * np.float32(1.0 / spec.scale))
        return np.clip(q, spec.qmin, spec.qmax).astype(np.int64)

    def align(q):
        # shifts on host ints: left exact, right floors like the shifter
        return (q << k if k >= 0 else q >> (-k)).astype(np.int32)

    wp_q = align(quant(np.maximum(np.asarray(params.w_pos), 0.0), rom_spec))
    wn_q = align(quant(np.maximum(np.asarray(params.w_neg), 0.0), rom_spec))
    bpos_q = quant(params.b_pos, operand_spec).astype(np.int32)
    bneg_q = quant(params.b_neg, operand_spec).astype(np.int32)
    return wp_q, wn_q, bpos_q, bneg_q


def forward_baseline(w: jax.Array, b: jax.Array, K: jax.Array) -> jax.Array:
    """Full-precision template kernel machine, eq. (1): the 'Normal SVM'."""
    return K @ w + b
