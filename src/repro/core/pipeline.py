"""Unified in-filter pipeline: audio in, class decisions out (paper Fig. 1).

``InFilterPipeline`` packs everything the deployed classifier needs —
filter-bank config, precomputed FIR taps, trained MP kernel-machine weights,
and the feature standardization statistics — into one pytree-serializable
object with two entry points:

* ``predict(x)``: one-shot ``audio (B, N) -> p (B, C)``. The whole multirate
  bank -> HWR/accumulate -> standardize -> MP kernel machine path traces as
  a single computation, so ``jax.jit(pipeline.predict)`` compiles the full
  audio->confidence graph in one unit (the "only classified data leaves the
  device" deployment mode).

* ``init_state(batch)`` / ``step(state, chunk)``: stateful streaming. The
  state carries, per octave, the FIR delay-line registers (the last
  ``max(bp_taps, lp_taps) - 1`` input samples), the decimator phase (global
  sample parity), and the running per-band accumulators — exactly the
  FPGA's zeroed-register streaming semantics, so arbitrarily long audio
  classifies in memory that does not grow with stream length. Feeding a
  signal chunk-by-chunk reproduces the one-shot band outputs sample-for-
  sample (identical FIR windows -> identical MP solves); only the
  accumulator summation order differs, so parity holds to float32
  round-off rather than bitwise. Exception: with ``quant_bits`` set,
  fake_quant scales by the chunk-local amax instead of the whole-signal
  amax, so quantized streaming only matches a deployment whose
  quantization window equals the chunking (see ROADMAP: carry a running
  amax in StreamingState).

Chunk lengths may vary call-to-call (jit retraces per length); within a
call the octave-level valid lengths are data-dependent scalars handled with
masking + dynamic slices, so ``step`` is fully jit-able.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kernel_machine as km
from repro.core import filterbank as fbm
from repro.core.filterbank import FilterBank, FilterBankConfig
from repro.core.quant import fake_quant

__all__ = ["InFilterPipeline", "StreamingState"]


class StreamingState(NamedTuple):
    """Streaming registers carried across chunks (all per-stream-batch B).

    delays:   per octave, (B, T-1) with T = max(bp_taps, lp_taps): the last
              T-1 samples of that octave's input signal (zeros at start —
              the FPGA's cleared register bank).
    consumed: per octave, () int32: octave samples seen so far. Its parity
              is the ÷2 decimator phase; it also dates the stream.
    acc:      (B, P) running renormalized per-band accumulators.
    """
    delays: tuple
    consumed: tuple
    acc: jax.Array


@jax.tree_util.register_pytree_node_class
class InFilterPipeline:
    """Config + taps + trained params + standardization in one pytree."""

    def __init__(self, config: FilterBankConfig, bp_taps: tuple,
                 lp_taps: tuple, mu: jax.Array, sigma: jax.Array,
                 clf: km.MPKernelMachineParams):
        self.config = config
        self.bp_taps = tuple(bp_taps)    # per octave: (F, M)
        self.lp_taps = tuple(lp_taps)    # per ÷2 stage: (M_lp,)
        self.mu = mu                     # (P,)
        self.sigma = sigma               # (P,)
        self.clf = clf

    # -- pytree protocol (config is static aux data; arrays are leaves) ----

    def tree_flatten(self):
        children = (self.bp_taps, self.lp_taps, self.mu, self.sigma, self.clf)
        return children, self.config

    @classmethod
    def tree_unflatten(cls, config, children):
        return cls(config, *children)

    # -- construction -------------------------------------------------------

    @classmethod
    def from_filterbank(cls, fb: FilterBank, clf: km.MPKernelMachineParams,
                        mu: jax.Array, sigma: jax.Array) -> "InFilterPipeline":
        return cls(fb.config, fb.bp_by_octave, fb.lp_filters,
                   jnp.asarray(mu), jnp.asarray(sigma), clf)

    @classmethod
    def fit(cls, config: FilterBankConfig, x_train, y_train,
            num_classes: int, train_cfg=None):
        """Extract features, standardize, train the MP kernel machine, and
        pack the deployable pipeline. Returns (pipeline, loss_trace)."""
        from repro.core import trainer  # lazy: trainer pulls in optimizers
        if train_cfg is None:
            train_cfg = trainer.TrainConfig()
        fb = FilterBank(config)
        x_train = jnp.asarray(x_train)
        s = jax.jit(fb.accumulate)(x_train)
        mu = jnp.mean(s, axis=0)
        sigma = jnp.std(s, axis=0, ddof=1) + 1e-6
        K = (s - mu) / sigma
        params, losses = trainer.train(K, jnp.asarray(y_train), num_classes,
                                       train_cfg)
        return cls.from_filterbank(fb, params, mu, sigma), losses

    # -- one-shot ------------------------------------------------------------

    @property
    def num_bands(self) -> int:
        return self.config.num_filters

    def features(self, x: jax.Array) -> jax.Array:
        """audio (B, N) -> standardized kernel vector Phi (B, P)."""
        s = fbm.multirate_accumulate(x, self.bp_taps, self.lp_taps,
                                     self.config)
        return (s - self.mu) / self.sigma

    def predict(self, x: jax.Array) -> jax.Array:
        """audio (B, N) -> signed per-class confidence p (B, C) in [-1, 1]."""
        return km.forward(self.clf, self.features(x))

    # -- streaming ------------------------------------------------------------

    @property
    def _delay_len(self) -> int:
        return max(self.config.bp_taps, self.config.lp_taps) - 1

    def init_state(self, batch: int, dtype=jnp.float32) -> StreamingState:
        c = self.config
        T1 = self._delay_len
        return StreamingState(
            delays=tuple(jnp.zeros((batch, T1), dtype)
                         for _ in range(c.num_octaves)),
            consumed=tuple(jnp.zeros((), jnp.int32)
                           for _ in range(c.num_octaves)),
            acc=jnp.zeros((batch, c.num_filters), dtype),
        )

    def step(self, state: StreamingState,
             chunk: jax.Array) -> tuple[StreamingState, jax.Array]:
        """Consume one (B, L) chunk; return (state', p (B, C)).

        p is the decision from all evidence so far — after the last chunk it
        matches ``predict`` over the concatenated signal to f32 round-off,
        EXCEPT under ``quant_bits``, where fake_quant's chunk-local amax
        scale breaks parity with the one-shot global scale (see NOTE below).
        """
        c = self.config
        if c.quant_bits is not None:
            # NOTE: fake_quant scales by the chunk's own amax, so quantized
            # streaming is only bit-faithful when the chunking matches the
            # deployment's quantization window.
            chunk = fake_quant(chunk, c.quant_bits)
        T1 = self._delay_len
        x_o = chunk
        l_max = chunk.shape[1]              # static per-call octave capacity
        n_o = jnp.asarray(chunk.shape[1], jnp.int32)   # dynamic valid count
        delays, consumed, parts = [], [], []
        for o in range(c.num_octaves):
            # splice the delay-line registers in front of the chunk; in-chunk
            # sample p sits at buf position T1 + p with its full FIR history
            buf = jnp.concatenate([state.delays[o], x_o], axis=1)
            y = fbm.bank_fir(buf, self.bp_taps[o], c)[..., T1:]  # (B, F, l_max)
            pos = jax.lax.broadcasted_iota(jnp.int32, y.shape, y.ndim - 1)
            hwr = jnp.where(pos < n_o, jnp.maximum(y, 0.0), 0.0)
            parts.append(jnp.sum(hwr, axis=-1) * (2.0 ** o))     # (B, F)
            # register updates: last T1 *valid* samples become the new delay
            delays.append(jax.lax.dynamic_slice_in_dim(buf, n_o, T1, axis=1))
            consumed.append(state.consumed[o] + n_o)
            if o < c.num_octaves - 1:
                y_lp = fbm.single_fir(buf, self.lp_taps[o], c)[..., T1:]
                # ÷2 decimator: keep even GLOBAL indices. The first kept
                # in-chunk index is the stream-parity phase of this octave.
                start = jnp.remainder(state.consumed[o], 2)
                l_next = (l_max + 1) // 2
                y_pad = jnp.pad(y_lp, ((0, 0), (0, 2 * l_next + 1 - l_max)))
                kept = jax.lax.dynamic_slice_in_dim(
                    y_pad, start, 2 * l_next, axis=1)[:, ::2]
                x_o = kept                                       # (B, l_next)
                n_o = jnp.maximum(0, (n_o - start + 1) // 2)
                l_max = l_next
        acc = state.acc + jnp.concatenate(parts, axis=-1)
        state = StreamingState(tuple(delays), tuple(consumed), acc)
        phi = (acc - self.mu) / self.sigma
        return state, km.forward(self.clf, phi)

    def stream(self, chunks) -> jax.Array:
        """Convenience: classify an iterable of (B, L_i) chunks; returns the
        final p. Memory stays fixed regardless of total stream length."""
        state = None
        p = None
        for chunk in chunks:
            chunk = jnp.asarray(chunk)
            if state is None:
                state = self.init_state(chunk.shape[0], chunk.dtype)
            state, p = self.step(state, chunk)
        if p is None:
            raise ValueError("stream() needs at least one chunk")
        return p
