"""Unified in-filter pipeline: audio in, class decisions out (paper Fig. 1).

``InFilterPipeline`` packs everything the deployed classifier needs —
filter-bank config, precomputed FIR taps, trained MP kernel-machine weights,
and the feature standardization statistics — into one pytree-serializable
object with ONE entry point:

* ``apply(x, state=None)``: the unified surface.

  - **Stateless** (``state=None``): one-shot ``audio (B, N) -> p (B, C)``.
    The whole multirate bank -> HWR/accumulate -> standardize -> MP kernel
    machine path traces as a single computation, so
    ``jax.jit(InFilterPipeline.apply)`` compiles the full audio->confidence
    graph in one unit (the "only classified data leaves the device"
    deployment mode). ``predict(x)`` remains as an alias.

  - **Stateful** (``state=`` a :class:`SessionState`): slot-batched
    streaming. The state packs S logical sensor streams ("slots") into
    stacked ``(S, ...)`` registers — per-octave FIR delay lines (the last
    ``max(bp_taps, lp_taps) - 1`` input samples), per-slot decimator phases
    (octave sample parities), running per-band accumulators, the running
    signal amax used for deployment-faithful quantization, per-slot sample
    counts, and a per-slot active mask. Feeding a chunk returns
    ``(p, state')``; arbitrarily long audio classifies in memory that does
    not grow with stream length — exactly the FPGA's zeroed-register
    streaming semantics, multiplexed S-wide.

Per-slot ``valid`` counts let one compiled call carry streams of different
chunk lengths (shorter rows are zero-padded and masked); a slot with zero
valid samples — or ``active=False`` — is provably inert: its registers are
bit-identical before and after the call, and it never perturbs other slots
(every op in the step is row-independent).

With ``quant_bits`` set, the chunk is quantized against the RUNNING amax
carried in the state (updated before scaling), matching the one-shot path's
per-stream amax semantics: once a stream's running amax equals its global
amax (e.g. the peak sits in the first chunk, or the state was seeded with a
calibrated ``amax``), streamed band outputs are bit-identical to the
one-shot deployment.

Chunk lengths may vary call-to-call (jit retraces per length — the serving
layer buckets lengths to powers of two to bound this); within a call the
octave-level valid lengths are data-dependent per-slot vectors handled with
masking + per-row dynamic slices, so the step is fully jit-able.

With ``config.numerics == "fixed"`` BOTH paths execute the bit-true int32
hardware twin (``repro.core.fixed``): the audio quantizes onto the static
calibrated ADC grid and every stage runs in add/sub/shift/compare integer
arithmetic, dequantizing only at the output surface. The session path
carries every register as an integer in the fixed-point grid (8-bit
octave-signal delay lines, 32-bit accumulators, running max |code|), and —
because the ADC grid is static and integer addition is associative —
chunked streaming decisions are bit-for-bit equal to one-shot ``apply(x)``
from the FIRST chunk, with no peak-seen caveat (docs/numerics.md). Both
stream impls stream fixed numerics: ``stream_impl="pallas"`` routes the
identical integer step through the VMEM-resident kernel
(``kernels.fir_mp_stream_q``) with bit-for-bit the same registers and
decisions. Note the program lowering is host-side, so ``jax.jit`` a closure
over a *concrete* pipeline (``jit(lambda x, st: pipe.apply(x, st))``) or
the compiled program (``prog = pipe.fixed_program(); jit(lambda x:
fixed.predict(prog, x))``) rather than ``InFilterPipeline.apply`` with the
pipeline as a traced pytree argument — that raises a TypeError with this
guidance.

Migration (PR 2): ``init_state``/``step``/``StreamingState`` — the one-
cohort streaming API — remain as thin shims over the session path and will
go away; new code should use ``init_session``/``apply``/``SessionState``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kernel_machine as km
from repro.core import filterbank as fbm
from repro.core import mp as mp_mod
from repro.core.filterbank import FilterBank, FilterBankConfig

__all__ = [
    "InFilterPipeline",
    "SessionState",
    "StreamingState",
    "clear_slots",
    "set_active",
    "take_slot",
    "put_slot",
]


class SessionState(NamedTuple):
    """Slot-batched streaming registers: S logical streams, stacked (S, ...).

    delays:   per octave, (S, T-1) with T = max(bp_taps, lp_taps): the last
              T-1 samples of that octave's input signal (zeros at start —
              the FPGA's cleared register bank).
    consumed: per octave, (S,) int32: octave samples seen so far, per slot.
              Its parity is that slot's ÷2 decimator phase.
    acc:      (S, P) running renormalized per-band accumulators.
    amax:     (S,) running max |input| per slot — the symmetric quantization
              range under ``quant_bits`` (and free calibration telemetry
              without). Seed it via ``init_session(amax=...)`` for
              bit-faithful quantized streaming from the first chunk.
    count:    (S,) int32 input samples consumed per slot (== consumed[0];
              kept separately so serving code never reaches into octaves).
    active:   (S,) bool slot admission mask. Inactive slots are forced to
              zero valid samples, so they are inert no matter what the
              padded chunk rows contain.
    """
    delays: tuple
    consumed: tuple
    acc: jax.Array
    amax: jax.Array
    count: jax.Array
    active: jax.Array

    @property
    def capacity(self) -> int:
        return self.acc.shape[0]


class StreamingState(NamedTuple):
    """DEPRECATED one-cohort streaming state (pre-session API).

    Kept so existing ``init_state``/``step`` callers run unchanged; it is a
    view of :class:`SessionState` where all B streams share one age (scalar
    per-octave ``consumed``). ``amax`` is the per-stream running amax that
    now backs quantized streaming (the old chunk-local scaling is gone).
    """
    delays: tuple
    consumed: tuple
    acc: jax.Array
    amax: jax.Array


@jax.tree_util.register_pytree_node_class
class InFilterPipeline:
    """Config + taps + trained params + standardization in one pytree."""

    def __init__(self, config: FilterBankConfig, bp_taps: tuple,
                 lp_taps: tuple, mu: jax.Array, sigma: jax.Array,
                 clf: km.MPKernelMachineParams):
        self.config = config
        self.bp_taps = tuple(bp_taps)    # per octave: (F, M)
        self.lp_taps = tuple(lp_taps)    # per ÷2 stage: (M_lp,)
        self.mu = mu                     # (P,)
        self.sigma = sigma               # (P,)
        self.clf = clf
        self._fixed_prog = None          # lazy compile_pipeline cache

    # -- pytree protocol (config is static aux data; arrays are leaves) ----

    def tree_flatten(self):
        children = (self.bp_taps, self.lp_taps, self.mu, self.sigma, self.clf)
        return children, self.config

    @classmethod
    def tree_unflatten(cls, config, children):
        return cls(config, *children)

    # -- construction -------------------------------------------------------

    @classmethod
    def from_filterbank(cls, fb: FilterBank, clf: km.MPKernelMachineParams,
                        mu: jax.Array, sigma: jax.Array) -> "InFilterPipeline":
        return cls(fb.config, fb.bp_by_octave, fb.lp_filters,
                   jnp.asarray(mu), jnp.asarray(sigma), clf)

    @classmethod
    def fit(cls, config: FilterBankConfig, x_train, y_train,
            num_classes: int, train_cfg=None):
        """Extract features, standardize, train the MP kernel machine, and
        pack the deployable pipeline. Returns (pipeline, loss_trace)."""
        from repro.core import trainer  # lazy: trainer pulls in optimizers
        if train_cfg is None:
            train_cfg = trainer.TrainConfig()
        fb = FilterBank(config)
        x_train = jnp.asarray(x_train)
        s = jax.jit(fb.accumulate)(x_train)
        mu = jnp.mean(s, axis=0)
        sigma = jnp.std(s, axis=0, ddof=1) + 1e-6
        K = (s - mu) / sigma
        params, losses = trainer.train(K, jnp.asarray(y_train), num_classes,
                                       train_cfg)
        return cls.from_filterbank(fb, params, mu, sigma), losses

    # -- unified entry point -------------------------------------------------

    def apply(self, x: jax.Array, state: SessionState | None = None, *,
              valid: jax.Array | None = None, return_features: bool = False):
        """The one inference surface: stateless one-shot or stateful session.

        Stateless (``state=None``): ``x (B, N) -> p (B, C)`` signed per-class
        confidence in [-1, 1]; with ``return_features=True`` returns
        ``(p, phi)`` where ``phi (B, P)`` is the standardized kernel vector.

        Stateful: ``x (S, L)`` is one chunk per slot of ``state`` (use zeros
        for slots with nothing to feed and pass per-slot ``valid`` sample
        counts; ``None`` means every row is fully valid). Returns
        ``(p, state')`` — note output-first, unlike the deprecated ``step``
        — or ``(p, phi, state')`` with ``return_features=True``. ``p`` is
        each slot's decision from all evidence so far.

        Numerics and the parity guarantee: with ``numerics="float"``
        (default) both paths run the f32 engine; streamed decisions match
        one-shot to f32 round-off, bit-for-bit when the whole signal fits
        one call, and under ``quant_bits`` bit-for-bit once the running
        amax has seen the stream's peak. With ``numerics="fixed"`` both
        paths run the bit-true int32 hardware twin and streamed decisions
        (and every register) are bit-for-bit equal to one-shot ``apply(x)``
        under ANY chunking, from the first chunk — the ADC grid is static
        and integer addition is associative (docs/numerics.md).
        """
        x = jnp.asarray(x)
        if state is None:
            if self.config.numerics == "fixed":
                from repro.core import fixed
                p, phi = fixed.predict(
                    self.fixed_program(), x,
                    use_pallas=self.config.use_pallas)
                return (p, phi) if return_features else p
            phi = self.features(x)
            p = km.forward(self.clf, phi, exact=False)
            return (p, phi) if return_features else p
        if isinstance(state, StreamingState):
            raise TypeError(
                "apply() takes a SessionState (init_session); for the "
                "deprecated one-cohort StreamingState keep using step(), or "
                "migrate: state = pipe.init_session(S); p, state = "
                "pipe.apply(chunk, state)")
        if x.ndim != 2 or x.shape[0] != state.capacity:
            raise ValueError(
                f"chunk shape {x.shape} does not match session capacity "
                f"{state.capacity}: expected ({state.capacity}, L)")
        if valid is None:
            valid = jnp.full((state.capacity,), x.shape[1], jnp.int32)
        state, p, phi = self._session_step(state, x, valid)
        if return_features:
            return p, phi, state
        return p, state

    # -- one-shot ------------------------------------------------------------

    @property
    def num_bands(self) -> int:
        return self.config.num_filters

    def features(self, x: jax.Array,
                 amax: jax.Array | None = None) -> jax.Array:
        """audio (B, N) -> standardized kernel vector Phi (B, P).

        Under ``quant_bits`` the signal is quantized per stream row (scale =
        that row's amax, or the explicit ``amax`` override), matching the
        session streaming path's running-amax semantics. With
        ``numerics='fixed'`` this dequantizes the integer path's 8-bit
        standardized kernel vector instead (pow2-snapped sigma; see
        ``repro.core.fixed``).
        """
        if self.config.numerics == "fixed":
            if amax is not None:
                # the fixed program quantizes on its STATIC calibrated ADC
                # grid; silently dropping a per-call amax override would
                # hand back wrong-scale features
                raise ValueError(
                    "features(amax=...) has no effect under "
                    "numerics='fixed' — the ADC full-scale is the static "
                    "config.fixed_amax / fixed_program(amax=...) "
                    "calibration")
            from repro.core import fixed
            prog = self.fixed_program()
            _, phi_q, _ = fixed.infer_q(
                prog, fixed.quantize_signal(prog, x),
                use_pallas=self.config.use_pallas)
            return prog.phi.dequantize(phi_q)
        s = fbm.multirate_accumulate(x, self.bp_taps, self.lp_taps,
                                     self.config, amax=amax)
        return (s - self.mu) / self.sigma

    def fixed_program(self, **overrides):
        """The compiled integer program for this pipeline (lazy, cached for
        the no-override call — the program ``apply``/``features`` and the
        session streaming path execute). ``overrides`` pass through to
        ``repro.core.fixed.compile_pipeline`` (amax, signal_bits,
        internal_bits, phi_amax, octave_gains, calibration_audio) and
        return a fresh, UNcached program; use :meth:`calibrate_fixed` to
        make a calibrated program the pinned one."""
        from repro.core import fixed
        if overrides:
            return fixed.compile_pipeline(self, **overrides)
        if self._fixed_prog is None:
            self._fixed_prog = fixed.compile_pipeline(self)
        return self._fixed_prog

    def calibrate_fixed(self, calibration_audio, **overrides):
        """Compile the integer program calibrated on ``calibration_audio``
        (ADC full-scale + per-octave register pre-gains) and PIN it as this
        pipeline's cached program, so one-shot ``apply``/``features`` AND
        the integer session-streaming path all execute the calibrated
        datapath. Returns the program."""
        from repro.core import fixed
        self._fixed_prog = fixed.compile_pipeline(
            self, calibration_audio=calibration_audio, **overrides)
        return self._fixed_prog

    def predict(self, x: jax.Array) -> jax.Array:
        """audio (B, N) -> signed per-class confidence p (B, C) in [-1, 1].

        Alias for stateless ``apply(x)``."""
        return self.apply(x)

    # -- session streaming ---------------------------------------------------

    @property
    def _delay_len(self) -> int:
        return max(self.config.bp_taps, self.config.lp_taps) - 1

    def init_session(self, capacity: int, dtype=jnp.float32, *,
                     amax: jax.Array | float | None = None,
                     active: jax.Array | None = None) -> SessionState:
        """Fresh slot-batched state for ``capacity`` logical streams.

        ``amax`` pre-seeds the running quantization range (scalar or (S,)
        — e.g. a calibrated ADC full-scale) so quantized streaming is
        bit-faithful from the first chunk. ``active`` sets the admission
        mask (default: all slots active; a StreamServer starts all-inactive
        and admits via open()).

        With ``numerics="fixed"`` every register is an integer on the
        fixed-point grid (``dtype`` is ignored): delay lines hold 8-bit
        octave-signal codes, ``acc`` the 32-bit accumulators, and ``amax``
        the running max |ADC code| — telemetry only, since the ADC grid is
        static (a float ``amax`` seed is converted to codes)."""
        c = self.config
        T1 = self._delay_len
        if c.numerics == "fixed":
            dtype = jnp.int32
        if amax is None:
            amax_arr = jnp.zeros((capacity,), dtype)
        elif c.numerics == "fixed":
            amax_arr = jnp.broadcast_to(
                self.fixed_program().signal.quantize(jnp.abs(
                    jnp.asarray(amax, jnp.float32))), (capacity,))
        else:
            amax_arr = jnp.broadcast_to(
                jnp.asarray(amax, dtype), (capacity,))
        if active is None:
            active_arr = jnp.ones((capacity,), bool)
        else:
            active_arr = jnp.asarray(active, bool)
        return SessionState(
            delays=tuple(jnp.zeros((capacity, T1), dtype)
                         for _ in range(c.num_octaves)),
            consumed=tuple(jnp.zeros((capacity,), jnp.int32)
                           for _ in range(c.num_octaves)),
            acc=jnp.zeros((capacity, c.num_filters), dtype),
            amax=amax_arr,
            count=jnp.zeros((capacity,), jnp.int32),
            active=active_arr,
        )

    def _session_step(self, state: SessionState, chunk: jax.Array,
                      valid: jax.Array):
        """Consume one (S, L) slot-batched chunk with per-slot valid counts.

        Returns (state', p (S, C), phi (S, P)). Every operation is row-
        independent, and rows with zero valid samples keep bit-identical
        registers (delay slice at offset 0 re-reads the old delays; masked
        HWR sums vanish), which is what makes padding slots inert.

        ``config.stream_impl`` selects the octave-cascade hot path: "xla"
        splices [delay, chunk] per octave in XLA (below); "pallas" runs
        ``kernels.fir_mp_stream``, a stateful kernel that carries the delay
        lines / accumulators / running amax in VMEM scratch across its
        chunk-block grid. Both run the same solver math in the same blocked
        accumulation order, so in interpret mode they agree bit-for-bit.
        """
        c = self.config
        if c.numerics == "fixed":
            return self._session_step_fixed(state, chunk, valid)
        S, L = chunk.shape
        n = jnp.where(state.active, jnp.asarray(valid, jnp.int32), 0)
        if L == 0:
            # a zero-length chunk is a pure readout: no register moves
            phi = (state.acc - self.mu) / self.sigma
            return state, km.forward(self.clf, phi, exact=False), phi
        pos0 = jax.lax.broadcasted_iota(jnp.int32, (S, L), 1)
        chunk = jnp.where(pos0 < n[:, None], chunk, 0)
        if c.stream_impl == "pallas":
            state = self._cascade_pallas(state, chunk, n)
        elif c.stream_impl == "xla":
            state = self._cascade_xla(state, chunk, n)
        else:
            # a typo must not silently serve XLA results as "the kernel"
            raise ValueError(f"unknown stream_impl {c.stream_impl!r}: "
                             "expected 'xla' or 'pallas'")
        phi = (state.acc - self.mu) / self.sigma
        return state, km.forward(self.clf, phi, exact=False), phi

    def _session_step_fixed(self, state: SessionState, chunk: jax.Array,
                            valid: jax.Array):
        """The int32 session step: quantize the chunk onto the static ADC
        grid, zero invalid positions, and run the integer cascade — every
        register stays on the fixed-point grid and chunked decisions are
        bit-for-bit the one-shot program's. The kernel selection happens
        HERE: "xla" runs ``fixed.session_step_q``; "pallas" runs the
        VMEM-resident integer kernel (``kernels.fir_mp_stream_q``) —
        bit-identical registers and decisions either way."""
        from repro.core import fixed
        c = self.config
        if c.stream_impl not in ("xla", "pallas"):
            raise ValueError(f"unknown stream_impl {c.stream_impl!r}: "
                             "expected 'xla' or 'pallas'")
        prog = self.fixed_program()
        S, L = chunk.shape
        n = jnp.where(state.active, jnp.asarray(valid, jnp.int32), 0)
        if L == 0:
            xq = jnp.zeros((S, 0), jnp.int32)
        else:
            xq = fixed.quantize_signal(prog, chunk)
            pos0 = jax.lax.broadcasted_iota(jnp.int32, (S, L), 1)
            xq = jnp.where(pos0 < n[:, None], xq, 0)
        if c.stream_impl == "pallas":
            state, p_q, phi_q = self._cascade_pallas_fixed(prog, state,
                                                           xq, n)
        else:
            state, p_q, phi_q = fixed.session_step_q(prog, state, xq, n)
        return state, prog.out_spec.dequantize(p_q), \
            prog.phi.dequantize(phi_q)

    def _cascade_pallas_fixed(self, prog, state: SessionState,
                              xq: jax.Array, n: jax.Array):
        """Integer octave cascade through the stateful int Pallas kernel
        (``kernels.fir_mp_stream_q``): the same registers-in-VMEM state
        machine as the float ``_cascade_pallas``, on the fixed-point
        datapath — bit-for-bit equal to ``fixed.session_step_q``."""
        from repro.core import fixed
        c = self.config
        if c.mode != "mp":
            raise ValueError(
                f"stream_impl='pallas' runs the MP streaming kernel; it has "
                f"no {c.mode!r}-mode variant (use stream_impl='xla')")
        if xq.shape[1] == 0:
            # a zero-length chunk is a pure readout: no register moves
            p_q, phi_q = fixed.readout_q(prog, state.acc)
            return state, p_q, phi_q
        from repro.kernels import fir_mp_stream_q
        delays, consumed, acc, amax = fir_mp_stream_q(
            prog, xq, n, state.delays, state.consumed, state.acc,
            state.amax)
        state = state._replace(delays=delays, consumed=consumed, acc=acc,
                               amax=amax, count=state.count + n)
        p_q, phi_q = fixed.readout_q(prog, acc)
        return state, p_q, phi_q

    def _cascade_pallas(self, state: SessionState, chunk: jax.Array,
                        n: jax.Array) -> SessionState:
        """Octave cascade through the stateful Pallas streaming kernel."""
        c = self.config
        if c.mode != "mp":
            raise ValueError(
                f"stream_impl='pallas' runs the MP streaming kernel; it has "
                f"no {c.mode!r}-mode variant (use stream_impl='xla')")
        from repro.kernels import fir_mp_stream
        if c.quant_bits is not None:
            # quantization needs the post-update running amax BEFORE the
            # filter pass, so it cannot fold into the kernel's single sweep
            amax = jnp.maximum(state.amax, jnp.max(jnp.abs(chunk), axis=-1))
            chunk = fbm.quant_signal(chunk, c, amax=amax)
            update_amax = False
        else:
            # raw path: the octave-0 kernel folds the running-amax update
            # into its grid sweep (one HBM read serves filter AND calibrate)
            amax = state.amax
            update_amax = True
        delays, consumed, acc, amax = fir_mp_stream(
            chunk, n, state.delays, state.consumed, state.acc, amax,
            self.bp_taps, self.lp_taps, c.gamma_f, solver=c.solver,
            update_amax=update_amax)
        return SessionState(delays, consumed, acc, amax,
                            state.count + n, state.active)

    def _cascade_xla(self, state: SessionState, chunk: jax.Array,
                     n: jax.Array) -> SessionState:
        """Octave cascade in XLA: per-octave [delay, chunk] splice."""
        c = self.config
        S, L = chunk.shape
        # running amax update precedes scaling: chunk i is quantized against
        # max over chunks 0..i, converging to the one-shot global scale
        amax = jnp.maximum(state.amax, jnp.max(jnp.abs(chunk), axis=-1))
        if c.quant_bits is not None:
            chunk = fbm.quant_signal(chunk, c, amax=amax)
        T1 = self._delay_len
        M_bp, M_lp = c.bp_taps, c.lp_taps
        x_o, n_o = chunk, n
        l_max = L                          # static per-call octave capacity
        delays, consumed, parts = [], [], []
        for o in range(c.num_octaves):
            # splice the delay-line registers in front of the chunk; in-chunk
            # sample p sits at buf position T1 + p with its full FIR history.
            # Valid-mode FIR on the trailing window skips the T1 prefix
            # solves the padded form would compute and throw away — the
            # kept positions are bitwise the same.
            buf = jnp.concatenate([state.delays[o], x_o], axis=1)
            y = fbm.bank_fir_valid(buf[:, T1 - (M_bp - 1):],
                                   self.bp_taps[o], c)       # (S, F, l_max)
            # blocked HWR accumulation: the shared reduction order that
            # keeps this path bit-identical to one-shot accumulate (single
            # chunk) and to the Pallas streaming kernel's grid-carried sums
            parts.append(fbm.hwr_accumulate(y, n_o[:, None])
                         * (2.0 ** o))                           # (S, F)
            # register update: the last T1 *valid* samples become the new
            # delay line — per-slot offsets, so vmap the dynamic slice
            delays.append(jax.vmap(
                lambda b, s: jax.lax.dynamic_slice_in_dim(b, s, T1, axis=0)
            )(buf, n_o))
            consumed.append(state.consumed[o] + n_o)
            if o < c.num_octaves - 1:
                # ÷2 decimator: keep even GLOBAL indices. The first kept
                # in-chunk index is each slot's stream-parity phase.
                start = jnp.remainder(state.consumed[o], 2)       # (S,)
                l_next = (l_max + 1) // 2
                buf_lp = buf[:, T1 - (M_lp - 1):]
                if c.mode == "mp" and not c.use_pallas:
                    # solve ONLY the kept positions: per-slot stride-2
                    # window gather (kept sample k of slot s ends at
                    # buf_lp[s, start_s + 2k + M_lp - 1]); halves the LP
                    # solve count vs filter-then-discard, bit-identically.
                    buf_lp = jnp.pad(buf_lp, ((0, 0), (0, 1)))
                    widx = (2 * jnp.arange(l_next)[:, None]
                            + jnp.arange(M_lp)[None, :])   # (l_next, M_lp)
                    win = jax.vmap(lambda r, s: r[s + widx])(buf_lp, start)
                    kept = mp_mod._mp_dot_fast(
                        win, self.lp_taps[o][::-1], c.gamma_f, c.solver)
                else:
                    y_lp = fbm.single_fir_valid(buf_lp, self.lp_taps[o],
                                                c)        # (S, l_max)
                    y_pad = jnp.pad(y_lp,
                                    ((0, 0), (0, 2 * l_next + 1 - l_max)))
                    kept = jax.vmap(
                        lambda r, s: jax.lax.dynamic_slice_in_dim(
                            r, s, 2 * l_next, axis=0)
                    )(y_pad, start)[:, ::2]
                x_o = kept                                        # (S, l_next)
                n_o = jnp.maximum(0, (n_o - start + 1) // 2)
                l_max = l_next
        acc = state.acc + jnp.concatenate(parts, axis=-1)
        return SessionState(tuple(delays), tuple(consumed), acc, amax,
                            state.count + n, state.active)

    # -- deprecated one-cohort streaming shims -------------------------------

    def init_state(self, batch: int, dtype=jnp.float32) -> StreamingState:
        """DEPRECATED: use ``init_session``. One cohort of ``batch`` streams
        that all advance in lockstep (scalar per-octave ages)."""
        sess = self.init_session(batch, dtype)
        return StreamingState(
            delays=sess.delays,
            consumed=tuple(jnp.zeros((), jnp.int32) for _ in sess.consumed),
            acc=sess.acc,
            amax=sess.amax,
        )

    def step(self, state: StreamingState,
             chunk: jax.Array) -> tuple[StreamingState, jax.Array]:
        """DEPRECATED: use ``apply``. Consume one (B, L) chunk; return
        (state', p (B, C)).

        Thin shim over the session step: lifts the cohort state to a
        SessionState (broadcast ages), runs the unified path, and collapses
        back (all rows advance by the same chunk, so ages stay uniform).
        p matches ``predict`` over the concatenated signal to f32 round-off;
        under ``quant_bits`` the running-amax state quantizes exactly like
        one-shot deployment once the stream's peak has been seen.
        """
        chunk = jnp.asarray(chunk)
        B = chunk.shape[0]
        sess = SessionState(
            delays=state.delays,
            consumed=tuple(jnp.broadcast_to(cns, (B,)).astype(jnp.int32)
                           for cns in state.consumed),
            acc=state.acc,
            amax=state.amax,
            count=jnp.broadcast_to(state.consumed[0], (B,)).astype(jnp.int32),
            active=jnp.ones((B,), bool),
        )
        sess, p, _ = self._session_step(
            sess, chunk, jnp.full((B,), chunk.shape[1], jnp.int32))
        state = StreamingState(sess.delays,
                               tuple(cns[0] for cns in sess.consumed),
                               sess.acc, sess.amax)
        return state, p

    def stream(self, chunks, *, dtype=None) -> jax.Array:
        """Convenience: classify an iterable of (B, L_i) chunks; returns the
        final p. Memory stays fixed regardless of total stream length.

        ``dtype`` fixes the state/register dtype up front (``None``: the
        first chunk's dtype). Chunks whose dtype disagrees raise instead of
        letting XLA silently upcast the registers mid-stream.
        """
        state = None
        p = None
        for chunk in chunks:
            chunk = jnp.asarray(chunk)
            if dtype is None:
                dtype = chunk.dtype
            if chunk.dtype != jnp.dtype(dtype):
                raise ValueError(
                    f"stream() chunk dtype {chunk.dtype} != stream dtype "
                    f"{jnp.dtype(dtype)}; cast explicitly (mixed-dtype "
                    "chunks would silently upcast the streaming registers)")
            if state is None:
                state = self.init_state(chunk.shape[0], dtype)
            state, p = self.step(state, chunk)
        if p is None:
            raise ValueError("stream() needs at least one chunk")
        return p


# ---------------------------------------------------------------------------
# slot surgery helpers (host-side admission bookkeeping for serving code)
# ---------------------------------------------------------------------------


def clear_slots(state: SessionState, slots) -> SessionState:
    """Zero the per-stream registers of ``slots`` (fresh-tenant admission:
    a reused slot must not leak the previous stream). Leaves ``active``
    untouched — pair with :func:`set_active`."""
    slots = jnp.asarray(slots)
    return SessionState(
        delays=tuple(d.at[slots].set(0) for d in state.delays),
        consumed=tuple(cns.at[slots].set(0) for cns in state.consumed),
        acc=state.acc.at[slots].set(0),
        amax=state.amax.at[slots].set(0),
        count=state.count.at[slots].set(0),
        active=state.active,
    )


def set_active(state: SessionState, slots, value: bool) -> SessionState:
    """Flip the admission mask for ``slots``."""
    return state._replace(
        active=state.active.at[jnp.asarray(slots)].set(bool(value)))


def take_slot(state: SessionState, slot: int) -> SessionState:
    """Extract one slot's registers as an unbatched row tree (for
    checkpointing an evicted session)."""
    return jax.tree.map(lambda a: a[slot], state)


def put_slot(state: SessionState, slot: int, row: SessionState) -> SessionState:
    """Insert a row tree (from :func:`take_slot`) back into ``slot``."""
    return jax.tree.map(lambda a, r: a.at[slot].set(r), state, row)
