"""Bit-true fixed-point "hardware twin" of the in-filter pipeline.

The paper's headline (§III-A, §V, Tables I/II) is that the whole in-filter
kernel machine runs MULTIPLIERLESS: 8-bit fixed-point signals/weights, a
10-bit internal path, and a datapath built from adders, shifters and
comparators only. ``repro.core.quant`` simulates that with float tensors
carrying quantized values (the QAT proxy); this module EXECUTES it: every
stage — signal quantization, the multirate MP FIR bank, HWR + accumulate,
standardization, and the MP kernel-machine readout — runs on int32 arrays
using only add/subtract/compare/shift, the paper's primitive set.

Design rules that make the integer path provably equal to a float
simulation of the same datapath (the parity contract tested in
tests/test_fixed.py and pinned by the int golden fixtures):

* Every format is a :class:`repro.core.quant.FixedPointSpec` — a POWER-OF-
  TWO scale — so converting between formats is a bit shift: left shifts are
  exact, right shifts are floor rounding, identically in int32 and in a
  float carrier (``floor(ldexp(q, -k))``).
* The MP solve is integer bisection (:func:`fxp_mp_bisect`): halving is an
  arithmetic right shift, the constraint sum is an exact integer sum, and
  the result is the smallest grid point z with ``sum [L - z]_+ <= gamma`` —
  a deterministic LSB-exact answer, not an approximation to tolerance.
* Integer addition is associative, so HWR accumulation needs none of the
  fixed-tree ordering machinery the float path carries
  (``filterbank.hwr_accumulate``): any reduction order gives the same bits.

Carriers: all ``fxp_*`` kernels are dtype-generic. Called on int32 they run
the real integer datapath (what ``benchmarks/hardware_cost.py`` censuses);
called on float32 arrays carrying integer values they run the fake-quant
float twin, and the two agree BIT-FOR-BIT as long as magnitudes stay below
2**24 (f32's exact-integer range; the esc10-mp accumulators peak around
2**23 at 1 s of audio).

The deployment preview is driven through ``FilterBankConfig``:
``numerics="fixed"`` routes ``InFilterPipeline.apply``/``predict`` and
``FilterBank.accumulate`` through :func:`compile_pipeline` /
:func:`compile_bank` programs (static int32 taps, ROMs and shift tables
derived from the float pipeline plus a calibrated ADC full-scale
``fixed_amax``). Session streaming runs the same program chunk-by-chunk
through :func:`session_step_q` — every ``SessionState`` register carried as
an integer in the fixed-point grid, with chunked decisions bit-for-bit
equal to one-shot :func:`infer_q` (docs/numerics.md has the argument).
With ``stream_impl="pallas"`` the identical step runs through the
VMEM-resident integer kernel (``repro.kernels.fir_mp_stream_q``) —
bit-for-bit the same registers and decisions.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import FixedPointSpec, pow2_spec_for

__all__ = [
    "FixedBankProgram",
    "FixedClassifier",
    "FixedPointProgram",
    "OctaveStage",
    "calibrate_octave_gains",
    "compile_bank",
    "compile_pipeline",
    "fxp_fir_bank",
    "fxp_fir_shift_add",
    "fxp_hwr_accumulate",
    "fxp_mp_bisect",
    "fxp_mp_dot",
    "fxp_mpabs",
    "bank_accumulate_q",
    "standardize_q",
    "classifier_q",
    "infer_q",
    "quantize_signal",
    "predict",
    "readout_q",
    "session_step_q",
    "shift_left",
    "shift_right",
    "rescale",
]


# ---------------------------------------------------------------------------
# carrier-generic shift/add/compare primitives
# ---------------------------------------------------------------------------


def _floatp(x) -> bool:
    return jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)


def _c(a, like):
    """Coerce a program constant onto the carrier dtype of ``like``."""
    a = jnp.asarray(a)
    return a.astype(jnp.float32) if _floatp(like) else a.astype(jnp.int32)


def shift_right(q, k):
    """Arithmetic (floor) shift right by ``k`` >= 0 (static int or int
    array — shift amounts are always integers, never carrier values).

    Int carrier: ``q >> k``. Float carrier: ``floor(ldexp(q, -k))`` — ldexp
    scales by an exact power of two and floor matches the arithmetic
    shift's round-toward-minus-infinity on negatives.
    """
    if _floatp(q):
        return jnp.floor(jnp.ldexp(q, -jnp.asarray(k, jnp.int32)))
    return jnp.right_shift(q, k)


def shift_left(q, k):
    """Shift left by ``k`` >= 0 (exact in both carriers)."""
    if _floatp(q):
        return jnp.ldexp(q, jnp.asarray(k, jnp.int32))
    return jnp.left_shift(q, k)


def rescale(q, k):
    """Multiply a q-array by 2**k: left shift for k >= 0, floor right shift
    for k < 0 — the format-conversion primitive (pow2 scales only)."""
    if isinstance(k, (int, np.integer)):
        k = int(k)
        return shift_left(q, k) if k >= 0 else shift_right(q, -k)
    k = jnp.asarray(k)
    return jnp.where(k >= 0, shift_left(q, jnp.maximum(k, 0)),
                     shift_right(q, jnp.maximum(-k, 0)))


def _clamp(q, spec: FixedPointSpec):
    """Saturating clamp onto a spec's representable range (compare/select)."""
    return jnp.clip(q, spec.qmin, spec.qmax)


def _relu(q):
    return jnp.maximum(q, 0)


# ---------------------------------------------------------------------------
# integer MP solve (bisection: add/compare/shift only)
# ---------------------------------------------------------------------------


def bisect_iters(gamma_q: int) -> int:
    """Iterations until the integer bisection interval collapses to one LSB:
    the initial width is gamma_q, halving each step."""
    return max(2, int(gamma_q).bit_length() + 2)


def fxp_mp_bisect(L, gamma_q, iters: int):
    """z = MP(L, gamma) on the fixed-point grid, along the last axis.

    Identical structure to :func:`repro.core.mp.mp_bisect`, but the midpoint
    is an arithmetic right shift (floor) and the constraint sum is an exact
    integer sum, so the loop is LSB-deterministic. Returns the smallest grid
    point ``z`` reached with ``sum_i [L_i - z]_+ <= gamma_q`` — within one
    LSB above the real-valued root.
    """
    gamma_q = _c(gamma_q, L)
    hi = jnp.max(L, axis=-1)
    lo = hi - gamma_q

    def body(_, state):
        lo, hi = state
        mid = shift_right(lo + hi, 1)
        h = jnp.sum(_relu(L - mid[..., None]), axis=-1)
        too_low = h > gamma_q
        lo = jnp.where(too_low, mid, lo)
        hi = jnp.where(too_low, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return hi


def fxp_mpabs(u, gamma_q, iters: int):
    """MP([u; -u], gamma) without materializing the concatenation (the
    eq. 9 operand form): the constraint splits into the u branch plus the
    -u branch. |u| = max(u, -u) is a compare/select, an allowed primitive."""
    gamma_q = _c(gamma_q, u)
    a = jnp.abs(u)
    hi = jnp.max(a, axis=-1)
    lo = hi - gamma_q

    def body(_, state):
        lo, hi = state
        mid = shift_right(lo + hi, 1)
        h = (jnp.sum(_relu(u - mid[..., None]), axis=-1)
             + jnp.sum(_relu(-u - mid[..., None]), axis=-1))
        too_low = h > gamma_q
        lo = jnp.where(too_low, mid, lo)
        hi = jnp.where(too_low, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return hi


def fxp_mp_dot(win, w, gamma_q, iters: int, spec: FixedPointSpec):
    """Multiplierless inner product (eq. 9) on the fixed-point grid:
    <w, win> ~= mpabs(w + win) - mpabs(w - win). Operand sums saturate onto
    ``spec`` (the 10-bit internal path) before the solve."""
    u = _clamp(w + win, spec)
    v = _clamp(w - win, spec)
    return fxp_mpabs(u, gamma_q, iters) - fxp_mpabs(v, gamma_q, iters)


# ---------------------------------------------------------------------------
# integer FIR primitives
# ---------------------------------------------------------------------------


def fxp_fir_bank(x, H, gamma_q, iters: int, spec: FixedPointSpec,
                 chunk_n: Optional[int] = 1024, pad: bool = True):
    """Multi-filter MP FIR on the integer grid: x (..., N), H (F, M) ->
    (..., F, N). Causal zero-padded form (matches the one-shot float path's
    ``mp_conv1d_bank(pad=True)`` window contents); long signals solve in
    ``chunk_n``-position blocks exactly like the float bank.

    ``pad=False`` computes ONLY the fully-covered positions — output p's
    window is ``x[p .. p+M-1]``, shape (..., F, N-M+1). The integer session
    step splices its delay-line registers in front of the chunk and uses
    this form; every window solve is an independent LSB-deterministic
    bisection, so shared positions match the padded form bit-for-bit."""
    H = _c(H, x)
    F, M = H.shape
    lead = x.shape[:-1]
    N = x.shape[-1] if pad else x.shape[-1] - M + 1
    x2 = x.reshape(-1, x.shape[-1])
    hr = H[:, ::-1].reshape(F, 1, 1, M)

    def solve(win):  # (B, Q, M) -> (F, B, Q)
        return fxp_mp_dot(win[None], hr, gamma_q, iters, spec)

    xp = jnp.pad(x2, ((0, 0), (M - 1, 0))) if pad else x2
    if chunk_n is None or N <= chunk_n:
        idx = jnp.arange(N)[:, None] + jnp.arange(M)[None, :]
        y = solve(xp[:, idx])
    else:
        Q = chunk_n
        n_blocks = -(-N // Q)
        xp = jnp.pad(xp, ((0, 0), (0, n_blocks * Q + M - 1 - xp.shape[1])))
        idx = jnp.arange(Q)[:, None] + jnp.arange(M)[None, :]

        def one(start):
            seg = jax.lax.dynamic_slice_in_dim(xp, start, Q + M - 1, axis=1)
            return solve(seg[:, idx])

        ys = jax.lax.map(one, jnp.arange(n_blocks) * Q)  # (nc, F, B, Q)
        y = jnp.moveaxis(ys, 0, 2).reshape(F, x2.shape[0], n_blocks * Q)
        y = y[..., :N]
    return jnp.moveaxis(y, 0, 1).reshape(*lead, F, N)


def _csd(v: int) -> list:
    """Canonical signed-digit decomposition: v == sum(sign << bit) with no
    two adjacent nonzero digits — the minimal shift/add realization of a
    constant multiplier."""
    v = int(v)
    terms = []
    k = 0
    while v != 0:
        if v & 1:
            r = 2 - (v & 3)  # +1 when v % 4 == 1, -1 when v % 4 == 3
            terms.append((r, k))
            v -= r
        v >>= 1
        k += 1
    return terms


def fxp_fir_shift_add(x, h_q: np.ndarray, pad: bool = True):
    """Constant-coefficient FIR as trace-time-unrolled CSD shift/adds:
    y(n) = sum_k h[k] x(n-k) with every tap expanded into signed powers of
    two — the classic multiplierless realization of a MAC FIR. ``h_q`` must
    be STATIC host integers (the ROM contents). Output q-values carry scale
    2**(x.exp + h.exp). ``pad=False`` keeps only the fully-covered positions
    (shape ``(..., N-M+1)``) — the session step's delay-splice form."""
    h_q = np.asarray(h_q)
    assert h_q.ndim == 1
    M = h_q.shape[0]
    N = x.shape[-1] if pad else x.shape[-1] - M + 1
    xp = x if not pad else \
        jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(M - 1, 0)])
    y = jnp.zeros(x.shape[:-1] + (N,), x.dtype)
    for k_tap in range(M):
        sk = jax.lax.slice_in_dim(xp, M - 1 - k_tap, M - 1 - k_tap + N,
                                  axis=x.ndim - 1)
        for sign, bit in _csd(int(h_q[k_tap])):
            t = shift_left(sk, bit)
            y = y + t if sign > 0 else y - t
    return y


def fxp_hwr_accumulate(y, valid=None):
    """s = sum_n [y_n]_+ over the last axis. Integer adds are associative,
    so no blocked-reduction ordering is needed for bit parity (unlike the
    float path's ``filterbank.hwr_accumulate``) — and chunked streaming
    accumulation is EXACTLY one-shot accumulation, not merely close.

    ``valid`` (broadcastable to ``y.shape[:-1]``, trailing axis dropped —
    e.g. ``n[:, None]`` for a (S, F, l) bank output) zeroes positions >=
    valid before the sum, so padded slots contribute no-op terms."""
    h = _relu(y)
    if valid is not None:
        pos = jax.lax.broadcasted_iota(jnp.int32, y.shape, y.ndim - 1)
        h = jnp.where(pos < jnp.asarray(valid)[..., None], h, 0)
    return jnp.sum(h, axis=-1)


# ---------------------------------------------------------------------------
# compiled programs: static taps/ROMs/shift tables + per-stage specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OctaveStage:
    """One octave's static datapath: band-pass taps + the anti-aliasing
    low-pass feeding the next octave, with their internal-path formats.

    ``in_spec`` is this octave's 8-bit signal register format. Its exp may
    sit below the ADC's by a calibrated static pre-gain (a left shift baked
    into the design, see ``calibrate_octave_gains``): deeper octaves carry
    progressively smaller signals, and without the per-octave gain their
    content drowns in the shared full-scale grid."""
    in_spec: FixedPointSpec    # 8-bit octave signal register format
    bp_q: jax.Array            # (F, M) int32 taps, pre-aligned to band_spec
    band_spec: FixedPointSpec  # 10-bit internal format of the BP MP stage
    sig_shift: int             # in_spec.exp - band_spec.exp (align x; a
    #                            negative value floors input LSBs away —
    #                            the 10-bit adder path's width limit)
    gamma_bp: int              # gamma_f on the band grid
    iters_bp: int
    acc_shift: int             # (band exp + octave renorm) -> acc exp, >= 0
    lp_q: Optional[jax.Array]  # (1, M_lp) int32, None for the last octave
    lp_spec: Optional[FixedPointSpec]
    lp_sig_shift: int          # in_spec.exp - lp_spec.exp
    gamma_lp: int
    iters_lp: int
    lp_out_shift: int          # lp_spec.exp -> next octave's register exp
    # MAC (shift-add) mode extras: raw ROM taps + product-grid rescales
    bp_rom: Optional[np.ndarray] = None   # (F, M) host ints at rom exp
    lp_rom: Optional[np.ndarray] = None
    bp_prod_shift: int = 0     # (in+rom exp) -> band exp
    lp_prod_shift: int = 0     # (in+rom exp) -> lp_spec exp


@dataclasses.dataclass(frozen=True)
class FixedBankProgram:
    """Integer multirate filter bank: quantized signal in, 32-bit per-band
    accumulators out. Built once from static taps by :func:`compile_bank`."""
    mode: str                  # "mp" | "mac"
    signal: FixedPointSpec     # 8-bit ADC format (exp from fixed_amax)
    acc: FixedPointSpec        # 32-bit accumulator format
    octaves: tuple             # OctaveStage per octave

    @property
    def num_filters(self) -> int:
        return sum(int(o.bp_q.shape[0]) for o in self.octaves)


@dataclasses.dataclass(frozen=True)
class FixedClassifier:
    """MP kernel machine ROMs on the classifier operand grid."""
    wp_q: jax.Array            # (P, C) int32 at spec.exp
    wn_q: jax.Array
    bpos_q: jax.Array          # (C,)
    bneg_q: jax.Array
    spec: FixedPointSpec       # 10-bit operand/output format
    phi_shift: int             # phi.exp - spec.exp (align K, usually >= 0)
    gamma1_q: int
    gamman_q: int
    iters1: int
    iters_n: int


@dataclasses.dataclass(frozen=True)
class FixedPointProgram:
    """The full audio -> decision integer program: bank + standardization
    shift table + classifier. ``infer_q`` executes it.

    Standardization is shift-add: 1/sigma (folded with the acc->phi grid
    change) is approximated per band by a two-term CSD reciprocal
    ``2**k1 + sign * 2**k2`` (<= ~9% relative error vs ~41% for a single
    power of two), so ``phi = (s - mu) / sigma`` costs two shifts and one
    add/select per band — no divider on the FPGA."""
    bank: FixedBankProgram
    mu_q: jax.Array            # (P,) int32 at bank.acc.exp
    phi_shift_q: jax.Array     # (P,) int32: leading CSD shift per band
    phi_shift2_q: jax.Array    # (P,) int32: second CSD term's shift
    phi_sign2_q: jax.Array     # (P,) int32 in {-1, 0, +1}: second term sign
    phi: FixedPointSpec        # 8-bit standardized-feature format
    clf: FixedClassifier

    @property
    def signal(self) -> FixedPointSpec:
        return self.bank.signal

    @property
    def out_spec(self) -> FixedPointSpec:
        return self.clf.spec


def _plan_bits(cfg):
    """Per-stage bitwidth plan from a FilterBankConfig: 8-bit signals and
    weights, a (bits+2)-bit internal path — the paper's 8/10-bit split."""
    signal_bits = cfg.quant_bits if cfg.quant_bits is not None else 8
    return signal_bits, signal_bits, signal_bits + 2


def calibrate_octave_gains(cfg, lp_taps, audio,
                           max_gain: int = 8) -> tuple:
    """Static per-octave pre-gains (left shifts) from calibration audio.

    The multirate cascade halves bandwidth per octave, so deep-octave
    signals are usually far below the ADC full-scale; a fixed-point design
    bakes a power-of-two gain into each octave's register format to recover
    the lost resolution (block-format calibration — still shift-only).
    Runs the FLOAT LP cascade on ``audio`` and returns
    ``g_o = clip(floor(log2(full_scale / peak_o)), 0, max_gain)`` per
    octave, with ``g_0 = 0`` (the ADC grid is the ADC grid).
    """
    from repro.core import filterbank as fbm
    fcfg = cfg._replace(numerics="float", quant_bits=None)
    amax = float(cfg.fixed_amax)
    x_o = jnp.asarray(np.atleast_2d(np.asarray(audio, np.float32)))
    gains = [0]
    for o in range(cfg.num_octaves - 1):
        x_o = fbm.single_fir(x_o, jnp.asarray(lp_taps[o]), fcfg)[..., ::2]
        peak = float(jnp.max(jnp.abs(x_o)))
        g = 0 if peak <= 0 else math.floor(math.log2(amax / peak))
        gains.append(int(np.clip(g, 0, max_gain)))
    return tuple(gains)


def compile_bank(cfg, bp_taps, lp_taps, *, amax: float | None = None,
                 signal_bits: int | None = None,
                 internal_bits: int | None = None,
                 octave_gains=None) -> FixedBankProgram:
    """Lower a float filter bank (per-octave (F, M) bp taps + per-stage lp
    taps) to the integer program. ``amax`` is the ADC full-scale
    (default ``cfg.fixed_amax``): a STATIC calibration, like real hardware —
    inputs beyond it saturate. ``octave_gains`` (from
    :func:`calibrate_octave_gains`) bakes a left-shift pre-gain into each
    octave's register format; default all-zero (flat full-scale grids)."""
    if cfg.mode not in ("mp", "mac"):
        raise ValueError(f"numerics='fixed' supports mode 'mp' or 'mac', "
                         f"got {cfg.mode!r}")
    sb, tb, ib = _plan_bits(cfg)
    if signal_bits is not None:
        sb = tb = signal_bits
        ib = signal_bits + 2
    if internal_bits is not None:
        ib = internal_bits
    amax = float(cfg.fixed_amax if amax is None else amax)
    signal = pow2_spec_for(None, sb, amax=amax)
    num_oct = cfg.num_octaves
    if octave_gains is None:
        octave_gains = (0,) * num_oct
    octave_gains = tuple(int(g) for g in octave_gains)
    if len(octave_gains) != num_oct or octave_gains[0] != 0 \
            or any(g < 0 for g in octave_gains):
        raise ValueError(f"octave_gains must be {num_oct} ints >= 0 with "
                         f"gains[0] == 0, got {octave_gains}")
    # octave signal registers: the ADC format shifted down by the pre-gain
    in_specs = [FixedPointSpec(bits=sb, exp=signal.exp - g)
                for g in octave_gains]

    def stage_for(h: np.ndarray, in_spec: FixedPointSpec):
        """(taps ROM ints + exp, internal spec) for one FIR stage. The
        internal exp covers |h|max + the octave register range (the MP
        operand range u = h +- x) at ``ib`` bits; ROM taps align onto it by
        shift."""
        h = np.asarray(h, np.float64)
        rom_spec = pow2_spec_for(h, tb)
        rom = np.clip(np.round(h / rom_spec.scale),
                      rom_spec.qmin, rom_spec.qmax).astype(np.int64)
        if cfg.mode == "mp":
            cover = float(np.max(np.abs(h))) + in_spec.amax
        else:
            # shift-add MAC: output range is the l1 gain times the signal
            cover = max(float(np.sum(np.abs(h), axis=-1).max()), 1.0) \
                * in_spec.amax
        spec = pow2_spec_for(None, ib, amax=cover)
        # align ROM onto the internal grid (host-side floor shift)
        k = rom_spec.exp - spec.exp
        aligned = rom * (1 << k) if k >= 0 else rom >> (-k)
        return rom, rom_spec, spec, np.asarray(aligned, np.int32)

    pre = []
    for o in range(num_oct):
        bp_rom, bp_rom_spec, band_spec, bp_q = stage_for(bp_taps[o],
                                                         in_specs[o])
        if o < num_oct - 1:
            lp_rom, lp_rom_spec, lp_spec, lp_q = stage_for(
                np.asarray(lp_taps[o])[None, :], in_specs[o])
        else:
            lp_rom = lp_rom_spec = lp_spec = lp_q = None
        pre.append((bp_rom, bp_rom_spec, band_spec, bp_q,
                    lp_rom, lp_rom_spec, lp_spec, lp_q))
    # accumulator grid: the finest (band exp + octave renorm) across octaves
    acc_exp = min(p[2].exp + o for o, p in enumerate(pre))
    acc = FixedPointSpec(bits=32, exp=acc_exp)
    stages = []
    for o, (bp_rom, bp_rom_spec, band_spec, bp_q,
            lp_rom, lp_rom_spec, lp_spec, lp_q) in enumerate(pre):
        in_spec = in_specs[o]
        gamma_bp = max(1, int(round(cfg.gamma_f / band_spec.scale)))
        if lp_spec is not None:
            gamma_lp = max(1, int(round(cfg.gamma_f / lp_spec.scale)))
            lp_sig_shift = in_spec.exp - lp_spec.exp
            lp_out_shift = lp_spec.exp - in_specs[o + 1].exp
            lp_prod_shift = (in_spec.exp + lp_rom_spec.exp) - lp_spec.exp
        else:
            gamma_lp = 1
            lp_sig_shift = lp_out_shift = lp_prod_shift = 0
        stages.append(OctaveStage(
            in_spec=in_spec, bp_q=bp_q, band_spec=band_spec,
            sig_shift=in_spec.exp - band_spec.exp,
            gamma_bp=gamma_bp, iters_bp=bisect_iters(gamma_bp),
            acc_shift=band_spec.exp + o - acc_exp,
            lp_q=lp_q, lp_spec=lp_spec, lp_sig_shift=lp_sig_shift,
            gamma_lp=gamma_lp, iters_lp=bisect_iters(gamma_lp),
            lp_out_shift=lp_out_shift,
            bp_rom=bp_rom, lp_rom=lp_rom,
            bp_prod_shift=(in_spec.exp + bp_rom_spec.exp) - band_spec.exp,
            lp_prod_shift=lp_prod_shift,
        ))
    return FixedBankProgram(mode=cfg.mode, signal=signal, acc=acc,
                            octaves=tuple(stages))


def compile_pipeline(pipe, *, amax: float | None = None,
                     signal_bits: int | None = None,
                     internal_bits: int | None = None,
                     phi_amax: float = 4.0,
                     octave_gains=None,
                     calibration_audio=None) -> FixedPointProgram:
    """Lower a trained ``InFilterPipeline`` to the full integer program.

    Standardization becomes subtract-and-shift (two-term CSD reciprocal
    sigma — exact standardization would need a true divider); mu and the
    classifier ROMs quantize onto their stage grids. ``calibration_audio``
    (host array) derives the ADC full-scale (when ``amax`` is None) and the
    per-octave register pre-gains; or pass ``octave_gains`` directly. Must
    be called with CONCRETE (non-traced) pipeline arrays.

    The one program serves BOTH execution shapes with one parity contract:
    one-shot :func:`infer_q` and chunked :func:`session_step_q` produce
    identical integer codes (any chunking, from the first chunk), and each
    runs bit-identically on int32 or float-carried integers.
    """
    from repro.core import kernel_machine as km

    cfg = pipe.config
    if any(isinstance(leaf, jax.core.Tracer) for leaf in jax.tree.leaves(
            (pipe.bp_taps, pipe.lp_taps, pipe.mu, pipe.sigma, pipe.clf))):
        raise TypeError(
            "compile_pipeline needs CONCRETE pipeline arrays — it bakes the "
            "ROMs and shift tables host-side. Do not jit "
            "InFilterPipeline.apply/predict/features with numerics='fixed' "
            "directly (the pipeline pytree's leaves become tracers); "
            "precompile instead:  prog = pipe.fixed_program(); "
            "jax.jit(lambda x: fixed.predict(prog, x))")
    if calibration_audio is not None:
        cal = np.asarray(calibration_audio, np.float32)
        if amax is None:
            amax = float(np.max(np.abs(cal))) or 1.0
        if octave_gains is None:
            octave_gains = calibrate_octave_gains(
                cfg._replace(fixed_amax=amax), pipe.lp_taps, cal)
    bank = compile_bank(cfg, [np.asarray(t) for t in pipe.bp_taps],
                        [np.asarray(t) for t in pipe.lp_taps],
                        amax=amax, signal_bits=signal_bits,
                        internal_bits=internal_bits,
                        octave_gains=octave_gains)
    _, tb, ib = _plan_bits(cfg)
    if signal_bits is not None:
        tb, ib = signal_bits, signal_bits + 2
    if internal_bits is not None:
        ib = internal_bits

    mu = np.asarray(pipe.mu, np.float64)
    sigma = np.asarray(pipe.sigma, np.float64)
    mu_q = np.asarray(np.round(mu / bank.acc.scale), np.int32)
    # phi = (s - mu) * g with g = 2**(acc.exp - phi.exp) / sigma, realized
    # as the best two-term CSD approximation g ~= 2**k1 + sign * 2**k2
    phi = pow2_spec_for(None, tb, amax=phi_amax)
    g = math.ldexp(1.0, bank.acc.exp - phi.exp) / np.maximum(sigma, 1e-30)
    k1s, k2s, s2s = [], [], []
    for gi in g:
        best = (math.inf, 0, 0, 0)
        for k1 in (math.floor(math.log2(gi)), math.ceil(math.log2(gi))):
            for sign, k2 in [(0, k1 - 1)] + [(s, k1 - d)
                                             for s in (-1, 1)
                                             for d in range(1, 7)]:
                approx = math.ldexp(1.0, k1) + sign * math.ldexp(1.0, k2)
                err = abs(approx - gi) / gi
                if err < best[0]:
                    best = (err, k1, k2, sign)
        k1s.append(best[1]); k2s.append(best[2]); s2s.append(best[3])
    phi_shift_q = np.asarray(k1s, np.int32)
    phi_shift2_q = np.asarray(k2s, np.int32)
    phi_sign2_q = np.asarray(s2s, np.int32)

    # classifier operand grid: cover |w|max + |phi|max at internal bits
    wp = np.maximum(np.asarray(pipe.clf.w_pos, np.float64), 0.0)
    wn = np.maximum(np.asarray(pipe.clf.w_neg, np.float64), 0.0)
    bias_amax = float(max(np.max(np.abs(np.asarray(pipe.clf.b_pos))),
                          np.max(np.abs(np.asarray(pipe.clf.b_neg))), 0.0))
    wmax = float(max(wp.max(), wn.max(), 1e-6))
    cover = max(wmax + phi.amax, bias_amax, 1.0)
    cspec = pow2_spec_for(None, ib, amax=cover)
    rom_spec = pow2_spec_for(None, tb, amax=max(wmax, bias_amax, 1e-6))
    wp_q, wn_q, bpos_q, bneg_q = km.quantize_params(pipe.clf, rom_spec,
                                                    cspec)
    gamma1 = float(np.exp(np.asarray(pipe.clf.log_gamma1)))
    gamma1_q = max(1, int(round(gamma1 / cspec.scale)))
    gamman_q = max(1, int(round(1.0 / cspec.scale)))
    clf = FixedClassifier(
        wp_q=wp_q, wn_q=wn_q, bpos_q=bpos_q, bneg_q=bneg_q, spec=cspec,
        phi_shift=phi.exp - cspec.exp,
        gamma1_q=gamma1_q, gamman_q=gamman_q,
        iters1=bisect_iters(gamma1_q), iters_n=bisect_iters(gamman_q))
    if clf.phi_shift < 0:
        raise ValueError("classifier operand grid coarser than phi grid "
                         f"(phi exp {phi.exp} < operand exp {cspec.exp})")
    return FixedPointProgram(bank=bank, mu_q=mu_q, phi_shift_q=phi_shift_q,
                             phi_shift2_q=phi_shift2_q,
                             phi_sign2_q=phi_sign2_q, phi=phi, clf=clf)


# ---------------------------------------------------------------------------
# program execution (int32 carrier = the hardware twin; float carrier =
# the fake-quant simulation — bit-identical by construction)
# ---------------------------------------------------------------------------


def quantize_signal(prog, x, carrier: str = "int"):
    """ADC: float audio -> signal-format codes. ``carrier="int"`` gives the
    int32 hardware path; ``carrier="float"`` gives float-carried codes for
    the fake-quant twin."""
    signal = prog.signal if isinstance(prog, FixedBankProgram) \
        else prog.bank.signal
    dtype = jnp.int32 if carrier == "int" else jnp.float32
    if carrier not in ("int", "float"):
        raise ValueError(f"carrier must be 'int' or 'float', got {carrier!r}")
    return signal.quantize(x, dtype=dtype)


def bank_accumulate_q(bank: FixedBankProgram, xq, *,
                      use_pallas: bool = False):
    """Quantized signal (B, N) -> 32-bit accumulators (B, P) at
    ``bank.acc``. The integer mirror of ``filterbank.multirate_accumulate``
    (renormalization by 2**octave is folded into ``acc_shift``).

    ``use_pallas`` routes the MP band solves + HWR accumulation through the
    fused integer Pallas kernels (``kernels.fir_mp_bank_q*`` — one
    VMEM-resident signal block per octave), bit-for-bit equal to the XLA
    ``fxp_*`` path; MAC mode always runs the XLA shift-add FIR."""
    if use_pallas and bank.mode == "mp":
        from repro.kernels import fir_mp_bank_q, fir_mp_bank_q_accumulate
    x_o = xq
    parts = []
    for o, st in enumerate(bank.octaves):
        if bank.mode == "mp":
            x_op = rescale(x_o, st.sig_shift)
            if use_pallas:
                parts.append(shift_left(fir_mp_bank_q_accumulate(
                    x_op, st.bp_q, gamma_q=st.gamma_bp, iters=st.iters_bp,
                    qmin=int(st.band_spec.qmin),
                    qmax=int(st.band_spec.qmax)), st.acc_shift))
            else:
                band = fxp_fir_bank(x_op, st.bp_q, st.gamma_bp, st.iters_bp,
                                    st.band_spec)
                parts.append(shift_left(fxp_hwr_accumulate(band),
                                        st.acc_shift))
        else:
            bands = [rescale(fxp_fir_shift_add(x_o, st.bp_rom[f]),
                             st.bp_prod_shift)
                     for f in range(st.bp_rom.shape[0])]
            band = _clamp(jnp.stack(bands, axis=-2), st.band_spec)
            parts.append(shift_left(fxp_hwr_accumulate(band), st.acc_shift))
        if st.lp_q is not None:
            if bank.mode == "mp":
                x_lp = rescale(x_o, st.lp_sig_shift)
                if use_pallas:
                    y_lp = fir_mp_bank_q(
                        x_lp, st.lp_q, gamma_q=st.gamma_lp,
                        iters=st.iters_lp, qmin=int(st.lp_spec.qmin),
                        qmax=int(st.lp_spec.qmax))[..., 0, :]
                else:
                    y_lp = fxp_fir_bank(x_lp, st.lp_q, st.gamma_lp,
                                        st.iters_lp, st.lp_spec)[..., 0, :]
            else:
                y_lp = _clamp(rescale(fxp_fir_shift_add(x_o, st.lp_rom[0]),
                                      st.lp_prod_shift), st.lp_spec)
            # requantize onto the NEXT octave's 8-bit register bank (its
            # exp carries that octave's calibrated pre-gain), then ÷2
            x_o = _clamp(rescale(y_lp, st.lp_out_shift),
                         bank.octaves[o + 1].in_spec)[..., ::2]
    return jnp.concatenate(parts, axis=-1)


def standardize_q(prog: FixedPointProgram, s_q):
    """32-bit accumulators -> 8-bit standardized kernel vector: subtract
    the mu ROM, then the per-band two-term CSD reciprocal-sigma (two
    shifts + one add/select per band)."""
    diff = s_q - _c(prog.mu_q, s_q)
    t1 = rescale(diff, jnp.asarray(prog.phi_shift_q, jnp.int32))
    t2 = rescale(diff, jnp.asarray(prog.phi_shift2_q, jnp.int32))
    s2 = jnp.asarray(prog.phi_sign2_q, jnp.int32)
    phi = jnp.where(s2 > 0, t1 + t2, jnp.where(s2 < 0, t1 - t2, t1))
    return _clamp(phi, prog.phi)


def classifier_q(clf: FixedClassifier, K_q):
    """Integer MP kernel machine (paper eq. 2-7): the same operand layout
    as ``kernel_machine.forward``, solved by integer bisection."""
    K = shift_left(K_q, clf.phi_shift)          # phi grid -> operand grid
    Kp = K[:, :, None]
    Kn = -K[:, :, None]
    wp = _c(clf.wp_q, K_q)
    wn = _c(clf.wn_q, K_q)

    def z_of(a, b, bias):
        ops = jnp.concatenate([_clamp(a[None] + Kp, clf.spec),
                               _clamp(b[None] + Kn, clf.spec)], axis=1)
        bias_col = jnp.broadcast_to(_c(bias, K_q)[None, None, :],
                                    (ops.shape[0], 1, ops.shape[2]))
        ops = jnp.concatenate([ops, bias_col], axis=1)   # (B, 2P+1, C)
        return fxp_mp_bisect(jnp.moveaxis(ops, 1, -1), clf.gamma1_q,
                             clf.iters1)

    z_pos = z_of(wp, wn, clf.bpos_q)
    z_neg = z_of(wn, wp, clf.bneg_q)
    z = fxp_mp_bisect(jnp.stack([z_pos, z_neg], axis=-1), clf.gamman_q,
                      clf.iters_n)
    return _relu(z_pos - z) - _relu(z_neg - z)


def infer_q(prog: FixedPointProgram, xq, *, use_pallas: bool = False):
    """The pure-integer inference program: quantized signal codes in,
    (p_q, phi_q, s_q) codes out. This is the function
    ``benchmarks/hardware_cost.py`` censuses — its jaxpr must contain no
    multiply and no divide (with or without ``use_pallas``, which swaps the
    MP bank solves onto the fused integer Pallas kernels bit-for-bit)."""
    s_q = bank_accumulate_q(prog.bank, xq, use_pallas=use_pallas)
    phi_q = standardize_q(prog, s_q)
    p_q = classifier_q(prog.clf, phi_q)
    return p_q, phi_q, s_q


def predict(prog: FixedPointProgram, x, carrier: str = "int", *,
            use_pallas: bool = False):
    """Float audio (B, N) -> dequantized (p, phi): the deployment-preview
    surface. ``p`` carries scale ``2**clf.spec.exp`` (the [-1, 1] signed
    confidence on the operand grid)."""
    xq = quantize_signal(prog, x, carrier=carrier)
    p_q, phi_q, _ = infer_q(prog, xq, use_pallas=use_pallas)
    return prog.out_spec.dequantize(p_q), prog.phi.dequantize(phi_q)


# ---------------------------------------------------------------------------
# integer session streaming: every SessionState register is an int in the
# fixed-point grid, and chunked execution is bit-for-bit the one-shot
# program (see docs/numerics.md for the exactness argument)
# ---------------------------------------------------------------------------


def readout_q(prog: FixedPointProgram, acc_q):
    """Pure readout from 32-bit accumulator registers: (p_q, phi_q).
    The decision from all evidence so far — what a zero-length session
    chunk (and every chunk's trailing readout) computes."""
    phi_q = standardize_q(prog, acc_q)
    return classifier_q(prog.clf, phi_q), phi_q


def session_step_q(prog: FixedPointProgram, state, chunk_q, n):
    """One slot-batched INTEGER session step: signal codes in, codes out.

    The int32 mirror of the pipeline's XLA session cascade. ``state`` is a
    ``SessionState``-shaped namedtuple whose registers are carried on the
    fixed-point grid: per-octave delay lines hold that octave's 8-bit
    signal-register codes (``OctaveStage.in_spec``), ``acc`` is the 32-bit
    accumulator at ``prog.bank.acc``, and ``amax`` is the running max
    |signal code| (pure calibration telemetry — the ADC grid is STATIC, so
    unlike the float path no quantization scale depends on it). ``chunk_q``
    is (S, L) ADC codes with positions >= ``n`` already zeroed; ``n`` is
    (S,) int32 effective valid counts (active mask applied by the caller).

    Exactness: every band value at a global stream position is one
    LSB-deterministic integer bisection over a window of octave-register
    codes, the delay lines carry those codes losslessly across chunk
    boundaries (zero-initialized registers == the one-shot path's zero
    padding), and integer accumulator addition is associative — so ANY
    chunk partition reproduces the one-shot :func:`infer_q` codes
    bit-for-bit, from the FIRST chunk (no peak-seen caveat). Returns
    ``(state', p_q, phi_q)``.

    Carrier-generic like every ``fxp_*`` kernel: int32 registers run the
    hardware path (what ``benchmarks/hardware_cost.py`` censuses — zero
    multiplies/divides per chunk); float-carried registers run the
    fake-quant twin bit-identically.
    """
    bank = prog.bank
    S, L = chunk_q.shape
    if L == 0:
        p_q, phi_q = readout_q(prog, state.acc)
        return state, p_q, phi_q
    T1 = state.delays[0].shape[1]
    # running amax telemetry: invalid positions are zero codes, so they
    # never raise the max (|code| >= 0 and the register starts at 0)
    amax = jnp.maximum(state.amax, jnp.max(jnp.abs(chunk_q), axis=-1))
    x_o, n_o = chunk_q, n
    l_max = L
    delays, consumed, parts = [], [], []
    for o, st in enumerate(bank.octaves):
        M_bp = st.bp_q.shape[-1]
        # splice the delay registers in front of the chunk: in-chunk
        # position p sits at buf[T1 + p] with its full FIR history
        buf = jnp.concatenate([state.delays[o], x_o], axis=1)
        buf_bp = buf[:, T1 - (M_bp - 1):]
        if bank.mode == "mp":
            band = fxp_fir_bank(rescale(buf_bp, st.sig_shift), st.bp_q,
                                st.gamma_bp, st.iters_bp, st.band_spec,
                                pad=False)                     # (S, F, l_max)
        else:
            bands = [rescale(fxp_fir_shift_add(buf_bp, st.bp_rom[f],
                                               pad=False), st.bp_prod_shift)
                     for f in range(st.bp_rom.shape[0])]
            band = _clamp(jnp.stack(bands, axis=-2), st.band_spec)
        parts.append(shift_left(fxp_hwr_accumulate(band, n_o[:, None]),
                                st.acc_shift))
        # register update: the last T1 *valid* samples become the new delay
        # line (slots with n_o == 0 re-read their old registers: inert)
        delays.append(jax.vmap(
            lambda b, s: jax.lax.dynamic_slice_in_dim(b, s, T1, axis=0)
        )(buf, n_o))
        consumed.append(state.consumed[o] + n_o)
        if st.lp_q is not None:
            M_lp = st.lp_q.shape[-1]
            # ÷2 decimator keeps even GLOBAL positions; each slot's phase
            # is its octave-sample parity (bit-and, not a divider)
            start = jnp.bitwise_and(state.consumed[o], 1)          # (S,)
            l_next = (l_max + 1) // 2
            buf_lp = buf[:, T1 - (M_lp - 1):]
            if bank.mode == "mp":
                # solve ONLY the kept positions: stride-2 window gather
                # (kept sample k of slot s ends at start_s + 2k + M_lp - 1)
                xw = jnp.pad(rescale(buf_lp, st.lp_sig_shift),
                             ((0, 0), (0, 1)))
                widx = ((jnp.arange(l_next) << 1)[:, None]
                        + jnp.arange(M_lp)[None, :])       # (l_next, M_lp)
                win = jax.vmap(lambda r, s: r[s + widx])(xw, start)
                kept = fxp_mp_dot(win, _c(st.lp_q[0, ::-1], xw),
                                  st.gamma_lp, st.iters_lp, st.lp_spec)
            else:
                y_lp = _clamp(rescale(fxp_fir_shift_add(buf_lp, st.lp_rom[0],
                                                        pad=False),
                                      st.lp_prod_shift), st.lp_spec)
                y_pad = jnp.pad(y_lp, ((0, 0), (0, 2 * l_next + 1 - l_max)))
                kept = jax.vmap(
                    lambda r, s: jax.lax.dynamic_slice_in_dim(
                        r, s, 2 * l_next, axis=0)
                )(y_pad, start)[:, ::2]
            # requantize onto the next octave's 8-bit register bank (its
            # exp carries that octave's calibrated pre-gain)
            x_o = _clamp(rescale(kept, st.lp_out_shift),
                         bank.octaves[o + 1].in_spec)
            # kept-count update: arithmetic shift, not an integer divide
            # (the census must stay divider-free)
            n_o = jnp.right_shift(jnp.maximum(n_o - start + 1, 0), 1)
            l_max = l_next
    acc = state.acc + jnp.concatenate(parts, axis=-1)
    state = state._replace(delays=tuple(delays), consumed=tuple(consumed),
                           acc=acc, amax=amax, count=state.count + n)
    p_q, phi_q = readout_q(prog, acc)
    return state, p_q, phi_q
