"""MP-aware training (paper §III, §V): backprop *through* the MP
approximation with gamma annealing, so the learned weights absorb the
water-filling approximation error instead of fighting it.

The classifier output p is a signed confidence in [-1, 1] (one-vs-all per
class, as in the paper's Tables III/IV). We train with a margin (hinge-like)
loss on p directly, optionally with quantization-aware fake-quant on the
weights (8-bit fixed point deployment).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kernel_machine as km
from repro.core.quant import fake_quant

__all__ = ["TrainConfig", "TrainState", "train", "evaluate"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    num_steps: int = 400
    lr: float = 0.5
    momentum: float = 0.9
    batch_size: int = 64
    gamma_anneal_start: float = 4.0   # gamma_scale annealed start -> 1.0
    gamma_anneal_steps: int = 150
    weight_decay: float = 1e-5
    quant_bits: int | None = None     # QAT bit width for weights
    margin: float = 0.5
    seed: int = 0


class TrainState(NamedTuple):
    params: km.MPKernelMachineParams
    velocity: km.MPKernelMachineParams
    step: jax.Array


def _maybe_quant(params: km.MPKernelMachineParams, bits: int | None):
    if bits is None:
        return params
    return params._replace(
        w_pos=fake_quant(params.w_pos, bits),
        w_neg=fake_quant(params.w_neg, bits),
        b_pos=fake_quant(params.b_pos, bits),
        b_neg=fake_quant(params.b_neg, bits),
    )


def loss_fn(params, K, y_onehot, gamma_scale, cfg: TrainConfig):
    """Margin loss on the signed confidence p; y in {-1, +1} one-vs-all."""
    p = km.forward(_maybe_quant(params, cfg.quant_bits), K, gamma_scale)
    target = 2.0 * y_onehot - 1.0  # {0,1} -> {-1,+1}
    # hinge on the signed confidence with margin
    loss = jnp.mean(jax.nn.relu(cfg.margin - target * p))
    wd = cfg.weight_decay * (jnp.sum(params.w_pos ** 2) + jnp.sum(params.w_neg ** 2))
    return loss + wd


def train(K_train: jax.Array, y_train: jax.Array, num_classes: int,
          cfg: TrainConfig = TrainConfig()) -> tuple[km.MPKernelMachineParams, list[float]]:
    """Full-batch-shuffled minibatch SGD+momentum with gamma annealing.

    K_train: (M, P) standardized kernel features; y_train: (M,) int labels.
    Returns trained params and the loss trace.
    """
    key = jax.random.PRNGKey(cfg.seed)
    key, pkey = jax.random.split(key)
    params = km.init_params(pkey, K_train.shape[1], num_classes)
    velocity = jax.tree.map(jnp.zeros_like, params)
    y1h = jax.nn.one_hot(y_train, num_classes)

    @jax.jit
    def step_fn(state: TrainState, batch_idx: jax.Array):
        params, velocity, step = state
        frac = jnp.minimum(step.astype(jnp.float32) / cfg.gamma_anneal_steps, 1.0)
        gamma_scale = cfg.gamma_anneal_start * (1.0 - frac) + 1.0 * frac
        Kb = K_train[batch_idx]
        yb = y1h[batch_idx]
        loss, grads = jax.value_and_grad(loss_fn)(params, Kb, yb, gamma_scale, cfg)
        velocity = jax.tree.map(lambda v, g: cfg.momentum * v - cfg.lr * g,
                                velocity, grads)
        params = jax.tree.map(lambda p, v: p + v, params, velocity)
        return TrainState(params, velocity, step + 1), loss

    state = TrainState(params, velocity, jnp.asarray(0))
    M = K_train.shape[0]
    losses: list[float] = []
    rng = np.random.default_rng(cfg.seed)
    for _ in range(cfg.num_steps):
        idx = jnp.asarray(rng.integers(0, M, size=min(cfg.batch_size, M)))
        state, loss = step_fn(state, idx)
        losses.append(float(loss))
    return state.params, losses


def evaluate(params: km.MPKernelMachineParams, K: jax.Array, y: jax.Array,
             quant_bits: int | None = None) -> float:
    p = km.forward(_maybe_quant(params, quant_bits), K, 1.0)
    pred = jnp.argmax(p, axis=-1)
    return float(jnp.mean((pred == y).astype(jnp.float32)))
