"""Mixture-of-Experts layer with capacity-based gather dispatch.

Dispatch strategy (MaxText/GShard-style but gather-based): tokens are
grouped, each (group, expert) pair gets a static capacity
C = ceil(Sg * k / E * capacity_factor); per group we argsort token->expert
assignments so each expert's tokens are contiguous, then *gather* them into
the (G, E, C, D) expert batch. Gathers cost bytes, not FLOPs — unlike the
one-hot dispatch einsum, which costs G*Sg*E*C*D MACs and would dominate the
compute roofline for fine-grained MoE (deepseek: 64 experts of d_ff=1408).
Overflowing tokens are dropped (keep their residual path only), standard
Switch behaviour; combine scatters expert outputs back weighted by the
softmax gate.

Supports shared experts (DeepSeek-MoE: always-on dense experts fused into
one SwiGLU of width shared*d_ff) and top-k routed experts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L

__all__ = ["init_moe", "moe_block"]


# Expert SELECTION rounds router logits onto this absolute grid (gate
# values stay full precision). 2^-10 is ~100x above prefill/decode f32
# recompute noise (~1e-5, the tie-flip source) yet ~30x below bf16's own
# rounding step and far below any decision-relevant logit gap, so genuine
# routing decisions are unchanged; grid ties resolve to the lowest expert
# id in every execution path.
ROUTE_SNAP_BITS = 10


def _route_scores(logits):
    """Rounded selection scores: floor(logits * 2^bits) — floor, not
    round-to-nearest, so a score's cell assignment is a pure truncation of
    its bits and ties break by expert id deterministically."""
    return jnp.floor(logits * (2.0 ** ROUTE_SNAP_BITS))


def init_moe(key, cfg) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(ks[0], d, e),
        "wi_gate": jax.vmap(lambda k: L.dense_init(k, d, f))(
            jax.random.split(ks[1], e)),
        "wi_up": jax.vmap(lambda k: L.dense_init(k, d, f))(
            jax.random.split(ks[2], e)),
        "wo": jax.vmap(lambda k: L.dense_init(k, f, d))(
            jax.random.split(ks[3], e)),
    }
    if cfg.num_shared_experts:
        p["shared"] = L.init_swiglu(ks[4], d, f * cfg.num_shared_experts)
    return p


def moe_block(p, x, cfg):
    """x: (B, S, D) -> (B, S, D).

    Capacity policy comes from cfg.moe_capacity_factor: a float gives
    Switch-style C = ceil(g*K/E * cf) with overflow dropping; None gives the
    no-drop mode (C = g, exact — every assignment is honoured; used at
    decode and in parity tests)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    capacity_factor = cfg.moe_capacity_factor
    T = B * S
    xf = x.reshape(T, D)

    g = min(cfg.moe_group_size, T)
    G = T // g
    assert T % g == 0, (T, g)
    if capacity_factor is None:
        C = g  # no-drop: a token can land on an expert at most once
    else:
        C = max(int(g * K / E * capacity_factor), 1)
        # pad C to a friendly lane multiple when large enough to matter
        if C > 16:
            C = -(-C // 8) * 8
        C = min(C, g)

    logits = L.linear(xf, p["router"]).astype(jnp.float32)   # (T, E)
    # Deterministic tie-robust routing: SELECT experts on rounded scores
    # (exact ties broken by lowest expert id — lax.top_k is stable), then
    # GATE with the full-precision logits of the selected experts. Near-
    # tied gates otherwise flip between prefill and decode on ulp-level
    # recompute noise (the jamba hybrid amplifies ~4e-6 SSM decode noise
    # through top-2 routing; see tests/test_archs.py) — the snap grid
    # collapses both paths' scores to the same value so the same experts
    # win, while gate PRECISION is unaffected.
    _, top_idx = lax.top_k(_route_scores(logits), K)         # (T, K)
    top_val = jnp.take_along_axis(logits, top_idx, axis=-1)
    gates = jax.nn.softmax(top_val, axis=-1)

    xg = xf.reshape(G, g, D)
    eid = top_idx.reshape(G, g * K)          # flattened (token, choice)
    gate_flat = gates.reshape(G, g * K)
    tok_of = jnp.tile(jnp.arange(g)[:, None], (1, K)).reshape(g * K)

    def dispatch_group(eid_g):
        # stable sort assignments by expert id; returns the permutation
        order = jnp.argsort(eid_g, stable=True)              # (g*K,)
        sorted_eid = eid_g[order]
        # rank of each assignment within its expert = position - start[e]
        counts = jnp.bincount(eid_g, length=E)               # (E,)
        starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                  jnp.cumsum(counts)[:-1].astype(jnp.int32)])
        pos = jnp.arange(g * K)
        rank = pos - starts[sorted_eid]
        keep = rank < C
        # slot index into the (E*C) expert buffer; dropped -> sentinel E*C
        slot = jnp.where(keep, sorted_eid * C + rank, E * C)
        return order, slot

    order, slot = jax.vmap(dispatch_group)(eid)              # (G, g*K)

    # scatter token ids into the (G, E*C+1) buffer (last = drop bin)
    tok_sorted = jnp.take_along_axis(
        jnp.broadcast_to(tok_of[None, :], eid.shape), order, axis=1)
    gate_sorted = jnp.take_along_axis(gate_flat, order, axis=1)
    buf_tok = jnp.full((G, E * C + 1), 0, jnp.int32)
    buf_gate = jnp.zeros((G, E * C + 1), jnp.float32)
    gidx = jnp.broadcast_to(jnp.arange(G)[:, None], order.shape)
    buf_tok = buf_tok.at[gidx, slot].set(tok_sorted, mode="drop")
    buf_gate = buf_gate.at[gidx, slot].set(gate_sorted, mode="drop")
    buf_tok = buf_tok[:, : E * C]
    buf_gate = buf_gate[:, : E * C]                          # 0 for empty slots

    # gather -> expert FFNs -> weighted scatter, processed in group-chunks:
    # the (Gc, E, C, F) hidden transient is the largest MoE buffer (5+ GiB
    # per layer at mixtral prefill_32k if all G groups run at once); a
    # lax.map over chunks of groups bounds it to Gc/G of that.
    def run_groups(args):
        xg_c, tok_c, gate_c = args                 # (Gc,g,D),(Gc,E*C),(Gc,E*C)
        Gc = xg_c.shape[0]
        xe = jnp.take_along_axis(xg_c, tok_c[..., None], axis=1)
        xe = xe.reshape(Gc, E, C, D)

        def expert_ffn(x_e, wg, wu, wo):
            h = jax.nn.silu(jnp.einsum("gcd,df->gcf", x_e,
                                       wg.astype(x_e.dtype)))
            h = h * jnp.einsum("gcd,df->gcf", x_e, wu.astype(x_e.dtype))
            return jnp.einsum("gcf,fd->gcd", h, wo.astype(x_e.dtype))

        ye = jax.vmap(expert_ffn, in_axes=(1, 0, 0, 0), out_axes=1)(
            xe, p["wi_gate"], p["wi_up"], p["wo"])           # (Gc, E, C, D)
        ye = ye.reshape(Gc, E * C, D) * gate_c[..., None].astype(ye.dtype)
        cidx = jnp.broadcast_to(jnp.arange(Gc)[:, None], (Gc, E * C))
        yg = jnp.zeros((Gc, g, D), ye.dtype)
        return yg.at[cidx, tok_c].add(ye)

    gchunk = max(min(cfg.moe_group_chunk, G), 1)
    if G % gchunk != 0:
        gchunk = 1
    if gchunk == G:
        yg = run_groups((xg, buf_tok, buf_gate))
    else:
        nch = G // gchunk
        # remat the chunk body: lax.map is a scan, and its transpose would
        # otherwise SAVE each chunk's gathered (Gc,E,C,D) tokens — undoing
        # the memory cap in training (prefill is unaffected either way)
        body = jax.checkpoint(run_groups) if getattr(cfg, "remat", False) \
            else run_groups
        yg = jax.lax.map(
            body,
            (xg.reshape(nch, gchunk, g, D),
             buf_tok.reshape(nch, gchunk, E * C),
             buf_gate.reshape(nch, gchunk, E * C)))
        yg = yg.reshape(G, g, D)
    y = yg.reshape(B, S, D)

    if cfg.num_shared_experts:
        y = y + L.swiglu(p["shared"], x, cfg)
    return y.astype(x.dtype)
