"""Architecture zoo: config schema + functional model assembly.

One `ArchConfig` describes every assigned architecture (dense / MoE / SSM /
hybrid / encoder / VLM-backbone). Models are built functionally:

    params = init(cfg, key)                  # nested dict, f32 masters
    logits = forward(params, cfg, batch)     # training / prefill
    logits, cache = decode_step(params, cfg, tokens, cache, pos)

Scan-over-layers everywhere: per-layer params are stacked on a leading axis
and consumed by `lax.scan`, so HLO size (and SPMD-partitioner time) is O(1)
in depth — an 80-layer 72B model lowers as fast as a 24-layer 2B one. Hybrid
(Jamba) scans over period-groups (1 attention + 7 mamba sublayers, MoE on
alternate FFNs).

Modality frontends are stubs per the assignment: `[vlm]` consumes
precomputed patch embeddings, `[audio]` consumes precomputed frame
embeddings (the transformer BACKBONE is what the cells exercise).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod

__all__ = ["ArchConfig", "init", "forward", "decode_step", "init_cache",
           "param_count", "active_param_count"]


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # attention options
    use_rope: bool = True
    rope_theta: float = 1e6
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: Optional[int] = None
    is_encoder: bool = False
    norm: str = "rms"              # rms | ln
    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0    # deepseek: leading dense FFN layers
    moe_every: int = 1             # jamba: MoE on every 2nd FFN
    moe_group_size: int = 512      # dispatch group (tokens)
    moe_group_chunk: int = 16      # groups per expert-FFN chunk (memory cap)
    moe_capacity_factor: Optional[float] = 1.25   # None -> no-drop (exact)
    moe_decode_capacity_factor: Optional[float] = None  # decode: no-drop
    # SSM
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    attn_every: int = 0            # hybrid: one attn per this many layers
    # modality stubs
    vlm_patches: int = 0           # [vlm]: number of patch embeddings
    audio_frontend: bool = False   # [audio]: frames (B, S, D) input
    # misc
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    mp_mode: bool = False          # paper technique on linear layers
    mp_gamma: float = 8.0
    compute_dtype: str = "bfloat16"   # activations/matmul dtype (f32 for
                                      # exactness tests; params stay f32)
    sequence_parallel: bool = False   # Megatron-SP residual stream (dense
                                      # archs only; SSD wants full seq)
    remat: bool = True
    # attention chunking (memory-efficient attention block sizes)
    q_chunk: int = 512
    kv_chunk: int = 1024
    ssm_chunk: int = 256

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.num_heads, 1))
        if self.num_experts and not self.moe_d_ff:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab_size // 256) * 256

    @property
    def supports_decode(self) -> bool:
        return not self.is_encoder

    @property
    def subquadratic(self) -> bool:
        """Can run long_500k: SSM/hybrid or sliding-window attention."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None


# ---------------------------------------------------------------------------
# per-family layer stacks
# ---------------------------------------------------------------------------


def _init_norm(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "ln":
        return {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))}
    return {"scale": jnp.ones((d,))}


def _norm(p, x, cfg):
    if cfg.norm == "ln":
        return L.layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return L.rms_norm(x, p["scale"], cfg.norm_eps)


def _init_ffn(key, cfg, layer_is_moe: bool):
    if layer_is_moe:
        return moe_mod.init_moe(key, cfg)
    if cfg.norm == "ln":  # encoder family uses biased GELU MLP
        return L.init_gelu_mlp(key, cfg.d_model, cfg.d_ff)
    return L.init_swiglu(key, cfg.d_model, cfg.d_ff)


def _ffn(p, x, cfg, layer_is_moe: bool):
    if layer_is_moe:
        return moe_mod.moe_block(p, x, cfg)
    if cfg.norm == "ln":
        return L.gelu_mlp(p, x, cfg)
    return L.swiglu(p, x, cfg)


def _has_ffn(cfg, layer_is_moe: bool) -> bool:
    return layer_is_moe or cfg.d_ff > 0


def _init_block(key, cfg, *, mixer: str, layer_is_moe: bool) -> dict:
    """One residual block: norm -> mixer [-> norm -> ffn] (pre-norm).
    Pure-SSM archs (mamba2) have no FFN: the mixer IS the block."""
    k1, k2 = jax.random.split(key)
    p = {"norm1": _init_norm(cfg)}
    if mixer == "attn":
        p["attn"] = L.init_attention(k1, cfg)
    else:
        p["mamba"] = ssm_mod.init_mamba(k1, cfg)
    if _has_ffn(cfg, layer_is_moe):
        p["norm2"] = _init_norm(cfg)
        p["ffn"] = _init_ffn(k2, cfg, layer_is_moe)
    return p


def _block(p, x, cfg, positions, *, mixer: str, layer_is_moe: bool):
    h = _norm(p["norm1"], x, cfg)
    if mixer == "attn":
        h = L.attention_block(p["attn"], h, cfg, positions,
                              q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    else:
        h = ssm_mod.mamba_block(p["mamba"], h, cfg, chunk=cfg.ssm_chunk)
    x = x + h
    if _has_ffn(cfg, layer_is_moe):
        h = _norm(p["norm2"], x, cfg)
        h = _ffn(p["ffn"], h, cfg, layer_is_moe)
        x = x + h
    return x


def _block_decode(p, x, cfg, cache, cur_pos, *, mixer: str, layer_is_moe: bool):
    h = _norm(p["norm1"], x, cfg)
    if mixer == "attn":
        h, cache = L.attention_decode(p["attn"], h, cfg, cache, cur_pos)
    else:
        h, cache = ssm_mod.mamba_decode(p["mamba"], h, cfg, cache)
    x = x + h
    if _has_ffn(cfg, layer_is_moe):
        h = _norm(p["norm2"], x, cfg)
        h = _ffn(p["ffn"], h, cfg, layer_is_moe)
        x = x + h
    return x, cache


# Layer plan: which (mixer, is_moe) each layer uses, and how they group for
# the scan. Homogeneous families scan over all layers; special layers
# (deepseek's first dense FFN) are peeled off; hybrid scans over periods.


def _layer_plan(cfg: ArchConfig):
    if cfg.family == "hybrid":
        period = cfg.attn_every
        assert cfg.num_layers % period == 0
        subs = []
        for i in range(period):
            mixer = "attn" if i == 0 else "mamba"
            is_moe = (cfg.num_experts > 0) and (i % cfg.moe_every == 1)
            subs.append((mixer, is_moe))
        return {"kind": "periodic", "period": period, "subs": subs,
                "n_groups": cfg.num_layers // period}
    if cfg.family == "ssm":
        return {"kind": "uniform", "mixer": "mamba", "is_moe": False,
                "n_scan": cfg.num_layers, "n_prefix": 0}
    is_moe = cfg.num_experts > 0
    return {"kind": "uniform", "mixer": "attn", "is_moe": is_moe,
            "n_scan": cfg.num_layers - cfg.first_dense_layers,
            "n_prefix": cfg.first_dense_layers}


# ---------------------------------------------------------------------------
# init / forward / decode
# ---------------------------------------------------------------------------


def init(cfg: ArchConfig, key: jax.Array) -> dict:
    plan = _layer_plan(cfg)
    k_embed, k_layers, k_head, k_prefix = jax.random.split(key, 4)
    params: dict[str, Any] = {}
    if not cfg.audio_frontend:
        params["tok_embed"] = (jax.random.normal(
            k_embed, (cfg.padded_vocab, cfg.d_model)) * 0.02)
    else:  # stub frontend: a projection applied to precomputed frames
        params["frame_proj"] = L.dense_init(k_embed, cfg.d_model, cfg.d_model)

    if plan["kind"] == "uniform":
        if plan["n_prefix"]:
            # peeled dense-FFN layers (deepseek first layer): full d_ff dense
            dense_cfg = dataclasses.replace(cfg, num_experts=0)
            params["prefix_layers"] = [
                _init_block(k, dense_cfg, mixer=plan["mixer"], layer_is_moe=False)
                for k in jax.random.split(k_prefix, plan["n_prefix"])]
        keys = jax.random.split(k_layers, plan["n_scan"])
        params["layers"] = jax.vmap(
            lambda k: _init_block(k, cfg, mixer=plan["mixer"],
                                  layer_is_moe=plan["is_moe"]))(keys)
    else:  # periodic (jamba)
        n_g = plan["n_groups"]
        group_params = []
        for i, (mixer, is_moe) in enumerate(plan["subs"]):
            keys = jax.random.split(jax.random.fold_in(k_layers, i), n_g)
            group_params.append(jax.vmap(
                lambda k: _init_block(k, cfg, mixer=mixer, layer_is_moe=is_moe)
            )(keys))
        params["period_layers"] = group_params

    params["final_norm"] = _init_norm(cfg)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k_head, cfg.d_model, cfg.padded_vocab)
    return params


def _embed(params, cfg, batch):
    """Returns (x (B,S,D), positions (S,), text_offset)."""
    if cfg.audio_frontend:
        x = L.linear(batch["frames"], params["frame_proj"],
                     compute_dtype=L.cdt(cfg))
        S = x.shape[1]
        return x, jnp.arange(S), 0
    tok = params["tok_embed"]
    x = tok[batch["tokens"]].astype(L.cdt(cfg))
    if cfg.vlm_patches:
        patches = batch["patches"].astype(L.cdt(cfg))   # (B, P, D)
        x = jnp.concatenate([patches, x], axis=1)
        return x, jnp.arange(x.shape[1]), cfg.vlm_patches
    return x, jnp.arange(x.shape[1]), 0


def _maybe_remat(f, cfg):
    return jax.checkpoint(f) if cfg.remat else f


def _constrain(p_layer, cfg=None):
    """FSDP/TP constraint on the per-layer param slice inside scan bodies
    (keeps the partitioner from all-gathering the whole stacked params).
    No-op without an active mesh context (smoke tests, single device).

    Matrix-shaped leaves (>=2 trailing dims) are ALSO cast to the compute
    dtype here, BEFORE the on-use all-gather: the gather then moves bf16
    instead of f32 — half the ICI bytes and half the transient gathered-
    weights HBM (qwen2: ~3.5 GiB/layer f32 -> 1.75). Vector params (norm
    scales, biases, a_log) stay f32 for precision. Grads flow through the
    cast back to the f32 masters."""
    from repro.distributed.sharding import constrain_layer_params
    _KEEP_F32 = {"scale", "bias", "a_log", "dt_bias", "D", "conv_b",
                 "bq", "bk", "bv", "bi", "bo"}
    if cfg is not None and cfg.compute_dtype != "float32":
        dt = L.cdt(cfg)

        def cast(path, x):
            name = next((str(getattr(e, "key", "")) for e in reversed(path)
                         if getattr(e, "key", None)), "")
            if name in _KEEP_F32 or x.dtype != jnp.float32:
                return x
            return x.astype(dt)

        p_layer = jax.tree_util.tree_map_with_path(cast, p_layer)
    return constrain_layer_params(p_layer)


def _constrain_stream(x, sequence_parallel: bool = False):
    """Pin the residual stream to (batch -> DP, seq -> 'model' [SP], dm
    replicated).

    Two jobs:
    1. Without any constraint, the row-parallel wo spec P('model','data')
       propagates d-model-over-'data' INTO the stream; that conflicts with
       batch-over-'data' and the partitioner resolves it by replicating the
       batch — measured as full-global-batch f32 activations per device
       (37 GiB each at glm4 train_4k).
    2. Sequence parallelism: the per-layer residual saved for remat is the
       stream itself; with seq sharded over 'model' it shrinks |model|x
       (qwen2 train_4k: 80 layers x 1 GiB -> 80 x 64 MiB). Compute
       all-gathers S transiently inside the layer (Megatron-SP schedule).
    """
    from repro.distributed.sharding import constrain_activations
    return constrain_activations(
        x, ("model", None) if sequence_parallel else (None, None))


def forward(params: dict, cfg: ArchConfig, batch: dict,
            return_hidden: bool = False) -> jax.Array:
    """Full-sequence forward -> logits (B, S_total, padded_vocab), or the
    final-norm hidden states (B, S_total, D) when return_hidden (the
    chunked-CE loss applies the LM head itself, chunk by chunk, so the full
    logits tensor never materializes)."""
    plan = _layer_plan(cfg)
    x, positions, _ = _embed(params, cfg, batch)
    x = _constrain_stream(x, cfg.sequence_parallel)

    if plan["kind"] == "uniform":
        for p in params.get("prefix_layers", []):
            dense_cfg = dataclasses.replace(cfg, num_experts=0)
            x = _block(p, x, dense_cfg, positions,
                       mixer=plan["mixer"], layer_is_moe=False)

        def body(x, p_layer):
            p_layer = _constrain(p_layer, cfg)
            y = _maybe_remat(
                lambda px, xx: _block(px, xx, cfg, positions,
                                      mixer=plan["mixer"],
                                      layer_is_moe=plan["is_moe"]),
                cfg)(p_layer, x)
            return _constrain_stream(y, cfg.sequence_parallel), None

        x, _ = lax.scan(body, x, params["layers"])
    else:
        subs = plan["subs"]

        def body(x, p_group):
            p_group = _constrain(p_group, cfg)
            def group_fwd(pg, xx):
                # per-sublayer remat bounds the RECOMPUTE transient of the
                # outer (whole-group) remat to one sublayer's intermediates
                for i, (mixer, is_moe) in enumerate(subs):
                    blk = lambda p_, x_, m=mixer, mo=is_moe: _block(
                        p_, x_, cfg, positions, mixer=m, layer_is_moe=mo)
                    xx = _maybe_remat(blk, cfg)(pg[i], xx)
                return xx
            return _constrain_stream(
                _maybe_remat(group_fwd, cfg)(p_group, x),
                cfg.sequence_parallel), None

        x, _ = lax.scan(body, x, tuple(params["period_layers"]))

    x = _norm(params["final_norm"], x, cfg)
    if return_hidden:
        return x
    head = (params["tok_embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    return L.linear(x, head, mp_mode=cfg.mp_mode, mp_gamma=cfg.mp_gamma,
                    compute_dtype=L.cdt(cfg))


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, cache_len: int,
               dtype=None) -> dict:
    """Stacked per-layer caches matching the scan layout."""
    if dtype is None:
        dtype = L.cdt(cfg)
    plan = _layer_plan(cfg)
    if plan["kind"] == "uniform":
        if plan["mixer"] == "attn":
            one = lambda: L.init_attn_cache(cfg, batch, cache_len, dtype)
        else:
            one = lambda: ssm_mod.init_ssm_cache(cfg, batch)
        stack = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[one() for _ in range(plan["n_scan"])]) if plan["n_scan"] > 1 \
            else jax.tree.map(lambda x: x[None], one())
        prefix = [L.init_attn_cache(cfg, batch, cache_len, dtype)
                  for _ in range(plan["n_prefix"])]
        return {"scan": stack, "prefix": prefix}
    # periodic: attn cache for sub 0, ssm caches for subs 1..period-1
    n_g = plan["n_groups"]
    caches = []
    for (mixer, _) in plan["subs"]:
        if mixer == "attn":
            one = lambda: L.init_attn_cache(cfg, batch, cache_len, dtype)
        else:
            one = lambda: ssm_mod.init_ssm_cache(cfg, batch)
        caches.append(jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[one() for _ in range(n_g)])
                      if n_g > 1 else jax.tree.map(lambda x: x[None], one()))
    return {"periodic": caches}


def decode_step(params: dict, cfg: ArchConfig, tokens: jax.Array,
                cache: dict, cur_pos: jax.Array):
    """One decode step. tokens: (B, 1) int32; cur_pos: (B,) int32.
    Returns (logits (B, 1, V), new_cache)."""
    assert cfg.supports_decode, f"{cfg.name} is encoder-only"
    # decode uses its own MoE capacity policy (default no-drop: dropping a
    # user's token mid-generation is a quality bug, not a load-balance knob)
    cfg = dataclasses.replace(
        cfg, moe_capacity_factor=cfg.moe_decode_capacity_factor)
    plan = _layer_plan(cfg)
    x = params["tok_embed"][tokens].astype(L.cdt(cfg))

    new_cache: dict = {}
    if plan["kind"] == "uniform":
        new_prefix = []
        for p, c in zip(params.get("prefix_layers", []),
                        cache.get("prefix", [])):
            dense_cfg = dataclasses.replace(cfg, num_experts=0)
            x, c2 = _block_decode(p, x, dense_cfg, c, cur_pos,
                                  mixer=plan["mixer"], layer_is_moe=False)
            new_prefix.append(c2)

        def body(x, pc):
            p_layer, c_layer = pc
            p_layer = _constrain(p_layer, cfg)
            y, c2 = _block_decode(p_layer, x, cfg, c_layer, cur_pos,
                                  mixer=plan["mixer"],
                                  layer_is_moe=plan["is_moe"])
            return y, c2

        x, scan_cache = lax.scan(body, x, (params["layers"], cache["scan"]))
        new_cache = {"scan": scan_cache, "prefix": new_prefix}
    else:
        subs = plan["subs"]

        def body(x, pcs):
            p_group = _constrain(pcs[0], cfg)
            c_group = pcs[1]
            new_cs = []
            for i, (mixer, is_moe) in enumerate(subs):
                x, c2 = _block_decode(p_group[i], x, cfg, c_group[i], cur_pos,
                                      mixer=mixer, layer_is_moe=is_moe)
                new_cs.append(c2)
            return x, tuple(new_cs)

        x, per_cache = lax.scan(
            body, x, (tuple(params["period_layers"]),
                      tuple(cache["periodic"])))
        new_cache = {"periodic": list(per_cache)}

    x = _norm(params["final_norm"], x, cfg)
    head = (params["tok_embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = L.linear(x, head, mp_mode=cfg.mp_mode, mp_gamma=cfg.mp_gamma,
                      compute_dtype=L.cdt(cfg))
    return logits, new_cache


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------


def param_count(params) -> int:
    return sum(int(jnp.size(p)) for p in jax.tree.leaves(params))


def active_param_count(cfg: ArchConfig, params) -> int:
    """Parameters touched per token (MoE: top-k of routed experts)."""
    total = param_count(params)
    if not cfg.num_experts:
        return total
    plan = _layer_plan(cfg)
    # expert params per MoE layer
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    if plan["kind"] == "uniform":
        n_moe = plan["n_scan"] if plan["is_moe"] else 0
    else:
        n_moe = plan["n_groups"] * sum(1 for (_, m) in plan["subs"] if m)
    inactive = n_moe * per_expert * (cfg.num_experts - cfg.num_experts_per_tok)
    return total - inactive
