"""Shared neural-net layers for the architecture zoo (pure functional JAX).

Conventions:
  * params are plain nested dicts of jnp arrays;
  * every init_* returns (params, ...) given a PRNG key;
  * activations flow (B, S, D); attention uses (B, S, H, hd);
  * compute dtype = bf16 (configurable), params f32, reductions f32;
  * `linear()` is the universal projection and dispatches to the paper's
    multiplierless MP path when `mp=(gamma, iters)` is requested — the MP
    kernel machine technique as a first-class layer mode.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# initializers / basic ops
# ---------------------------------------------------------------------------


def cdt(cfg):
    """The arch's compute dtype (bf16 default; f32 for exactness tests)."""
    return jnp.dtype(getattr(cfg, "compute_dtype", "bfloat16"))


def dense_init(key, d_in, d_out, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def linear(x, w, b=None, *, mp_mode: bool = False, mp_gamma: float = 8.0,
           compute_dtype=jnp.bfloat16):
    """y = x @ w (+ b). With mp_mode, uses the paper's multiplierless MP
    approximation (eq. 9) through the fused Pallas kernel."""
    if mp_mode:
        from repro.kernels import mp_linear as mp_linear_kernel
        y = mp_linear_kernel(x.astype(jnp.float32), w.astype(jnp.float32),
                             mp_gamma)
        y = y.astype(compute_dtype)
    else:
        y = jnp.dot(x.astype(compute_dtype), w.astype(compute_dtype),
                    preferred_element_type=compute_dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def rms_norm(x, scale, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 1e4):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    pos = positions.astype(jnp.float32)
    if pos.ndim == 1:
        pos = pos[None, :]
    ang = pos[..., None] * freqs[None, None, :]        # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# memory-efficient attention (flash-style double-chunked online softmax)
# ---------------------------------------------------------------------------


def chunked_attention(
    q, k, v, *,
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
):
    """Flash attention in pure JAX with a custom VJP (GQA-aware).

    q: (B, Sq, H, hd); k, v: (B, Skv, Hk, hd), H % Hk == 0.
    Forward: online softmax over kv chunks inside a scan over q chunks;
    only (out, LSE) are saved. Backward: recomputes p blockwise and
    accumulates dq/dk/dv — O(S) memory instead of the O(S^2 / chunks)
    residual stack a plain scan transpose would save. This is what lets
    prefill_32k / train_4k fit HBM without a fused TPU kernel, and it is
    the memory-term hillclimb lever (score blocks never round-trip HBM as
    saved residuals).
    """
    B, Sq, H, hd = q.shape
    _, Skv, Hk, _ = k.shape
    G = H // Hk
    scale = 1.0 / math.sqrt(hd)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    qp = (-Sq) % q_chunk
    kp = (-Skv) % kv_chunk
    nq = (Sq + qp) // q_chunk
    nk = (Skv + kp) // kv_chunk

    # positions are always 0..S-1 here (training / prefill, no packing);
    # computed from STATIC lengths with numpy so the custom_vjp closure
    # holds constants, never tracers.
    import numpy as _np
    # plain numpy (NOT jnp): these are closure constants for the custom_vjp
    # and must not be bound to any single trace (the bwd rule runs under a
    # different trace than the fwd).
    qpos_c = _np.pad(_np.arange(Sq, dtype=_np.int32), (0, qp),
                     constant_values=-1).reshape(nq, q_chunk)
    kpos_c = _np.pad(_np.arange(Skv, dtype=_np.int32), (0, kp),
                     constant_values=2**30).reshape(nk, kv_chunk)

    def block_mask(qpos_i, kpos_j):
        mask = jnp.ones((q_chunk, kv_chunk), bool)
        if causal:
            mask &= qpos_i[:, None] >= kpos_j[None, :]
        if window is not None:
            mask &= (qpos_i[:, None] - kpos_j[None, :]) < window
        return mask

    # padded, chunked, grouped layouts (leading chunk axis for scan)
    def chunk_q(x):
        xp = jnp.pad(x, ((0, 0), (0, qp), (0, 0), (0, 0)))
        return xp.reshape(B, nq, q_chunk, Hk, G, hd).transpose(1, 0, 2, 3, 4, 5)

    def chunk_kv(x):
        xp = jnp.pad(x, ((0, 0), (0, kp), (0, 0), (0, 0)))
        return xp.reshape(B, nk, kv_chunk, Hk, hd).transpose(1, 0, 2, 3, 4)

    def unchunk_q(xg):  # (nq, B, qc, Hk, G, hd) -> (B, Sq, H, hd)
        x = xg.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * q_chunk, H, hd)
        return x[:, :Sq]

    def unchunk_kv(xg):
        x = xg.transpose(1, 0, 2, 3, 4).reshape(B, nk * kv_chunk, Hk, hd)
        return x[:, :Skv]

    def scores(qc, kc, qpos_i, kpos_j):
        s = jnp.einsum("bqkgd,bckd->bkgqc", qc.astype(jnp.float32),
                       kc.astype(jnp.float32)) * scale
        return jnp.where(block_mask(qpos_i, kpos_j)[None, None, None],
                         s, -1e30)

    @jax.custom_vjp
    def flash(qh, kh, vh):
        out, _ = _flash_fwd(qh, kh, vh)
        return out

    def _flash_fwd(qh, kh, vh):
        qg, kg, vg = chunk_q(qh), chunk_kv(kh), chunk_kv(vh)

        def q_step(_, qi):
            qc, qpos_i = qi

            def kv_step(carry, ki):
                m, l, acc = carry
                kc, vc, kpos_j = ki
                s = scores(qc, kc, qpos_i, kpos_j)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l = l * corr + jnp.sum(p, axis=-1)
                pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(vc.dtype),
                                vc, preferred_element_type=jnp.float32)
                acc = acc * corr[..., None] + pv
                return (m_new, l, acc), None

            m0 = jnp.full((B, Hk, G, q_chunk), -jnp.inf, jnp.float32)
            l0 = jnp.zeros((B, Hk, G, q_chunk), jnp.float32)
            a0 = jnp.zeros((B, Hk, G, q_chunk, hd), jnp.float32)
            (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), (kg, vg, kpos_c))
            l_safe = jnp.maximum(l, 1e-30)
            o = (acc / l_safe[..., None]).transpose(0, 3, 1, 2, 4)
            lse = m + jnp.log(l_safe)                   # (B, Hk, G, qc)
            return None, (o.astype(qh.dtype), lse)

        _, (outs, lses) = lax.scan(q_step, None, (qg, qpos_c))
        out = unchunk_q(outs.transpose(0, 1, 2, 3, 4, 5))
        return out, lses                                # lses: (nq,B,Hk,G,qc)

    def _fwd_rule(qh, kh, vh):
        out, lses = _flash_fwd(qh, kh, vh)
        return out, (qh, kh, vh, out, lses)

    def _bwd_rule(res, dout):
        qh, kh, vh, out, lses = res
        qg, kg, vg = chunk_q(qh), chunk_kv(kh), chunk_kv(vh)
        dog = chunk_q(dout)
        og = chunk_q(out)
        # D_i = rowsum(dout * out)  (B, Hk, G, qc) per q chunk
        Dg = jnp.sum(dog.astype(jnp.float32) * og.astype(jnp.float32), -1) \
            .transpose(0, 1, 3, 4, 2)                   # (nq,B,Hk,G,qc)

        def kv_step(dq_acc, ki):
            kc, vc, kpos_j = ki

            def q_step(carry, qi):
                dk, dv = carry
                qc, doc, lse, Dc, qpos_i = qi
                s = scores(qc, kc, qpos_i, kpos_j)
                p = jnp.exp(s - lse[..., None])         # (B,Hk,G,qc,kvc)
                dp = jnp.einsum("bqkgd,bckd->bkgqc",
                                doc.astype(jnp.float32),
                                vc.astype(jnp.float32))
                ds = p * (dp - Dc[..., None]) * scale
                pb = p.astype(vc.dtype)
                dsb = ds.astype(qc.dtype)
                dv = dv + jnp.einsum("bkgqc,bqkgd->bckd", pb, doc,
                                     preferred_element_type=jnp.float32)
                dk = dk + jnp.einsum("bkgqc,bqkgd->bckd", dsb, qc,
                                     preferred_element_type=jnp.float32)
                dq_i = jnp.einsum("bkgqc,bckd->bqkgd", dsb, kc,
                                  preferred_element_type=jnp.float32)
                return (dk, dv), dq_i

            dk0 = jnp.zeros((B, kv_chunk, Hk, hd), jnp.float32)
            dv0 = jnp.zeros((B, kv_chunk, Hk, hd), jnp.float32)
            (dk, dv), dq_parts = lax.scan(
                q_step, (dk0, dv0), (qg, dog, lses, Dg, qpos_c))
            return dq_acc + dq_parts, (dk, dv)

        dq0 = jnp.zeros((nq, B, q_chunk, Hk, G, hd), jnp.float32)
        dqg, (dks, dvs) = lax.scan(kv_step, dq0, (kg, vg, kpos_c))
        dq = unchunk_q(dqg.astype(qh.dtype))
        dk = unchunk_kv(dks.astype(kh.dtype))
        dv = unchunk_kv(dvs.astype(vh.dtype))
        return dq, dk, dv

    flash.defvjp(_fwd_rule, _bwd_rule)
    return flash(q, k, v)


def decode_attention(q, k_cache, v_cache, cur_pos, *, window=None):
    """Single-token decode: q (B, 1, H, hd) against a (B, S, Hk, hd) cache.

    cur_pos: (B,) int32 — index of the token being generated; cache slots
    > cur_pos (and outside the sliding window) are masked.
    """
    B, _, H, hd = q.shape
    _, S, Hk, _ = k_cache.shape
    G = H // Hk
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Hk, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(S)[None, :]                       # (1, S)
    mask = pos <= cur_pos[:, None]
    if window is not None:
        mask &= (cur_pos[:, None] - pos) < window
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (init + apply)
# ---------------------------------------------------------------------------


def init_attention(key, cfg) -> dict:
    ks = jax.random.split(key, 6)
    hd = cfg.head_dim
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.num_heads * hd),
        "wk": dense_init(ks[1], cfg.d_model, cfg.num_kv_heads * hd),
        "wv": dense_init(ks[2], cfg.d_model, cfg.num_kv_heads * hd),
        "wo": dense_init(ks[3], cfg.num_heads * hd, cfg.d_model),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,))
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,))
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,))
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,))
        p["k_norm"] = jnp.ones((hd,))
    return p


def _project_qkv(p, x, cfg, positions):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = linear(x, p["wq"], p.get("bq"), mp_mode=cfg.mp_mode,
               mp_gamma=cfg.mp_gamma, compute_dtype=cdt(cfg)).reshape(B, S, cfg.num_heads, hd)
    k = linear(x, p["wk"], p.get("bk"), mp_mode=cfg.mp_mode,
               mp_gamma=cfg.mp_gamma, compute_dtype=cdt(cfg)).reshape(B, S, cfg.num_kv_heads, hd)
    v = linear(x, p["wv"], p.get("bv"), mp_mode=cfg.mp_mode,
               mp_gamma=cfg.mp_gamma, compute_dtype=cdt(cfg)).reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(p, x, cfg, positions, *, q_chunk=512, kv_chunk=1024):
    """Full-sequence attention (training / prefill)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    out = chunked_attention(
        q, k, v, causal=not cfg.is_encoder, window=cfg.sliding_window,
        q_chunk=q_chunk, kv_chunk=kv_chunk)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    return linear(out, p["wo"], mp_mode=cfg.mp_mode, mp_gamma=cfg.mp_gamma, compute_dtype=cdt(cfg))


def attention_decode(p, x, cfg, cache, cur_pos):
    """x: (B, 1, D). cache: {"k": (B, S, Hk, hd), "v": ...}. Returns
    (out (B,1,D), new_cache)."""
    B = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg, cur_pos[:, None])
    # write the new kv at cur_pos (sliding windows use modular slots)
    S = cache["k"].shape[1]
    slot = cur_pos % S

    def write(c, new):
        return jax.vmap(
            lambda cb, nb, sb: lax.dynamic_update_slice_in_dim(cb, nb, sb, 0)
        )(c, new, slot)

    k_cache = write(cache["k"], k.astype(cache["k"].dtype))
    v_cache = write(cache["v"], v.astype(cache["v"].dtype))
    # For sliding-window caches the absolute positions rotate; decode masking
    # uses stored positions per slot.
    pos_cache = write(cache["pos"][..., None],
                      cur_pos[:, None, None])[..., 0]
    qg = q
    scale = 1.0 / math.sqrt(cfg.head_dim)
    G = cfg.num_heads // cfg.num_kv_heads
    qh = qg.reshape(B, cfg.num_kv_heads, G, cfg.head_dim)
    s = jnp.einsum("bkgd,bskd->bkgs", qh.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    valid = pos_cache <= cur_pos[:, None]
    if cfg.sliding_window is not None:
        valid &= (cur_pos[:, None] - pos_cache) < cfg.sliding_window
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", pr, v_cache.astype(jnp.float32))
    out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim).astype(x.dtype)
    out = linear(out, p["wo"], mp_mode=cfg.mp_mode, mp_gamma=cfg.mp_gamma, compute_dtype=cdt(cfg))
    new_cache = {"k": k_cache, "v": v_cache, "pos": pos_cache}
    return out, new_cache


def init_attn_cache(cfg, batch, cache_len, dtype=jnp.bfloat16):
    hd = cfg.head_dim
    return {
        "k": jnp.zeros((batch, cache_len, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, cache_len, cfg.num_kv_heads, hd), dtype),
        "pos": jnp.full((batch, cache_len), 2**30, jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_swiglu(key, d_model, d_ff):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, d_model, d_ff),
        "wi_up": dense_init(k2, d_model, d_ff),
        "wo": dense_init(k3, d_ff, d_model),
    }


def swiglu(p, x, cfg):
    g = linear(x, p["wi_gate"], mp_mode=cfg.mp_mode, mp_gamma=cfg.mp_gamma, compute_dtype=cdt(cfg))
    u = linear(x, p["wi_up"], mp_mode=cfg.mp_mode, mp_gamma=cfg.mp_gamma, compute_dtype=cdt(cfg))
    return linear(jax.nn.silu(g) * u, p["wo"], mp_mode=cfg.mp_mode,
                  mp_gamma=cfg.mp_gamma, compute_dtype=cdt(cfg))


def init_gelu_mlp(key, d_model, d_ff):
    k1, k2 = jax.random.split(key)
    return {"wi": dense_init(k1, d_model, d_ff),
            "bi": jnp.zeros((d_ff,)),
            "wo": dense_init(k2, d_ff, d_model),
            "bo": jnp.zeros((d_model,))}


def gelu_mlp(p, x, cfg):
    h = jax.nn.gelu(linear(x, p["wi"], p["bi"], mp_mode=cfg.mp_mode,
                           mp_gamma=cfg.mp_gamma, compute_dtype=cdt(cfg)))
    return linear(h, p["wo"], p["bo"], mp_mode=cfg.mp_mode,
                  mp_gamma=cfg.mp_gamma, compute_dtype=cdt(cfg))
