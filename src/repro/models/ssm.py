"""Mamba-2 (SSD — state-space duality) blocks, pure JAX.

Chunked SSD forward (Dao & Gu, arXiv:2405.21060, Listing 1 adapted):
the sequence is split into chunks of length Q; within a chunk the output is
a masked quadratic (attention-like) form — MXU-friendly — and across chunks
a tiny recurrent state (H heads x P headdim x N state) is carried by a scan.
This is the sub-quadratic path that makes the long_500k cells feasible.

Decode maintains the recurrent state exactly:
    h <- exp(dt*A) h + dt * (B outer x);   y = C . h + D*x

Block layout follows Mamba-2: in_proj -> [z | x | B | C | dt], short causal
depthwise conv on (x, B, C), SSD, gated RMSNorm, out_proj.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L

__all__ = ["init_mamba", "mamba_block", "mamba_decode", "init_ssm_cache"]

CONV_W = 4  # depthwise conv width


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_headdim
    return d_inner, nheads


def init_mamba(key, cfg) -> dict:
    d_inner, nheads = _dims(cfg)
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * N
    ks = jax.random.split(key, 4)
    in_dim = 2 * d_inner + 2 * N + nheads
    return {
        "in_proj": L.dense_init(ks[0], cfg.d_model, in_dim),
        "conv_w": jax.random.normal(ks[1], (CONV_W, conv_dim)) * 0.1,
        "conv_b": jnp.zeros((conv_dim,)),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)),   # A = -exp(a_log)
        "dt_bias": jnp.full((nheads,), math.log(math.e - 1) * 0.0),
        "D": jnp.ones((nheads,)),
        "norm": jnp.ones((d_inner,)),
        "out_proj": L.dense_init(ks[3], d_inner, cfg.d_model),
    }


def _split_proj(cfg, zxbcdt):
    d_inner, nheads = _dims(cfg)
    N = cfg.ssm_state
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N],
        axis=-1)
    return z, xin, Bc, Cc, dt


def _causal_dwconv(x, w, b):
    """x: (B, S, C), w: (W, C) depthwise causal conv."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i: i + x.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def mamba_block(p, x, cfg, *, chunk: int = 256):
    """x: (B, S, D) -> (B, S, D) via chunked SSD.

    The chunk-scan body is remat'ed (cfg.remat): without it, the scan
    transpose saves the (B, Q, Q, H) intra-chunk quadratic tensors for every
    chunk — ~multi-GiB per layer at 4k x 80 heads; with it only the chunk
    inputs and the carried (H, N, P) state are saved."""
    B, S, D = x.shape
    d_inner, H = _dims(cfg)
    P = cfg.ssm_headdim
    N = cfg.ssm_state

    zxbcdt = L.linear(x, p["in_proj"], mp_mode=cfg.mp_mode,
                      mp_gamma=cfg.mp_gamma, compute_dtype=L.cdt(cfg))
    z, xin, Bc, Cc, dt = _split_proj(cfg, zxbcdt)
    xbc = _causal_dwconv(jnp.concatenate([xin, Bc, Cc], -1).astype(jnp.float32),
                         p["conv_w"], p["conv_b"])
    xin, Bc, Cc = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["a_log"])                                      # (H,)
    xh = xin.reshape(B, S, H, P)

    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    causal = jnp.tril(jnp.ones((Q, Q), bool))

    # scan over chunks: intra-chunk quadratic form + carried recurrent state.
    # Keeps peak memory at one (B, Q, Q, H) score block instead of nc of them.
    def chunk_step(h, inp):
        xc, Bcc, Ccc, dtc = inp     # (B,Q,H,P), (B,Q,N), (B,Q,N), (B,Q,H)
        dA = dtc * A                                              # (B,Q,H)
        dAcs = jnp.cumsum(dA, axis=1)
        # intra: Lmat[i,j] = exp(dAcs_i - dAcs_j), i >= j. Mask BEFORE the
        # exp: the upper triangle has dAcs_i - dAcs_j > 0 (dAcs decreases)
        # and exp overflows there; where() after exp would still propagate
        # inf through the gradient (inf * 0 cotangent = NaN).
        diff = dAcs[:, :, None, :] - dAcs[:, None, :, :]          # (B,Q,Q,H)
        diff = jnp.where(causal[None, :, :, None], diff, -jnp.inf)
        Lmat = jnp.exp(diff)
        CB = jnp.einsum("bqn,bkn->bqk", Ccc, Bcc)                 # (B,Q,Q)
        W_ = CB[..., None] * Lmat                                 # (B,Q,Q,H)
        y_intra = jnp.einsum("bqkh,bkh,bkhp->bqhp", W_, dtc, xc)
        # inter: contribution of the carried state
        y_inter = jnp.einsum("bqn,bqh,bhnp->bqhp",
                             Ccc, jnp.exp(dAcs), h)
        # state update for the next chunk
        seg = jnp.exp(dAcs[:, -1:, :] - dAcs)                     # (B,Q,H)
        st = jnp.einsum("bkn,bkh,bkhp->bhnp", Bcc, dtc * seg, xc)
        h_new = h * jnp.exp(dAcs[:, -1])[..., None, None] + st
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    inputs = (
        xh.reshape(B, nc, Q, H, P).transpose(1, 0, 2, 3, 4),
        Bc.reshape(B, nc, Q, N).transpose(1, 0, 2, 3),
        Cc.reshape(B, nc, Q, N).transpose(1, 0, 2, 3),
        dt.reshape(B, nc, Q, H).transpose(1, 0, 2, 3),
    )
    step = jax.checkpoint(chunk_step) if getattr(cfg, "remat", False) \
        else chunk_step
    _, ys = lax.scan(step, h0, inputs)                            # (nc,B,Q,H,P)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(B, S, d_inner)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm"],
                   cfg.norm_eps)
    return L.linear(y.astype(x.dtype), p["out_proj"], mp_mode=cfg.mp_mode,
                    mp_gamma=cfg.mp_gamma, compute_dtype=L.cdt(cfg))


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_ssm_cache(cfg, batch, dtype=jnp.float32):
    d_inner, H = _dims(cfg)
    N = cfg.ssm_state
    P = cfg.ssm_headdim
    conv_dim = d_inner + 2 * N
    return {
        "h": jnp.zeros((batch, H, N, P), dtype),
        "conv": jnp.zeros((batch, CONV_W - 1, conv_dim), dtype),
    }


def mamba_decode(p, x, cfg, cache):
    """x: (B, 1, D) single step. Returns (y (B,1,D), new_cache)."""
    B = x.shape[0]
    d_inner, H = _dims(cfg)
    P, N = cfg.ssm_headdim, cfg.ssm_state

    zxbcdt = L.linear(x[:, 0], p["in_proj"], mp_mode=cfg.mp_mode,
                      mp_gamma=cfg.mp_gamma, compute_dtype=L.cdt(cfg))
    z, xin, Bc, Cc, dt = _split_proj(cfg, zxbcdt)
    xbc_new = jnp.concatenate([xin, Bc, Cc], -1).astype(jnp.float32)
    conv_win = jnp.concatenate([cache["conv"], xbc_new[:, None]], axis=1)
    xbc = jax.nn.silu(jnp.sum(conv_win * p["conv_w"][None], axis=1)
                      + p["conv_b"])
    xin, Bc, Cc = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    A = -jnp.exp(p["a_log"])
    dA = jnp.exp(dt * A)                                          # (B,H)
    xh = xin.reshape(B, H, P)
    dBx = jnp.einsum("bn,bh,bhp->bhnp", Bc, dt, xh)
    h = cache["h"] * dA[..., None, None] + dBx
    y = jnp.einsum("bn,bhnp->bhp", Cc, h)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B, d_inner)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm"],
                   cfg.norm_eps)
    y = L.linear(y.astype(x.dtype), p["out_proj"], mp_mode=cfg.mp_mode,
                 mp_gamma=cfg.mp_gamma, compute_dtype=L.cdt(cfg))
    return y[:, None], {"h": h, "conv": conv_win[:, 1:]}
