"""Straggler / health monitoring for the training loop.

At thousand-node scale the common failure modes are (a) a host that dies
(handled by checkpoint/restart in the launcher) and (b) a host that slows
down — thermal throttling, a flaky NIC — which silently drags every
synchronous step. This monitor keeps a per-source EWMA of step times and
flags sources whose recent step time exceeds `threshold` x the fleet median.

The launcher polls `verdict()` each step: 'ok' / 'straggler' (log + alert;
on TPU pods the remediation is re-scheduling the reserved core — simulated
here) / 'stall' (no heartbeat within timeout -> trigger restart-from-ckpt).
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Optional

__all__ = ["StragglerMonitor"]


@dataclasses.dataclass
class _Stat:
    ewma: float = 0.0
    n: int = 0
    last_beat: float = 0.0


class StragglerMonitor:
    def __init__(self, alpha: float = 0.2, threshold: float = 1.5,
                 stall_timeout_s: float = 300.0):
        self.alpha = alpha
        self.threshold = threshold
        self.stall_timeout_s = stall_timeout_s
        self.stats: dict[str, _Stat] = defaultdict(_Stat)

    def record(self, source: str, step_time_s: float,
               now: Optional[float] = None):
        st = self.stats[source]
        st.ewma = (step_time_s if st.n == 0
                   else self.alpha * step_time_s + (1 - self.alpha) * st.ewma)
        st.n += 1
        st.last_beat = now if now is not None else time.time()

    def fleet_median(self) -> float:
        vals = sorted(s.ewma for s in self.stats.values() if s.n > 0)
        if not vals:
            return 0.0
        return vals[len(vals) // 2]

    def verdict(self, source: str, now: Optional[float] = None) -> str:
        st = self.stats.get(source)
        now = now if now is not None else time.time()
        if st is None or st.n == 0:
            return "ok"
        if now - st.last_beat > self.stall_timeout_s:
            return "stall"
        med = self.fleet_median()
        if med > 0 and st.ewma > self.threshold * med and st.n >= 3:
            return "straggler"
        return "ok"

    def stragglers(self, now: Optional[float] = None) -> list:
        return [s for s in self.stats if self.verdict(s, now) != "ok"]
