"""Train / serve step builders shared by the launcher, dry-run and tests.

`make_train_step(cfg, opt)` -> (init_state, train_step) where train_step is
pjit-able: state and batch come in with shardings attached (in_shardings at
jit time), the loss/grad/update graph is pure.

`make_serve_step(cfg)` -> decode_step wrapper producing next-token ids +
updated cache (greedy by default; temperature sampling with a key).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.optim import AdamWConfig, AdamWState, adamw_init, adamw_update

__all__ = ["TrainState", "make_train_step", "make_serve_step", "make_loss_fn"]


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jax.Array


def _labels_and_logits(cfg, batch, logits):
    """Align logits with next-token labels per modality."""
    if cfg.audio_frontend:
        # masked-unit prediction: labels provided per frame
        return logits, batch["labels"]
    if cfg.vlm_patches:
        logits = logits[:, cfg.vlm_patches:]
    tokens = batch["tokens"]
    return logits[:, :-1], tokens[:, 1:]


def make_loss_fn(cfg, seq_chunk: int = 1024):
    """Chunked cross-entropy: the LM head + CE run inside a remat'ed scan
    over sequence chunks, so the (B, S, V) logits tensor never materializes
    (at 152k vocab x 4k seq that is the single largest train-time buffer).

    The vocab axis also stays model-sharded through the loss: the reductions
    (max / sum-exp / one-hot contraction) partial-reduce per shard. A
    take_along_axis gather would force XLA to all-gather full-vocab f32
    logits per device (~40 GiB) — measured as the dominant temp consumer
    before this was rewritten.
    """

    def loss_fn(params, batch):
        from repro.distributed.sharding import constrain_activations
        from repro.models import layers as L

        h = T.forward(params, cfg, batch, return_hidden=True)
        if cfg.audio_frontend:
            labels = batch["labels"]
        else:
            if cfg.vlm_patches:
                h = h[:, cfg.vlm_patches:]
            h = h[:, :-1]
            labels = batch["tokens"][:, 1:]
        head = (params["tok_embed"].T if cfg.tie_embeddings
                else params["lm_head"])

        B, S2, D = h.shape
        C = min(seq_chunk, S2)
        pad = (-S2) % C
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        n = (S2 + pad) // C
        hc = h.reshape(B, n, C, D).transpose(1, 0, 2, 3)
        lc = labels.reshape(B, n, C).transpose(1, 0, 2)

        def chunk_ce(tot, xs):
            hcc, lcc = xs                                # (B, C, D), (B, C)
            logits = L.linear(hcc, head, mp_mode=cfg.mp_mode,
                              mp_gamma=cfg.mp_gamma,
                              compute_dtype=L.cdt(cfg))
            logits = constrain_activations(logits, (None, "model"))
            logits = logits.astype(jnp.float32)
            m = jax.lax.stop_gradient(
                jnp.max(logits, axis=-1, keepdims=True))
            logz = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
            onehot = jax.nn.one_hot(lcc, logits.shape[-1],
                                    dtype=logits.dtype)
            gold = jnp.sum(logits * onehot, axis=-1)
            w = (lcc >= 0).astype(jnp.float32)
            return tot + jnp.sum((logz - gold) * w), None

        tot, _ = jax.lax.scan(jax.checkpoint(chunk_ce), jnp.zeros(()),
                              (hc, lc))
        return tot / jnp.maximum(jnp.sum(labels >= 0).astype(jnp.float32), 1)

    return loss_fn


def make_train_step(cfg, opt: AdamWConfig, accum: int = 1):
    """accum > 1 enables gradient accumulation: the global batch is split
    into `accum` microbatches, grads are averaged across them in a scan
    (activation memory / accum), and the optimizer applies ONCE."""
    loss_fn = make_loss_fn(cfg)

    def init_state(key) -> TrainState:
        params = T.init(cfg, key)
        return TrainState(params=params, opt=adamw_init(params),
                          step=jnp.zeros((), jnp.int32))

    def grads_of(params, batch):
        from repro.distributed.sharding import constrain_grads
        if accum == 1:
            loss, g = jax.value_and_grad(loss_fn)(params, batch)
            return loss, constrain_grads(g)
        micro = jax.tree.map(
            lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
            batch)

        def one(carry, mb):
            loss_sum, gsum = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            # reduce-scatter each microbatch's partial grads straight into
            # the FSDP-sharded accumulator (see sharding.constrain_grads)
            g = constrain_grads(g)
            return (loss_sum + loss,
                    jax.tree.map(jnp.add, gsum, g)), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, gsum), _ = jax.lax.scan(one, (jnp.zeros(()), zeros), micro)
        scale = 1.0 / accum
        return loss_sum * scale, jax.tree.map(lambda g: g * scale, gsum)

    def train_step(state: TrainState, batch):
        loss, grads = grads_of(state.params, batch)
        new_params, new_opt, om = adamw_update(opt, grads, state.opt,
                                               state.params)
        metrics = {"loss": loss, **om}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return init_state, train_step


def make_serve_step(cfg, temperature: float = 0.0):
    def serve_step(params, tokens, cache, cur_pos, key=None):
        logits, cache = T.decode_step(params, cfg, tokens, cache, cur_pos)
        logits = logits[:, 0, : cfg.vocab_size].astype(jnp.float32)
        if temperature > 0.0 and key is not None:
            next_tok = jax.random.categorical(key, logits / temperature)
        else:
            next_tok = jnp.argmax(logits, axis=-1)
        return next_tok.astype(jnp.int32)[:, None], logits, cache

    return serve_step
