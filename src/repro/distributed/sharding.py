"""Sharding rules: FSDP(data) x TP(model) x DP(pod), name-based.

Every parameter leaf gets a PartitionSpec from its *name* (last path
component): 2-D projection weights shard (d_in -> 'data' [FSDP],
d_out -> 'model' [TP]) or the transpose for output projections so that
activation layouts alternate naturally (Megatron column/row pattern).
Stacked scan dims (layers / periods / experts) are unsharded leading axes.

Divisibility sanitizer: a dim is only sharded if its size divides the mesh
axis product; otherwise the axis is dropped (e.g. batch=1 long-context decode
leaves 'data' idle instead of failing to lower). This keeps one rule table
valid across all 40 (arch x shape) cells and both meshes.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_specs", "batch_specs", "cache_specs", "data_axes",
           "sanitize", "tree_shardings", "session_specs",
           "session_shardings", "shard_session"]


# trailing-dims spec by parameter name; leading (stack) dims are unsharded.
_TRAILING: dict[str, tuple] = {
    # embeddings / heads
    "tok_embed": ("model", "data"),
    "lm_head": ("data", "model"),
    "frame_proj": ("data", "model"),
    # attention projections
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),
    # mlp
    "wi_gate": ("data", "model"),
    "wi_up": ("data", "model"),
    "wi": ("data", "model"),
    # mamba
    "in_proj": ("data", "model"),
    "out_proj": ("model", "data"),
    "conv_w": (None, "model"),
    # moe
    "router": ("data", None),
    # biases that follow a 'model'-sharded output
    "bq": ("model",),
    "bk": ("model",),
    "bv": ("model",),
    "bi": ("model",),
}


def data_axes(mesh: Mesh) -> tuple:
    """The pure-DP axes: ('pod', 'data') on the multi-pod mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def sanitize(spec: tuple, shape: tuple, mesh: Mesh) -> P:
    """Drop sharding on dims whose size does not divide the axis size."""
    out = []
    for dim, axis in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axis is not None and dim % _axis_size(mesh, axis) == 0 and dim > 0:
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


def _spec_for_leaf(path, leaf, mesh: Mesh) -> P:
    name = None
    for entry in reversed(path):
        key = getattr(entry, "key", None) or getattr(entry, "name", None)
        if isinstance(key, str):
            name = key
            break
    trailing = _TRAILING.get(name)
    nd = leaf.ndim
    if trailing is None or nd < len(trailing):
        return P()  # replicate (norm scales, small biases, scalars)
    spec = (None,) * (nd - len(trailing)) + tuple(trailing)
    return sanitize(spec, leaf.shape, mesh)


def param_specs(params, mesh: Mesh):
    """Pytree of PartitionSpec congruent with params (works on
    ShapeDtypeStructs or real arrays)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for_leaf(path, leaf, mesh), params)


def batch_specs(batch, mesh: Mesh):
    """Inputs shard batch over the DP axes ('pod','data'), rest replicated."""
    dp = data_axes(mesh)

    def spec(leaf):
        return sanitize((dp,) + (None,) * (leaf.ndim - 1), leaf.shape, mesh)

    return jax.tree.map(spec, batch)


def cache_specs(cache, mesh: Mesh):
    """Decode caches: batch -> DP axes; the long axis (attn seq / ssm heads)
    -> 'model' (sequence-parallel KV cache: kv heads are often < |model|, the
    32k seq axis always divides it). Stacked caches are (L, B, S/H, ...);
    the unstacked 'prefix' caches (deepseek's peeled dense layer) are
    (B, S, ...)."""
    dp = data_axes(mesh)

    def spec(path, leaf):
        stacked = not any(getattr(e, "key", None) == "prefix" for e in path)
        nd = leaf.ndim
        base = (None,) if stacked else ()
        base = base + (dp, "model")
        return sanitize(base + (None,) * (nd - len(base)), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, cache)


def tree_shardings(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def session_specs(state, mesh: Mesh):
    """Slot-batched streaming state (``SessionState`` or any pytree whose
    leaves lead with the slot axis S): shard S over the pure-DP axes,
    everything trailing replicated. Each slot is one independent sensor
    stream — the step is row-parallel, so slot sharding scales serving
    capacity linearly with device count and the partitioner inserts no
    collectives. Scalars (and any S not divisible by the axes, via
    ``sanitize``) replicate."""
    dp = data_axes(mesh)

    def spec(leaf):
        nd = getattr(leaf, "ndim", 0)
        if nd == 0:
            return P()
        return sanitize((dp,) + (None,) * (nd - 1), leaf.shape, mesh)

    return jax.tree.map(spec, state)


def session_shardings(state, mesh: Mesh):
    """NamedShardings congruent with ``state`` (see :func:`session_specs`)."""
    return tree_shardings(session_specs(state, mesh), mesh)


def shard_session(state, mesh: Mesh):
    """device_put the session state with the slot axis sharded over the
    mesh's DP axes. Chunks/valid vectors fed to the jitted step should be
    placed with the congruent specs so the step stays collective-free."""
    return jax.device_put(state, session_shardings(state, mesh))


# ---------------------------------------------------------------------------
# in-graph constraints (used from model code under an active mesh context)
# ---------------------------------------------------------------------------


def _context_mesh():
    """The mesh active via `with mesh:` during tracing, or None."""
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # noqa: BLE001 — constraint is best-effort
        return None


def constrain_layer_params(p_layer, gather_fsdp: bool = False):
    """Pin the per-layer param slice INSIDE the scan body to the FSDP
    compute schedule.

    gather_fsdp=True constrains weights to their spec with the 'data' axis
    replaced by replication (explicit gather-on-use). MEASURED WORSE and
    left off: on qwen2 it raised temp 18->31 GiB with no collective win
    (the dominant all-reduce is Megatron-TP activation traffic, not dW),
    and on mixtral it made XLA replicate expert compute (13x flops). Kept
    as a knob for future meshes where FSDP gathers do dominate.

    The default constraint still stops the partitioner gathering the whole
    stacked (L, ...) array before the loop (~40x the per-layer working
    set)."""
    mesh = _context_mesh()
    if mesh is None:
        return p_layer

    def spec_of(path, leaf):
        spec = _spec_for_leaf(path, leaf, mesh)
        if gather_fsdp:
            spec = P(*(None if a == "data" else a for a in spec))
        return spec

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, spec_of(path, leaf))),
        p_layer)


def constrain_grads(grads):
    """Constrain a gradient pytree (congruent with params) to the params'
    FSDP x TP sharding INSIDE the step function. Without this the
    partitioner may ALL-REDUCE full-size f32 grads across 'data' per
    microbatch (measured: 1.7e12 B/device/step on qwen2) instead of
    reduce-scattering each leaf into its owner shard (half the traffic and
    1/|data| the memory). No-op outside a mesh context."""
    mesh = _context_mesh()
    if mesh is None:
        return grads
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, _spec_for_leaf(path, leaf, mesh))),
        grads)


def constrain_activations(x, spec_tail=(None, None)):
    """Batch over DP axes, trailing dims per spec_tail (best-effort)."""
    mesh = _context_mesh()
    if mesh is None:
        return x
    dp = data_axes(mesh)
    spec = sanitize((dp,) + tuple(spec_tail), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
