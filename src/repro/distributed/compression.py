"""Int8 error-feedback gradient compression for the slow (inter-pod) axis.

At 2+ pods the per-step gradient all-reduce crosses the inter-pod links; at
1000+ nodes that hop is the scaling bottleneck. QSGD-style compression:

    c_t   = quantize_int8(g_t + e_t)          (per-tensor symmetric scale)
    g_hat = all-reduce(c_t) * scale / n_pods  (4x fewer bytes on the wire)
    e_t+1 = (g_t + e_t) - dequant(c_t)        (error feedback, keeps SGD
                                               convergence guarantees)

Implemented with shard_map over the 'pod' axis so the quantize/dequantize
happens on each pod's local shard and only int8 crosses pods. Intra-pod
reduction stays full precision.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["compress_state_init", "compressed_psum", "compressed_grad_allreduce"]


def compress_state_init(grads: Any) -> Any:
    """Error-feedback residual buffers, congruent with grads."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quant_dequant_int8(x):
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(x: jax.Array, err: jax.Array, axis_name: str):
    """Inside shard_map/pmap: psum int8-compressed x over axis_name with
    error feedback. Returns (mean_estimate, new_err)."""
    xf = x.astype(jnp.float32) + err
    q, scale = _quant_dequant_int8(xf)
    deq = q.astype(jnp.float32) * scale
    new_err = xf - deq
    # all-reduce the int8 payload (sum in int32 to avoid overflow) and the
    # scales; each pod contributes its own scale so we sum dequantized means.
    total = jax.lax.psum(q.astype(jnp.int32).astype(jnp.float32) * scale,
                         axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return (total / n).astype(x.dtype), new_err


def compressed_grad_allreduce(grads: Any, err_state: Any, mesh,
                              axis_name: str = "pod"):
    """Apply compressed_psum leaf-wise over the pod axis via shard_map.

    grads are assumed already averaged within the pod (XLA's normal sharded
    backward does that); this handles only the cross-pod hop.
    """
    from jax.experimental.shard_map import shard_map

    def leaf_fn(g, e):
        return compressed_psum(g, e, axis_name)

    # everything is replicated over 'pod' except the reduction itself
    spec = P()

    def wrapped(g, e):
        return leaf_fn(g, e)

    out = jax.tree.map(
        lambda g, e: shard_map(
            wrapped, mesh=mesh,
            in_specs=(spec, spec), out_specs=(spec, spec),
            check_rep=False)(g, e),
        grads, err_state)
    new_grads = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return new_grads, new_err
