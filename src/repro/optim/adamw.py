"""AdamW + cosine schedule + global-norm clipping, from scratch (no optax).

Optimizer state is a pytree congruent with params, so it inherits the
FSDP x TP parameter sharding unchanged (ZeRO-style: each device owns the
moments of its parameter shard).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params),
                      count=jnp.zeros((), jnp.int32))


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    count = state.count + 1
    lr = cosine_schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state.nu, grads)

    def upd(p, m, v):
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
        return (p - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(mu, nu, count), {"grad_norm": gnorm, "lr": lr}
