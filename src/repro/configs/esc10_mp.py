"""The paper's own configuration: multiplierless in-filter acoustic
classifier (30-filter multirate MP FIR bank + MP kernel machine), as
deployed on the Spartan-7 FPGA (Table I)."""

from repro.core.filterbank import FilterBankConfig
from repro.core.trainer import TrainConfig

FILTERBANK = FilterBankConfig(
    fs=16000.0,
    num_octaves=6,
    filters_per_octave=5,     # 30 filters, Table III
    bp_taps=16,               # BP window size 16
    lp_taps=6,                # LP window size 6
    mode="mp",
    gamma_f=4.0,
)

FILTERBANK_MAC_BASELINE = FILTERBANK._replace(mode="mac")

TRAIN = TrainConfig(
    num_steps=600,
    lr=0.5,
    gamma_anneal_start=4.0,
    gamma_anneal_steps=200,
)

# deployment quantization (Fig. 8: stable down to 8 bits)
QUANT_BITS = 8
