"""The paper's own configuration: multiplierless in-filter acoustic
classifier (30-filter multirate MP FIR bank + MP kernel machine), as
deployed on the Spartan-7 FPGA (Table I)."""

from repro.core.filterbank import FilterBankConfig
from repro.core.trainer import TrainConfig

FILTERBANK = FilterBankConfig(
    fs=16000.0,
    num_octaves=6,
    filters_per_octave=5,     # 30 filters, Table III
    bp_taps=16,               # BP window size 16
    lp_taps=6,                # LP window size 6
    mode="mp",
    gamma_f=4.0,
)

FILTERBANK_MAC_BASELINE = FILTERBANK._replace(mode="mac")

TRAIN = TrainConfig(
    num_steps=600,
    lr=0.5,
    gamma_anneal_start=4.0,
    gamma_anneal_steps=200,
)

# deployment quantization (Fig. 8: stable down to 8 bits)
QUANT_BITS = 8

# reduced same-family config for CPU smoke paths (serve demo, benchmarks)
FILTERBANK_SMOKE = FILTERBANK._replace(fs=4000.0, num_octaves=3,
                                       filters_per_octave=3)


def make_pipeline(smoke: bool = False, seed: int = 0,
                  quant_bits: int | None = None,
                  num_classes: int = 10,
                  stream_impl: str = "xla",
                  numerics: str = "float",
                  fixed_amax: float | None = None):
    """Build a deployable ``InFilterPipeline`` at the paper's configuration.

    The classifier is randomly initialized with identity standardization —
    serving-path demos and throughput benchmarks exercise the datapath, not
    accuracy; use ``InFilterPipeline.fit`` for a trained pipeline.
    ``stream_impl`` selects the session-step hot path: "xla" (default) or
    "pallas" (the stateful ``fir_mp_stream`` kernel; interpret mode on CPU,
    compiled on TPU). ``numerics="fixed"`` builds the bit-true int32
    hardware twin — one-shot AND session streaming, under either
    stream_impl, with chunked decisions bit-for-bit equal to one-shot
    inference (``fixed_amax`` calibrates the static ADC full-scale;
    stream_impl="pallas" routes the identical integer step through
    ``kernels.fir_mp_stream_q``)."""
    import jax
    import jax.numpy as jnp

    from repro.core import kernel_machine as km
    from repro.core.filterbank import FilterBank
    from repro.core.pipeline import InFilterPipeline

    cfg = FILTERBANK_SMOKE if smoke else FILTERBANK
    if quant_bits is not None:
        cfg = cfg._replace(quant_bits=quant_bits)
    if stream_impl not in ("xla", "pallas"):
        raise ValueError(f"unknown stream_impl {stream_impl!r}: "
                         "expected 'xla' or 'pallas'")
    if stream_impl != "xla":
        cfg = cfg._replace(stream_impl=stream_impl)
    if numerics not in ("float", "fixed"):
        raise ValueError(f"unknown numerics {numerics!r}: "
                         "expected 'float' or 'fixed'")
    if numerics != "float":
        cfg = cfg._replace(numerics=numerics)
    if fixed_amax is not None:
        cfg = cfg._replace(fixed_amax=float(fixed_amax))
    fb = FilterBank(cfg)
    P = cfg.num_filters
    clf = km.init_params(jax.random.PRNGKey(seed), P, num_classes)
    return InFilterPipeline.from_filterbank(fb, clf, jnp.zeros((P,)),
                                            jnp.ones((P,)))
