"""qwen2-72b [dense] — GQA, QKV bias. [arXiv:2407.10671; hf]"""

import dataclasses

from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    rope_theta=1e6,
    qkv_bias=True,
)

SMOKE = dataclasses.replace(
    CONFIG, head_dim=0, name="qwen2-smoke",
    num_layers=3, d_model=64, num_heads=8, num_kv_heads=2, d_ff=160,
    vocab_size=512, remat=False, q_chunk=32, kv_chunk=32,
)
