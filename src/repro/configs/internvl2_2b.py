"""internvl2-2b [vlm] — InternViT frontend (STUB: precomputed patch
embeddings) + InternLM2-style backbone. [arXiv:2404.16821; hf]"""

import dataclasses

from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    rope_theta=1e6,
    vlm_patches=1024,      # stub InternViT: (B, 1024, d_model) patch embeds
)

SMOKE = dataclasses.replace(
    CONFIG, head_dim=0, name="internvl2-smoke",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=512, vlm_patches=8, remat=False, q_chunk=32, kv_chunk=32,
)
