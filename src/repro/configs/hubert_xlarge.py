"""hubert-xlarge [audio] — encoder-only; conv feature frontend is a STUB
(precomputed frame embeddings); masked-unit prediction head over 504
clusters. [arXiv:2106.07447; unverified]"""

import dataclasses

from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    use_rope=False,        # HuBERT uses conv positional embedding (in the stub)
    is_encoder=True,
    norm="ln",
    audio_frontend=True,
)

SMOKE = dataclasses.replace(
    CONFIG, head_dim=0, name="hubert-smoke",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=64, remat=False, q_chunk=32, kv_chunk=32,
)
