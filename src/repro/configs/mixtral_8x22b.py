"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""

import dataclasses

from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    rope_theta=1e6,
    sliding_window=4096,
    num_experts=8,
    num_experts_per_tok=2,
    moe_d_ff=16384,
)

SMOKE = dataclasses.replace(
    CONFIG, head_dim=0, name="mixtral-smoke",
    num_layers=3, d_model=64, num_heads=8, num_kv_heads=2, d_ff=128,
    vocab_size=512, sliding_window=16, num_experts=4, num_experts_per_tok=2,
    moe_d_ff=128, remat=False, q_chunk=32, kv_chunk=32,
)
