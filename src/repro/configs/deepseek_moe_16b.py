"""deepseek-moe-16b [moe] — fine-grained MoE, 2 shared + 64 routed top-6,
first layer dense. [arXiv:2401.06066; hf]"""

import dataclasses

from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=10944,            # the single leading dense-FFN layer
    vocab_size=102400,
    rope_theta=1e4,
    num_experts=64,
    num_experts_per_tok=6,
    num_shared_experts=2,
    moe_d_ff=1408,
    first_dense_layers=1,
)

SMOKE = dataclasses.replace(
    CONFIG, head_dim=0, name="deepseek-moe-smoke",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, d_ff=96,
    vocab_size=512, num_experts=8, num_experts_per_tok=2,
    num_shared_experts=1, moe_d_ff=32, first_dense_layers=1, remat=False,
    q_chunk=32, kv_chunk=32,
)
