"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
on alternate FFNs. [arXiv:2403.19887; hf]"""

import dataclasses

from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    use_rope=False,        # Jamba uses no positional encoding (Mamba carries order)
    num_experts=16,
    num_experts_per_tok=2,
    moe_d_ff=14336,
    moe_every=2,           # MoE on every 2nd sublayer of the period
    attn_every=8,          # 1 attention + 7 mamba per period
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=64,        # 8192 inner / 64 = 128 SSD heads
)

SMOKE = dataclasses.replace(
    CONFIG, head_dim=0, name="jamba-smoke",
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=512, num_experts=4, num_experts_per_tok=2, moe_d_ff=128,
    attn_every=4, ssm_state=8, ssm_headdim=16, remat=False,
    q_chunk=32, kv_chunk=32, ssm_chunk=32,
)
