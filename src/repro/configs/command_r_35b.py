"""command-r-35b [dense] — GQA, no biases, large vocab.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""

import dataclasses

from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    rope_theta=1e4,
    tie_embeddings=True,   # command-r ties input/output embeddings
)

SMOKE = dataclasses.replace(
    CONFIG, head_dim=0, name="command-r-smoke",
    num_layers=3, d_model=64, num_heads=8, num_kv_heads=2, d_ff=128,
    vocab_size=512, remat=False, q_chunk=32, kv_chunk=32,
)
