"""mamba2-2.7b [ssm] — attention-free, SSD (state-space duality).
[arXiv:2405.21060; unverified]"""

import dataclasses

from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=1,            # unused (attention-free)
    d_ff=0,                # no separate FFN: the mamba mixer is the block
    vocab_size=50280,
    use_rope=False,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,        # 5120 inner / 64 = 80 SSD heads
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, name="mamba2-smoke",
    num_layers=3, d_model=64, vocab_size=512, ssm_state=16, ssm_headdim=16,
    remat=False, ssm_chunk=32,
)
