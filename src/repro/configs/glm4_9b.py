"""glm4-9b [dense] — RoPE, aggressive GQA (kv=2). [hf:THUDM/glm-4-9b; hf]"""

import dataclasses

from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    rope_theta=1e4,
)

SMOKE = dataclasses.replace(
    CONFIG, head_dim=0, name="glm4-smoke",
    num_layers=3, d_model=64, num_heads=8, num_kv_heads=2, d_ff=128,
    vocab_size=512, remat=False, q_chunk=32, kv_chunk=32,
)
