"""qwen3-8b [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]"""

import dataclasses

from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12288,
    vocab_size=151936,
    rope_theta=1e6,
    qk_norm=True,
)

SMOKE = dataclasses.replace(
    CONFIG, head_dim=0, name="qwen3-smoke",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=512, remat=False, q_chunk=32, kv_chunk=32,
)
