"""Architecture registry: one module per assigned architecture + the
paper's own acoustic configuration. `get_arch(name)` returns an ArchConfig;
`get_smoke(name)` returns the reduced same-family config used by CPU smoke
tests (full configs are only exercised abstractly via the dry-run)."""

from __future__ import annotations

import importlib

ARCH_NAMES = [
    "deepseek_moe_16b",
    "mixtral_8x22b",
    "mamba2_2p7b",
    "jamba_v0p1_52b",
    "internvl2_2b",
    "hubert_xlarge",
    "glm4_9b",
    "qwen3_8b",
    "qwen2_72b",
    "command_r_35b",
]

# canonical ids as assigned (dash form) -> module name
ARCH_IDS = {
    "deepseek-moe-16b": "deepseek_moe_16b",
    "mixtral-8x22b": "mixtral_8x22b",
    "mamba2-2.7b": "mamba2_2p7b",
    "jamba-v0.1-52b": "jamba_v0p1_52b",
    "internvl2-2b": "internvl2_2b",
    "hubert-xlarge": "hubert_xlarge",
    "glm4-9b": "glm4_9b",
    "qwen3-8b": "qwen3_8b",
    "qwen2-72b": "qwen2_72b",
    "command-r-35b": "command_r_35b",
}


def _module(name: str):
    mod_name = ARCH_IDS.get(name, name)
    return importlib.import_module(f"repro.configs.{mod_name}")


def get_arch(name: str):
    return _module(name).CONFIG


def get_smoke(name: str):
    return _module(name).SMOKE
