"""Verilog netlist backend for the typed fixed-point IR.

:func:`emit_verilog` lowers an executable :class:`~repro.ir.isa.Program`
to one synthesizable Verilog-2001 module pair: a ``<name>_top`` wrapper
instantiating the ``<name>`` core, which holds

* one memory per (non-ROM) register, declared at the width the register
  allocator proves sufficient (``repro.ir.alloc`` — ``required_bits``
  two's-complement, not the int32 carrier; predicates are 1-bit),
* one 32-bit ROM memory per constant table, initialized with
  ``$readmemh`` from the SAME ``rom/<name>.mem`` images the C reference
  uses (committed under ``artifacts/ir/<target>/rom/``),
* a single ``always @(posedge clk)`` FSM: one state per IR instruction,
  element loops expressed as behavioral ``for`` loops inside the state.
  ``scan`` regions become trip-counted state subgraphs — the datapath
  instructions inside the MP-bisection loop exist ONCE and are revisited
  every window solve, which is exactly the paper's time-multiplexed MP
  module sharing (Table I folds the whole bank onto 3 MP units).

The emitted subset is deliberately restricted so that
``repro.ir.vsim`` can simulate it bit-for-bit without an external tool:

* every datapath statement reads memories into 32-bit signed scratch
  registers (``$signed(...)`` on every i32 load), computes in 32-bit
  signed context, and stores through a constant part-select truncation
  (``r[addr] = t[W-1:0]``), which pins Verilog's expression-width rules
  to the one trivial case;
* all addressing is multiplierless: loop nests keep incremental address
  registers stepped by constant adds (the per-dimension correction trick
  recovers arbitrary strides), and dynamic-index * constant-stride
  products (gather / dynamic_slice) are emitted as shift-add chains;
* control flow is one ``case (state)`` with constant labels, constant
  ``for`` bounds, ``if``/ternary — no functions, tasks, generate, or
  delays.

Machine-readable ``// @io`` / ``// @trace`` / ``// @rom`` header comments
tell the simulator (and the iverilog testbench that
:func:`emit_testbench` generates) where program inputs/outputs live and
which FSM state commits which IR instruction — that mapping is what
``repro.ir.debug.first_divergence`` uses to name the first mismatching
register instead of failing with a bare assert.
"""

from __future__ import annotations

from repro.ir.alloc import Allocation, allocate
from repro.ir.isa import Program

__all__ = ["EmitError", "emit_verilog", "emit_testbench"]


class EmitError(Exception):
    """The program contains a construct outside the netlist subset."""


def _strides(shape) -> list:
    """Row-major element strides (suffix products)."""
    st = [1] * len(shape)
    for d in range(len(shape) - 2, -1, -1):
        st[d] = st[d + 1] * int(shape[d + 1])
    return st


def _size(shape) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


def _shape_txt(shape) -> str:
    return "x".join(str(int(d)) for d in shape) if shape else "-"


def _pow2_terms(c: int) -> list:
    """Bit positions of a positive constant: the shift-add decomposition
    of ``x * c`` (multiplierless index arithmetic)."""
    return [b for b in range(max(c.bit_length(), 1)) if (c >> b) & 1]


class _Addr:
    """One incremental address register of a loop nest: value at loop
    coordinates (c_0..c_{D-1}) is ``init + sum(c_d * stride_d)``,
    maintained with constant-add updates only."""

    def __init__(self, init, strides):
        self.init = init            # int, or str (runtime expression)
        self.strides = list(strides)
        self.name = None            # assigned by the state builder


class _St:
    """One FSM state: raw statement lines plus a symbolic successor that
    is resolved to a literal state number at render time."""

    def __init__(self, tag=""):
        self.tag = tag
        self.lines: list = []
        # ("seq",) | ("goto", st) | ("branch", cond, st_true, st_false)
        self.next = ("seq",)
        self.trace = None           # (instr_id, op, [dest mem names])


class _VGen:
    def __init__(self, prog: Program, alloc: Allocation):
        self.prog = prog
        self.alloc = alloc
        self.states: list = []
        self.max_t = 0
        self.max_a = 0
        self.max_c = 0
        self.loop_uid = 0
        self.counter_decls: list = []   # persistent loop counters/offsets
        self.shadow_decls: list = []    # (name, width, size) carry shadows
        self.instr_count = 0

    # -- naming -----------------------------------------------------------

    def mem(self, reg_idx: int) -> str:
        rom = self.prog.rom_of_reg.get(reg_idx)
        if rom is not None:
            return self.prog.roms[rom].name
        return f"r{reg_idx}"

    def _is_rom(self, reg_idx: int) -> bool:
        return reg_idx in self.prog.rom_of_reg

    def _dtype(self, reg_idx: int) -> str:
        return self.prog.regs[reg_idx].dtype

    def _width(self, reg_idx: int) -> int:
        return self.alloc.width(reg_idx)

    # -- canonical load/store forms ---------------------------------------

    def load(self, t: str, reg_idx: int, addr: str) -> str:
        m = self.mem(reg_idx)
        if self._is_rom(reg_idx):
            if self._dtype(reg_idx) == "i1":
                return f"{t} = ({m}[{addr}] != 0);"
            return f"{t} = $signed({m}[{addr}]);"
        if self._dtype(reg_idx) == "i1":
            return f"{t} = {m}[{addr}];"
        return f"{t} = $signed({m}[{addr}]);"

    def store(self, reg_idx: int, addr: str, val: str) -> str:
        m = self.mem(reg_idx)
        if self._dtype(reg_idx) == "i1":
            return f"{m}[{addr}] = ({val} != 0);"
        w = self._width(reg_idx)
        if w >= 32:
            return f"{m}[{addr}] = {val};"
        return f"{m}[{addr}] = {val}[{w - 1}:0];"

    # -- state plumbing ---------------------------------------------------

    def new_state(self, tag="") -> _St:
        st = _St(tag)
        self.states.append(st)
        return st

    def map_state(self, dims, addrs, body_fn, pre=(), post=(),
                  tag="") -> _St:
        """Emit one FSM state running a loop nest over ``dims``.

        ``addrs`` are :class:`_Addr` instances; ``body_fn(names)`` returns
        the innermost statement lines given their register names. Address
        updates are constant adds only: stride s_d per c_d iteration is
        maintained by an innermost ``+= s_{D-1}`` plus a per-level
        correction ``s_d - D_{d+1} * s_{d+1}`` after each inner sweep.
        """
        st = self.new_state(tag)
        st.lines.extend(pre)
        dims = [int(d) for d in dims]
        self.max_a = max(self.max_a, len(addrs))
        self.max_c = max(self.max_c, len(dims))
        for i, ad in enumerate(addrs):
            ad.name = f"a{i}"
            if len(ad.strides) != len(dims):
                raise EmitError("address/stride rank mismatch")
            st.lines.append(f"{ad.name} = {ad.init};")
        names = [ad.name for ad in addrs]
        body = body_fn(names)

        def inc_lines(level):
            out = []
            for ad in addrs:
                if level == len(dims) - 1:
                    delta = ad.strides[level]
                else:
                    delta = (ad.strides[level]
                             - dims[level + 1] * ad.strides[level + 1])
                if delta > 0:
                    out.append(f"{ad.name} = {ad.name} + {delta};")
                elif delta < 0:
                    out.append(f"{ad.name} = {ad.name} - {-delta};")
            return out

        if not dims or _size(dims) == 1 and not dims:
            st.lines.extend(body)
        else:
            ind = ""
            for d, n in enumerate(dims):
                st.lines.append(
                    f"{ind}for (c{d} = 0; c{d} < {n}; c{d} = c{d} + 1) "
                    "begin")
                ind += "  "
            st.lines.extend(ind + ln for ln in body)
            st.lines.extend(ind + ln for ln in inc_lines(len(dims) - 1))
            for d in range(len(dims) - 1, -1, -1):
                ind = ind[:-2]
                st.lines.append(f"{ind}end")
                if d > 0:
                    st.lines.extend(ind + ln for ln in inc_lines(d - 1))
        st.lines.extend(post)
        return st

    # -- broadcast-aware source addressing --------------------------------

    def _bcast_addr(self, src_idx: int, dshape) -> _Addr:
        """Numpy-style trailing-aligned broadcast of a source register
        into the destination iteration space (rank padding + size-1
        dims), as ``interp``/``cgen`` implement elementwise ops."""
        s = self.prog.regs[src_idx]
        sst = _strides(s.shape)
        off = len(dshape) - len(s.shape)
        if off < 0:
            raise EmitError(
                f"source r{src_idx} outranks destination in elementwise op")
        strides = []
        for d in range(len(dshape)):
            if d < off or int(s.shape[d - off]) == 1:
                strides.append(0)
            else:
                strides.append(sst[d - off])
        return _Addr(0, strides)

    # -- instruction dispatch ---------------------------------------------

    def emit_body(self, instrs) -> None:
        for ins in instrs:
            self.emit_instr(ins)

    def emit_instr(self, ins) -> None:
        iid = self.instr_count
        self.instr_count += 1
        first = len(self.states)
        op = ins.op
        fn = getattr(self, f"_op_{op}", None)
        if fn is None:
            fn = self._op_elementwise
        fn(ins)
        if len(self.states) == first:
            raise EmitError(f"op {op!r} emitted no states")
        last = self.states[-1]
        last.trace = (iid, op, [self.mem(d) for d in ins.dests])
        self.states[first].tag = f"instr {iid} {op}"

    # elementwise family ---------------------------------------------------

    _EW_BODY = {
        "add": ("{0} + {1}", 2),
        "sub": ("{0} - {1}", 2),
        "neg": ("0 - {0}", 1),
        "min": ("({1} < {0}) ? {1} : {0}", 2),
        "max": ("({0} < {1}) ? {1} : {0}", 2),
        "abs": ("({0} < 0) ? (0 - {0}) : {0}", 1),
        "sign": ("({0} > 0) ? 1 : (({0} < 0) ? -1 : 0)", 1),
        "lt": ("({0} < {1}) ? 1 : 0", 2),
        "le": ("({0} <= {1}) ? 1 : 0", 2),
        "gt": ("({0} > {1}) ? 1 : 0", 2),
        "ge": ("({0} >= {1}) ? 1 : 0", 2),
        "eq": ("({0} == {1}) ? 1 : 0", 2),
        "ne": ("({0} != {1}) ? 1 : 0", 2),
        "and": ("{0} & {1}", 2),
        "or": ("{0} | {1}", 2),
        "xor": ("{0} ^ {1}", 2),
        "mov": ("{0}", 1),
        "convert": ("{0}", 1),
    }

    def _op_elementwise(self, ins) -> None:
        op = ins.op
        if op not in self._EW_BODY and op not in (
                "not", "clamp", "select_n", "shl", "shra", "shrl"):
            raise EmitError(
                f"op {op!r} is outside the netlist subset "
                f"(jax primitive {ins.jax_prim!r})")
        d0 = ins.dests[0]
        dshape = self.prog.regs[d0].shape
        srcs = list(ins.srcs)

        def body(names):
            lines = []
            ts = []
            for i, s in enumerate(srcs):
                lines.append(self.load(f"t{i}", s, names[1 + i]))
                ts.append(f"t{i}")
            self.max_t = max(self.max_t, len(srcs) + 3)
            tr = f"t{len(srcs)}"
            if op in self._EW_BODY:
                tpl, nargs = self._EW_BODY[op]
                if len(ts) != nargs:
                    raise EmitError(f"{op}: bad arity {len(ts)}")
                lines.append(f"{tr} = {tpl.format(*ts)};")
            elif op == "not":
                if self._dtype(ins.srcs[0]) == "i1":
                    lines.append(f"{tr} = ({ts[0]} == 0) ? 1 : 0;")
                else:
                    lines.append(f"{tr} = ~{ts[0]};")
            elif op == "clamp":
                lo, x, hi = ts
                t3 = f"t{len(srcs)}"
                t4 = f"t{len(srcs) + 1}"
                lines.append(f"{t3} = ({x} < {lo}) ? {lo} : {x};")
                lines.append(f"{t4} = ({hi} < {t3}) ? {hi} : {t3};")
                tr = t4
            elif op == "select_n":
                if len(ts) != 3 or self._dtype(ins.srcs[0]) != "i1":
                    raise EmitError(
                        "select_n outside the bool-predicate 2-case form")
                lines.append(
                    f"{tr} = ({ts[0]} != 0) ? {ts[2]} : {ts[1]};")
            elif op in ("shl", "shra", "shrl"):
                vop = {"shl": "<<", "shra": ">>>", "shrl": ">>"}[op]
                if "imm" in ins.attrs:
                    k = int(ins.attrs["imm"])
                    lines.append(f"{tr} = {ts[0]} {vop} {k};")
                else:
                    lines.append(f"{tr} = {ts[0]} {vop} {ts[1]};")
            lines.append(self.store(d0, names[0], tr))
            return lines

        addrs = [_Addr(0, _strides(dshape))]
        addrs += [self._bcast_addr(s, dshape) for s in srcs]
        self.map_state(list(dshape), addrs, body, tag=ins.op)

    # shifts with immediate drop the amount operand at build time, so the
    # generic elementwise path covers them; register explicit aliases for
    # readability of dispatch
    _op_shl = _op_shra = _op_shrl = _op_elementwise
    _op_not = _op_clamp = _op_select_n = _op_elementwise

    # pure data movement ---------------------------------------------------

    def _copy_state(self, dst, src, dst_addr=None, src_addr=None,
                    dims=None, tag="copy") -> _St:
        """dst[...] = src[...] over ``dims`` (defaults: dense flat)."""
        n = self.prog.regs[src].size
        dims = [n] if dims is None else dims
        da = dst_addr or _Addr(0, [1] * len(dims))
        sa = src_addr or _Addr(0, [1] * len(dims))

        def body(names):
            self.max_t = max(self.max_t, 1)
            return [self.load("t0", src, names[1]),
                    self.store(dst, names[0], "t0")]
        return self.map_state(dims, [da, sa], body, tag=tag)

    def _op_reshape(self, ins) -> None:
        self._copy_state(ins.dests[0], ins.srcs[0], tag="reshape")

    def _op_broadcast(self, ins) -> None:
        d0 = ins.dests[0]
        dshape = self.prog.regs[d0].shape
        s = self.prog.regs[ins.srcs[0]]
        sst = _strides(s.shape)
        strides = [0] * len(dshape)
        for i, d in enumerate(ins.attrs["broadcast_dimensions"]):
            if int(s.shape[i]) != 1:
                strides[int(d)] = sst[i]
        self._copy_state(d0, ins.srcs[0],
                         dst_addr=_Addr(0, _strides(dshape)),
                         src_addr=_Addr(0, strides),
                         dims=list(dshape), tag="broadcast")

    def _op_transpose(self, ins) -> None:
        d0 = ins.dests[0]
        dshape = self.prog.regs[d0].shape
        sst = _strides(self.prog.regs[ins.srcs[0]].shape)
        perm = [int(p) for p in ins.attrs["permutation"]]
        self._copy_state(d0, ins.srcs[0],
                         dst_addr=_Addr(0, _strides(dshape)),
                         src_addr=_Addr(0, [sst[p] for p in perm]),
                         dims=list(dshape), tag="transpose")

    def _op_rev(self, ins) -> None:
        d0 = ins.dests[0]
        s = self.prog.regs[ins.srcs[0]]
        sst = _strides(s.shape)
        dims = set(int(d) for d in ins.attrs["dimensions"])
        init = sum((int(s.shape[d]) - 1) * sst[d] for d in dims)
        strides = [-sst[d] if d in dims else sst[d]
                   for d in range(len(s.shape))]
        self._copy_state(d0, ins.srcs[0],
                         dst_addr=_Addr(0, _strides(s.shape)),
                         src_addr=_Addr(init, strides),
                         dims=list(s.shape), tag="rev")

    def _op_slice(self, ins) -> None:
        d0 = ins.dests[0]
        dshape = self.prog.regs[d0].shape
        sst = _strides(self.prog.regs[ins.srcs[0]].shape)
        starts = [int(v) for v in ins.attrs["start_indices"]]
        steps = [int(v) for v in ins.attrs["strides"]]
        init = sum(st * s for st, s in zip(starts, sst))
        self._copy_state(d0, ins.srcs[0],
                         dst_addr=_Addr(0, _strides(dshape)),
                         src_addr=_Addr(init, [k * s for k, s
                                               in zip(steps, sst)]),
                         dims=list(dshape), tag="slice")

    def _op_concat(self, ins) -> None:
        d0 = ins.dests[0]
        dst = _strides(self.prog.regs[d0].shape)
        axis = int(ins.attrs["dimension"])
        off = 0
        for s in ins.srcs:
            sshape = self.prog.regs[s].shape
            self._copy_state(d0, s,
                             dst_addr=_Addr(off * dst[axis], dst),
                             src_addr=_Addr(0, _strides(sshape)),
                             dims=list(sshape), tag="concat")
            off += int(sshape[axis])

    def _op_iota(self, ins) -> None:
        d0 = ins.dests[0]
        dshape = [int(d) for d in ins.attrs["shape"]]
        dim = int(ins.attrs["dimension"])
        val = _Addr(0, [1 if d == dim else 0 for d in range(len(dshape))])

        def body(names):
            self.max_t = max(self.max_t, 1)
            return [f"t0 = {names[1]};",
                    self.store(d0, names[0], "t0")]
        self.map_state(dshape, [_Addr(0, _strides(dshape)), val], body,
                       tag="iota")

    def _op_pad(self, ins) -> None:
        d0 = ins.dests[0]
        out_shape = self.prog.regs[d0].shape
        dst = _strides(out_shape)
        s = self.prog.regs[ins.srcs[0]]
        cfg = [(int(lo), int(hi), int(it))
               for lo, hi, it in ins.attrs["padding_config"]]
        # state A: fill with the pad value (scalar register)
        pv_load = self.load("t0", ins.srcs[1], "0")
        self.max_t = max(self.max_t, 1)
        self.map_state([self.prog.regs[d0].size],
                       [_Addr(0, [1])],
                       lambda names: [self.store(d0, names[0], "t0")],
                       pre=[pv_load], tag="pad.fill")
        if s.size == 0:
            return
        # state B: scatter the operand at (lo + i*(interior+1)) per dim;
        # negative lo/hi trim via affine guard counters
        init = sum(lo * st for (lo, _h, _i), st in zip(cfg, dst))
        strides = [(it + 1) * st for (_l, _h, it), st in zip(cfg, dst)]
        addrs = [_Addr(init, strides), _Addr(0, _strides(s.shape))]
        guards = []
        for d, (lo, hi, it) in enumerate(cfg):
            if lo < 0 or hi < 0:
                g = _Addr(lo, [(it + 1) if e == d else 0
                               for e in range(len(cfg))])
                guards.append((g, int(out_shape[d])))
                addrs.append(g)

        def body(names):
            self.max_t = max(self.max_t, 2)
            lines = [self.load("t1", ins.srcs[0], names[1])]
            store = self.store(d0, names[0], "t1")
            if guards:
                conds = []
                for i, (_g, bound) in enumerate(guards):
                    gn = names[2 + i]
                    conds.append(f"({gn} >= 0) && ({gn} < {bound})")
                lines.append(f"if ({' && '.join(conds)}) begin")
                lines.append(f"  {store}")
                lines.append("end")
            else:
                lines.append(store)
            return lines
        self.map_state(list(s.shape), addrs, body, tag="pad.scatter")

    # reductions -----------------------------------------------------------

    def _op_reduce(self, ins, kind) -> None:
        d0 = ins.dests[0]
        dreg = self.prog.regs[d0]
        s = self.prog.regs[ins.srcs[0]]
        axes = set(int(a) for a in ins.attrs["axes"])
        # init = the combine-neutral element WITHIN the destination's
        # proven interval, so every narrow-width partial store is exact
        if dreg.dtype == "i1":
            init = {"sum": "0", "max": "0", "min": "1"}[kind]
        elif kind == "sum":
            init = "0"
        elif dreg.interval is not None:
            init = str(int(dreg.interval[0] if kind == "max"
                           else dreg.interval[1]))
        else:
            init = "(1 << 31)" if kind == "max" else "2147483647"
        self.max_t = max(self.max_t, 1)
        self.map_state([dreg.size], [_Addr(0, [1])],
                       lambda names: [self.store(d0, names[0], "t0")],
                       pre=[f"t0 = {init};"], tag=f"reduce.{kind}.init")

        dst_full = _strides(dreg.shape)
        kept = [d for d in range(len(s.shape)) if d not in axes]
        dstrides = [0] * len(s.shape)
        for i, d in enumerate(kept):
            dstrides[d] = dst_full[i]
        if kind == "sum":
            combine = "t2 = t0 + t1;"
        elif kind == "max":
            combine = ("t2 = t0 | t1;" if dreg.dtype == "i1"
                       else "t2 = (t0 < t1) ? t1 : t0;")
        else:
            combine = ("t2 = t0 & t1;" if dreg.dtype == "i1"
                       else "t2 = (t1 < t0) ? t1 : t0;")

        def body(names):
            self.max_t = max(self.max_t, 3)
            return [self.load("t0", d0, names[0]),
                    self.load("t1", ins.srcs[0], names[1]),
                    combine,
                    self.store(d0, names[0], "t2")]
        self.map_state(list(s.shape),
                       [_Addr(0, dstrides), _Addr(0, _strides(s.shape))],
                       body, tag=f"reduce.{kind}.acc")

    def _op_reduce_sum(self, ins):
        self._op_reduce(ins, "sum")

    def _op_reduce_max(self, ins):
        self._op_reduce(ins, "max")

    def _op_reduce_min(self, ins):
        self._op_reduce(ins, "min")

    # dynamic indexing -----------------------------------------------------

    def _shift_add(self, dst_t: str, src_t: str, c: int) -> list:
        """``dst_t = src_t * c`` for constant c >= 0 as a shift-add chain."""
        if c == 0:
            return [f"{dst_t} = 0;"]
        terms = _pow2_terms(c)
        lines = []
        first = terms[0]
        lines.append(f"{dst_t} = {src_t} << {first};" if first
                     else f"{dst_t} = {src_t};")
        for b in terms[1:]:
            lines.append(f"{dst_t} = {dst_t} + ({src_t} << {b});")
        return lines

    def _clamped_start(self, lines, t_in, t_out, max_start: int) -> None:
        lines.append(f"{t_out} = ({t_in} < 0) ? 0 : {t_in};")
        lines.append(f"{t_out} = ({t_out} > {max_start}) ? {max_start} "
                     f": {t_out};")

    def _op_dynamic_slice(self, ins) -> None:
        d0 = ins.dests[0]
        dshape = self.prog.regs[d0].shape
        opnd = self.prog.regs[ins.srcs[0]]
        sst = _strides(opnd.shape)
        sizes = [int(v) for v in ins.attrs["slice_sizes"]]
        pre = ["t9 = 0;"]
        self.max_t = max(self.max_t, 10)
        for d, start_reg in enumerate(ins.srcs[1:]):
            pre.append(self.load("t0", start_reg, "0"))
            self._clamped_start(pre, "t0", "t1",
                                int(opnd.shape[d]) - sizes[d])
            pre.extend(self._shift_add("t2", "t1", sst[d]))
            pre.append("t9 = t9 + t2;")
        self._copy_state(d0, ins.srcs[0],
                         dst_addr=_Addr(0, _strides(dshape)),
                         src_addr=_Addr("t9", sst),
                         dims=list(dshape), tag="dynamic_slice")
        # the pre block must run in the SAME state before the loop
        st = self.states[-1]
        st.lines = pre + st.lines

    def _op_dynamic_update_slice(self, ins) -> None:
        d0 = ins.dests[0]
        opnd = self.prog.regs[ins.srcs[0]]
        upd = self.prog.regs[ins.srcs[1]]
        sst = _strides(opnd.shape)
        self._copy_state(d0, ins.srcs[0], tag="dus.copy")
        pre = ["t9 = 0;"]
        self.max_t = max(self.max_t, 10)
        for d, start_reg in enumerate(ins.srcs[2:]):
            pre.append(self.load("t0", start_reg, "0"))
            self._clamped_start(pre, "t0", "t1",
                                int(opnd.shape[d]) - int(upd.shape[d]))
            pre.extend(self._shift_add("t2", "t1", sst[d]))
            pre.append("t9 = t9 + t2;")
        self._copy_state(d0, ins.srcs[1],
                         dst_addr=_Addr("t9", sst),
                         src_addr=_Addr(0, _strides(upd.shape)),
                         dims=list(upd.shape), tag="dus.update")
        st = self.states[-1]
        st.lines = pre + st.lines

    def _op_gather(self, ins) -> None:
        a = ins.attrs
        d0 = ins.dests[0]
        out_shape = self.prog.regs[d0].shape
        opnd = self.prog.regs[ins.srcs[0]]
        idx = self.prog.regs[ins.srcs[1]]
        op_st = _strides(opnd.shape)
        offset_dims = [int(v) for v in a["offset_dims"]]
        collapsed = set(int(v) for v in a["collapsed_slice_dims"])
        op_batch = [int(v) for v in a["operand_batching_dims"]]
        idx_batch = [int(v) for v in a["start_indices_batching_dims"]]
        start_map = [int(v) for v in a["start_index_map"]]
        sizes = [int(v) for v in a["slice_sizes"]]

        batch_shape = idx.shape[:-1]
        bst = _strides(batch_shape)
        k = int(idx.shape[-1]) if idx.shape else 1
        out_batch_positions = [d for d in range(len(out_shape))
                               if d not in offset_dims]
        D = len(out_shape)

        # indices-row pointer: flat batch index * k
        row_strides = [0] * D
        for i, p in enumerate(out_batch_positions):
            row_strides[p] = bst[i] * k

        # static operand offset: batching dims follow the paired indices
        # batch coordinate; free + non-collapsed slice dims follow the
        # offset_dims coordinates in operand order
        static_strides = [0] * D
        dims_no_batch = [d for d in range(len(opnd.shape))
                         if d not in op_batch]
        offset_iter = iter(offset_dims)
        for d in range(len(opnd.shape)):
            if d in op_batch:
                j = idx_batch[op_batch.index(d)]
                static_strides[out_batch_positions[j]] += op_st[d]
            elif d in collapsed:
                if d not in dims_no_batch:
                    raise EmitError("gather: collapsed batching dim")
            else:
                out_dim = next(offset_iter)
                static_strides[out_dim] += op_st[d]

        def body(names):
            self.max_t = max(self.max_t, 10)
            lines = ["t9 = 0;"]
            for j, d in enumerate(start_map):
                lines.append(self.load(
                    "t0", ins.srcs[1],
                    f"{names[2]} + {j}" if j else names[2]))
                self._clamped_start(lines, "t0", "t1",
                                    int(opnd.shape[d]) - sizes[d])
                lines.extend(self._shift_add("t2", "t1", op_st[d]))
                lines.append("t9 = t9 + t2;")
            lines.append(self.load("t3", ins.srcs[0],
                                   f"{names[1]} + t9"))
            lines.append(self.store(d0, names[0], "t3"))
            return lines

        self.map_state(list(out_shape),
                       [_Addr(0, _strides(out_shape)),
                        _Addr(0, static_strides),
                        _Addr(0, row_strides)],
                       body, tag="gather")

    # scan loops -----------------------------------------------------------

    def _op_loop(self, ins) -> None:
        rg = ins.regions[0]
        nc = int(ins.attrs["num_consts"])
        nk = int(ins.attrs["num_carry"])
        length = int(ins.attrs["length"])
        reverse = bool(rg.attrs.get("reverse", False))
        consts = list(ins.srcs[:nc])
        carries = list(ins.srcs[nc:nc + nk])
        xs = list(ins.srcs[nc + nk:])
        cin = list(rg.inputs[nc:nc + nk])
        xin = list(rg.inputs[nc + nk:])
        couts = list(rg.outputs[:nk])
        ys = list(rg.outputs[nk:])
        y_dests = list(ins.dests[nk:])
        k_dests = list(ins.dests[:nk])

        if length == 0:
            # scan of length 0: carries pass through, ys are zero-filled
            for d, s in zip(k_dests, carries):
                self._copy_state(d, s, tag="loop0.carry")
            for d in y_dests:
                self.max_t = max(self.max_t, 1)
                self.map_state(
                    [self.prog.regs[d].size], [_Addr(0, [1])],
                    lambda names, d=d: [self.store(d, names[0], "t0")],
                    pre=["t0 = 0;"], tag="loop0.ys")
            if not k_dests and not y_dests:
                self.new_state("loop0.empty").lines.append("t0 = 0;")
            return

        uid = self.loop_uid
        self.loop_uid += 1
        kv = f"k{uid}"
        self.counter_decls.append(kv)
        x_offs, y_offs = [], []
        for j, x in enumerate(xs):
            name = f"o{uid}x{j}"
            self.counter_decls.append(name)
            x_offs.append(name)
        for j in range(len(ys)):
            name = f"o{uid}y{j}"
            self.counter_decls.append(name)
            y_offs.append(name)

        # S_init: counters + per-entry const/carry binding
        init_st = self.new_state(f"loop{uid}.init")
        init_st.lines.append(f"{kv} = 0;")
        for j, (name, x) in enumerate(zip(x_offs, xs)):
            n = _size(self.prog.regs[xin[j]].shape)
            init_st.lines.append(
                f"{name} = {(length - 1) * n if reverse else 0};")
        for j, name in enumerate(y_offs):
            n = _size(self.prog.regs[ys[j]].shape)
            init_st.lines.append(
                f"{name} = {(length - 1) * n if reverse else 0};")
        for dst, src in zip(rg.inputs[:nc], consts):
            if dst != src:
                self._copy_state(dst, src, tag=f"loop{uid}.const")
        for dst, src in zip(cin, carries):
            if dst != src:
                self._copy_state(dst, src, tag=f"loop{uid}.carry0")

        head = self.new_state(f"loop{uid}.head")
        first_body = len(self.states)   # next state emitted = loop entry

        # per-trip x binding
        for j, (x, dst) in enumerate(zip(xs, xin)):
            n = _size(self.prog.regs[dst].shape)
            self._copy_state(dst, x,
                             dst_addr=_Addr(0, [1]),
                             src_addr=_Addr(x_offs[j], [1]),
                             dims=[n], tag=f"loop{uid}.x{j}")
        if not xs and first_body == len(self.states) and not rg.body:
            # degenerate: loop with an empty body still needs an entry
            self.new_state(f"loop{uid}.body").lines.append("t0 = 0;")

        self.emit_body(rg.body)

        # per-trip tail: ys stores, carry copy (through shadows if the
        # output registers alias other carry input slots), trip advance
        for j, (y, d) in enumerate(zip(ys, y_dests)):
            n = _size(self.prog.regs[y].shape)
            self._copy_state(d, y,
                             dst_addr=_Addr(y_offs[j], [1]),
                             src_addr=_Addr(0, [1]),
                             dims=[n], tag=f"loop{uid}.y{j}")
        hazard = any(c in cin and cin.index(c) != j
                     for j, c in enumerate(couts))
        if hazard:
            shadows = []
            for j, c in enumerate(couts):
                r = self.prog.regs[c]
                name = f"s{uid}c{j}"
                self.shadow_decls.append(
                    (name, self._width(c) if r.dtype != "i1" else 1,
                     max(r.size, 1), r.dtype))
                shadows.append(name)
                self._copy_raw(name, c, tag=f"loop{uid}.shadow{j}")
            for j, (dst, name) in enumerate(zip(cin, shadows)):
                self._copy_raw_back(dst, name, couts[j],
                                    tag=f"loop{uid}.unshadow{j}")
        else:
            for dst, src in zip(cin, couts):
                if dst != src:
                    self._copy_state(dst, src, tag=f"loop{uid}.knext")

        adv = self.new_state(f"loop{uid}.adv")
        adv.lines.append(f"{kv} = {kv} + 1;")
        for j, name in enumerate(x_offs):
            n = _size(self.prog.regs[xin[j]].shape)
            adv.lines.append(f"{name} = {name} - {n};" if reverse
                             else f"{name} = {name} + {n};")
        for j, name in enumerate(y_offs):
            n = _size(self.prog.regs[ys[j]].shape)
            adv.lines.append(f"{name} = {name} - {n};" if reverse
                             else f"{name} = {name} + {n};")
        adv.next = ("goto", head)

        # exit: move carries into the loop destinations
        first_exit = len(self.states)
        for d, src in zip(k_dests, cin):
            if d != src:
                self._copy_state(d, src, tag=f"loop{uid}.out")
        if first_exit == len(self.states):
            self.new_state(f"loop{uid}.exit").lines.append("t0 = 0;")
        head.next = ("branch", f"{kv} == {length}",
                     self.states[first_exit], self.states[first_body])

    def _copy_raw(self, dst_name, src_reg, tag) -> None:
        """Copy a register memory into a raw named shadow memory."""
        n = max(self.prog.regs[src_reg].size, 1)

        def body(names):
            self.max_t = max(self.max_t, 1)
            w = self._width(src_reg)
            trunc = ("" if self._dtype(src_reg) == "i1" or w >= 32
                     else f"[{w - 1}:0]")
            val = f"t0{trunc}" if trunc else "t0"
            if self._dtype(src_reg) == "i1":
                val = "(t0 != 0)"
            return [self.load("t0", src_reg, names[1]),
                    f"{dst_name}[{names[0]}] = {val};"]
        self.map_state([n], [_Addr(0, [1]), _Addr(0, [1])], body, tag=tag)

    def _copy_raw_back(self, dst_reg, src_name, like_reg, tag) -> None:
        n = max(self.prog.regs[dst_reg].size, 1)

        def body(names):
            self.max_t = max(self.max_t, 1)
            if self._dtype(like_reg) == "i1":
                load = f"t0 = {src_name}[{names[1]}];"
            else:
                load = f"t0 = $signed({src_name}[{names[1]}]);"
            return [load, self.store(dst_reg, names[0], "t0")]
        self.map_state([n], [_Addr(0, [1]), _Addr(0, [1])], body, tag=tag)

    def _op_grid(self, ins) -> None:
        raise EmitError("grid regions have no netlist lowering")

    def _op_cond(self, ins) -> None:
        raise EmitError("cond outside a grid region has no netlist "
                        "lowering")


# ---------------------------------------------------------------------------
# rendering
# ---------------------------------------------------------------------------


def _render_header(prog, alloc, gen, state_of) -> list:
    out = [f"// @meta name {prog.name}",
           f"// @meta states {len(gen.states) + 2}",
           f"// @meta instrs {gen.instr_count}"]
    for pos, reg in enumerate(prog.inputs):
        r = prog.regs[reg]
        out.append(f"// @io input {pos} mem {gen.mem(reg)} dtype {r.dtype}"
                   f" width {alloc.width(reg)} shape {_shape_txt(r.shape)}")
    for pos, reg in enumerate(prog.outputs):
        r = prog.regs[reg]
        w = 32 if gen._is_rom(reg) else alloc.width(reg)
        out.append(f"// @io output {pos} mem {gen.mem(reg)} dtype "
                   f"{r.dtype} width {w} shape {_shape_txt(r.shape)}")
    for rom in prog.roms:
        out.append(f"// @rom {rom.name} file rom/{rom.name}.mem "
                   f"words {max(rom.data.size, 1)}")
    for st in gen.states:
        if st.trace is not None:
            iid, op, mems = st.trace
            out.append(f"// @trace state {state_of[id(st)]} instr {iid} "
                       f"op {op} dests {' '.join(mems) or '-'}")
    return out


def emit_verilog(prog: Program, alloc: Allocation = None) -> str:
    """Emit the synthesizable netlist (core + top wrapper) for an
    executable program. Raises :class:`EmitError` /
    ``NotImplementedError`` outside the supported subset."""
    if not prog.executable:
        raise NotImplementedError(
            f"program {prog.name!r} contains a grid region and has no "
            "sequential netlist (census/verification surface only)")
    if alloc is None:
        alloc = allocate(prog)
    gen = _VGen(prog, alloc)
    gen.emit_body(prog.body)

    # state numbering: 0 = wait-for-start, then the generated states,
    # then the final done state
    num = {}
    for i, st in enumerate(gen.states):
        num[id(st)] = i + 1
    done_state = len(gen.states) + 1

    def succ(i, st):
        if st.next == ("seq",):
            return i + 2 if i + 1 < len(gen.states) else done_state
        if st.next[0] == "goto":
            return num[id(st.next[1])]
        return None

    body = []
    body.append("    0: begin if (start) state <= 1; end")
    for i, st in enumerate(gen.states):
        lbl = num[id(st)]
        body.append(f"    {lbl}: begin  // {st.tag}")
        for ln in st.lines:
            body.append(f"      {ln}")
        if st.next[0] == "branch":
            cond, st_t, st_f = st.next[1], st.next[2], st.next[3]
            body.append(f"      if ({cond}) state <= {num[id(st_t)]};")
            body.append(f"      else state <= {num[id(st_f)]};")
        else:
            body.append(f"      state <= {succ(i, st)};")
        body.append("    end")
    body.append(f"    {done_state}: begin done <= 1; end")
    body.append("    default: state <= 0;")

    decls = []
    rom_regs = set(prog.rom_of_reg)
    for r in prog.regs:
        if r.idx in rom_regs:
            continue
        n = max(r.size, 1)
        if r.dtype == "i1":
            decls.append(f"  reg r{r.idx} [0:{n - 1}];")
        else:
            w = alloc.width(r.idx)
            decls.append(f"  reg signed [{w - 1}:0] r{r.idx} "
                         f"[0:{n - 1}];")
    for rom in prog.roms:
        n = max(rom.data.size, 1)
        decls.append(f"  reg signed [31:0] {rom.name} [0:{n - 1}];")

    scratch = []
    for i in range(max(gen.max_t, 10)):
        scratch.append(f"  reg signed [31:0] t{i};")
    for i in range(gen.max_a):
        scratch.append(f"  integer a{i};")
    for i in range(gen.max_c):
        scratch.append(f"  integer c{i};")
    for name in gen.counter_decls:
        scratch.append(f"  integer {name};")
    for name, w, n, dt in gen.shadow_decls:
        if dt == "i1":
            scratch.append(f"  reg {name} [0:{n - 1}];")
        else:
            scratch.append(f"  reg signed [{w - 1}:0] {name} "
                           f"[0:{n - 1}];")
    scratch.append("  integer state;")

    inits = []
    for rom in prog.roms:
        inits.append(f"  initial $readmemh(\"rom/{rom.name}.mem\", "
                     f"{rom.name});")

    header = _render_header(prog, alloc, gen, num)
    lines = []
    lines.extend(header)
    lines.append("")
    lines.append(f"module {prog.name}(input wire clk, input wire rst, "
                 "input wire start, output reg done);")
    lines.extend(decls)
    lines.extend(scratch)
    lines.extend(inits)
    lines.append("  always @(posedge clk) begin")
    lines.append("    if (rst) begin")
    lines.append("      state <= 0;")
    lines.append("      done <= 0;")
    lines.append("    end else begin")
    lines.append("      case (state)")
    lines.extend("  " + ln for ln in body)
    lines.append("      endcase")
    lines.append("    end")
    lines.append("  end")
    lines.append("endmodule")
    lines.append("")
    lines.append(f"module {prog.name}_top(input wire clk, input wire "
                 "rst, input wire start, output wire done);")
    lines.append(f"  {prog.name} u_core(.clk(clk), .rst(rst), "
                 ".start(start), .done(done));")
    lines.append("endmodule")
    return "\n".join(lines) + "\n"


def emit_testbench(prog: Program, alloc: Allocation = None,
                   max_cycles: int = 200_000_000) -> str:
    """Self-checking iverilog testbench: loads width-matched input
    ``in_<mem>.mem`` images, runs to ``done``, writes ``out_<mem>.mem``
    via hierarchical references. Generated at test time, not committed
    (``repro.ir.vsim.write_input_mems`` / ``read_output_mems`` produce
    and consume the images)."""
    if alloc is None:
        alloc = allocate(prog)
    gen = _VGen(prog, alloc)   # only for mem naming
    lines = ["`timescale 1ns/1ps", "module tb;",
             "  reg clk = 0; reg rst = 1; reg start = 0; wire done;",
             f"  {prog.name}_top dut(.clk(clk), .rst(rst), "
             ".start(start), .done(done));",
             "  always #5 clk = ~clk;",
             "  initial begin"]
    for reg in prog.inputs:
        m = gen.mem(reg)
        lines.append(f"    $readmemh(\"in_{m}.mem\", dut.u_core.{m});")
    lines.append("    #20 rst = 0; start = 1;")
    lines.append("    wait (done);")
    lines.append("    @(posedge clk);")
    for reg in prog.outputs:
        m = gen.mem(reg)
        lines.append(f"    $writememh(\"out_{m}.mem\", dut.u_core.{m});")
    lines.append("    $finish;")
    lines.append("  end")
    lines.append(f"  initial begin #{10 * max_cycles} "
                 "$display(\"TB TIMEOUT\"); $finish; end")
    lines.append("endmodule")
    return "\n".join(lines) + "\n"
