"""Hardware-op census as an IR pass.

Counts the same multiply/add/compare/shift buckets as
``repro.analysis.legality.census_jaxpr``, over IR instructions instead of
jaxpr equations. Because the builder lowers 1:1 (one instruction per leaf
equation, ``loop``/``grid`` regions scaled by trip count, pow2-literal
muls already classified as shifts by the legality rules), the totals are
EXACTLY the jaxpr-walk numbers — ``benchmarks/hardware_cost.py`` pins the
two against each other at runtime, so the committed ``hw.*`` rows cannot
move. Bucket membership is imported from ``legality`` (single source of
truth): each instruction is classified by the jax primitive it was
lowered from, which is precisely what the jaxpr walk classifies.
"""

from __future__ import annotations

from collections import Counter

from repro.analysis.legality import (ADD_OPS, CMP_OPS, REDUCE_ADD_OPS,
                                     REDUCE_CMP_OPS, SHIFT_OPS)


def census_program(prog) -> Counter:
    """Scaled op census of an IR :class:`~repro.ir.isa.Program` —
    the drop-in equal of ``legality.census_jaxpr`` on the jaxpr the
    program was lowered from."""
    counts: Counter = Counter()

    def visit(instrs, scale: int) -> None:
        for ins in instrs:
            if ins.op in ("loop", "grid"):
                for rg in ins.regions:
                    visit(rg.body, scale * rg.trip_count)
                continue
            prim = ins.jax_prim
            n = ins.census_out_elems
            if prim == "mul":
                # the builder only admits pow2-literal scalings, which the
                # jaxpr census already counts as shifts
                counts["shift"] += n * scale
            elif prim in ADD_OPS:
                counts["add"] += n * scale
            elif prim in CMP_OPS:
                counts["compare"] += n * scale
            elif prim in SHIFT_OPS:
                counts["shift"] += n * scale
            elif prim in REDUCE_ADD_OPS:
                counts["add"] += max(ins.census_in_elems - n, 0) * scale
            elif prim in REDUCE_CMP_OPS:
                counts["compare"] += max(ins.census_in_elems - n, 0) * scale

    visit(prog.body, 1)
    return counts
