"""First-divergence localization between the IR interpreter and the
Verilog netlist simulator.

A failed end-to-end parity check on a multi-thousand-instruction program
says almost nothing; what you want is the FIRST instruction whose
committed destination registers differ, because everything after it is
noise. Both backends expose the same commit-ordered trace (the
interpreter fires per executed instruction — loop bodies per trip, the
loop itself once after its last trip — and the netlist's ``// @trace``
states fire in exactly that order), so the two streams are compared
positionally, register by register.

Memory stays O(1) in trace length: the interpreter pass stores only a
digest per (instruction, destination) pair, the simulator compares
digests on the fly and stops at the first mismatch, and a second
interpreter pass recovers the expected values for just that event.
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.ir import interp as ir_interp
from repro.ir import vsim

__all__ = ["Divergence", "first_divergence"]


@dataclasses.dataclass(frozen=True)
class Divergence:
    """The first trace event where the netlist differs from the IR."""
    event_index: int          # position in the commit-ordered trace
    cycle: int                # netlist cycle that committed the event
    state: int                # FSM state label
    instr_id: int             # emitter instruction id (see // @trace)
    op: str                   # IR opcode
    reg: str                  # first mismatching destination memory
    flat_index: int           # first differing flat element
    got: int                  # netlist value
    want: int                 # interpreter value

    def __str__(self) -> str:
        return (f"first divergence at trace event {self.event_index} "
                f"(cycle {self.cycle}, state {self.state}, instr "
                f"{self.instr_id} op={self.op}): {self.reg}"
                f"[{self.flat_index}] = {self.got}, interpreter says "
                f"{self.want}")


def _norm(v) -> np.ndarray:
    return np.asarray(v).astype(np.int64).ravel()


def _digest(arr: np.ndarray) -> bytes:
    return hashlib.blake2b(arr.tobytes(), digest_size=16).digest()


class _Stop(Exception):
    pass


def first_divergence(prog, netlist, inputs, rom_loader=None, *,
                     vectorize: bool = True):
    """Run ``prog`` through the interpreter and ``netlist`` through the
    simulator on the same ``inputs`` and return the first trace event
    whose destination registers differ, or ``None`` if the two replay
    identically (full outputs included)."""
    if isinstance(netlist, str):
        netlist = vsim.parse_netlist(netlist)

    # pass 1: interpreter digests, commit order
    ref: list = []

    def rec(ins, vals):
        ref.append((ins.op, tuple(_digest(_norm(v)) for v in vals)))

    want_outs = ir_interp.run(prog, inputs, trace=rec)

    hit: dict = {}

    def chk(cycle, state, iid, op, mems, vals):
        k = len(hit.setdefault("seen", []))
        hit["seen"].append(None)
        if k >= len(ref):
            hit["ev"] = (k, cycle, state, iid, op, mems, vals, -1)
            raise _Stop
        rop, rdigs = ref[k]
        if rop != op or len(rdigs) != len(vals):
            hit["ev"] = (k, cycle, state, iid, op, mems, vals, -2)
            raise _Stop
        for j, v in enumerate(vals):
            if _digest(v.astype(np.int64)) != rdigs[j]:
                hit["ev"] = (k, cycle, state, iid, op, mems, vals, j)
                raise _Stop

    try:
        got_outs = vsim.run_netlist(netlist, inputs, rom_loader,
                                    vectorize=vectorize, trace=chk)
    except _Stop:
        got_outs = None

    if "ev" not in hit:
        # traces identical; confirm the program outputs agree too
        for o, w in zip(got_outs, want_outs):
            if not np.array_equal(_norm(o), _norm(w)):
                raise AssertionError(
                    "trace replayed identically but outputs differ — "
                    "output wiring bug, not a datapath divergence")
        return None

    k, cycle, state, iid, op, mems, vals, j = hit["ev"]
    if j < 0:
        return Divergence(event_index=k, cycle=cycle, state=state,
                          instr_id=iid, op=op,
                          reg=mems[0] if mems else "?", flat_index=-1,
                          got=0, want=0)

    # pass 2: recover the expected values for event k only
    box: dict = {"i": 0}

    def cap(ins, vs):
        if box["i"] == k:
            box["want"] = [_norm(v) for v in vs]
        box["i"] += 1

    ir_interp.run(prog, inputs, trace=cap)
    want = box["want"][j]
    got = vals[j].astype(np.int64)
    n = min(len(got), len(want))
    bad = np.nonzero(got[:n] != want[:n])[0]
    fi = int(bad[0]) if len(bad) else n
    return Divergence(event_index=k, cycle=cycle, state=state,
                      instr_id=iid, op=op, reg=mems[j],
                      flat_index=fi,
                      got=int(got[fi]) if fi < len(got) else 0,
                      want=int(want[fi]) if fi < len(want) else 0)
