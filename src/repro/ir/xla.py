"""IR -> XLA emitter: close the round trip back to the deployed int path.

Emits a jax-traceable function from an executable IR
:class:`~repro.ir.isa.Program`, using the same ``lax`` primitives the
program was lowered from — one primitive per instruction, ``loop`` regions
back to ``lax.scan`` — so the emitted function is bit-for-bit identical to
the original ``fixed.infer_q``/``session_step_q`` computation (pinned on
the golden fixtures in tests/test_ir.py). This is the proof that the IR
is a faithful carrier: jaxpr -> IR -> XLA loses nothing.
"""

from __future__ import annotations

import numpy as np

from repro.ir.isa import Program

_CMP = {"lt": "lt", "le": "le", "gt": "gt", "ge": "ge",
        "eq": "eq", "ne": "ne"}


def emit(prog: Program):
    """Return ``fn(*inputs) -> tuple(outputs)``, a jax-traceable function
    reproducing ``prog`` with XLA int primitives."""
    if not prog.executable:
        raise NotImplementedError(
            f"program {prog.name!r} contains a grid region — only the "
            "sequential SSA stream re-emits to XLA")

    import jax.numpy as jnp
    from jax import lax

    rom_vals = {reg: jnp.asarray(prog.roms[rom].data)
                for reg, rom in prog.rom_of_reg.items()}

    def run_stream(instrs, env) -> None:
        for ins in instrs:
            step(ins, env)

    def step(ins, env) -> None:
        op, a = ins.op, ins.attrs
        src = [env[s] for s in ins.srcs]
        d0 = ins.dests[0] if ins.dests else None

        def bc(x, y):
            # scalar (rank-0) literal operands broadcast against arrays,
            # exactly as in the source jaxpr
            return jnp.broadcast_arrays(x, y)

        if op in ("add", "sub", "neg", "min", "max", "abs", "sign"):
            fn = {"add": lax.add, "sub": lax.sub, "neg": lax.neg,
                  "min": lax.min, "max": lax.max, "abs": lax.abs,
                  "sign": lax.sign}[op]
            args = bc(*src) if len(src) == 2 else src
            env[d0] = fn(*args)
        elif op == "clamp":
            lo, x, hi = src
            env[d0] = lax.clamp(jnp.broadcast_to(lo, x.shape), x,
                                jnp.broadcast_to(hi, x.shape))
        elif op in _CMP:
            fn = {"lt": lax.lt, "le": lax.le, "gt": lax.gt, "ge": lax.ge,
                  "eq": lax.eq, "ne": lax.ne}[op]
            env[d0] = fn(*bc(*src))
        elif op == "select_n":
            env[d0] = lax.select_n(src[0], *src[1:])
        elif op in ("and", "or", "xor"):
            fn = {"and": lax.bitwise_and, "or": lax.bitwise_or,
                  "xor": lax.bitwise_xor}[op]
            env[d0] = fn(*bc(*src))
        elif op == "not":
            env[d0] = lax.bitwise_not(src[0])
        elif op in ("shl", "shra", "shrl"):
            fn = {"shl": lax.shift_left,
                  "shra": lax.shift_right_arithmetic,
                  "shrl": lax.shift_right_logical}[op]
            x = src[0]
            k = (jnp.asarray(np.int32(a["imm"])) if "imm" in a else src[1])
            env[d0] = fn(*bc(x, k))
        elif op == "reduce_sum":
            env[d0] = jnp.sum(src[0], axis=tuple(a["axes"]))
        elif op == "reduce_max":
            env[d0] = jnp.max(src[0], axis=tuple(a["axes"]))
        elif op == "reduce_min":
            env[d0] = jnp.min(src[0], axis=tuple(a["axes"]))
        elif op == "broadcast":
            env[d0] = lax.broadcast_in_dim(
                src[0], tuple(a["shape"]),
                tuple(a["broadcast_dimensions"]))
        elif op == "reshape":
            env[d0] = jnp.reshape(src[0], tuple(a["new_shape"]))
        elif op == "transpose":
            env[d0] = lax.transpose(src[0], tuple(a["permutation"]))
        elif op == "rev":
            env[d0] = lax.rev(src[0], tuple(a["dimensions"]))
        elif op == "slice":
            env[d0] = lax.slice(src[0], a["start_indices"],
                                a["limit_indices"], a["strides"])
        elif op == "concat":
            env[d0] = lax.concatenate(src, int(a["dimension"]))
        elif op == "pad":
            env[d0] = lax.pad(src[0], jnp.reshape(src[1], ()),
                              [tuple(c) for c in a["padding_config"]])
        elif op == "iota":
            env[d0] = lax.broadcasted_iota(jnp.int32, tuple(a["shape"]),
                                           int(a["dimension"]))
        elif op == "convert":
            env[d0] = lax.convert_element_type(
                src[0], jnp.bool_ if a["to"] == "i1" else jnp.int32)
        elif op == "mov":
            env[d0] = src[0]
        elif op == "gather":
            dn = lax.GatherDimensionNumbers(
                offset_dims=tuple(a["offset_dims"]),
                collapsed_slice_dims=tuple(a["collapsed_slice_dims"]),
                start_index_map=tuple(a["start_index_map"]),
                operand_batching_dims=tuple(a["operand_batching_dims"]),
                start_indices_batching_dims=tuple(
                    a["start_indices_batching_dims"]))
            env[d0] = lax.gather(
                src[0], src[1], dn, tuple(a["slice_sizes"]),
                mode=lax.GatherScatterMode.PROMISE_IN_BOUNDS)
        elif op == "dynamic_slice":
            env[d0] = lax.dynamic_slice(src[0], src[1:], a["slice_sizes"])
        elif op == "dynamic_update_slice":
            env[d0] = lax.dynamic_update_slice(src[0], src[1], src[2:])
        elif op == "loop":
            rg = ins.regions[0]
            nc, nk = a["num_consts"], a["num_carry"]
            length = a["length"]
            consts = src[:nc]
            init = tuple(src[nc:nc + nk])
            xs = tuple(src[nc + nk:])

            def body(carry, x):
                benv = dict(rom_vals)
                for r, v in zip(rg.inputs[:nc], consts):
                    benv[r] = v
                for r, v in zip(rg.inputs[nc:nc + nk], carry):
                    benv[r] = v
                for r, v in zip(rg.inputs[nc + nk:], x):
                    benv[r] = v
                run_stream(rg.body, benv)
                outs = [benv[o] for o in rg.outputs]
                return tuple(outs[:nk]), tuple(outs[nk:])

            carry, ys = lax.scan(body, init, xs, length=length,
                                 reverse=rg.attrs.get("reverse", False))
            for d, v in zip(ins.dests[:nk], carry):
                env[d] = v
            for d, v in zip(ins.dests[nk:], ys):
                env[d] = v
        else:
            raise NotImplementedError(f"IR op {op!r} in XLA emitter")

    def fn(*inputs):
        if len(inputs) != len(prog.inputs):
            raise ValueError(
                f"program {prog.name!r} takes {len(prog.inputs)} inputs, "
                f"got {len(inputs)}")
        env = dict(rom_vals)
        for r, v in zip(prog.inputs, inputs):
            env[r] = jnp.asarray(v)
        run_stream(prog.body, env)
        return tuple(env[o] for o in prog.outputs)

    return fn
