"""The typed fixed-point op-stream IR: register model + instruction set.

This is the explicit lowering artifact between the trained model and the
hardware target (ROADMAP: "unify program lowering into a small fixed-point
IR with pluggable backends"). A :class:`Program` is a flat stream of
:class:`Instr` over an SSA register file of :class:`Reg` — every register
carries its static shape, its carrier dtype, and (when the program was
built with input intervals) the PROVEN worst-case value interval and the
minimal two's-complement width from ``repro.analysis.intervals``. That
register table is exactly what a netlist register-allocator consumes; the
instruction stream is exactly what the C/ROM emitter and the Python
ground-truth interpreter execute.

The instruction set is the paper's primitive contract, made explicit:

==============  ===========================================================
class           opcodes
==============  ===========================================================
arith           ``add sub neg min max abs sign clamp``
shift           ``shl shra shrl`` (operand or immediate ``imm`` amounts)
compare         ``lt le gt ge eq ne``
select          ``select_n``
bitwise         ``and or xor not``
reduce          ``reduce_sum reduce_max reduce_min`` (attr ``axes``)
movement        ``mov broadcast reshape transpose rev slice gather
                concat pad iota convert dynamic_slice
                dynamic_update_slice``
control         ``loop`` (a scan region: consts + carries + per-trip xs),
                ``grid`` (a pallas grid region — census/verification only)
ref (grid)      ``ref_get ref_swap program_id num_programs`` — movement
                inside a ``grid`` region's memory cells
const           ``rom`` (a named constant table), scalar immediates in
                ``attrs``
==============  ===========================================================

There is deliberately NO multiply, NO divide and NO float opcode: a
program that cannot be expressed here cannot be built, so "the datapath is
multiplierless" is a *type error*, not a census result. (The one
mul-shaped thing hardware does — scaling by a constant power of two — is
required to arrive as a ``shl``/``shra``; ``build`` folds literal-pow2
multiplies into shifts and rejects everything else.)

Instructions remember the jaxpr primitive they were lowered from
(``Instr.jax_prim``) plus the census element counts, so the IR census pass
(``repro.ir.census``) reproduces the jaxpr-walk census numbers EXACTLY —
the committed ``hw.*`` benchmark rows are pinned byte-identical.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "Reg", "Rom", "Instr", "Region", "Program",
    "ARITH_OPS", "SHIFT_OPS", "CMP_OPS", "SELECT_OPS", "BITWISE_OPS",
    "REDUCE_ADD_OPS", "REDUCE_CMP_OPS", "MOVE_OPS", "CONTROL_OPS",
    "REF_OPS", "ALL_OPS", "DTYPES",
]

# dtype codes: the IR carries two value kinds only — the int32 datapath
# carrier and the 1-bit predicate wires comparisons produce
DTYPES = ("i32", "i1")

ARITH_OPS = frozenset({"add", "sub", "neg", "min", "max", "abs", "sign",
                       "clamp"})
SHIFT_OPS = frozenset({"shl", "shra", "shrl"})
CMP_OPS = frozenset({"lt", "le", "gt", "ge", "eq", "ne"})
SELECT_OPS = frozenset({"select_n"})
BITWISE_OPS = frozenset({"and", "or", "xor", "not"})
REDUCE_ADD_OPS = frozenset({"reduce_sum"})
REDUCE_CMP_OPS = frozenset({"reduce_max", "reduce_min"})
MOVE_OPS = frozenset({
    "mov", "broadcast", "reshape", "transpose", "rev", "slice", "gather",
    "concat", "pad", "iota", "convert", "dynamic_slice",
    "dynamic_update_slice",
})
CONTROL_OPS = frozenset({"loop", "grid", "cond"})
REF_OPS = frozenset({"ref_get", "ref_swap", "program_id", "num_programs"})

ALL_OPS = (ARITH_OPS | SHIFT_OPS | CMP_OPS | SELECT_OPS | BITWISE_OPS
           | REDUCE_ADD_OPS | REDUCE_CMP_OPS | MOVE_OPS | CONTROL_OPS
           | REF_OPS)


@dataclasses.dataclass(frozen=True)
class Reg:
    """One SSA value: a typed register (scalar or tensor).

    ``bits`` is the carrier width (32 for the int32 datapath, 1 for
    predicate wires). ``interval``/``required_bits`` are the worst-case
    facts from the interval pass when the program was built with declared
    input intervals — ``required_bits`` is the minimal two's-complement
    register a netlist needs, ``bits`` what the software carrier spends.
    ``None`` means the fact was not computed (untyped build) or the value
    is a predicate.
    """
    idx: int
    shape: tuple
    dtype: str                              # "i32" | "i1"
    bits: int                               # carrier width
    interval: Optional[tuple] = None        # (lo, hi) exact ints
    required_bits: Optional[int] = None

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= int(d)
        return n

    @property
    def storage_bits(self) -> int:
        """The width a netlist register allocator assigns this register:
        1 for predicate wires, the proven minimal two's-complement width
        when the program was typed by the interval pass, the full carrier
        width otherwise. Never below 1."""
        if self.dtype == "i1":
            return 1
        if self.required_bits is not None:
            return max(1, int(self.required_bits))
        return int(self.bits)

    def short(self) -> str:
        iv = "" if self.interval is None else \
            f" in [{self.interval[0]}, {self.interval[1]}]" \
            f" ({self.required_bits}b)"
        shp = "x".join(str(d) for d in self.shape) or "scalar"
        return f"r{self.idx}:{self.dtype}[{shp}]{iv}"


@dataclasses.dataclass(frozen=True)
class Rom:
    """A named constant table (taps, mu, shift tables, classifier weights):
    the contents of one hardware ROM. ``data`` is a host int32 (or bool)
    ndarray; the C emitter writes one ``.mem`` init file per ROM."""
    idx: int
    name: str
    data: np.ndarray

    @property
    def shape(self) -> tuple:
        return tuple(self.data.shape)


@dataclasses.dataclass(frozen=True)
class Instr:
    """One IR instruction: ``dest = op(srcs, **attrs)``.

    ``srcs`` are register indices; ``dests`` usually one register (``loop``
    carries + stacked outputs make it several). ``attrs`` hold the static
    parameters (shift immediates, reduce axes, gather dimension numbers…)
    as plain JSON-serializable values. ``regions`` holds the sub-programs
    of control instructions (the ``loop`` body / ``grid`` kernel).

    ``jax_prim`` + ``census_out_elems``/``census_in_elems`` pin the census
    semantics of the jaxpr equation this instruction was lowered from, so
    the IR census is bit-identical to the legacy jaxpr-walk census.
    """
    op: str
    dests: tuple
    srcs: tuple
    attrs: dict
    regions: tuple = ()
    jax_prim: str = ""
    census_out_elems: int = 0
    census_in_elems: int = 0


@dataclasses.dataclass
class Region:
    """A control instruction's sub-program: its own instruction stream over
    the shared register file. ``inputs`` are the registers the region binds
    per entry (loop: consts + carries + per-trip x slices; grid: cells),
    ``outputs`` the registers it yields per trip."""
    kind: str                    # "loop" | "grid"
    trip_count: int              # loop length / pallas grid product
    inputs: tuple                # reg indices bound at region entry
    outputs: tuple               # reg indices yielded per trip
    body: list = dataclasses.field(default_factory=list)   # [Instr]
    attrs: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Program:
    """A lowered fixed-point program: typed registers + ROMs + the op
    stream. ``executable`` is False for programs containing a ``grid``
    region (census/verification surface only — the Pallas kernel's memory
    cells have no sequential SSA execution here)."""
    name: str
    inputs: tuple                # reg indices, program argument order
    outputs: tuple               # reg indices, program result order
    regs: list                   # Reg, indexed by Reg.idx
    roms: list                   # Rom, indexed by Rom.idx
    rom_of_reg: dict             # reg idx -> rom idx (const registers)
    body: list                   # [Instr]
    meta: dict = dataclasses.field(default_factory=dict)
    executable: bool = True

    # -- introspection ----------------------------------------------------

    def num_instrs(self) -> int:
        def count(instrs) -> int:
            n = 0
            for ins in instrs:
                n += 1
                for rg in ins.regions:
                    n += count(rg.body)
            return n
        return count(self.body)

    def rom_bytes(self) -> int:
        return sum(r.data.size * 4 for r in self.roms)

    def register_table(self) -> list:
        """The netlist view: every typed register with its proven width,
        sorted by index (deterministic)."""
        rows = []
        for r in self.regs:
            rows.append({
                "reg": r.idx,
                "shape": list(r.shape),
                "dtype": r.dtype,
                "carrier_bits": r.bits,
                "interval": (None if r.interval is None
                             else [int(r.interval[0]), int(r.interval[1])]),
                "required_bits": r.required_bits,
            })
        return rows
