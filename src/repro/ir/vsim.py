"""Cycle simulator for the emitted Verilog netlist subset.

This container has no iverilog, so the simulation half of the netlist
parity gate is in-repo: :func:`run_netlist` parses EXACTLY the subset
``repro.ir.verilog`` emits (module + instantiation, register/memory
declarations, ``$readmemh`` ROM initialization, one clocked ``always``
FSM with ``case``/``if``/``for``, blocking and nonblocking assigns) and
replays it cycle by cycle with Verilog's 32-bit-signed expression
semantics:

* every declared object stores its value CANONICALLY sign-extended (what
  a ``$signed`` read of the W-bit cell yields), so loads are identity
  and stores truncate-and-sign-extend to the declared width;
* operators evaluate in 32-bit two's-complement context (the emitter pins
  every expression to that context); shift amounts are unsigned with
  >=32 saturating to 0 / sign-fill, per the LRM;
* nonblocking assigns (``state``/``done``) apply at cycle end.

Executing one element per FSM visit would be hopeless in Python (the
full one-shot program retires ~3.4e8 element-ops), so each behavioral
``for`` nest is vectorized: the emitter maintains addresses as
constant-add induction registers, which makes every address an affine
function of the loop coordinates — the simulator recovers the stride
vectors from the increment statements, materializes the whole iteration
space as numpy arrays, and recognizes the emitter's canonical
read-modify-write reduction body as ``np.add.at`` /
``np.maximum.at`` / ``np.minimum.at`` (sound: adds compose mod 2**W,
order-free; max/min partials stay inside the destination's proven
interval so the W-bit store is exact). Any state the vectorizer does not
recognize falls back to a faithful statement-by-statement interpretation
— ``vectorize=False`` forces that slow path everywhere, and the test
suite pins fast == slow.

``// @io`` / ``// @rom`` / ``// @trace`` header comments (machine
metadata the emitter writes) map program inputs/outputs onto memories,
ROM memories onto their committed ``rom/*.mem`` images, and FSM states
onto IR instructions for register-granular trace comparison
(``repro.ir.debug``).
"""

from __future__ import annotations

import dataclasses
import os
import re

import numpy as np

__all__ = [
    "VsimError", "IoPort", "Netlist", "parse_netlist", "run_netlist",
    "rom_loader_from_dir", "rom_loader_from_mems", "parse_mem_words",
    "write_input_mems", "read_output_mems", "have_iverilog",
    "run_iverilog",
]

_M32 = 0xFFFFFFFF
_KEYWORDS = {
    "module", "endmodule", "input", "output", "inout", "wire", "reg",
    "integer", "signed", "initial", "always", "begin", "end", "if",
    "else", "case", "endcase", "default", "for", "posedge", "negedge",
}


class VsimError(Exception):
    """The netlist is outside the simulated subset (or misbehaves)."""


# ---------------------------------------------------------------------------
# 32-bit-signed-context arithmetic (scalar ints and numpy arrays)
# ---------------------------------------------------------------------------


def _w32(v):
    """Wrap to canonical 32-bit two's-complement (int or int64 array)."""
    if isinstance(v, np.ndarray):
        return ((v & _M32) ^ 0x80000000) - 0x80000000
    v &= _M32
    return v - 0x100000000 if v & 0x80000000 else v


def _canon(v, width: int, signed: bool = True):
    """Truncate to ``width`` bits and store canonically: what a read of
    the W-bit cell yields in a 32-bit context — sign-extended for
    ``reg signed`` declarations, zero-extended otherwise."""
    if width >= 32:
        return _w32(v)
    mask = (1 << width) - 1
    if not signed:
        return v & mask
    sign = 1 << (width - 1)
    if isinstance(v, np.ndarray):
        return ((v & mask) ^ sign) - sign
    v &= mask
    return v - (1 << width) if v & sign else v


def _shl(a, k):
    if isinstance(a, np.ndarray) or isinstance(k, np.ndarray):
        ku = np.minimum(np.asarray(k, np.int64) & _M32, 32)
        return _w32(np.left_shift(np.asarray(a, np.int64), ku))
    ku = k & _M32
    return 0 if ku >= 32 else _w32(a << ku)


def _shra(a, k):
    if isinstance(a, np.ndarray) or isinstance(k, np.ndarray):
        ku = np.minimum(np.asarray(k, np.int64) & _M32, 31)
        return np.right_shift(np.asarray(a, np.int64), ku)
    ku = min(k & _M32, 31)
    return a >> ku


def _shrl(a, k):
    if isinstance(a, np.ndarray) or isinstance(k, np.ndarray):
        ku = np.minimum(np.asarray(k, np.int64) & _M32, 32)
        return _w32(np.right_shift(np.asarray(a, np.int64) & _M32, ku))
    ku = k & _M32
    return 0 if ku >= 32 else _w32((a & _M32) >> ku)


def _as_flag(v):
    if isinstance(v, np.ndarray):
        return (v != 0)
    return v != 0


def _flag_int(b):
    if isinstance(b, np.ndarray):
        return b.astype(np.int64)
    return 1 if b else 0


# ---------------------------------------------------------------------------
# tokenizer
# ---------------------------------------------------------------------------

_TOKEN_RE = re.compile(r"""
      (?P<ws>\s+)
    | (?P<str>"[^"]*")
    | (?P<num>\d+)
    | (?P<id>\$?[A-Za-z_][A-Za-z0-9_]*)
    | (?P<op><<|>>>|>>|<=|>=|==|!=|&&|\|\||[-+&|^~!<>?:;,.=()\[\]{}@#*/])
""", re.VERBOSE)


def _tokenize(text: str) -> list:
    text = re.sub(r"//[^\n]*", "", text)
    text = re.sub(r"`[^\n]*", "", text)        # `timescale etc.
    toks = []
    pos = 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if m is None:
            raise VsimError(f"lex error near {text[pos:pos + 30]!r}")
        pos = m.end()
        if m.lastgroup == "ws":
            continue
        toks.append((m.lastgroup, m.group()))
    toks.append(("eof", ""))
    return toks


# ---------------------------------------------------------------------------
# parser
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Decl:
    kind: str        # "mem" | "reg" | "integer"
    width: int
    signed: bool
    size: int        # memory words (1 for scalars)


@dataclasses.dataclass
class _Module:
    name: str
    ports: list
    decls: dict
    readmems: list               # (file, mem_name)
    always: object               # stmt or None
    instances: list              # (module_name, inst_name)


class _Parser:
    def __init__(self, toks):
        self.toks = toks
        self.i = 0

    def peek(self, k=0):
        return self.toks[min(self.i + k, len(self.toks) - 1)]

    def next(self):
        t = self.toks[self.i]
        self.i += 1
        return t

    def expect(self, val):
        t = self.next()
        if t[1] != val:
            raise VsimError(f"expected {val!r}, got {t[1]!r}")
        return t

    def accept(self, val) -> bool:
        if self.peek()[1] == val:
            self.i += 1
            return True
        return False

    # -- modules ----------------------------------------------------------

    def parse_file(self) -> list:
        mods = []
        while self.peek()[0] != "eof":
            if self.peek()[1] == "module":
                mods.append(self.parse_module())
            else:
                self.next()
        return mods

    def parse_module(self) -> _Module:
        self.expect("module")
        name = self.next()[1]
        ports = []
        if self.accept("("):
            while not self.accept(")"):
                t = self.next()
                if t[0] == "id" and t[1] not in _KEYWORDS:
                    ports.append(t[1])
        self.expect(";")
        mod = _Module(name, ports, {}, [], None, [])
        for p in ports:
            mod.decls.setdefault(p, _Decl("reg", 1, False, 1))
        while not self.accept("endmodule"):
            self.parse_item(mod)
        return mod

    def parse_item(self, mod: _Module) -> None:
        t = self.peek()
        if t[1] in ("input", "output", "inout"):
            self.next()
            while self.peek()[1] in ("wire", "reg", "signed"):
                self.next()
            nm = self.next()[1]
            mod.decls[nm] = _Decl("reg", 1, False, 1)
            self.expect(";")
        elif t[1] == "reg":
            self.next()
            signed = self.accept("signed")
            width = 1
            if self.accept("["):
                hi = int(self.next()[1])
                self.expect(":")
                lo = int(self.next()[1])
                self.expect("]")
                width = hi - lo + 1
            nm = self.next()[1]
            if self.accept("["):
                lo = int(self.next()[1])
                self.expect(":")
                hi = int(self.next()[1])
                self.expect("]")
                mod.decls[nm] = _Decl("mem", width, signed, hi - lo + 1)
            else:
                mod.decls[nm] = _Decl("reg", width, signed, 1)
            self.expect(";")
        elif t[1] == "integer":
            self.next()
            nm = self.next()[1]
            mod.decls[nm] = _Decl("integer", 32, True, 1)
            self.expect(";")
        elif t[1] == "initial":
            self.next()
            st = self.parse_stmt()
            for call in self._calls(st):
                if call[1] == "$readmemh":
                    args = call[2]
                    if (len(args) != 2 or args[0][0] != "str"
                            or args[1][0] != "var"):
                        raise VsimError("unsupported $readmemh form")
                    mod.readmems.append((args[0][1], args[1][1]))
        elif t[1] == "always":
            self.next()
            self.expect("@")
            self.expect("(")
            self.expect("posedge")
            self.next()                     # clock name
            self.expect(")")
            if mod.always is not None:
                raise VsimError("multiple always blocks")
            mod.always = self.parse_stmt()
        elif t[0] == "id":
            # module instantiation: NAME inst ( .port(expr), ... ) ;
            mname = self.next()[1]
            iname = self.next()[1]
            self.expect("(")
            depth = 1
            while depth:
                tv = self.next()
                if tv[1] == "(":
                    depth += 1
                elif tv[1] == ")":
                    depth -= 1
                elif tv[0] == "eof":
                    raise VsimError("unterminated instantiation")
            self.expect(";")
            mod.instances.append((mname, iname))
        else:
            raise VsimError(f"unexpected token {t[1]!r} in module body")

    def _calls(self, st):
        if st[0] == "call":
            yield st
        elif st[0] == "block":
            for s in st[1]:
                yield from self._calls(s)

    # -- statements -------------------------------------------------------

    def parse_stmt(self):
        t = self.peek()
        if t[1] == "begin":
            self.next()
            body = []
            while not self.accept("end"):
                body.append(self.parse_stmt())
            return ("block", body)
        if t[1] == "if":
            self.next()
            self.expect("(")
            cond = self.parse_expr()
            self.expect(")")
            then = self.parse_stmt()
            other = None
            if self.accept("else"):
                other = self.parse_stmt()
            return ("if", cond, then, other)
        if t[1] == "case":
            self.next()
            self.expect("(")
            sel = self.parse_expr()
            self.expect(")")
            items = {}
            default = None
            while not self.accept("endcase"):
                if self.accept("default"):
                    self.expect(":")
                    default = self.parse_stmt()
                else:
                    lbl = int(self.next()[1])
                    self.expect(":")
                    items[lbl] = self.parse_stmt()
            return ("case", sel, items, default)
        if t[1] == "for":
            self.next()
            self.expect("(")
            init = self.parse_assign(stop=";")
            cond = self.parse_expr()
            self.expect(";")
            step = self.parse_assign(stop=")")
            body = self.parse_stmt()
            return ("for", init, cond, step, body)
        if t[1].startswith("$"):
            name = self.next()[1]
            args = []
            if self.accept("("):
                while not self.accept(")"):
                    if self.peek()[0] == "str":
                        args.append(("str", self.next()[1].strip('"')))
                    else:
                        args.append(self.parse_expr())
                    self.accept(",")
            self.expect(";")
            return ("call", name, args)
        return self.parse_assign(stop=";")

    def parse_assign(self, stop):
        nm = self.next()
        if nm[0] != "id":
            raise VsimError(f"bad lvalue {nm[1]!r}")
        lhs = ("var", nm[1])
        if self.accept("["):
            idx = self.parse_expr()
            self.expect("]")
            lhs = ("idx", nm[1], idx)
        if self.accept("="):
            blocking = True
        elif self.accept("<="):
            blocking = False
        else:
            raise VsimError(f"expected assignment after {nm[1]!r}")
        rhs = self.parse_expr()
        self.expect(stop)
        return ("assign", lhs, rhs, blocking)

    # -- expressions ------------------------------------------------------

    _BINPREC = [
        ("||",), ("&&",), ("|",), ("^",), ("&",), ("==", "!="),
        ("<", "<=", ">", ">="), ("<<", ">>", ">>>"), ("+", "-"),
    ]

    def parse_expr(self):
        return self._ternary()

    def _ternary(self):
        c = self._binary(0)
        if self.accept("?"):
            a = self._ternary()
            self.expect(":")
            b = self._ternary()
            return ("tern", c, a, b)
        return c

    def _binary(self, lvl):
        if lvl >= len(self._BINPREC):
            return self._unary()
        ops = self._BINPREC[lvl]
        e = self._binary(lvl + 1)
        while self.peek()[1] in ops:
            op = self.next()[1]
            rhs = self._binary(lvl + 1)
            e = ("bin", op, e, rhs)
        return e

    def _unary(self):
        t = self.peek()
        if t[1] in ("-", "~", "!", "+"):
            self.next()
            return ("unary", t[1], self._unary())
        return self._primary()

    def _primary(self):
        t = self.next()
        if t[0] == "num":
            return ("num", int(t[1]))
        if t[1] == "(":
            e = self.parse_expr()
            self.expect(")")
            return e
        if t[1] in ("$signed", "$unsigned"):
            self.expect("(")
            e = self.parse_expr()
            self.expect(")")
            return ("signed", e) if t[1] == "$signed" else e
        if t[0] == "id":
            name = t[1]
            if self.accept("["):
                first = self.parse_expr()
                if self.accept(":"):
                    lo = self.parse_expr()
                    self.expect("]")
                    if first[0] != "num" or lo[0] != "num":
                        raise VsimError("part-select bounds must be "
                                        "constant")
                    return ("psel", name, first[1], lo[1])
                self.expect("]")
                return ("idx", name, first)
            return ("var", name)
        raise VsimError(f"unexpected token {t[1]!r} in expression")


# ---------------------------------------------------------------------------
# netlist metadata (// @... header comments)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class IoPort:
    pos: int
    mem: str
    dtype: str
    width: int
    shape: tuple


@dataclasses.dataclass
class Netlist:
    text: str
    name: str
    modules: list
    core: _Module
    inputs: list                  # [IoPort]
    outputs: list                 # [IoPort]
    roms: list                    # (mem_name, file, words)
    trace_map: dict               # state -> (instr_id, op, [mems])
    meta: dict


def _parse_shape(txt: str) -> tuple:
    if txt == "-":
        return ()
    return tuple(int(d) for d in txt.split("x"))


def parse_netlist(text: str) -> Netlist:
    meta = {}
    ins, outs, roms = [], [], []
    trace_map = {}
    for line in text.splitlines():
        line = line.strip()
        if not line.startswith("// @"):
            continue
        f = line[3:].split()
        if f[0] == "@meta":
            meta[f[1]] = f[2]
        elif f[0] == "@io":
            port = IoPort(pos=int(f[2]), mem=f[4], dtype=f[6],
                          width=int(f[8]), shape=_parse_shape(f[10]))
            (ins if f[1] == "input" else outs).append(port)
        elif f[0] == "@rom":
            roms.append((f[1], f[3], int(f[5])))
        elif f[0] == "@trace":
            dests = [] if f[8] == "-" else f[8:]
            trace_map[int(f[2])] = (int(f[4]), f[6], dests)
    mods = _Parser(_tokenize(text)).parse_file()
    cores = [m for m in mods if m.always is not None]
    if len(cores) != 1:
        raise VsimError(f"expected exactly one clocked module, "
                        f"found {len(cores)}")
    core = cores[0]
    ins.sort(key=lambda p: p.pos)
    outs.sort(key=lambda p: p.pos)
    return Netlist(text=text, name=meta.get("name", core.name),
                   modules=mods, core=core, inputs=ins, outputs=outs,
                   roms=roms, trace_map=trace_map, meta=meta)


# ---------------------------------------------------------------------------
# .mem image helpers (shared with the iverilog testbench path)
# ---------------------------------------------------------------------------


def parse_mem_words(text: str, width: int = 32) -> np.ndarray:
    vals = []
    for tok in text.split():
        if tok.startswith("//") or tok.startswith("@"):
            continue
        v = int(tok, 16)
        vals.append(_canon(v, width))
    return np.asarray(vals, dtype=np.int64)


def rom_loader_from_dir(base_dir: str):
    """ROM loader resolving the netlist's ``rom/<name>.mem`` paths
    against a directory (e.g. ``artifacts/ir/<target>``)."""
    def load(path: str) -> np.ndarray:
        with open(os.path.join(base_dir, path)) as f:
            return parse_mem_words(f.read(), 32)
    return load


def rom_loader_from_mems(mems: dict):
    """ROM loader over in-memory ``{filename: text}`` images — exactly
    what ``repro.ir.cgen.emit_rom_mem`` returns."""
    def load(path: str) -> np.ndarray:
        return parse_mem_words(mems[os.path.basename(path)], 32)
    return load


def write_input_mems(net: Netlist, inputs, out_dir: str) -> list:
    """Write width-matched ``in_<mem>.mem`` images for the testbench."""
    if len(inputs) != len(net.inputs):
        raise VsimError(f"netlist takes {len(net.inputs)} inputs, "
                        f"got {len(inputs)}")
    paths = []
    for port, val in zip(net.inputs, inputs):
        arr = np.asarray(val).astype(np.int64).ravel()
        digits = max(1, (port.width + 3) // 4)
        mask = (1 << port.width) - 1
        lines = [format(int(v) & mask, f"0{digits}x") for v in arr]
        if not lines:
            lines = ["0"]
        p = os.path.join(out_dir, f"in_{port.mem}.mem")
        with open(p, "w") as f:
            f.write("\n".join(lines) + "\n")
        paths.append(p)
    return paths


def read_output_mems(net: Netlist, out_dir: str) -> list:
    """Parse the testbench's ``out_<mem>.mem`` images back into shaped
    arrays (sign-extending from the allocated width)."""
    outs = []
    for port in net.outputs:
        with open(os.path.join(out_dir, f"out_{port.mem}.mem")) as f:
            vals = parse_mem_words(f.read(), port.width)
        outs.append(_shape_out(port, vals))
    return outs


def _shape_out(port: IoPort, flat: np.ndarray):
    n = 1
    for d in port.shape:
        n *= d
    flat = flat[:n].reshape(port.shape)
    if port.dtype == "i1":
        return flat != 0
    return flat.astype(np.int32)


# ---------------------------------------------------------------------------
# real-simulator path (taken automatically when iverilog is installed)
# ---------------------------------------------------------------------------


def have_iverilog() -> bool:
    import shutil
    return shutil.which("iverilog") is not None


def run_iverilog(netlist_text: str, tb_text: str, inputs,
                 rom_dir: str | None = None, rom_mems: dict | None = None):
    """Compile the emitted netlist + testbench with iverilog, run it
    under vvp, and return the program outputs (same shapes/dtypes as
    :func:`run_netlist`). ROM images come from ``rom_dir`` (a committed
    ``artifacts/ir/<target>`` tree) or in-memory ``rom_mems``."""
    import shutil
    import subprocess
    import tempfile

    net = parse_netlist(netlist_text)
    with tempfile.TemporaryDirectory(prefix="vsim_iv_") as work:
        with open(os.path.join(work, "design.v"), "w") as f:
            f.write(netlist_text)
        with open(os.path.join(work, "tb.v"), "w") as f:
            f.write(tb_text)
        if net.roms:
            os.makedirs(os.path.join(work, "rom"), exist_ok=True)
            for _mem, fname, _words in net.roms:
                base = os.path.basename(fname)
                dst = os.path.join(work, "rom", base)
                if rom_mems is not None:
                    with open(dst, "w") as f:
                        f.write(rom_mems[base])
                elif rom_dir is not None:
                    shutil.copyfile(os.path.join(rom_dir, "rom", base),
                                    dst)
                else:
                    raise VsimError("netlist has ROMs but neither "
                                    "rom_dir nor rom_mems was given")
        write_input_mems(net, inputs, work)
        subprocess.run(["iverilog", "-g2005", "-o", "sim.vvp",
                        "design.v", "tb.v"],
                       cwd=work, check=True, capture_output=True)
        subprocess.run(["vvp", "sim.vvp"], cwd=work, check=True,
                       capture_output=True)
        return read_output_mems(net, work)


# ---------------------------------------------------------------------------
# the simulator
# ---------------------------------------------------------------------------


class _VecPlan:
    """Compiled form of one behavioral ``for`` nest: affine induction
    values over the full iteration space + a vectorizable body."""

    def __init__(self, dims, loop_vars, advances, ops):
        self.dims = dims                  # [int]
        self.loop_vars = loop_vars        # [name] per level
        self.advances = advances          # {var: [adv per level]}
        self.ops = ops                    # compiled body ops


class _Sim:
    def __init__(self, net: Netlist, rom_loader=None, vectorize=True,
                 trace=None, max_cycles: int = 200_000_000):
        self.net = net
        self.mod = net.core
        self.vectorize = vectorize
        self.trace = trace
        self.max_cycles = max_cycles
        self.env: dict = {}
        self.nb: list = []
        self._plans: dict = {}            # id(for-node) -> _VecPlan|None
        for name, d in self.mod.decls.items():
            if d.kind == "mem":
                self.env[name] = np.zeros(d.size, dtype=np.int64)
            else:
                self.env[name] = 0
        for path, mem in self.mod.readmems:
            if rom_loader is None:
                raise VsimError(
                    f"netlist reads {path!r} but no rom_loader given")
            data = np.asarray(rom_loader(path), dtype=np.int64)
            d = self.mod.decls.get(mem)
            if d is None or d.kind != "mem":
                raise VsimError(f"$readmemh into unknown memory {mem!r}")
            n = min(len(data), d.size)
            self.env[mem][:n] = _canon(data[:n], d.width, d.signed)

    # -- register-file access --------------------------------------------

    def poke(self, port: IoPort, value) -> None:
        arr = np.asarray(value).astype(np.int64).ravel()
        mem = self.env[port.mem]
        d = self.mod.decls[port.mem]
        mem[:len(arr)] = _canon(arr, port.width, d.signed)

    def peek(self, port: IoPort):
        return _shape_out(port, self.env[port.mem].copy())

    # -- evaluation (scalar) ---------------------------------------------

    def eval(self, e):
        k = e[0]
        if k == "num":
            return e[1]
        if k == "var":
            v = self.env[e[1]]
            if isinstance(v, np.ndarray):
                raise VsimError(f"memory {e[1]!r} used as scalar")
            return v
        if k == "idx":
            arr = self.env[e[1]]
            if not isinstance(arr, np.ndarray):
                raise VsimError(f"indexing non-memory {e[1]!r}")
            return int(arr[self.eval(e[2])])
        if k == "psel":
            v = self.eval_name_scalar(e[1])
            width = e[2] - e[3] + 1
            return (v >> e[3]) & ((1 << width) - 1)
        if k == "signed":
            return self.eval(e[1])
        if k == "unary":
            v = self.eval(e[2])
            if e[1] == "-":
                return _w32(-v)
            if e[1] == "~":
                return _w32(~v)
            if e[1] == "!":
                return 0 if v else 1
            return v
        if k == "bin":
            op = e[1]
            a = self.eval(e[2])
            if op == "&&":
                return 1 if (a != 0 and self.eval(e[3]) != 0) else 0
            if op == "||":
                return 1 if (a != 0 or self.eval(e[3]) != 0) else 0
            b = self.eval(e[3])
            if op == "+":
                return _w32(a + b)
            if op == "-":
                return _w32(a - b)
            if op == "&":
                return _w32(a & b)
            if op == "|":
                return _w32(a | b)
            if op == "^":
                return _w32(a ^ b)
            if op == "<<":
                return _shl(a, b)
            if op == ">>":
                return _shrl(a, b)
            if op == ">>>":
                return _shra(a, b)
            if op == "<":
                return 1 if a < b else 0
            if op == "<=":
                return 1 if a <= b else 0
            if op == ">":
                return 1 if a > b else 0
            if op == ">=":
                return 1 if a >= b else 0
            if op == "==":
                return 1 if a == b else 0
            if op == "!=":
                return 1 if a != b else 0
        if k == "tern":
            return (self.eval(e[2]) if self.eval(e[1]) != 0
                    else self.eval(e[3]))
        raise VsimError(f"cannot evaluate {e!r}")

    def eval_name_scalar(self, name):
        v = self.env[name]
        if isinstance(v, np.ndarray):
            raise VsimError(f"memory {name!r} used as scalar")
        return v

    # -- statement execution ---------------------------------------------

    def exec_stmt(self, st) -> None:
        k = st[0]
        if k == "block":
            for s in st[1]:
                self.exec_stmt(s)
        elif k == "assign":
            self._do_assign(st)
        elif k == "if":
            if self.eval(st[1]) != 0:
                self.exec_stmt(st[2])
            elif st[3] is not None:
                self.exec_stmt(st[3])
        elif k == "case":
            sel = self.eval(st[1])
            item = st[2].get(sel, st[3])
            if item is not None:
                self.exec_stmt(item)
        elif k == "for":
            if self.vectorize:
                plan = self._plan_for(st)
                if plan is not None:
                    self._run_plan(plan)
                    return
            self._slow_for(st)
        elif k == "call":
            pass                          # $display etc.: ignored
        else:
            raise VsimError(f"cannot execute {k!r}")

    def _do_assign(self, st) -> None:
        _, lhs, rhs, blocking = st
        val = self.eval(rhs)
        if blocking:
            self._write(lhs, val)
        else:
            if lhs[0] == "idx":
                self.nb.append((lhs[1], self.eval(lhs[2]), val))
            else:
                self.nb.append((lhs[1], None, val))

    def _write(self, lhs, val) -> None:
        d = self.mod.decls.get(lhs[1])
        if d is None:
            raise VsimError(f"assignment to undeclared {lhs[1]!r}")
        if lhs[0] == "idx":
            idx = self.eval(lhs[2])
            self.env[lhs[1]][idx] = _canon(val, d.width, d.signed)
        else:
            self.env[lhs[1]] = (_w32(val) if d.kind == "integer"
                                else _canon(val, d.width, d.signed)
                                if d.kind == "reg" and d.width < 32
                                else _w32(val))

    def _slow_for(self, st) -> None:
        _, init, cond, step, body = st
        self.exec_stmt(init)
        guard = 0
        while self.eval(cond) != 0:
            self.exec_stmt(body)
            self.exec_stmt(step)
            guard += 1
            if guard > 10_000_000:
                raise VsimError("runaway for loop")

    # -- cycle loop -------------------------------------------------------

    def cycle(self) -> None:
        self.exec_stmt(self.mod.always)
        for name, idx, val in self.nb:
            if idx is None:
                self._write(("var", name), val)
            else:
                d = self.mod.decls[name]
                self.env[name][idx] = _canon(val, d.width, d.signed)
        self.nb = []

    def run(self) -> int:
        self.env["rst"] = 1
        self.env["start"] = 0
        self.cycle()
        self.env["rst"] = 0
        self.env["start"] = 1
        cycles = 0
        trace_map = self.net.trace_map if self.trace else {}
        while self.env.get("done", 0) == 0:
            state = self.env.get("state", 0)
            self.cycle()
            cycles += 1
            if self.trace and state in trace_map:
                iid, op, mems = trace_map[state]
                vals = [self.env[m].copy() for m in mems]
                self.trace(cycles, state, iid, op, mems, vals)
            if cycles > self.max_cycles:
                raise VsimError(
                    f"no done after {cycles} cycles (state "
                    f"{self.env.get('state')})")
        return cycles

    # -- vectorizer -------------------------------------------------------

    def _plan_for(self, node):
        key = id(node)
        if key in self._plans:
            return self._plans[key]
        plan = None
        try:
            plan = self._build_plan(node)
        except _NoVec:
            plan = None
        self._plans[key] = plan
        return plan

    def _build_plan(self, node):
        dims, loop_vars = [], []
        inductions = []               # per level: [(var, delta)]
        core = None
        cur = node
        while True:
            _, init, cond, step, body = cur
            var = self._loop_var(init, cond, step)
            n = cond[3][1]
            dims.append(n)
            loop_vars.append(var)
            stmts = body[1] if body[0] == "block" else [body]
            trail = []
            while stmts and self._induction(stmts[-1]) is not None:
                trail.insert(0, self._induction(stmts[-1]))
                stmts = stmts[:-1]
            inductions.append(trail)
            if len(stmts) == 1 and stmts[0][0] == "for":
                cur = stmts[0]
                continue
            core = stmts
            break
        if any(n <= 0 for n in dims):
            raise _NoVec          # nothing to do; slow path handles
        # net advance per level-d iteration (inner sweeps included)
        advances: dict = {}
        for d in range(len(dims) - 1, -1, -1):
            seen = set(advances)
            for var, delta in inductions[d]:
                inner = advances.get(var, [0] * len(dims))
                advances[var] = inner
            for var in set(v for v, _ in inductions[d]) | seen:
                adv = advances.setdefault(var, [0] * len(dims))
                delta = sum(dl for v, dl in inductions[d] if v == var)
                inner_adv = (adv[d + 1] * dims[d + 1]
                             if d + 1 < len(dims) else 0)
                adv[d] = delta + inner_adv
        ops = self._compile_core(core, set(advances) | set(loop_vars))
        return _VecPlan(dims, loop_vars, advances, ops)

    def _loop_var(self, init, cond, step):
        if (init[0] != "assign" or init[1][0] != "var"
                or init[2] != ("num", 0) or not init[3]):
            raise _NoVec
        var = init[1][1]
        if (cond[0] != "bin" or cond[1] != "<" or cond[2] != ("var", var)
                or cond[3][0] != "num"):
            raise _NoVec
        if (step[0] != "assign" or step[1] != ("var", var)
                or step[2] != ("bin", "+", ("var", var), ("num", 1))):
            raise _NoVec
        return var

    def _induction(self, st):
        """``a = a + C`` / ``a = a - C`` on a declared integer."""
        if st[0] != "assign" or not st[3] or st[1][0] != "var":
            return None
        var = st[1][1]
        d = self.mod.decls.get(var)
        if d is None or d.kind != "integer":
            return None
        rhs = st[2]
        if (rhs[0] == "bin" and rhs[1] in "+-"
                and rhs[2] == ("var", var) and rhs[3][0] == "num"):
            return (var, rhs[3][1] if rhs[1] == "+" else -rhs[3][1])
        return None

    def _compile_core(self, core, vec_vars):
        # read-modify-write reduction: the emitter's canonical 4-stmt body
        rmw = self._match_rmw(core)
        if rmw is not None:
            return [rmw]
        ops = []
        written_mems = set()
        for st in core:
            if st[0] == "assign" and st[3]:
                if st[1][0] == "var":
                    d = self.mod.decls.get(st[1][1])
                    if d is None or d.kind == "mem":
                        raise _NoVec
                    self._check_no_mem_rmw(st[2], written_mems)
                    ops.append(("set", st[1][1], st[2], d))
                else:
                    self._check_no_mem_rmw(st[2], {st[1][1]})
                    written_mems.add(st[1][1])
                    ops.append(("store", st[1][1], st[1][2], st[2], None))
            elif st[0] == "if" and st[3] is None:
                inner = st[2][1] if st[2][0] == "block" else [st[2]]
                stores = []
                for s in inner:
                    if (s[0] != "assign" or not s[3]
                            or s[1][0] != "idx"):
                        raise _NoVec
                    written_mems.add(s[1][1])
                    stores.append((s[1][1], s[1][2], s[2]))
                ops.append(("guard", st[1], stores))
            else:
                raise _NoVec
        return ops

    def _check_no_mem_rmw(self, e, written_mems):
        """A later statement must not read a memory the nest already
        wrote (vectorized stores have no intra-nest ordering)."""
        k = e[0]
        if k == "idx" and e[1] in written_mems:
            raise _NoVec
        for sub in e[1:]:
            if isinstance(sub, tuple):
                self._check_no_mem_rmw(sub, written_mems)

    _RMW_UFUNC = {"+": "add", "|": "bitwise_or", "&": "bitwise_and"}

    def _match_rmw(self, core):
        if len(core) != 4:
            return None
        s_acc, s_src, s_comb, s_store = core
        for s in core[:3]:
            if s[0] != "assign" or not s[3] or s[1][0] != "var":
                return None
        if s_store[0] != "assign" or not s_store[3] \
                or s_store[1][0] != "idx":
            return None
        mem = s_store[1][1]
        if s_store[1][2][0] != "var":
            return None
        avar = s_store[1][2][1]
        t_acc = s_acc[1][1]
        t_src = s_src[1][1]
        t_comb = s_comb[1][1]
        acc_read = self._unwrap_signed(s_acc[2])
        if acc_read != ("idx", mem, ("var", avar)):
            return None
        store_val = self._unwrap_store(s_store[2])
        if store_val != ("var", t_comb):
            return None
        comb = s_comb[2]
        ufunc = None
        A, B = ("var", t_acc), ("var", t_src)
        if comb[0] == "bin" and comb[1] in self._RMW_UFUNC \
                and {comb[2], comb[3]} == {A, B}:
            ufunc = self._RMW_UFUNC[comb[1]]
        elif comb == ("tern", ("bin", "<", A, B), B, A):
            ufunc = "maximum"
        elif comb == ("tern", ("bin", "<", B, A), B, A):
            ufunc = "minimum"
        if ufunc is None:
            return None
        d = self.mod.decls.get(mem)
        if d is None or d.kind != "mem":
            return None
        return ("rmw", ufunc, mem, avar, s_src[2], d)

    def _unwrap_signed(self, e):
        return e[1] if e[0] == "signed" else e

    def _unwrap_store(self, e):
        if e[0] == "psel":
            return ("var", e[1])
        if (e[0] == "bin" and e[1] == "!=" and e[3] == ("num", 0)):
            return self._unwrap_signed(e[2])
        return self._unwrap_signed(e)

    # -- vectorized execution --------------------------------------------

    def _run_plan(self, plan: _VecPlan) -> None:
        dims = plan.dims
        shape = tuple(dims)
        vec: dict = {}
        for d, var in enumerate(plan.loop_vars):
            rs = [1] * len(dims)
            rs[d] = dims[d]
            vec[var] = np.arange(dims[d], dtype=np.int64).reshape(rs)
        for var, adv in plan.advances.items():
            base = self.env[var]
            total = None
            for d, a in enumerate(adv):
                if a == 0:
                    continue
                rs = [1] * len(dims)
                rs[d] = dims[d]
                term = (np.arange(dims[d], dtype=np.int64) * a).reshape(rs)
                total = term if total is None else total + term
            vec[var] = base if total is None else base + total

        for op in plan.ops:
            if op[0] == "set":
                _, name, rhs, d = op
                v = self._veval(rhs, vec, shape)
                vec[name] = (_canon(v, d.width, d.signed)
                             if d.kind == "reg" and d.width < 32
                             else _w32(v))
            elif op[0] == "store":
                _, mem, iexpr, rhs, _w = op
                d = self.mod.decls[mem]
                idx = self._veval(iexpr, vec, shape)
                val = _canon(self._veval(rhs, vec, shape), d.width,
                             d.signed)
                arr = self.env[mem]
                if isinstance(idx, np.ndarray):
                    idx_b = np.broadcast_to(idx, shape).ravel()
                    val_b = np.broadcast_to(
                        np.asarray(val, np.int64), shape).ravel()
                    arr[idx_b] = val_b
                else:
                    arr[int(idx)] = int(np.asarray(val).ravel()[-1]) \
                        if isinstance(val, np.ndarray) else val
            elif op[0] == "guard":
                _, cond, stores = op
                m = self._veval(cond, vec, shape)
                mask = np.broadcast_to(_as_flag(m), shape).ravel()
                for mem, iexpr, rhs in stores:
                    d = self.mod.decls[mem]
                    idx = np.broadcast_to(
                        np.asarray(self._veval(iexpr, vec, shape),
                                   np.int64), shape).ravel()
                    val = np.broadcast_to(
                        np.asarray(_canon(self._veval(rhs, vec, shape),
                                          d.width, d.signed), np.int64),
                        shape).ravel()
                    arr = self.env[mem]
                    arr[idx[mask]] = val[mask]
            elif op[0] == "rmw":
                _, ufunc, mem, avar, src_rhs, d = op
                arr = self.env[mem]
                idx = np.broadcast_to(
                    np.asarray(vec[avar], np.int64), shape).ravel()
                val = np.broadcast_to(
                    np.asarray(self._veval(src_rhs, vec, shape),
                               np.int64), shape).ravel()
                getattr(np, ufunc).at(arr, idx, val)
                arr[:] = _canon(arr, d.width, d.signed)

        # finalize scalars: the value after the last iteration
        for op in plan.ops:
            if op[0] == "set":
                v = vec[op[1]]
                self.env[op[1]] = (int(np.broadcast_to(v, shape)
                                       .ravel()[-1])
                                   if isinstance(v, np.ndarray)
                                   else int(v))
        for var, adv in plan.advances.items():
            self.env[var] = int(self.env[var]
                                + (adv[0] * dims[0] if dims else 0))
        for d, var in enumerate(plan.loop_vars):
            self.env[var] = dims[d]

    def _veval(self, e, vec, shape):
        k = e[0]
        if k == "num":
            return e[1]
        if k == "var":
            if e[1] in vec:
                return vec[e[1]]
            v = self.env[e[1]]
            if isinstance(v, np.ndarray):
                raise VsimError(f"memory {e[1]!r} used as scalar")
            return v
        if k == "idx":
            arr = self.env[e[1]]
            idx = self._veval(e[2], vec, shape)
            if isinstance(idx, np.ndarray):
                return arr[idx]
            return int(arr[int(idx)])
        if k == "psel":
            v = self._veval(("var", e[1]), vec, shape)
            width = e[2] - e[3] + 1
            return (v >> e[3]) & ((1 << width) - 1)
        if k == "signed":
            return self._veval(e[1], vec, shape)
        if k == "unary":
            v = self._veval(e[2], vec, shape)
            if e[1] == "-":
                return _w32(np.negative(v) if isinstance(v, np.ndarray)
                            else -v)
            if e[1] == "~":
                return _w32(np.invert(v) if isinstance(v, np.ndarray)
                            else ~v)
            if e[1] == "!":
                return _flag_int(~_as_flag(v)
                                 if isinstance(v, np.ndarray)
                                 else not _as_flag(v))
            return v
        if k == "bin":
            op = e[1]
            a = self._veval(e[2], vec, shape)
            b = self._veval(e[3], vec, shape)
            if op == "+":
                return _w32(np.add(a, b) if _anyarr(a, b) else a + b)
            if op == "-":
                return _w32(np.subtract(a, b) if _anyarr(a, b)
                            else a - b)
            if op == "&":
                return _w32(a & b)
            if op == "|":
                return _w32(a | b)
            if op == "^":
                return _w32(a ^ b)
            if op == "<<":
                return _shl(a, b)
            if op == ">>":
                return _shrl(a, b)
            if op == ">>>":
                return _shra(a, b)
            if op == "&&":
                return _flag_int(_as_flag(a) & _as_flag(b)
                                 if _anyarr(a, b)
                                 else (_as_flag(a) and _as_flag(b)))
            if op == "||":
                return _flag_int(_as_flag(a) | _as_flag(b)
                                 if _anyarr(a, b)
                                 else (_as_flag(a) or _as_flag(b)))
            cmp = {"<": np.less, "<=": np.less_equal, ">": np.greater,
                   ">=": np.greater_equal, "==": np.equal,
                   "!=": np.not_equal}[op]
            if _anyarr(a, b):
                return cmp(a, b).astype(np.int64)
            return 1 if cmp(a, b) else 0
        if k == "tern":
            c = self._veval(e[1], vec, shape)
            a = self._veval(e[2], vec, shape)
            b = self._veval(e[3], vec, shape)
            if _anyarr(a, b, c):
                return np.where(_as_flag(c), a, b)
            return a if c != 0 else b
        raise VsimError(f"cannot vector-evaluate {e!r}")


def _anyarr(*vals) -> bool:
    return any(isinstance(v, np.ndarray) for v in vals)


class _NoVec(Exception):
    pass


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------


def run_netlist(net, inputs, rom_loader=None, *, vectorize=True,
                trace=None, max_cycles: int = 200_000_000):
    """Simulate a netlist (text or parsed :class:`Netlist`) to ``done``
    and return the program outputs (shaped int32 / bool arrays).

    ``trace(cycle, state, instr_id, op, mems, values)`` fires after each
    FSM state that commits an IR instruction; ``vectorize=False`` forces
    the statement-by-statement slow path everywhere.
    """
    if isinstance(net, str):
        net = parse_netlist(net)
    sim = _Sim(net, rom_loader=rom_loader, vectorize=vectorize,
               trace=trace, max_cycles=max_cycles)
    if len(inputs) != len(net.inputs):
        raise VsimError(f"netlist takes {len(net.inputs)} inputs, "
                        f"got {len(inputs)}")
    for port, val in zip(net.inputs, inputs):
        sim.poke(port, val)
    sim.run()
    return [sim.peek(port) for port in net.outputs]
