"""Netlist register allocation: interval-proven widths, not the carrier.

The software backends carry every value in int32. Hardware does not have
to: the interval pass proves a worst-case value range per register, and
``Reg.required_bits`` is the minimal two's-complement width that holds it
(``Reg.storage_bits`` falls back to the 32-bit carrier for untyped
registers and to 1 bit for predicate wires). :func:`allocate` turns the
register table into the width map the Verilog emitter declares memories
with, plus a machine-readable cost report — the repo's stand-in for the
paper's slice count (Table I: 0 DSP, <1K slices) until a real synthesis
run exists.

Storing a value proven to lie in ``[lo, hi]`` into a ``required_bits``-wide
register and sign-extending it on read is exact; 32-bit datapath math with
a W-bit truncating store composes bit-for-bit for the wraparound group
(add/sub/neg/shl are congruences mod 2**W) and is value-exact for the
order group (cmp/min/max/shra) because the stored value is the value.
That argument is what lets the emitted netlist run narrow registers under
a 32-bit ALU and still replay the interpreter bit-for-bit.

ROMs stay 32-bit in the netlist so the committed ``rom/*.mem`` $readmemh
images load unchanged; the report prices them at both the carrier and the
minimal width so the table tracks what a width-trimmed ROM would cost.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.ir.isa import Program, CMP_OPS, SHIFT_OPS

__all__ = ["Allocation", "allocate"]


def _min_signed_bits(lo: int, hi: int) -> int:
    """Smallest two's-complement width holding every value in [lo, hi]
    (same convention as ``repro.analysis.intervals.signed_bits``)."""
    lo, hi = int(lo), int(hi)
    n_hi = hi.bit_length() + 1 if hi >= 0 else 1
    n_lo = (-lo - 1).bit_length() + 1 if lo < 0 else 1
    return max(n_lo, n_hi, 1)


@dataclasses.dataclass(frozen=True)
class Allocation:
    """Width assignment + cost report for one program.

    ``widths[reg_idx]`` is the storage width the netlist declares for the
    register's memory (ROM-backed registers keep the 32-bit $readmemh
    carrier). ``report`` is JSON-ready and committed as ``alloc.json``.
    """
    program: str
    widths: tuple
    report: dict

    def width(self, reg_idx: int) -> int:
        return self.widths[reg_idx]


def _walk_instrs(instrs):
    for ins in instrs:
        yield ins
        for rg in ins.regions:
            yield from _walk_instrs(rg.body)


def allocate(prog: Program) -> Allocation:
    """Assign every register its interval-proven storage width and price
    the datapath: register bits, ROM bits, and the static shift/add/
    compare unit sites a fully time-multiplexed FSM schedules work onto
    (the paper's MP modules are exactly such shared units)."""
    rom_regs = set(prog.rom_of_reg)
    widths = []
    for r in prog.regs:
        if r.idx in rom_regs:
            widths.append(32)           # the $readmemh image carrier
        else:
            widths.append(r.storage_bits)

    reg_count = reg_elems = bits_alloc = bits_carrier = 0
    histogram: dict = {}
    for r in prog.regs:
        if r.idx in rom_regs:
            continue
        w = widths[r.idx]
        reg_count += 1
        reg_elems += r.size
        bits_alloc += w * r.size
        bits_carrier += (1 if r.dtype == "i1" else 32) * r.size
        histogram[w] = histogram.get(w, 0) + 1

    rom_words = sum(r.data.size for r in prog.roms)
    rom_bits_min = 0
    for r in prog.roms:
        data = np.asarray(r.data)
        lo = int(data.min()) if data.size else 0
        hi = int(data.max()) if data.size else 0
        rom_bits_min += _min_signed_bits(lo, hi) * data.size

    # static datapath unit sites: one entry per instruction that needs the
    # unit, regardless of how many elements the FSM time-multiplexes
    # through it (min/max/abs/sign/clamp/select are comparator+mux pairs;
    # immediate-distance shifts are wiring on an FPGA, dynamic ones are
    # barrel shifters)
    adders = comparators = muxes = dyn_shifters = imm_shifts = 0
    element_ops = 0
    for ins in _walk_instrs(prog.body):
        element_ops += ins.census_out_elems if ins.op != "loop" else 0
        if ins.op in ("add", "sub", "neg", "reduce_sum"):
            adders += 1
        elif ins.op in ("abs",):
            adders += 1
            comparators += 1
            muxes += 1
        elif ins.op in CMP_OPS:
            comparators += 1
        elif ins.op in ("min", "max", "reduce_max", "reduce_min"):
            comparators += 1
            muxes += 1
        elif ins.op == "clamp":
            comparators += 2
            muxes += 2
        elif ins.op == "sign":
            comparators += 2
            muxes += 2
        elif ins.op == "select_n":
            muxes += 1
        elif ins.op in SHIFT_OPS:
            if "imm" in ins.attrs:
                imm_shifts += 1
            else:
                dyn_shifters += 1

    report = {
        "program": prog.name,
        "registers": {
            "count": reg_count,
            "elements": reg_elems,
            "bits_allocated": bits_alloc,
            "bits_carrier": bits_carrier,
            "carrier_saving": (round(1.0 - bits_alloc / bits_carrier, 4)
                               if bits_carrier else 0.0),
            "width_histogram": {str(k): v
                                for k, v in sorted(histogram.items())},
        },
        "roms": {
            "count": len(prog.roms),
            "words": rom_words,
            "bits_stored": 32 * rom_words,
            "bits_minimal": rom_bits_min,
        },
        "datapath": {
            "adder_sites": adders,
            "comparator_sites": comparators,
            "mux_sites": muxes,
            "dyn_shifter_sites": dyn_shifters,
            "imm_shift_sites": imm_shifts,
        },
        "time_multiplexed": {
            # one element-op per cycle on shared units: the sequential
            # cycle bound a fully folded FSM implementation pays
            "element_ops_per_inference": element_ops,
        },
    }
    return Allocation(program=prog.name, widths=tuple(widths),
                      report=report)
