"""Pure-Python ground-truth executor for the fixed-point IR.

Executes a :class:`~repro.ir.isa.Program` on numpy int32/bool arrays with
the EXACT semantics the XLA int path implements: int32 two's-complement
wraparound (reductions forced to ``dtype=int32`` so numpy's int64
accumulator promotion can't mask a hardware overflow), arithmetic right
shift on negatives, clamped dynamic-slice starts, full XLA gather
dimension-number semantics. The interpreter is the reference the XLA
emitter and the generated C are tested against bit-for-bit — it is
deliberately simple (loops where loops are clearest) rather than fast.

Only executable programs run here; a program with a ``grid`` region
(Pallas kernel) is a census/verification surface, not a sequential SSA
stream, and raises.
"""

from __future__ import annotations

import numpy as np

from repro.ir.isa import Program


def _asr(x: np.ndarray, k) -> np.ndarray:
    # numpy >> on signed ints IS arithmetic — keep the helper as the single
    # named place the semantics live (cgen emits the portable equivalent)
    return np.right_shift(x, k).astype(np.int32)


def _shl(x: np.ndarray, k) -> np.ndarray:
    # int32 wraparound semantics: shift in the unsigned domain
    return (np.left_shift(x.astype(np.uint32), k)).astype(np.int32)


def _shrl(x: np.ndarray, k) -> np.ndarray:
    return np.right_shift(x.astype(np.uint32), k).astype(np.int32)


def _pad(x: np.ndarray, padval, config) -> np.ndarray:
    """XLA ``pad`` semantics: per-dim (lo, hi, interior), negative lo/hi
    trim."""
    out = x
    for d, (lo, hi, interior) in enumerate(config):
        lo, hi, interior = int(lo), int(hi), int(interior)
        if interior:
            n = out.shape[d]
            dil = max(n + (n - 1) * interior, 0)
            shape = list(out.shape)
            shape[d] = dil
            y = np.full(shape, padval, dtype=out.dtype)
            idx = [slice(None)] * out.ndim
            idx[d] = slice(0, dil, interior + 1)
            y[tuple(idx)] = out
            out = y
        if lo < 0:
            idx = [slice(None)] * out.ndim
            idx[d] = slice(-lo, None)
            out = out[tuple(idx)]
            lo = 0
        if hi < 0:
            idx = [slice(None)] * out.ndim
            idx[d] = slice(None, out.shape[d] + hi)
            out = out[tuple(idx)]
            hi = 0
        if lo or hi:
            width = [(0, 0)] * out.ndim
            width[d] = (lo, hi)
            out = np.pad(out, width, constant_values=padval)
    return out


def _gather(operand: np.ndarray, indices: np.ndarray, a: dict,
            out_shape: tuple) -> np.ndarray:
    """General XLA gather (index vector dim = last indices dim, starts
    clamped in-range — what PROMISE_IN_BOUNDS programs satisfy anyway)."""
    offset_dims = tuple(a["offset_dims"])
    collapsed = set(a["collapsed_slice_dims"])
    op_batch = list(a["operand_batching_dims"])
    idx_batch = list(a["start_indices_batching_dims"])
    start_map = list(a["start_index_map"])
    sizes = list(a["slice_sizes"])

    batch_shape = indices.shape[:-1]
    flat_idx = indices.reshape(-1, indices.shape[-1])
    out_batch_positions = [d for d in range(len(out_shape))
                           if d not in offset_dims]
    out = np.zeros(out_shape, dtype=operand.dtype)

    for b in range(max(flat_idx.shape[0], 1)):
        bcoord = np.unravel_index(b, batch_shape) if batch_shape else ()
        spec = []
        for d in range(operand.ndim):
            if d in op_batch:
                # a batching dim is indexed by the paired indices batch
                # coordinate (integer indexing consumes the dim)
                spec.append(int(bcoord[idx_batch[op_batch.index(d)]]))
            elif d in start_map:
                s = int(flat_idx[b, start_map.index(d)])
                s = max(0, min(s, operand.shape[d] - sizes[d]))
                spec.append(slice(s, s + sizes[d]))
            else:
                spec.append(slice(0, sizes[d]))
        piece = operand[tuple(spec)]
        # collapsed slice dims are size-1 by XLA contract: squeeze them
        # (positions renumbered after batching dims were consumed)
        dims_after_batch = [d for d in range(operand.ndim)
                            if d not in op_batch]
        sq = tuple(i for i, d in enumerate(dims_after_batch)
                   if d in collapsed)
        piece = np.squeeze(piece, axis=sq) if sq else piece
        sel = [slice(None)] * len(out_shape)
        for i, p in enumerate(out_batch_positions):
            sel[p] = int(bcoord[i]) if bcoord else 0
        out[tuple(sel)] = piece
    return out


def _clamped_starts(starts, shape, sizes):
    return [max(0, min(int(s), int(dim) - int(sz)))
            for s, dim, sz in zip(starts, shape, sizes)]


class _Machine:
    def __init__(self, prog: Program, trace=None):
        self.prog = prog
        self.trace = trace
        self.env: dict = {}
        for reg, rom in prog.rom_of_reg.items():
            self.env[reg] = prog.roms[rom].data

    def _np_dtype(self, reg: int):
        return np.bool_ if self.prog.regs[reg].dtype == "i1" else np.int32

    def set(self, reg: int, val: np.ndarray) -> None:
        self.env[reg] = np.asarray(val, dtype=self._np_dtype(reg))

    def run(self, instrs) -> None:
        for ins in instrs:
            self.step(ins)

    def step(self, ins) -> None:
        env = self.env
        op = ins.op
        a = ins.attrs
        src = [env[s] for s in ins.srcs]
        d0 = ins.dests[0] if ins.dests else None

        if op == "add":
            self.set(d0, (src[0].astype(np.uint32)
                          + src[1].astype(np.uint32)))
        elif op == "sub":
            self.set(d0, (src[0].astype(np.uint32)
                          - src[1].astype(np.uint32)))
        elif op == "neg":
            self.set(d0, (-src[0].astype(np.uint32)))
        elif op == "min":
            self.set(d0, np.minimum(src[0], src[1]))
        elif op == "max":
            self.set(d0, np.maximum(src[0], src[1]))
        elif op == "abs":
            self.set(d0, np.abs(src[0]))
        elif op == "sign":
            self.set(d0, np.sign(src[0]))
        elif op == "clamp":
            lo, x, hi = src
            self.set(d0, np.minimum(np.maximum(x, lo), hi))
        elif op in ("lt", "le", "gt", "ge", "eq", "ne"):
            fn = {"lt": np.less, "le": np.less_equal, "gt": np.greater,
                  "ge": np.greater_equal, "eq": np.equal,
                  "ne": np.not_equal}[op]
            self.set(d0, fn(src[0], src[1]))
        elif op == "select_n":
            pred, cases = src[0], src[1:]
            if pred.dtype == np.bool_ and len(cases) == 2:
                self.set(d0, np.where(pred, cases[1], cases[0]))
            else:
                stacked = np.stack(np.broadcast_arrays(*cases))
                sel = np.asarray(pred, dtype=np.intp)
                self.set(d0, np.take_along_axis(
                    stacked, np.broadcast_to(
                        sel, stacked.shape[1:])[None], axis=0)[0])
        elif op in ("and", "or", "xor"):
            fn = {"and": np.bitwise_and, "or": np.bitwise_or,
                  "xor": np.bitwise_xor}[op]
            self.set(d0, fn(src[0], src[1]))
        elif op == "not":
            x = src[0]
            self.set(d0, ~x)
        elif op == "shl":
            k = a.get("imm") if "imm" in a else src[1]
            x = src[0]
            self.set(d0, _shl(x, k))
        elif op == "shra":
            k = a.get("imm") if "imm" in a else src[1]
            self.set(d0, _asr(src[0], k))
        elif op == "shrl":
            k = a.get("imm") if "imm" in a else src[1]
            self.set(d0, _shrl(src[0], k))
        elif op == "reduce_sum":
            self.set(d0, np.sum(src[0], axis=tuple(a["axes"]),
                                dtype=np.int32))
        elif op == "reduce_max":
            self.set(d0, np.max(src[0], axis=tuple(a["axes"])))
        elif op == "reduce_min":
            self.set(d0, np.min(src[0], axis=tuple(a["axes"])))
        elif op == "broadcast":
            shape = tuple(a["shape"])
            bdims = tuple(a["broadcast_dimensions"])
            tmp = [1] * len(shape)
            for i, d in enumerate(bdims):
                tmp[d] = src[0].shape[i]
            self.set(d0, np.broadcast_to(src[0].reshape(tmp), shape))
        elif op == "reshape":
            self.set(d0, src[0].reshape(tuple(a["new_shape"])))
        elif op == "transpose":
            self.set(d0, np.transpose(src[0], tuple(a["permutation"])))
        elif op == "rev":
            self.set(d0, np.flip(src[0], axis=tuple(a["dimensions"])))
        elif op == "slice":
            idx = tuple(slice(int(s), int(l), int(st)) for s, l, st in
                        zip(a["start_indices"], a["limit_indices"],
                            a["strides"]))
            self.set(d0, src[0][idx])
        elif op == "concat":
            self.set(d0, np.concatenate(src, axis=int(a["dimension"])))
        elif op == "pad":
            self.set(d0, _pad(src[0], src[1][()] if src[1].ndim == 0
                              else src[1], a["padding_config"]))
        elif op == "iota":
            shape = tuple(a["shape"])
            dim = int(a["dimension"])
            ar = np.arange(shape[dim], dtype=np.int32)
            tmp = [1] * len(shape)
            tmp[dim] = shape[dim]
            self.set(d0, np.broadcast_to(ar.reshape(tmp), shape))
        elif op == "convert":
            if a["to"] == "i1":
                self.set(d0, src[0] != 0)
            else:
                self.set(d0, src[0].astype(np.int32))
        elif op == "mov":
            self.set(d0, src[0])
        elif op == "gather":
            self.set(d0, _gather(src[0], src[1], a,
                                 self.prog.regs[d0].shape))
        elif op == "dynamic_slice":
            operand, starts = src[0], src[1:]
            sizes = a["slice_sizes"]
            st = _clamped_starts([s[()] for s in starts],
                                 operand.shape, sizes)
            idx = tuple(slice(s, s + int(sz)) for s, sz in zip(st, sizes))
            self.set(d0, operand[idx])
        elif op == "dynamic_update_slice":
            operand, update = src[0], src[1]
            starts = src[2:]
            st = _clamped_starts([s[()] for s in starts],
                                 operand.shape, update.shape)
            out = operand.copy()
            idx = tuple(slice(s, s + sz) for s, sz in zip(st, update.shape))
            out[idx] = update
            self.set(d0, out)
        elif op == "loop":
            self._loop(ins)
        elif op == "grid":
            raise NotImplementedError(
                "grid regions (Pallas kernels) are a census/verification "
                "surface, not interpretable SSA")
        else:
            raise NotImplementedError(f"IR op {op!r}")

        if self.trace is not None:
            self.trace(ins, [self.env[d] for d in ins.dests])

    def _loop(self, ins) -> None:
        rg = ins.regions[0]
        nc = ins.attrs["num_consts"]
        nk = ins.attrs["num_carry"]
        length = ins.attrs["length"]
        reverse = rg.attrs.get("reverse", False)
        consts = [self.env[s] for s in ins.srcs[:nc]]
        carry = [self.env[s] for s in ins.srcs[nc:nc + nk]]
        xs = [self.env[s] for s in ins.srcs[nc + nk:]]
        n_ys = len(rg.outputs) - nk
        ys: list = [[None] * length for _ in range(n_ys)]

        for r, v in zip(rg.inputs[:nc], consts):
            self.set(r, v)
        order = range(length - 1, -1, -1) if reverse else range(length)
        for t in order:
            for r, v in zip(rg.inputs[nc:nc + nk], carry):
                self.set(r, v)
            for r, x in zip(rg.inputs[nc + nk:], xs):
                self.set(r, x[t])
            self.run(rg.body)
            carry = [self.env[o] for o in rg.outputs[:nk]]
            for j, o in enumerate(rg.outputs[nk:]):
                ys[j][t] = self.env[o]
        for d, v in zip(ins.dests[:nk], carry):
            self.set(d, v)
        for d, col in zip(ins.dests[nk:], ys):
            shape = self.prog.regs[d].shape
            if length == 0:
                self.set(d, np.zeros(shape, dtype=self._np_dtype(d)))
            else:
                self.set(d, np.stack(col, axis=0))


def run(prog: Program, inputs, trace=None) -> list:
    """Execute ``prog`` on numpy inputs; returns the output arrays in
    program order (int32 / bool, exactly what ``fixed.infer_q`` yields).

    ``trace``, when given, is called as ``trace(instr, dest_values)``
    after EVERY executed instruction — loop bodies fire once per trip,
    the ``loop`` instruction itself once after its last trip — in exactly
    the dynamic order the Verilog FSM commits instructions, which is what
    ``repro.ir.debug.first_divergence`` aligns against."""
    if not prog.executable:
        raise NotImplementedError(
            f"program {prog.name!r} contains a grid region and is not "
            "sequentially executable (census/verification surface only)")
    m = _Machine(prog, trace=trace)
    if len(inputs) != len(prog.inputs):
        raise ValueError(f"program {prog.name!r} takes {len(prog.inputs)} "
                         f"inputs, got {len(inputs)}")
    for r, v in zip(prog.inputs, inputs):
        m.set(r, np.asarray(v))
    m.run(prog.body)
    return [m.env[o] for o in prog.outputs]
