"""Synthesizable-artifact emitter: fixed-point C reference + ROM inits.

Turns an executable IR :class:`~repro.ir.isa.Program` into the artifact a
hardware flow consumes:

* ``program.c`` — a freestanding, dependency-free C99 translation. Every
  register is a static int32 (or uint8 predicate) array, every ROM a
  ``static const`` table, every instruction an explicit loop nest with the
  EXACT integer semantics of the XLA path: two's-complement wraparound via
  unsigned arithmetic (signed overflow is UB in C — the generated code
  never relies on it), portable arithmetic right shift, clamped
  dynamic-slice starts, full gather dimension-number semantics. The
  ``main()`` harness reads raw little-endian inputs and writes raw
  outputs, which is how tests/test_ir.py pins the compiled binary
  bit-for-bit against ``fixed.infer_q``.
* ``rom/<name>.mem`` — one init file per ROM: one 8-hex-digit
  two's-complement word per line (the ``$readmemh`` format Verilog ROM
  inference consumes on the paper's Spartan-7 target).

The emitted bytes are a pure function of the Program (no timestamps, no
environment), so tier-1 drift-gates them exactly like ANALYSIS.json.
"""

from __future__ import annotations

import numpy as np

from repro.ir.isa import Program

_PRELUDE = r"""/* Generated fixed-point reference — see repro.ir.cgen. Do not edit. */
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>

static int32_t add32(int32_t a, int32_t b) {
    return (int32_t)((uint32_t)a + (uint32_t)b);
}
static int32_t sub32(int32_t a, int32_t b) {
    return (int32_t)((uint32_t)a - (uint32_t)b);
}
static int32_t neg32(int32_t a) { return (int32_t)(0u - (uint32_t)a); }
static int32_t min32(int32_t a, int32_t b) { return a < b ? a : b; }
static int32_t max32(int32_t a, int32_t b) { return a > b ? a : b; }
static int32_t abs32(int32_t a) { return a < 0 ? neg32(a) : a; }
static int32_t sign32(int32_t a) { return a > 0 ? 1 : (a < 0 ? -1 : 0); }
static int32_t shl32(int32_t x, int32_t k) {
    if (k >= 32 || k < 0) return 0;
    return (int32_t)((uint32_t)x << k);
}
static int32_t asr32(int32_t x, int32_t k) {
    if (k < 0) k = 0;
    if (k >= 32) return x < 0 ? -1 : 0;
    if (k == 0) return x;
    {
        uint32_t s = (uint32_t)x >> k;
        if (x < 0) s |= ~(uint32_t)0 << (32 - k);
        return (int32_t)s;
    }
}
static int32_t shrl32(int32_t x, int32_t k) {
    if (k >= 32 || k < 0) return 0;
    return (int32_t)((uint32_t)x >> k);
}
static long clamp_start(long s, long dim, long size) {
    if (s < 0) s = 0;
    if (s > dim - size) s = dim - size;
    return s;
}
"""


def _size(shape) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


def _strides(shape) -> list:
    st = [1] * len(shape)
    for d in range(len(shape) - 2, -1, -1):
        st[d] = st[d + 1] * int(shape[d + 1])
    return st


def _fmt_words(vals) -> str:
    parts, line = [], []
    for v in vals:
        line.append(str(int(v)))
        if len(line) == 12:
            parts.append(", ".join(line))
            line = []
    if line:
        parts.append(", ".join(line))
    return ",\n    ".join(parts)


class _CGen:
    def __init__(self, prog: Program):
        self.prog = prog
        self.lines: list = []
        self._tmp = 0

    # -- naming -----------------------------------------------------------

    def reg_name(self, idx: int) -> str:
        return f"r{idx}"

    def ctype(self, idx: int) -> str:
        return "uint8_t" if self.prog.regs[idx].dtype == "i1" else "int32_t"

    def shape(self, idx: int) -> tuple:
        return self.prog.regs[idx].shape

    def emit(self, s: str = "") -> None:
        self.lines.append(s)

    def fresh(self, stem: str) -> str:
        self._tmp += 1
        return f"{stem}{self._tmp}"

    # -- declarations -----------------------------------------------------

    def declarations(self) -> None:
        p = self.prog
        for rom in p.roms:
            data = np.ravel(rom.data).astype(np.int64)
            ct = "uint8_t" if rom.data.dtype == np.bool_ else "int32_t"
            self.emit(f"static const {ct} {rom.name}[{max(data.size, 1)}]"
                      f" = {{\n    {_fmt_words(data)}\n}};")
        self.emit()
        for reg in p.regs:
            rom = p.rom_of_reg.get(reg.idx)
            if rom is not None:
                self.emit(f"static const {self.ctype(reg.idx)} *const "
                          f"{self.reg_name(reg.idx)} = {p.roms[rom].name};")
            else:
                self.emit(f"static {self.ctype(reg.idx)} "
                          f"{self.reg_name(reg.idx)}"
                          f"[{max(reg.size, 1)}];")
        self.emit()

    # -- loop helpers -----------------------------------------------------

    def _coords(self, body: list, ivar: str, shape, cvar: str) -> list:
        """Emit coord decomposition of flat ``ivar`` over ``shape`` into
        ``cvar0..``; returns coord var names."""
        st = _strides(shape)
        names = []
        t = self.fresh("t")
        body.append(f"long {t} = {ivar};")
        for d in range(len(shape)):
            c = f"{cvar}{d}"
            names.append(c)
            if d < len(shape) - 1:
                body.append(f"long {c} = {t} / {st[d]}; {t} %= {st[d]};")
            else:
                body.append(f"long {c} = {t};")
        return names

    def flat_loop(self, n: int, body_fn) -> None:
        i = self.fresh("i")
        body: list = []
        body_fn(i, body)
        self.emit(f"for (long {i} = 0; {i} < {n}; ++{i}) {{")
        for ln in body:
            self.emit(f"    {ln}")
        self.emit("}")

    def _needs_bcast(self, d0: int, srcs) -> bool:
        ds = self.shape(d0)
        return any(self.shape(s) != ds and len(self.shape(s)) > 0
                   for s in srcs)

    def _bcast_index(self, s: int, coords, dest_shape) -> str:
        """numpy-broadcast source index from dest coords: size-1 dims get
        stride 0, missing leading dims are dropped."""
        shape = self.shape(s)
        if len(shape) == 0:
            return "0"
        st = _strides(shape)
        off = len(dest_shape) - len(shape)
        terms = [f"{coords[off + i]} * {st[i]}"
                 for i in range(len(shape)) if int(shape[i]) != 1]
        return " + ".join(terms) if terms else "0"

    def _ew(self, ins, expr_fn) -> None:
        """Elementwise loop with full broadcast semantics; ``expr_fn``
        maps src element-ref strings to the rhs expression."""
        d0 = ins.dests[0]
        dn = self.reg_name(d0)
        N = max(self.prog.regs[d0].size, 1)
        dest_shape = self.shape(d0)
        if not self._needs_bcast(d0, ins.srcs):
            def body(i, b):
                refs = [f"{self.reg_name(s)}"
                        f"[{'0' if len(self.shape(s)) == 0 else i}]"
                        for s in ins.srcs]
                b.append(f"{dn}[{i}] = {expr_fn(refs)};")
            self.flat_loop(N, body)
            return

        def body(i, b):
            coords = self._coords(b, i, dest_shape, self.fresh("c"))
            refs = [f"{self.reg_name(s)}"
                    f"[{self._bcast_index(s, coords, dest_shape)}]"
                    for s in ins.srcs]
            b.append(f"{dn}[{i}] = {expr_fn(refs)};")
        self.flat_loop(N, body)

    # -- instruction lowering ---------------------------------------------

    def instr(self, ins) -> None:
        op, a = ins.op, ins.attrs
        d0 = ins.dests[0] if ins.dests else None
        dn = self.reg_name(d0) if d0 is not None else None
        srcs = ins.srcs
        N = max(self.prog.regs[d0].size, 1) if d0 is not None else 0
        self.emit(f"/* {op} {ins.jax_prim and f'[{ins.jax_prim}] ' or ''}"
                  f"-> r{d0} */")

        bin_fn = {"add": "add32", "sub": "sub32", "min": "min32",
                  "max": "max32"}
        cmp_c = {"lt": "<", "le": "<=", "gt": ">", "ge": ">=",
                 "eq": "==", "ne": "!="}

        if op in bin_fn:
            f = bin_fn[op]
            self._ew(ins, lambda r: f"{f}({r[0]}, {r[1]})")
        elif op == "neg":
            self._ew(ins, lambda r: f"neg32({r[0]})")
        elif op == "abs":
            self._ew(ins, lambda r: f"abs32({r[0]})")
        elif op == "sign":
            self._ew(ins, lambda r: f"sign32({r[0]})")
        elif op == "clamp":
            self._ew(ins, lambda r: f"min32(max32({r[1]}, {r[0]}), {r[2]})")
        elif op in cmp_c:
            c = cmp_c[op]
            self._ew(ins, lambda r: f"{r[0]} {c} {r[1]} ? 1 : 0")
        elif op == "select_n":
            n_cases = len(srcs) - 1

            def sel(r):
                expr = r[-1]
                for k in range(n_cases - 2, -1, -1):
                    expr = f"{r[0]} == {k} ? {r[1 + k]} : ({expr})"
                return expr
            self._ew(ins, sel)
        elif op in ("and", "or", "xor"):
            c = {"and": "&", "or": "|", "xor": "^"}[op]
            self._ew(ins, lambda r: f"{r[0]} {c} {r[1]}")
        elif op == "not":
            if self.prog.regs[d0].dtype == "i1":
                self._ew(ins, lambda r: f"{r[0]} ? 0 : 1")
            else:
                self._ew(ins, lambda r: f"~{r[0]}")
        elif op in ("shl", "shra", "shrl"):
            f = {"shl": "shl32", "shra": "asr32", "shrl": "shrl32"}[op]
            if "imm" in a:
                k = int(a["imm"])
                self._ew(ins, lambda r: f"{f}({r[0]}, {k})")
            else:
                self._ew(ins, lambda r: f"{f}({r[0]}, {r[1]})")
        elif op in ("reduce_sum", "reduce_max", "reduce_min"):
            self._reduce(ins)
        elif op == "broadcast":
            self._broadcast(ins)
        elif op in ("reshape", "mov"):
            s = srcs[0]
            if self.ctype(d0) == self.ctype(s):
                self.emit(f"memcpy({dn}, {self.reg_name(s)}, "
                          f"sizeof({self.ctype(d0)}) * {N});")
            else:
                ct = self.ctype(d0)
                self._ew(ins, lambda r: f"({ct}){r[0]}")
        elif op == "convert":
            if a["to"] == "i1":
                self._ew(ins, lambda r: f"{r[0]} != 0 ? 1 : 0")
            else:
                self._ew(ins, lambda r: f"(int32_t){r[0]}")
        elif op == "transpose":
            self._transpose(ins)
        elif op == "rev":
            self._rev(ins)
        elif op == "slice":
            self._slice(ins)
        elif op == "concat":
            self._concat(ins)
        elif op == "pad":
            self._pad(ins)
        elif op == "iota":
            self._iota(ins)
        elif op == "gather":
            self._gather(ins)
        elif op == "dynamic_slice":
            self._dynamic_slice(ins)
        elif op == "dynamic_update_slice":
            self._dus(ins)
        elif op == "loop":
            self._loop(ins)
        else:
            raise NotImplementedError(f"IR op {op!r} in C emitter")

    def _reduce(self, ins) -> None:
        op = ins.op
        d0, src = ins.dests[0], ins.srcs[0]
        axes = set(ins.attrs["axes"])
        src_shape = self.shape(src)
        dn = self.reg_name(d0)
        N = max(self.prog.regs[d0].size, 1)
        init = {"reduce_sum": "0", "reduce_max": "(-2147483647 - 1)",
                "reduce_min": "2147483647"}[op]
        self.flat_loop(N, lambda i, b: b.append(f"{dn}[{i}] = {init};"))
        kept = [d for d in range(len(src_shape)) if d not in axes]
        out_st = _strides([int(src_shape[d]) for d in kept])

        def body(i, b):
            coords = self._coords(b, i, src_shape, self.fresh("c"))
            terms = [f"{coords[d]} * {out_st[j]}"
                     for j, d in enumerate(kept)]
            dst = " + ".join(terms) if terms else "0"
            acc = {"reduce_sum": "add32", "reduce_max": "max32",
                   "reduce_min": "min32"}[op]
            b.append(f"{dn}[{dst}] = {acc}({dn}[{dst}], "
                     f"{self.reg_name(src)}[{i}]);")
        self.flat_loop(max(_size(src_shape), 1), body)

    # -- movement codegen --------------------------------------------------

    def _map_loop(self, d0: int, src: int, coord_to_src) -> None:
        """dest flat loop; ``coord_to_src(coords) -> src index expr``."""
        shape = self.shape(d0)
        dn = self.reg_name(d0)

        def body(i, b):
            coords = self._coords(b, i, shape, self.fresh("c"))
            b.append(f"{dn}[{i}] = {self.reg_name(src)}"
                     f"[{coord_to_src(coords)}];")
        self.flat_loop(max(self.prog.regs[d0].size, 1), body)

    def _broadcast(self, ins) -> None:
        a = ins.attrs
        src_shape = self.shape(ins.srcs[0])
        bdims = list(a["broadcast_dimensions"])
        src_st = _strides(src_shape)

        def to_src(coords):
            terms = []
            for i, d in enumerate(bdims):
                if int(src_shape[i]) != 1:
                    terms.append(f"{coords[d]} * {src_st[i]}")
            return " + ".join(terms) if terms else "0"
        self._map_loop(ins.dests[0], ins.srcs[0], to_src)

    def _transpose(self, ins) -> None:
        perm = list(ins.attrs["permutation"])
        src_st = _strides(self.shape(ins.srcs[0]))

        def to_src(coords):
            terms = [f"{coords[d]} * {src_st[perm[d]]}"
                     for d in range(len(perm))]
            return " + ".join(terms) if terms else "0"
        self._map_loop(ins.dests[0], ins.srcs[0], to_src)

    def _rev(self, ins) -> None:
        dims = set(ins.attrs["dimensions"])
        src_shape = self.shape(ins.srcs[0])
        src_st = _strides(src_shape)

        def to_src(coords):
            terms = []
            for d in range(len(src_shape)):
                c = (f"({src_shape[d]} - 1 - {coords[d]})"
                     if d in dims else coords[d])
                terms.append(f"{c} * {src_st[d]}")
            return " + ".join(terms) if terms else "0"
        self._map_loop(ins.dests[0], ins.srcs[0], to_src)

    def _slice(self, ins) -> None:
        a = ins.attrs
        src_st = _strides(self.shape(ins.srcs[0]))
        starts, strides = a["start_indices"], a["strides"]

        def to_src(coords):
            terms = [f"({starts[d]} + {coords[d]} * {strides[d]}) "
                     f"* {src_st[d]}" for d in range(len(src_st))]
            return " + ".join(terms) if terms else "0"
        self._map_loop(ins.dests[0], ins.srcs[0], to_src)

    def _concat(self, ins) -> None:
        axis = int(ins.attrs["dimension"])
        d0 = ins.dests[0]
        out_st = _strides(self.shape(d0))
        dn = self.reg_name(d0)
        off = 0
        for s in ins.srcs:
            sshape = self.shape(s)
            sst = _strides(sshape)

            def body(i, b, s=s, sshape=sshape, sst=sst, off=off):
                coords = self._coords(b, i, sshape, self.fresh("c"))
                terms = []
                for d in range(len(sshape)):
                    c = (f"({coords[d]} + {off})" if d == axis
                         else coords[d])
                    terms.append(f"{c} * {out_st[d]}")
                dst = " + ".join(terms) if terms else "0"
                b.append(f"{dn}[{dst}] = {self.reg_name(s)}[{i}];")
            self.flat_loop(max(_size(sshape), 1), body)
            off += int(sshape[axis])

    def _pad(self, ins) -> None:
        a = ins.attrs["padding_config"]
        d0, src, pv = ins.dests[0], ins.srcs[0], ins.srcs[1]
        dn = self.reg_name(d0)
        out_shape = self.shape(d0)
        out_st = _strides(out_shape)
        N = max(self.prog.regs[d0].size, 1)
        self.flat_loop(N, lambda i, b: b.append(
            f"{dn}[{i}] = {self.reg_name(pv)}[0];"))
        src_shape = self.shape(src)

        def body(i, b):
            coords = self._coords(b, i, src_shape, self.fresh("c"))
            terms, guards = [], []
            for d in range(len(src_shape)):
                lo, _hi, inter = (int(x) for x in a[d])
                dc = self.fresh("d")
                b.append(f"long {dc} = {lo} + {coords[d]} "
                         f"* {inter + 1};")
                guards.append(f"{dc} >= 0 && {dc} < {out_shape[d]}")
                terms.append(f"{dc} * {out_st[d]}")
            dst = " + ".join(terms) if terms else "0"
            cond = " && ".join(guards) if guards else "1"
            b.append(f"if ({cond}) {dn}[{dst}] = "
                     f"{self.reg_name(src)}[{i}];")
        self.flat_loop(max(_size(src_shape), 1), body)

    def _iota(self, ins) -> None:
        dim = int(ins.attrs["dimension"])
        d0 = ins.dests[0]
        shape = self.shape(d0)
        dn = self.reg_name(d0)

        def body(i, b):
            coords = self._coords(b, i, shape, self.fresh("c"))
            b.append(f"{dn}[{i}] = (int32_t){coords[dim]};")
        self.flat_loop(max(self.prog.regs[d0].size, 1), body)

    def _gather(self, ins) -> None:
        a = ins.attrs
        d0, operand, indices = ins.dests[0], ins.srcs[0], ins.srcs[1]
        out_shape = self.shape(d0)
        op_shape = self.shape(operand)
        idx_shape = self.shape(indices)
        op_st = _strides(op_shape)
        offset_dims = list(a["offset_dims"])
        collapsed = set(a["collapsed_slice_dims"])
        op_batch = list(a["operand_batching_dims"])
        idx_batch = list(a["start_indices_batching_dims"])
        start_map = list(a["start_index_map"])
        sizes = list(a["slice_sizes"])
        batch_shape = list(idx_shape[:-1])
        k = int(idx_shape[-1]) if idx_shape else 1
        batch_positions = [d for d in range(len(out_shape))
                           if d not in offset_dims]
        # operand dims carrying offset coords, in order
        offset_src = [d for d in range(len(op_shape))
                      if d not in collapsed and d not in op_batch]
        dn = self.reg_name(d0)

        def body(i, b):
            coords = self._coords(b, i, out_shape, self.fresh("c"))
            bcoords = [coords[p] for p in batch_positions]
            # flat index row for this batch coordinate
            ist = _strides(batch_shape + [k]) if idx_shape else [1]
            row = " + ".join(f"{c} * {ist[j]}"
                             for j, c in enumerate(bcoords)) or "0"
            rv = self.fresh("row")
            b.append(f"long {rv} = {row};")
            terms = []
            for d in range(len(op_shape)):
                if d in op_batch:
                    terms.append(
                        f"{bcoords[idx_batch[op_batch.index(d)]]}"
                        f" * {op_st[d]}")
                elif d in start_map:
                    sv = self.fresh("s")
                    b.append(
                        f"long {sv} = clamp_start((long)"
                        f"{self.reg_name(indices)}"
                        f"[{rv} + {start_map.index(d)}], "
                        f"{op_shape[d]}, {sizes[d]});")
                    if d in collapsed:
                        terms.append(f"{sv} * {op_st[d]}")
                    else:
                        oc = coords[offset_dims[offset_src.index(d)]]
                        terms.append(f"({sv} + {oc}) * {op_st[d]}")
                else:
                    oc = (coords[offset_dims[offset_src.index(d)]]
                          if d not in collapsed else "0")
                    terms.append(f"{oc} * {op_st[d]}")
            src = " + ".join(terms) if terms else "0"
            b.append(f"{dn}[{i}] = {self.reg_name(operand)}[{src}];")
        self.flat_loop(max(self.prog.regs[d0].size, 1), body)

    def _dynamic_slice(self, ins) -> None:
        a = ins.attrs
        d0, operand = ins.dests[0], ins.srcs[0]
        starts = ins.srcs[1:]
        src_shape = self.shape(operand)
        src_st = _strides(src_shape)
        sizes = a["slice_sizes"]
        svars = []
        for d, s in enumerate(starts):
            sv = self.fresh("s")
            self.emit(f"long {sv} = clamp_start((long)"
                      f"{self.reg_name(s)}[0], {src_shape[d]}, "
                      f"{sizes[d]});")
            svars.append(sv)

        def to_src(coords):
            terms = [f"({svars[d]} + {coords[d]}) * {src_st[d]}"
                     for d in range(len(src_shape))]
            return " + ".join(terms) if terms else "0"
        self.emit("{")
        self._map_loop(d0, operand, to_src)
        self.emit("}")

    def _dus(self, ins) -> None:
        d0, operand, update = ins.dests[0], ins.srcs[0], ins.srcs[1]
        starts = ins.srcs[2:]
        out_shape = self.shape(d0)
        out_st = _strides(out_shape)
        up_shape = self.shape(update)
        dn = self.reg_name(d0)
        N = max(self.prog.regs[d0].size, 1)
        self.emit(f"memcpy({dn}, {self.reg_name(operand)}, "
                  f"sizeof({self.ctype(d0)}) * {N});")
        svars = []
        self.emit("{")
        for d, s in enumerate(starts):
            sv = self.fresh("s")
            self.emit(f"long {sv} = clamp_start((long)"
                      f"{self.reg_name(s)}[0], {out_shape[d]}, "
                      f"{up_shape[d]});")
            svars.append(sv)

        def body(i, b):
            coords = self._coords(b, i, up_shape, self.fresh("c"))
            terms = [f"({svars[d]} + {coords[d]}) * {out_st[d]}"
                     for d in range(len(up_shape))]
            dst = " + ".join(terms) if terms else "0"
            b.append(f"{dn}[{dst}] = {self.reg_name(update)}[{i}];")
        self.flat_loop(max(_size(up_shape), 1), body)
        self.emit("}")

    # -- loop regions ------------------------------------------------------

    def _copy(self, dst: int, src: int) -> None:
        n = max(self.prog.regs[dst].size, 1)
        self.emit(f"memcpy({self.reg_name(dst)}, {self.reg_name(src)}, "
                  f"sizeof({self.ctype(dst)}) * {n});")

    def _loop(self, ins) -> None:
        rg = ins.regions[0]
        a = ins.attrs
        nc, nk, length = a["num_consts"], a["num_carry"], a["length"]
        reverse = rg.attrs.get("reverse", False)
        consts = ins.srcs[:nc]
        init = ins.srcs[nc:nc + nk]
        xs = ins.srcs[nc + nk:]
        cin = rg.inputs[nc:nc + nk]
        xin = rg.inputs[nc + nk:]
        for r, s in zip(rg.inputs[:nc], consts):
            self._copy(r, s)
        for r, s in zip(cin, init):
            self._copy(r, s)
        t = self.fresh("t")
        self.emit(f"for (long {t} = 0; {t} < {length}; ++{t}) {{")
        tt = f"({length} - 1 - {t})" if reverse else t
        for r, s in zip(xin, xs):
            n = max(self.prog.regs[r].size, 1)
            self.emit(f"    memcpy({self.reg_name(r)}, "
                      f"{self.reg_name(s)} + {tt} * {n}, "
                      f"sizeof({self.ctype(r)}) * {n});")
        inner = _CGen(self.prog)
        inner._tmp = self._tmp + 1000
        for bins in rg.body:
            inner.instr(bins)
        for ln in inner.lines:
            self.emit(f"    {ln}")
        self._tmp = inner._tmp
        for j, o in enumerate(rg.outputs[nk:]):
            d = ins.dests[nk + j]
            n = max(self.prog.regs[o].size, 1)
            self.emit(f"    memcpy({self.reg_name(d)} + {tt} * {n}, "
                      f"{self.reg_name(o)}, "
                      f"sizeof({self.ctype(o)}) * {n});")
        for r, o in zip(cin, rg.outputs[:nk]):
            self.emit(f"    memcpy({self.reg_name(r)}, "
                      f"{self.reg_name(o)}, "
                      f"sizeof({self.ctype(r)}) * "
                      f"{max(self.prog.regs[r].size, 1)});")
        self.emit("}")
        for d, r in zip(ins.dests[:nk], cin):
            self._copy(d, r)

    # -- program ----------------------------------------------------------

    def generate(self) -> str:
        p = self.prog
        out = [_PRELUDE]
        self.lines = []
        self.declarations()
        out.extend(self.lines)
        self.lines = []
        self.emit("static void program_run(void) {")
        body = _CGen(p)
        body._tmp = 0
        for ins in p.body:
            body.instr(ins)
        for ln in body.lines:
            self.emit(f"    {ln}")
        self.emit("}")
        self.emit()
        # harness: argv[1] raw input bytes in program order, argv[2] output
        self.emit("int main(int argc, char **argv) {")
        self.emit("    if (argc != 3) { fprintf(stderr, \"usage: %s "
                  "in.bin out.bin\\n\", argv[0]); return 2; }")
        self.emit("    FILE *fi = fopen(argv[1], \"rb\");")
        self.emit("    if (!fi) { perror(\"in\"); return 2; }")
        for r in p.inputs:
            n = max(p.regs[r].size, 1)
            self.emit(f"    if (fread({self.reg_name(r)}, "
                      f"sizeof({self.ctype(r)}), {n}, fi) != {n}) "
                      "{ fprintf(stderr, \"short read\\n\"); return 2; }")
        self.emit("    fclose(fi);")
        self.emit("    program_run();")
        self.emit("    FILE *fo = fopen(argv[2], \"wb\");")
        self.emit("    if (!fo) { perror(\"out\"); return 2; }")
        for r in p.outputs:
            n = max(p.regs[r].size, 1)
            self.emit(f"    fwrite({self.reg_name(r)}, "
                      f"sizeof({self.ctype(r)}), {n}, fo);")
        self.emit("    fclose(fo);")
        self.emit("    return 0;")
        self.emit("}")
        out.extend(self.lines)
        return "\n".join(out) + "\n"


def emit_c(prog: Program) -> str:
    """The C99 reference translation of an executable program."""
    if not prog.executable:
        raise NotImplementedError(
            f"program {prog.name!r} contains a grid region — emit C only "
            "for the sequential SSA targets")
    return _CGen(prog).generate()


def emit_rom_mem(prog: Program) -> dict:
    """``{filename: text}`` of per-ROM ``$readmemh`` init files: one
    8-hex-digit two's-complement word per line."""
    out = {}
    for rom in prog.roms:
        words = np.ravel(rom.data).astype(np.int64)
        lines = [f"{int(w) & 0xFFFFFFFF:08x}" for w in words]
        out[f"{rom.name}.mem"] = "\n".join(lines) + "\n"
    return out
