"""Lower a traced integer jaxpr into the typed op-stream IR.

The lowering is deliberately 1:1 with the traced program: every leaf jaxpr
equation becomes exactly one IR instruction (``pjit``/call wrappers are
inlined with no instruction, ``scan`` becomes one ``loop`` with a body
region, ``pallas_call`` one ``grid`` region), so the IR census
(``repro.ir.census``) reproduces the jaxpr-walk census numbers EXACTLY —
there is no re-association, fusion or strength reduction that could move
the committed ``hw.*`` benchmark rows. The single rewrite the builder does
perform is the one hardware demands anyway: a ``mul`` whose multiplier is
a positive pow2 literal (the only multiplies the legality whitelist
admits) is folded into a ``shl`` immediate — which is also how the census
already classifies it, so even that moves no numbers.

Register typing: pass ``in_intervals`` (one
:class:`repro.analysis.intervals.Interval` per flattened program input)
and the builder runs the worst-case interval pass over the SAME
``ClosedJaxpr`` object, then keys each equation's proven interval /
minimal bitwidth by ``(path, id(eqn))`` — the builder's recursion
replicates the analyzer's path strings exactly (``""`` at top,
``/pjit`` for inlined calls, ``/scan[N]`` for loop bodies,
``/pallas_call`` for grid kernels), so every IR register carries the fact
the static proof established for its defining equation.

Anything outside the multiplierless integer contract — a float dtype, a
real multiply, a divide, ``cond``/``while``/``scatter`` — fails the build
loudly with the offending equation's source location. "Expressible in the
IR" IS the legality proof, by construction.
"""

from __future__ import annotations

import math

import numpy as np

from repro.ir.isa import Instr, Program, Reg, Region, Rom

# leaf jax primitives with a direct IR opcode (same-arity, srcs = invars)
_DIRECT = {
    "add": "add", "sub": "sub", "neg": "neg", "max": "max", "min": "min",
    "abs": "abs", "sign": "sign", "clamp": "clamp",
    "lt": "lt", "le": "le", "gt": "gt", "ge": "ge", "eq": "eq", "ne": "ne",
    "select_n": "select_n",
    "and": "and", "or": "or", "xor": "xor", "not": "not",
    "shift_left": "shl", "shift_right_arithmetic": "shra",
    "shift_right_logical": "shrl",
    "reduce_sum": "reduce_sum", "reduce_max": "reduce_max",
    "reduce_min": "reduce_min",
    "broadcast_in_dim": "broadcast", "reshape": "reshape",
    "squeeze": "reshape", "transpose": "transpose", "rev": "rev",
    "slice": "slice", "gather": "gather", "concatenate": "concat",
    "pad": "pad", "iota": "iota", "convert_element_type": "convert",
    "dynamic_slice": "dynamic_slice",
    "dynamic_update_slice": "dynamic_update_slice",
    "device_put": "mov", "copy": "mov", "stop_gradient": "mov",
    "get": "ref_get", "swap": "ref_swap",
    "program_id": "program_id", "num_programs": "num_programs",
}

_CALL_PRIMS = ("pjit", "closed_call", "custom_vjp_call", "custom_jvp_call",
               "custom_vjp_call_jaxpr", "remat", "checkpoint")


class BuildError(ValueError):
    """The traced program is outside the IR's multiplierless contract."""


def _src(eqn) -> str:
    from repro.analysis.traverse import eqn_source
    return eqn_source(eqn)


def _dtype_code(dtype) -> str:
    d = np.dtype(dtype)
    if d.kind == "b":
        return "i1"
    if d == np.int32:
        return "i32"
    raise BuildError(
        f"dtype {d} is outside the int32 datapath carrier "
        "(the IR admits i32 values and i1 predicates only)")


def _shape_of(aval) -> tuple:
    return tuple(int(d) for d in getattr(aval, "shape", ()))


def _scalar_pow2_shift(val) -> object:
    """log2 of a positive-pow2 scalar/uniform literal, else None."""
    arr = np.ravel(np.asarray(val))
    if arr.size == 0:
        return None
    first = arr[0]
    if not np.all(arr == first):
        return None
    f = float(first)
    if f <= 0 or abs(math.log2(f) % 1.0) >= 1e-9:
        return None
    return int(round(math.log2(f)))


class _Builder:
    def __init__(self, records: dict):
        self.records = records        # (path, id(eqn)) -> RegisterRecord
        self.regs: list = []
        self.roms: list = []
        self.rom_of_reg: dict = {}
        self._const_cache: dict = {}  # (dtype, shape, bytes) -> reg idx
        self.has_grid = False
        self.grid_depth = 0

    # -- registers --------------------------------------------------------

    def new_reg(self, shape, dtype, rec=None) -> int:
        code = _dtype_code(dtype)
        bits = 1 if code == "i1" else 32
        interval = required = None
        if rec is not None and code != "i1":
            rb = rec.required_bits
            if not (isinstance(rb, float) and math.isinf(rb)):
                interval = (int(rec.lo), int(rec.hi))
                required = int(rb)
        self.regs.append(Reg(idx=len(self.regs), shape=shape, dtype=code,
                             bits=bits, interval=interval,
                             required_bits=required))
        return self.regs[-1].idx

    def const_reg(self, val, name: str) -> int:
        arr = np.asarray(val)
        if arr.dtype.kind == "b":
            data = arr.astype(np.bool_)
        elif arr.dtype.kind in ("i", "u") or (
                arr.dtype.kind == "f" and np.all(arr == np.trunc(arr))):
            # weak-typed scalar literals trace as f32 even in int programs;
            # an integral value is an int constant, a fractional one is not
            data = arr.astype(np.int64)
            if np.any(data > np.iinfo(np.int32).max) or \
                    np.any(data < np.iinfo(np.int32).min):
                raise BuildError(f"constant {name} exceeds int32")
            data = data.astype(np.int32)
        else:
            raise BuildError(
                f"constant {name} has non-integral float values — outside "
                "the int32 datapath")
        key = (data.dtype.str, data.shape, data.tobytes())
        hit = self._const_cache.get(key)
        if hit is not None:
            return hit
        ridx = len(self.roms)
        self.roms.append(Rom(idx=ridx, name=f"rom{ridx}_{name}", data=data))
        reg = self.new_reg(tuple(data.shape), data.dtype)
        self.rom_of_reg[reg] = ridx
        self._const_cache[key] = reg
        return reg

    # -- environment ------------------------------------------------------

    def _read(self, env, v) -> int:
        from jax._src.core import Literal
        if isinstance(v, Literal):
            return self.const_reg(v.val, "lit")
        return env[v]

    def _rec(self, path, eqn):
        return self.records.get((path, id(eqn)))

    def _bind_outs(self, eqn, env, path) -> tuple:
        rec = self._rec(path, eqn)
        outs = []
        for v in eqn.outvars:
            r = self.new_reg(_shape_of(v.aval),
                             getattr(v.aval, "dtype", np.bool_), rec)
            env[v] = r
            outs.append(r)
        return tuple(outs)

    @staticmethod
    def _census_elems(eqn) -> tuple:
        out = 0
        for v in eqn.outvars:
            n = 1
            for d in _shape_of(v.aval):
                n *= d
            out += n
        first = 1
        for d in _shape_of(eqn.invars[0].aval) if eqn.invars else ():
            first *= d
        return out, first

    # -- lowering ---------------------------------------------------------

    def lower_closed(self, closed, in_regs, path, stream) -> list:
        consts = [self.const_reg(c, "c") for c in closed.consts]
        return self.lower_jaxpr(closed.jaxpr, consts + list(in_regs),
                                path, stream)

    def lower_jaxpr(self, jaxpr, in_regs, path, stream) -> list:
        env = {}
        allvars = list(jaxpr.constvars) + list(jaxpr.invars)
        if len(allvars) != len(in_regs):
            raise BuildError(f"arity mismatch at {path or '<top>'}")
        for v, r in zip(allvars, in_regs):
            env[v] = r
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if name in _CALL_PRIMS:
                self._lower_call(eqn, env, path, stream)
            elif name == "scan":
                self._lower_scan(eqn, env, path, stream)
            elif name == "pallas_call":
                self._lower_pallas(eqn, env, path, stream)
            elif name == "cond":
                # ``pl.when`` predication inside a grid kernel: legal as a
                # predicated region (hardware enable signal). The census
                # skips the branches — exactly the jaxpr census's
                # ``cond_branches=False`` semantics — while the analysis
                # verification passes already recurse into them.
                if self.grid_depth == 0:
                    raise BuildError(
                        f"cond at {path}/{_src(eqn)} outside a grid "
                        "region has no IR lowering")
                self._lower_cond(eqn, env, path, stream)
            elif name in ("while", "scatter", "scatter-add",
                          "dot_general", "conv_general_dilated"):
                raise BuildError(
                    f"{name} at {path}/{_src(eqn)} has no IR lowering — "
                    "the deployed integer datapath must not contain it")
            elif name == "mul":
                self._lower_mul(eqn, env, path, stream)
            else:
                self._lower_leaf(eqn, env, path, stream)
        return [self._read(env, v) for v in jaxpr.outvars]

    def _lower_call(self, eqn, env, path, stream) -> None:
        closed = (eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                  or eqn.params.get("fun_jaxpr"))
        ins = [self._read(env, v) for v in eqn.invars]
        sub = f"{path}/{eqn.primitive.name}"
        if hasattr(closed, "consts"):
            outs = self.lower_closed(closed, ins, sub, stream)
        else:
            outs = self.lower_jaxpr(closed, ins, sub, stream)
        # inlined: sub-jaxpr outputs alias straight into this scope
        for v, r in zip(eqn.outvars, outs):
            env[v] = r

    def _lower_mul(self, eqn, env, path, stream) -> None:
        from jax._src.core import Literal
        lits = [v for v in eqn.invars if isinstance(v, Literal)]
        others = [v for v in eqn.invars if not isinstance(v, Literal)]
        k = _scalar_pow2_shift(lits[0].val) if len(lits) == 1 else None
        if k is None or len(others) != 1:
            raise BuildError(
                f"mul at {path}/{_src(eqn)} is not a positive-pow2-literal "
                "scaling — a real multiplier cannot be lowered to the "
                "multiplierless IR")
        x = self._read(env, others[0])
        out, first = self._census_elems(eqn)
        dests = self._bind_outs(eqn, env, path)
        stream.append(Instr(op="shl", dests=dests, srcs=(x,),
                            attrs={"imm": k}, jax_prim="mul",
                            census_out_elems=out, census_in_elems=first))

    def _lower_scan(self, eqn, env, path, stream) -> None:
        p = eqn.params
        closed = p["jaxpr"]
        length = p.get("length")
        length = 1 if length is None else int(length)
        n_consts, n_carry = int(p["num_consts"]), int(p["num_carry"])
        ins = [self._read(env, v) for v in eqn.invars]
        spath = f"{path}/scan[{length}]"

        body_consts = [self.const_reg(c, "c") for c in closed.consts]
        body_ins = [self.new_reg(_shape_of(v.aval),
                                 getattr(v.aval, "dtype", np.bool_))
                    for v in closed.jaxpr.invars]
        body_stream: list = []
        body_outs = self.lower_jaxpr(closed.jaxpr, body_consts + body_ins,
                                     spath, body_stream)
        region = Region(kind="loop", trip_count=length,
                        inputs=tuple(body_ins), outputs=tuple(body_outs),
                        body=body_stream,
                        attrs={"num_consts": n_consts, "num_carry": n_carry,
                               "reverse": bool(p.get("reverse", False))})
        dests = self._bind_outs(eqn, env, path)
        out, first = self._census_elems(eqn)
        stream.append(Instr(op="loop", dests=dests, srcs=tuple(ins),
                            attrs={"num_consts": n_consts,
                                   "num_carry": n_carry, "length": length},
                            regions=(region,), jax_prim="scan",
                            census_out_elems=out, census_in_elems=first))

    def _lower_cond(self, eqn, env, path, stream) -> None:
        ins = [self._read(env, v) for v in eqn.invars]
        regions = []
        for i, br in enumerate(eqn.params["branches"]):
            bpath = f"{path}/cond.branch{i}"
            body_consts = [self.const_reg(c, "c") for c in br.consts]
            body_ins = [self.new_reg(_shape_of(v.aval),
                                     getattr(v.aval, "dtype", np.bool_))
                        for v in br.jaxpr.invars]
            body_stream: list = []
            body_outs = self.lower_jaxpr(br.jaxpr, body_consts + body_ins,
                                         bpath, body_stream)
            regions.append(Region(kind="branch", trip_count=1,
                                  inputs=tuple(body_ins),
                                  outputs=tuple(body_outs),
                                  body=body_stream))
        dests = self._bind_outs(eqn, env, path)
        out, first = self._census_elems(eqn)
        stream.append(Instr(op="cond", dests=dests, srcs=tuple(ins),
                            attrs={}, regions=tuple(regions),
                            jax_prim="cond",
                            census_out_elems=out, census_in_elems=first))

    def _lower_pallas(self, eqn, env, path, stream) -> None:
        from repro.analysis.traverse import grid_product
        self.has_grid = True
        self.grid_depth += 1
        gm = eqn.params["grid_mapping"]
        grid = tuple(int(g) for g in (getattr(gm, "grid", ()) or ()))
        inner = eqn.params["jaxpr"]
        ins = [self._read(env, v) for v in eqn.invars]
        n_index = int(getattr(gm, "num_index_operands", 0) or 0)
        n_outputs = int(getattr(gm, "num_outputs", len(eqn.outvars))
                        or len(eqn.outvars))
        n_inputs_attr = getattr(gm, "num_inputs", None)
        n_inputs = (int(n_inputs_attr) if n_inputs_attr is not None
                    else len(ins) - n_index)
        ppath = f"{path}/pallas_call"
        cells = [self.new_reg(_shape_of(v.aval),
                              getattr(v.aval, "dtype", np.int32))
                 for v in inner.invars]
        body_stream: list = []
        self.lower_jaxpr(inner, cells, ppath, body_stream)
        self.grid_depth -= 1
        region = Region(kind="grid", trip_count=grid_product(eqn),
                        inputs=tuple(cells), outputs=(), body=body_stream,
                        attrs={"grid": list(grid), "num_index": n_index,
                               "num_inputs": n_inputs,
                               "num_outputs": n_outputs})
        dests = self._bind_outs(eqn, env, path)
        out, first = self._census_elems(eqn)
        stream.append(Instr(op="grid", dests=dests, srcs=tuple(ins),
                            attrs=dict(region.attrs), regions=(region,),
                            jax_prim="pallas_call",
                            census_out_elems=out, census_in_elems=first))

    _ATTR_KEYS = {
        "slice": ("start_indices", "limit_indices", "strides"),
        "broadcast_in_dim": ("shape", "broadcast_dimensions"),
        "transpose": ("permutation",),
        "rev": ("dimensions",),
        "concatenate": ("dimension",),
        "pad": ("padding_config",),
        "dynamic_slice": ("slice_sizes",),
        "reduce_sum": ("axes",), "reduce_max": ("axes",),
        "reduce_min": ("axes",),
        "iota": ("shape", "dimension"),
        "program_id": ("axis",), "num_programs": ("axis",),
    }

    def _lower_leaf(self, eqn, env, path, stream) -> None:
        from jax._src.core import Literal
        name = eqn.primitive.name
        op = _DIRECT.get(name)
        if op is None:
            raise BuildError(
                f"primitive {name} at {path}/{_src(eqn)} is outside the "
                "multiplierless IR instruction set")

        attrs: dict = {}
        srcs = [self._read(env, v) for v in eqn.invars]
        for k in self._ATTR_KEYS.get(name, ()):
            val = eqn.params.get(k)
            if val is not None:
                attrs[k] = _plain(val)
        if name == "slice" and eqn.params.get("strides") is None:
            attrs["strides"] = [1] * len(attrs["start_indices"])
        if name in ("reshape", "squeeze"):
            attrs["new_shape"] = list(_shape_of(eqn.outvars[0].aval))
        if name == "convert_element_type":
            attrs["to"] = _dtype_code(eqn.params["new_dtype"])
        if name == "gather":
            dn = eqn.params["dimension_numbers"]
            attrs.update(
                offset_dims=list(dn.offset_dims),
                collapsed_slice_dims=list(dn.collapsed_slice_dims),
                start_index_map=list(dn.start_index_map),
                operand_batching_dims=list(
                    getattr(dn, "operand_batching_dims", ()) or ()),
                start_indices_batching_dims=list(
                    getattr(dn, "start_indices_batching_dims", ()) or ()),
                slice_sizes=list(eqn.params["slice_sizes"]))
        if name in ("get", "swap"):
            attrs["tree"] = str(eqn.params.get("tree"))
        # fold literal scalar shift amounts into an immediate (the shifter
        # the netlist instantiates is constant-distance when the program is)
        if name in ("shift_left", "shift_right_arithmetic",
                    "shift_right_logical") and len(eqn.invars) == 2 \
                and isinstance(eqn.invars[1], Literal) \
                and np.ndim(eqn.invars[1].val) == 0:
            attrs["imm"] = int(eqn.invars[1].val)
            srcs = srcs[:1]

        out, first = self._census_elems(eqn)
        dests = self._bind_outs(eqn, env, path)
        stream.append(Instr(op=op, dests=dests, srcs=tuple(srcs),
                            attrs=attrs, jax_prim=name,
                            census_out_elems=out, census_in_elems=first))


def _plain(v):
    """Static param -> JSON-serializable plain value."""
    if isinstance(v, (list, tuple)):
        return [_plain(x) for x in v]
    if isinstance(v, (np.integer, np.bool_)):
        return int(v)
    return v


class _InputRec:
    """Record-shaped view of a declared input interval (ducks the interval
    pass's RegisterRecord for ``_Builder.new_reg``)."""

    def __init__(self, lo, hi, required_bits):
        self.lo, self.hi, self.required_bits = lo, hi, required_bits


def build_program(closed_jaxpr, *, name: str, in_intervals=None,
                  scan_unroll_limit: int = 64,
                  grid_unroll_limit: int = 4096) -> Program:
    """Lower a traced ``ClosedJaxpr`` into a typed IR :class:`Program`.

    With ``in_intervals`` (one Interval per flattened input, as in
    ``repro.analysis.targets``) the worst-case interval pass runs over the
    same jaxpr first and every register is typed with its PROVEN interval
    and minimal two's-complement width. Without it registers carry only
    shapes and carrier widths.
    """
    records: dict = {}
    interval_meta: dict = {}
    if in_intervals is not None:
        from repro.analysis.intervals import analyze_intervals
        res = analyze_intervals(closed_jaxpr, in_intervals,
                                scan_unroll_limit=scan_unroll_limit,
                                grid_unroll_limit=grid_unroll_limit)
        records = res.records_by_eqn
        interval_meta = {
            "interval_ok": bool(res.ok),
            "min_headroom_bits": (None if isinstance(res.min_headroom_bits,
                                                     float)
                                  else int(res.min_headroom_bits)),
            "max_required_bits": (None if isinstance(res.max_required_bits,
                                                     float)
                                  else int(res.max_required_bits)),
        }

    b = _Builder(records)
    jaxpr = closed_jaxpr.jaxpr
    # input registers are typed straight from the DECLARED intervals (the
    # interval pass records only equation outputs): the netlist register
    # allocator sees the ADC input ports at their true width, not int32
    in_recs: list = [None] * len(jaxpr.invars)
    if in_intervals is not None:
        from repro.analysis.intervals import carrier_bits
        for i, iv in enumerate(list(in_intervals)[:len(in_recs)]):
            in_recs[i] = _InputRec(lo=iv.lo, hi=iv.hi,
                                   required_bits=carrier_bits(iv))
    in_regs = [b.new_reg(_shape_of(v.aval),
                         getattr(v.aval, "dtype", np.int32), in_recs[i])
               for i, v in enumerate(jaxpr.invars)]
    stream: list = []
    const_regs = [b.const_reg(c, "c") for c in closed_jaxpr.consts]
    outs = b.lower_jaxpr(jaxpr, const_regs + in_regs, "", stream)
    meta = {"num_instrs": None, "rom_bytes": None}
    meta.update(interval_meta)
    prog = Program(name=name, inputs=tuple(in_regs), outputs=tuple(outs),
                   regs=b.regs, roms=b.roms, rom_of_reg=b.rom_of_reg,
                   body=stream, meta=meta, executable=not b.has_grid)
    prog.meta["num_instrs"] = prog.num_instrs()
    prog.meta["rom_bytes"] = prog.rom_bytes()
    return prog
