"""Typed fixed-point op-stream IR with pluggable backends.

The explicit lowering artifact between ``fixed.compile_pipeline`` and the
paper's Spartan-7 target (ROADMAP: "unify program lowering into a small
fixed-point IR"). ``repro.analysis`` is the front half — its traversal
and worst-case interval facts type the registers; this package is the
back half:

* :mod:`repro.ir.isa`    — the instruction set + typed register model
* :mod:`repro.ir.build`  — jaxpr -> IR lowering (1:1, multiplierless by
  construction)
* :mod:`repro.ir.interp` — pure-Python/numpy ground-truth executor
* :mod:`repro.ir.xla`    — emitter back to the XLA int path
* :mod:`repro.ir.cgen`   — synthesizable fixed-point C + ROM ``.mem``
  artifact emitter (deterministic bytes, drift-gated in tier-1)
* :mod:`repro.ir.census` — the hardware-op census as an IR pass
* :mod:`repro.ir.alloc`  — interval-proven register-width allocation +
  hardware cost report (``alloc.json``)
* :mod:`repro.ir.verilog`— synthesizable Verilog netlist emitter (one
  time-multiplexed FSM, shift/add/compare datapath, $readmemh ROMs)
* :mod:`repro.ir.vsim`   — cycle simulator for exactly the emitted
  netlist subset (iverilog is used instead when present)
* :mod:`repro.ir.debug`  — register-granular first-divergence locator
  between interpreter and netlist traces

All five consumers are bit-for-bit: interpreter, XLA emitter, compiled C
reference and the simulated Verilog netlist reproduce ``fixed.infer_q``
exactly on the golden fixtures (tests/test_ir.py, tests/test_verilog.py),
and the IR census equals the jaxpr census exactly (pinned in
benchmarks/hardware_cost.py).
"""

from repro.ir.build import BuildError, build_program
from repro.ir.census import census_program
from repro.ir.isa import Instr, Program, Reg, Region, Rom

__all__ = [
    "BuildError", "build_program", "census_program",
    "Instr", "Program", "Reg", "Region", "Rom",
]
