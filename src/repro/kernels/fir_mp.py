"""Pallas kernel: in-filter MP FIR (paper eq. 8 + 9, Fig. 5).

y[b, n] = mpabs(h + x[b, n-M+1..n]) - mpabs(h - x[b, n-M+1..n])

TPU adaptation of the FPGA's register-bank streaming: instead of
materializing the (N, M) sliding-window matrix in HBM (M-fold read
amplification) the raw signal row lives in VMEM and the M tap-shifted views
are formed in-register with static slices (M is a small compile-time
constant, 16 in the paper), unrolled. Both MP bisection states advance
together as in mp_linear.

Optionally fuses the paper's entire in-filter readout
    s[b] = sum_n max(0, y[b, n])        (HWR + accumulate, Appendix A)
so one HBM read of the signal produces the scalar kernel feature directly —
the TPU analogue of the FPGA's per-band accumulator register.

Tiling: grid over batch tiles; block holds (block_b, N) rows in VMEM
(1 s @ 16 kHz f32 = 64 KiB/row; block_b=8 -> 0.5 MiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ITERS = 26


def _fir_mp_body(x, h_ref, gamma, *, iters: int, M: int):
    """x: (bb, N) already left-padded by M-1 zeros upstream is NOT assumed;
    windows clamp at the left edge by zero-shifting (streaming from zeroed
    registers, as the FPGA does)."""
    bb, N = x.shape

    def shifted(k):
        # x[n-k] with zeros for n < k: shift right by k.
        if k == 0:
            return x
        return jnp.concatenate(
            [jnp.zeros((bb, k), x.dtype), x[:, : N - k]], axis=1)

    xs = [shifted(k) for k in range(M)]  # unrolled; M is static & small

    # per-n bisection bounds
    hi_u = xs[0] * 0.0 - jnp.inf
    hi_v = hi_u
    for k in range(M):
        hk = h_ref[0, k]
        hi_u = jnp.maximum(hi_u, jnp.abs(xs[k] + hk))
        hi_v = jnp.maximum(hi_v, jnp.abs(xs[k] - hk))
    lo_u, lo_v = hi_u - gamma, hi_v - gamma

    def body(_, state):
        lo_u, hi_u, lo_v, hi_v = state
        mid_u = (lo_u + hi_u) * 0.5
        mid_v = (lo_v + hi_v) * 0.5
        hu = jnp.zeros_like(mid_u)
        hv = jnp.zeros_like(mid_v)
        for k in range(M):
            hk = h_ref[0, k]
            u = xs[k] + hk
            v = xs[k] - hk
            hu = hu + jnp.maximum(u - mid_u, 0) + jnp.maximum(-u - mid_u, 0)
            hv = hv + jnp.maximum(v - mid_v, 0) + jnp.maximum(-v - mid_v, 0)
        tu = hu > gamma
        tv = hv > gamma
        lo_u = jnp.where(tu, mid_u, lo_u)
        hi_u = jnp.where(tu, hi_u, mid_u)
        lo_v = jnp.where(tv, mid_v, lo_v)
        hi_v = jnp.where(tv, hi_v, mid_v)
        return lo_u, hi_u, lo_v, hi_v

    lo_u, hi_u, lo_v, hi_v = jax.lax.fori_loop(
        0, iters, body, (lo_u, hi_u, lo_v, hi_v))
    return (lo_u + hi_u) * 0.5 - (lo_v + hi_v) * 0.5


def _fir_mp_kernel(gamma_ref, x_ref, h_ref, out_ref, *, iters, M, accumulate,
                   valid_n):
    y = _fir_mp_body(x_ref[...], h_ref, gamma_ref[0, 0], iters=iters, M=M)
    if accumulate:
        # mask the padded tail: positions >= valid_n see partial windows of
        # real data and would otherwise contribute spurious HWR terms.
        n_idx = jax.lax.broadcasted_iota(jnp.int32, y.shape, 1)
        y = jnp.where(n_idx < valid_n, y, 0.0)
        out_ref[...] = jnp.sum(jnp.maximum(y, 0.0), axis=-1, keepdims=True)
    else:
        out_ref[...] = y


def fir_mp_bank_pallas(
    x: jax.Array,
    H: jax.Array,
    gamma: jax.Array,
    *,
    accumulate: bool = False,
    iters: int = DEFAULT_ITERS,
    block_b: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Multi-filter variant: x (B, N), H (F, M) -> (F, B, N) or (B, F).

    Grid covers (batch_tile, filter) with the filter axis innermost, so the
    (block_b, N) signal block's index map is constant across the F inner
    steps: Pallas keeps it VMEM-resident and only the (1, M) tap row is
    re-fetched per filter. The per-filter path re-reads the signal from HBM
    F times; here one read serves the whole octave.
    """
    B, N = x.shape
    F, M = H.shape
    b_pad = (-B) % block_b
    n_pad = (-N) % 128
    xp = jnp.pad(x, ((0, b_pad), (0, n_pad)))
    Bp, Np = xp.shape
    H = H.astype(x.dtype)
    gamma_arr = jnp.asarray(gamma, dtype=x.dtype).reshape(1, 1)

    if accumulate:
        out_spec = pl.BlockSpec((block_b, 1), lambda i, j: (i, j))
        out_shape = jax.ShapeDtypeStruct((Bp, F), x.dtype)
    else:
        out_spec = pl.BlockSpec((1, block_b, Np), lambda i, j: (j, i, 0))
        out_shape = jax.ShapeDtypeStruct((F, Bp, Np), x.dtype)

    out = pl.pallas_call(
        functools.partial(_fir_mp_bank_kernel, iters=iters, M=M,
                          accumulate=accumulate, valid_n=N),
        grid=(Bp // block_b, F),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block_b, Np), lambda i, j: (i, 0)),
            pl.BlockSpec((1, M), lambda i, j: (j, 0)),
        ],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(gamma_arr, xp, H)

    if accumulate:
        return out[:B, :]
    return out[:, :B, :N]


def _fir_mp_bank_kernel(gamma_ref, x_ref, h_ref, out_ref, *, iters, M,
                        accumulate, valid_n):
    y = _fir_mp_body(x_ref[...], h_ref, gamma_ref[0, 0], iters=iters, M=M)
    if accumulate:
        n_idx = jax.lax.broadcasted_iota(jnp.int32, y.shape, 1)
        y = jnp.where(n_idx < valid_n, y, 0.0)
        out_ref[...] = jnp.sum(jnp.maximum(y, 0.0), axis=-1, keepdims=True)
    else:
        out_ref[...] = y[None]


def fir_mp_pallas(
    x: jax.Array,
    h: jax.Array,
    gamma: jax.Array,
    *,
    accumulate: bool = False,
    iters: int = DEFAULT_ITERS,
    block_b: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """x: (B, N) signal, h: (M,) taps -> y: (B, N), or s: (B,) if accumulate.

    The kernel pairs x-shift k with tap h(k) directly, implementing eq. 8's
    sum_k h(k) x(n-k) operand multiset without reordering the taps.
    """
    B, N = x.shape
    (M,) = h.shape
    b_pad = (-B) % block_b
    n_pad = (-N) % 128
    xp = jnp.pad(x, ((0, b_pad), (0, n_pad)))
    Bp, Np = xp.shape
    h_row = h.reshape(1, M).astype(x.dtype)
    gamma_arr = jnp.asarray(gamma, dtype=x.dtype).reshape(1, 1)

    if accumulate:
        out_spec = pl.BlockSpec((block_b, 1), lambda i: (i, 0))
        out_shape = jax.ShapeDtypeStruct((Bp, 1), x.dtype)
    else:
        out_spec = pl.BlockSpec((block_b, Np), lambda i: (i, 0))
        out_shape = jax.ShapeDtypeStruct((Bp, Np), x.dtype)

    out = pl.pallas_call(
        functools.partial(_fir_mp_kernel, iters=iters, M=M,
                          accumulate=accumulate, valid_n=N),
        grid=(Bp // block_b,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_b, Np), lambda i: (i, 0)),
            pl.BlockSpec((1, M), lambda i: (0, 0)),
        ],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(gamma_arr, xp, h_row)

    if accumulate:
        return out[:B, 0]
    return out[:B, :N]
